"""Model size / pruning-rate grid shared by the AOT compiler and tests.

These constants are mirrored in rust/src/model.rs (ModelConfig::preset).
Any change here must be reflected there: the rust runtime marshals flat
argument lists whose shapes are derived from the same arithmetic.
"""

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int
    seq: int          # training / eval sequence length
    batch: int        # per-step batch
    scan_steps: int   # K optimizer steps fused into one train-artifact call
    eval_rows: int    # rows per eval_choices call (items x choices, padded)
    lora_rank: int = 8
    lora_alpha: int = 16
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def pruned(self, rate_pct: int) -> "PrunedShapes":
        return PrunedShapes.for_rate(self, rate_pct)


# Pruning removes whole attention heads and MLP channel groups of this
# width (mirrors LLM-Pruner's coupled-structure granularity).
MLP_GROUP = 8


@dataclass(frozen=True)
class PrunedShapes:
    """Per-layer shapes after uniform structured pruning at `rate_pct`%.

    Uniform rate across layers (LLM-Pruner prunes its target layer range
    at a single ratio); *which* heads/channels go is decided at runtime
    by importance, which does not affect shapes.
    """

    heads_kept: int
    d_ff_kept: int

    @staticmethod
    def for_rate(cfg: ModelConfig, rate_pct: int) -> "PrunedShapes":
        keep = 1.0 - rate_pct / 100.0
        heads = max(1, round(cfg.n_heads * keep))
        dff = max(MLP_GROUP, int(cfg.d_ff * keep) // MLP_GROUP * MLP_GROUP)
        return PrunedShapes(heads_kept=heads, d_ff_kept=dff)

    def attn_dim(self, cfg: ModelConfig) -> int:
        return self.heads_kept * cfg.head_dim


SIZES = {
    "tiny": ModelConfig("tiny", d_model=64, n_layers=2, n_heads=4, d_ff=192,
                        vocab=256, seq=32, batch=4, scan_steps=4, eval_rows=16),
    "small": ModelConfig("small", d_model=128, n_layers=4, n_heads=4, d_ff=384,
                         vocab=512, seq=64, batch=4, scan_steps=8, eval_rows=32),
    "base": ModelConfig("base", d_model=384, n_layers=8, n_heads=8, d_ff=1024,
                        vocab=2048, seq=128, batch=4, scan_steps=8, eval_rows=32),
    # `large` exists as a config for completeness (97M-param class); no
    # artifacts are emitted for it by default — a few hundred steps on the
    # single-core CPU PJRT of this testbed is wall-clock infeasible.
    "large": ModelConfig("large", d_model=768, n_layers=12, n_heads=12, d_ff=2048,
                         vocab=8192, seq=128, batch=4, scan_steps=4, eval_rows=32),
}

RATES = (0, 20, 30, 50)

# Projection names, in the canonical stacking order used across the
# artifact ABI and the rust ParamStore.
PROJS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def proj_shape(cfg: ModelConfig, ps: PrunedShapes, proj: str) -> tuple:
    """[out, in] shape of a projection after pruning."""
    d, a, f = cfg.d_model, ps.attn_dim(cfg), ps.d_ff_kept
    return {
        "wq": (a, d), "wk": (a, d), "wv": (a, d), "wo": (d, a),
        "w_gate": (f, d), "w_up": (f, d), "w_down": (d, f),
    }[proj]


def param_count(cfg: ModelConfig, rate_pct: int = 0) -> int:
    ps = cfg.pruned(rate_pct)
    n = 2 * cfg.vocab * cfg.d_model + cfg.d_model  # embed + head + final norm
    per_layer = 2 * cfg.d_model  # two rmsnorm gains
    for p in PROJS:
        o, i = proj_shape(cfg, ps, p)
        per_layer += o * i
    return n + cfg.n_layers * per_layer
