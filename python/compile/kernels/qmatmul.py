"""Fused block-dequant x matmul Pallas kernels (the paper's hot spot).

The paper's deployment path stores weights as 4-bit codes + per-block
absmax scales (bitsandbytes) and dequantizes on the fly in front of the
GEMM. On GPU that is a CUDA dequant kernel + cuBLAS; the TPU rethink
(DESIGN.md §Hardware-Adaptation):

  * the (n-tile, K) code slab and its scale vector are staged into VMEM
    by BlockSpec — VMEM plays the role of the CUDA shared-memory staging
    buffer, but holds the whole contracted dimension so the MXU sees one
    long dot;
  * the 16-entry NF4 codebook lookup is a branchless vector select tree
    (no gather — TPU VPU has no fast per-lane gather);
  * tiles are sized so the contracted dim stays a multiple of 128 and
    the f32 dot feeds the 128x128 systolic array without padding.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; on a real TPU the same code lowers to Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .codebooks import NF4_CODEBOOK, BLOCK


def _codebook_select(codes, codebook):
    """Branchless 16-way lookup: a chain of vector selects.

    `codes` is any integer array; returns f32 array of codebook values.
    On TPU this compiles to 16 vector selects on the VPU instead of a
    per-lane gather.
    """
    out = jnp.full(codes.shape, codebook[0], dtype=jnp.float32)
    for i in range(1, len(codebook)):
        out = jnp.where(codes == i, jnp.float32(codebook[i]), out)
    return out


def _qmm_nf4_kernel(x_ref, codes_ref, scales_ref, o_ref, *, block, codebook):
    # x:      [M, K]        f32   (whole activations tile in VMEM)
    # codes:  [TN, K//2]    uint8 (packed nibbles for this n-tile)
    # scales: [TN, K//block] f32
    # o:      [M, TN]       f32
    packed = codes_ref[...]
    lo = (packed & 0x0F).astype(jnp.int32)
    hi = ((packed >> 4) & 0x0F).astype(jnp.int32)
    tn, kh = packed.shape
    k = kh * 2
    codes = jnp.stack([lo, hi], axis=-1).reshape(tn, k)
    vals = _codebook_select(codes, codebook)
    scales = scales_ref[...]
    w = (vals.reshape(tn, k // block, block)
         * scales[:, :, None]).reshape(tn, k)
    # MXU dot: [M, K] x [K, TN]
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def qmatmul_nf4(x, codes_packed, scales, *, tile_n=128, block=BLOCK,
                codebook=NF4_CODEBOOK, interpret=True):
    """y = x @ dequant_nf4(codes, scales).T

    x:            [M, K] f32
    codes_packed: [N, K//2] uint8  (two 4-bit codes per byte, low = even)
    scales:       [N, K//block] f32
    -> [M, N] f32.  K must be a multiple of `block`.
    """
    m, k = x.shape
    n = codes_packed.shape[0]
    assert k % block == 0 and codes_packed.shape[1] == k // 2
    tile_n = min(tile_n, n)
    assert n % tile_n == 0
    grid = (n // tile_n,)
    return pl.pallas_call(
        functools.partial(_qmm_nf4_kernel, block=block,
                          codebook=np.asarray(codebook)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((tile_n, k // 2), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k // block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, tile_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, codes_packed, scales)


def _qmm_int8_kernel(x_ref, codes_ref, scales_ref, o_ref, *, block):
    codes = codes_ref[...].astype(jnp.float32)
    tn, k = codes.shape
    scales = scales_ref[...]
    w = (codes.reshape(tn, k // block, block)
         * scales[:, :, None]).reshape(tn, k)
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def qmatmul_int8(x, codes, scales, *, tile_n=128, block=BLOCK,
                 interpret=True):
    """y = x @ (int8 codes * blockwise scales).T

    x: [M, K] f32; codes: [N, K] int8; scales: [N, K//block] f32.
    """
    m, k = x.shape
    n = codes.shape[0]
    assert k % block == 0
    tile_n = min(tile_n, n)
    assert n % tile_n == 0
    grid = (n // tile_n,)
    return pl.pallas_call(
        functools.partial(_qmm_int8_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, k // block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, tile_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, codes, scales)
