"""Pure-jnp oracles for every Pallas kernel (the correctness anchors).

pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with
hypothesis and asserts allclose between each kernel and its oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .codebooks import NF4_CODEBOOK, BLOCK


def dequant_nf4_ref(codes_packed, scales, block=BLOCK,
                    codebook=NF4_CODEBOOK):
    """[N, K/2] packed nibbles + [N, K/block] scales -> [N, K] f32."""
    packed = np.asarray(codes_packed)
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    n, kh = packed.shape
    codes = np.stack([lo, hi], axis=-1).reshape(n, kh * 2)
    vals = np.asarray(codebook)[codes]
    k = kh * 2
    w = vals.reshape(n, k // block, block) * np.asarray(scales)[:, :, None]
    return jnp.asarray(w.reshape(n, k), dtype=jnp.float32)


def qmatmul_nf4_ref(x, codes_packed, scales, block=BLOCK,
                    codebook=NF4_CODEBOOK):
    w = dequant_nf4_ref(codes_packed, scales, block, codebook)
    return jnp.asarray(x, jnp.float32) @ w.T


def qmatmul_int8_ref(x, codes, scales, block=BLOCK):
    codes = np.asarray(codes, dtype=np.float32)
    n, k = codes.shape
    w = codes.reshape(n, k // block, block) * np.asarray(scales)[:, :, None]
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(w.reshape(n, k)).T


def lora_matmul_ref(x, w, a, b, scaling):
    x = jnp.asarray(x, jnp.float32)
    return x @ jnp.asarray(w).T + (x @ jnp.asarray(a).T) @ jnp.asarray(b).T * scaling


def causal_attention_ref(q, k, v):
    """q/k/v: [BH, S, hd] -> causal softmax(QK^T/sqrt(hd)) V."""
    q = jnp.asarray(q, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    s = q.shape[1]
    hd = q.shape[2]
    scores = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", attn, v)


def rmsnorm_ref(x, g, eps=1e-6):
    x = jnp.asarray(x, jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * jnp.asarray(g)
