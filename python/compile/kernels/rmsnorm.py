"""RMSNorm Pallas kernel (row-tiled)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * g_ref[...]


def rmsnorm(x, g, *, eps=1e-6, tile_m=128, interpret=True):
    """x: [M, d]; g: [d] -> [M, d]."""
    m, d = x.shape
    tile_m = min(tile_m, m)
    assert m % tile_m == 0
    grid = (m // tile_m,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_m, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(x, g)
