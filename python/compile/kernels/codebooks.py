"""Quantization codebooks, identical to rust/src/quant.rs.

NF4 is the exact QLoRA (Dettmers et al., 2023) 4-bit NormalFloat table:
quantiles of N(0,1) renormalized to [-1, 1], code 7 pinned to exactly 0.
FP4 is the bitsandbytes E2M1 value set (positives, sign bit mirrors).

Block quantization is absmax-per-block along the last axis, block=64,
matching bitsandbytes' storage model that the paper uses.
"""

import numpy as np

NF4_CODEBOOK = np.array([
    -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
    -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
    0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
    0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
    0.7229568362236023, 1.0,
], dtype=np.float32)

# bitsandbytes FP4: 3 value bits (E2M1) + sign; code 0 == +0, code 8 == -0.
_FP4_POS = np.array([0.0, 0.0052083335, 0.16666667, 0.25,
                     0.33333334, 0.5, 0.6666667, 1.0], dtype=np.float32)
FP4_CODEBOOK = np.concatenate([_FP4_POS, -_FP4_POS]).astype(np.float32)

BLOCK = 64


def quantize_blockwise(w: np.ndarray, codebook: np.ndarray,
                       block: int = BLOCK):
    """Absmax blockwise quantization along the last axis.

    Returns (codes uint8 [..., n], scales f32 [..., ceil(n/block)]).
    Codes are *unpacked* (one per element); packing to nibbles is a
    storage concern handled by pack_nibbles().
    """
    w = np.asarray(w, dtype=np.float32)
    *lead, n = w.shape
    nblocks = -(-n // block)
    pad = nblocks * block - n
    wp = np.pad(w, [(0, 0)] * len(lead) + [(0, pad)])
    wb = wp.reshape(*lead, nblocks, block)
    absmax = np.abs(wb).max(axis=-1)
    scales = np.where(absmax > 0, absmax, 1.0).astype(np.float32)
    normed = wb / scales[..., None]
    # nearest codebook entry
    dist = np.abs(normed[..., None] - codebook[None, :])
    codes = dist.argmin(axis=-1).astype(np.uint8)
    codes = codes.reshape(*lead, nblocks * block)[..., :n]
    return codes, scales


def dequantize_blockwise(codes: np.ndarray, scales: np.ndarray,
                         codebook: np.ndarray, block: int = BLOCK):
    *lead, n = codes.shape
    nblocks = scales.shape[-1]
    pad = nblocks * block - n
    cp = np.pad(codes, [(0, 0)] * len(lead) + [(0, pad)])
    vals = codebook[cp].reshape(*lead, nblocks, block)
    out = (vals * scales[..., None]).reshape(*lead, nblocks * block)
    return out[..., :n].astype(np.float32)


def pack_nibbles(codes: np.ndarray) -> np.ndarray:
    """[..., n] 4-bit codes -> [..., n/2] bytes; even idx = low nibble."""
    assert codes.shape[-1] % 2 == 0
    lo = codes[..., 0::2].astype(np.uint8)
    hi = codes[..., 1::2].astype(np.uint8)
    return (lo | (hi << 4)).astype(np.uint8)


def unpack_nibbles(packed: np.ndarray) -> np.ndarray:
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    out = np.empty(packed.shape[:-1] + (packed.shape[-1] * 2,), dtype=np.uint8)
    out[..., 0::2] = lo
    out[..., 1::2] = hi
    return out


def int8_quantize_blockwise(w: np.ndarray, block: int = BLOCK):
    """Symmetric absmax INT8 per block; returns (codes int8, scales f32)."""
    w = np.asarray(w, dtype=np.float32)
    *lead, n = w.shape
    nblocks = -(-n // block)
    pad = nblocks * block - n
    wp = np.pad(w, [(0, 0)] * len(lead) + [(0, pad)])
    wb = wp.reshape(*lead, nblocks, block)
    absmax = np.abs(wb).max(axis=-1)
    scales = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
    codes = np.clip(np.round(wb / scales[..., None]), -127, 127).astype(np.int8)
    return codes.reshape(*lead, nblocks * block)[..., :n], scales


def int8_dequantize_blockwise(codes: np.ndarray, scales: np.ndarray,
                              block: int = BLOCK):
    *lead, n = codes.shape
    nblocks = scales.shape[-1]
    pad = nblocks * block - n
    cp = np.pad(codes.astype(np.float32), [(0, 0)] * len(lead) + [(0, pad)])
    out = (cp.reshape(*lead, nblocks, block) * scales[..., None])
    return out.reshape(*lead, nblocks * block)[..., :n].astype(np.float32)
