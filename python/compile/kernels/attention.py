"""Causal self-attention Pallas kernel (one (batch, head) slab per
grid step).

TPU schedule (DESIGN.md §Hardware-Adaptation): at our sequence lengths
(S <= 128) a whole head's Q/K/V tiles and the S x S score matrix fit in
VMEM simultaneously (128x128 f32 = 64 KiB), so the right blocking is
one head per grid step with a single MXU dot for QK^T and one for
attn x V — no flash-style K/V streaming needed until S x hd outgrows
VMEM, at which point the same kernel body becomes the inner loop of a
K-blocked online-softmax schedule. Softmax is max-subtracted for
stability. RoPE is applied by the caller (it is position-only and fuses
into XLA elementwise ops).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    # refs: [1, S, hd] blocks
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    s = q.shape[0]
    scores = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # [S, S]
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    attn = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jax.lax.dot_general(
        attn, v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def causal_attention(q, k, v, *, interpret=True):
    """q/k/v: [BH, S, hd] f32 -> [BH, S, hd] (causal, scaled)."""
    bh, s, hd = q.shape
    assert k.shape == (bh, s, hd) and v.shape == (bh, s, hd)
    scale = 1.0 / float(hd) ** 0.5
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
        interpret=interpret,
    )(q, k, v)
