"""Fused frozen-weight + LoRA matmul Pallas kernel.

Computes y = x @ W^T + ((x @ A^T) @ B^T) * s in one kernel. PEFT runs
the adapter as a separate pair of GEMM launches; on TPU the A/B tiles
(rank r <= 16) are tiny, so both products stay resident in VMEM and the
low-rank update rides along with the main MXU dot for free HBM traffic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lora_mm_kernel(x_ref, w_ref, a_ref, b_ref, o_ref, *, scaling):
    # x: [M, K]; w: [TN, K]; a: [r, K]; b: [TN, r]; o: [M, TN]
    x = x_ref[...]
    base = jax.lax.dot_general(
        x, w_ref[...], dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    xa = jax.lax.dot_general(
        x, a_ref[...], dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # [M, r]
    low = jax.lax.dot_general(
        xa, b_ref[...], dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # [M, TN]
    o_ref[...] = base + low * scaling


def lora_matmul(x, w, a, b, scaling, *, tile_n=128, interpret=True):
    """x: [M, K]; w: [N, K]; a: [r, K]; b: [N, r] -> [M, N]."""
    m, k = x.shape
    n = w.shape[0]
    r = a.shape[0]
    assert b.shape == (n, r)
    tile_n = min(tile_n, n)
    assert n % tile_n == 0
    grid = (n // tile_n,)
    return pl.pallas_call(
        functools.partial(_lora_mm_kernel, scaling=float(scaling)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((r, k), lambda i: (0, 0)),
            pl.BlockSpec((tile_n, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, tile_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, a, b)
