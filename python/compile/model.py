"""L2: LLaMA-style transformer in JAX — the paper's model substrate.

Everything here is build-time: `aot.py` lowers the jitted entry points
to HLO text once; the rust coordinator executes them via PJRT and never
imports Python.

ABI (mirrored by rust/src/runtime.rs + rust/src/model.rs — keep in sync!)
------------------------------------------------------------------------
Weights are *stacked by projection type* so the artifact argument list
stays small and the rust side can marshal one Literal per stack:

  weights (12 arrays):
     0 embed      [V, d]
     1 attn_norm  [L, d]
     2 wq         [L, A, d]      A = heads_kept * head_dim
     3 wk         [L, A, d]
     4 wv         [L, A, d]
     5 wo         [L, d, A]
     6 mlp_norm   [L, d]
     7 w_gate     [L, F, d]      F = d_ff_kept
     8 w_up       [L, F, d]
     9 w_down     [L, d, F]
    10 final_norm [d]
    11 lm_head    [V, d]

  lora (14 arrays): for each proj in PROJS order, (A [L, r, in],
    B [L, out, r]).  y = x W^T + (x A^T) B^T * (alpha / r).

  adam state: one array per lora array, m-list then v-list (28), plus a
    scalar f32 step count t.

Projections compute y = x @ W^T (PyTorch Linear convention), so pruning
a head removes *rows* of wq/wk/wv and *columns* of wo; pruning an MLP
channel group removes rows of w_gate/w_up and columns of w_down.
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, PrunedShapes, PROJS, proj_shape
from .kernels.attention import causal_attention
from .kernels.lora_matmul import lora_matmul
from .kernels.rmsnorm import rmsnorm as rmsnorm_kernel
from .kernels.qmatmul import qmatmul_nf4

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def _pick_tile(n: int, cap: int = 128) -> int:
    for t in (cap, 64, 32, 16, 8, 4, 2, 1):
        if t <= cap and n % t == 0:
            return t
    return 1


# --------------------------------------------------------------------- #
# primitive layers                                                      #
# --------------------------------------------------------------------- #

def _rmsnorm(x, g, use_kernels):
    if use_kernels:
        b, s, d = x.shape
        return rmsnorm_kernel(x.reshape(b * s, d), g,
                              tile_m=_pick_tile(b * s)).reshape(b, s, d)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + 1e-6) * g


def _linear(x, w, a, b, scaling, use_kernels):
    """x [B,S,in] @ w [out,in]^T + LoRA low-rank update."""
    bsz, s, k = x.shape
    if use_kernels:
        y = lora_matmul(x.reshape(bsz * s, k), w, a, b, scaling,
                        tile_n=_pick_tile(w.shape[0]))
        return y.reshape(bsz, s, w.shape[0])
    return x @ w.T + ((x @ a.T) @ b.T) * scaling


def _rope_tables(seq, head_dim, theta):
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    pos = jnp.arange(seq, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]          # [S, half]
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rope(x, cos, sin):
    # x: [B, H, S, hd]
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def _attention(q, k, v, n_heads, head_dim, use_kernels=False):
    # q/k/v: [B, S, A]
    b, s, _ = q.shape
    q = q.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)
    cos, sin = _rope_tables(s, head_dim, 10000.0)
    q = _apply_rope(q, cos, sin)
    k = _apply_rope(k, cos, sin)
    if use_kernels:
        ctx = causal_attention(
            q.reshape(b * n_heads, s, head_dim),
            k.reshape(b * n_heads, s, head_dim),
            v.reshape(b * n_heads, s, head_dim),
        ).reshape(b, n_heads, s, head_dim)
    else:
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
            jnp.float32(head_dim))
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask, scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    return ctx.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)


# --------------------------------------------------------------------- #
# forward                                                               #
# --------------------------------------------------------------------- #

class Shapes(NamedTuple):
    cfg: ModelConfig
    ps: PrunedShapes


def _layer(sh: Shapes, h, layer_w, layer_lora, use_kernels):
    cfg, ps = sh
    (an, wq, wk, wv, wo, mn, wg, wu, wd) = layer_w
    (aq, bq, ak, bk, av, bv, ao, bo_, ag, bg, au, bu, ad, bd) = layer_lora
    s = cfg.lora_alpha / cfg.lora_rank

    hn = _rmsnorm(h, an, use_kernels)
    q = _linear(hn, wq, aq, bq, s, use_kernels)
    k = _linear(hn, wk, ak, bk, s, use_kernels)
    v = _linear(hn, wv, av, bv, s, use_kernels)
    ctx = _attention(q, k, v, ps.heads_kept, cfg.head_dim, use_kernels)
    h = h + _linear(ctx, wo, ao, bo_, s, use_kernels)

    hn2 = _rmsnorm(h, mn, use_kernels)
    gate = jax.nn.silu(_linear(hn2, wg, ag, bg, s, use_kernels))
    up = _linear(hn2, wu, au, bu, s, use_kernels)
    h = h + _linear(gate * up, wd, ad, bd, s, use_kernels)
    return h


def forward(sh: Shapes, weights, lora, tokens, use_kernels=False,
            collect_hidden=False):
    """tokens [B, S] int32 -> logits [B, S, V] (opt. pooled hiddens)."""
    (embed, attn_norm, wq, wk, wv, wo, mlp_norm, wg, wu, wd,
     final_norm, head) = weights
    h = embed[tokens]                                  # [B, S, d]

    layer_xs = (attn_norm, wq, wk, wv, wo, mlp_norm, wg, wu, wd)
    lora_xs = tuple(lora)                              # 14 stacked arrays

    def body(h, xs):
        lw, ll = xs
        h = _layer(sh, h, lw, ll, use_kernels)
        pooled = jnp.mean(h, axis=1) if collect_hidden else jnp.zeros(
            (h.shape[0], 0), jnp.float32)
        return h, pooled

    h, pooled = jax.lax.scan(body, h, (layer_xs, lora_xs))
    h = _rmsnorm(h, final_norm, use_kernels)
    logits = h @ head.T
    if collect_hidden:
        return logits, pooled                          # pooled: [L, B, d]
    return logits


def lm_loss(sh, weights, lora, tokens, use_kernels=False):
    """tokens [B, S+1] -> scalar mean next-token cross-entropy."""
    logits = forward(sh, weights, lora, tokens[:, :-1], use_kernels)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# --------------------------------------------------------------------- #
# AOT entry points                                                      #
# --------------------------------------------------------------------- #

def make_fwd(sh: Shapes, use_kernels=True):
    def fwd(weights, lora, tokens):
        return (forward(sh, weights, lora, tokens, use_kernels),)
    return fwd


def make_eval_loss(sh: Shapes):
    def eval_loss(weights, lora, tokens):
        return (lm_loss(sh, weights, lora, tokens),)
    return eval_loss


def make_eval_choices(sh: Shapes):
    def eval_choices(weights, lora, tokens, mask):
        """tokens [R, S] int32, mask [R, S] f32 (1 on choice tokens).

        score[r] = sum_t mask[r, t] * log p(tokens[r, t] | tokens[r, :t]);
        counts[r] = number of scored positions (length normalization is
        done rust-side).
        """
        logits = forward(sh, weights, lora, tokens[:, :-1])
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = tokens[:, 1:]
        m = mask[:, 1:]
        tok_lp = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        return (jnp.sum(tok_lp * m, axis=-1), jnp.sum(m, axis=-1))
    return eval_choices


def make_calib(sh: Shapes):
    def calib(weights, lora, tokens):
        """tokens [B, S] -> (pooled [L, B, d], last-position logits [B, V]).

        Feeds the mutual-information bit allocator (paper Eq. 7): X_l is
        the mean-pooled post-block hidden state, Y the final prediction.
        """
        logits, pooled = forward(sh, weights, lora, tokens,
                                 collect_hidden=True)
        return (pooled, logits[:, -1, :])
    return calib


def make_grads(sh: Shapes):
    def grads(weights, lora, tokens):
        """Loss + per-stack weight gradients (Taylor importance, Eq. 5/6)."""
        loss, g = jax.value_and_grad(
            lambda w: lm_loss(sh, w, lora, tokens))(tuple(weights))
        return (loss,) + tuple(g)
    return grads


def _adamw(p, g, m, v, t, lr, wd=0.0):
    m = ADAM_B1 * m + (1 - ADAM_B1) * g
    v = ADAM_B2 * v + (1 - ADAM_B2) * g * g
    mhat = m / (1 - ADAM_B1 ** t)
    vhat = v / (1 - ADAM_B2 ** t)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + wd * p)
    return p, m, v


def make_train(sh: Shapes, use_kernels=False):
    """K fused LoRA-AdamW steps (base weights frozen)."""
    def train(weights, lora, m, v, t, tokens, lr):
        # tokens: [K, B, S+1]
        def step(carry, toks):
            lora, m, v, t = carry
            t = t + 1.0
            loss, g = jax.value_and_grad(
                lambda l: lm_loss(sh, weights, l, toks, use_kernels))(
                    tuple(lora))
            new = [_adamw(p, gi, mi, vi, t, lr)
                   for p, gi, mi, vi in zip(lora, g, m, v)]
            lora = tuple(n[0] for n in new)
            m = tuple(n[1] for n in new)
            v = tuple(n[2] for n in new)
            return (lora, m, v, t), loss

        (lora, m, v, t), losses = jax.lax.scan(
            step, (tuple(lora), tuple(m), tuple(v), t), tokens)
        return (losses,) + lora + m + v + (t,)
    return train


def make_pretrain(sh: Shapes):
    """K fused full-parameter AdamW steps (corpus pretraining)."""
    zero_lora = make_zero_lora(sh)

    def pretrain(weights, m, v, t, tokens, lr):
        def step(carry, toks):
            weights, m, v, t = carry
            t = t + 1.0
            loss, g = jax.value_and_grad(
                lambda w: lm_loss(sh, w, zero_lora, toks))(tuple(weights))
            new = [_adamw(p, gi, mi, vi, t, lr)
                   for p, gi, mi, vi in zip(weights, g, m, v)]
            weights = tuple(n[0] for n in new)
            m = tuple(n[1] for n in new)
            v = tuple(n[2] for n in new)
            return (weights, m, v, t), loss

        (weights, m, v, t), losses = jax.lax.scan(
            step, (tuple(weights), tuple(m), tuple(v), t), tokens)
        return (losses,) + weights + m + v + (t,)
    return pretrain


def make_qfwd(sh: Shapes):
    """Forward with NF4-quantized projections through the fused Pallas
    dequant-matmul kernel — the deployment inference path.

    Projection stacks are replaced by (codes [L, out, in/2] u8,
    scales [L, out, in/64] f32) pairs in PROJS order; requires
    `in` % 64 == 0, i.e. the unpruned (rate 0) shapes.
    """
    cfg, ps = sh
    sc = cfg.lora_alpha / cfg.lora_rank

    def qlinear(x, codes, scales, a, b):
        bsz, s, k = x.shape
        y = qmatmul_nf4(x.reshape(bsz * s, k), codes, scales,
                        tile_n=_pick_tile(codes.shape[0]))
        y = y.reshape(bsz, s, codes.shape[0])
        return y + ((x @ a.T) @ b.T) * sc

    def qfwd(embed, attn_norm, mlp_norm, final_norm, head, qproj, lora,
             tokens):
        h = embed[tokens]
        xs = (attn_norm, mlp_norm) + tuple(qproj) + tuple(lora)

        def body(h, xs):
            (an, mn, cq, sq, ck, sk, cv, sv, co, so, cg, sg, cu, su,
             cd, sd, aq, bq, ak, bk, av, bv, ao, bo_, ag, bg, au, bu,
             ad, bd) = xs
            hn = _rmsnorm(h, an, False)
            q = qlinear(hn, cq, sq, aq, bq)
            k = qlinear(hn, ck, sk, ak, bk)
            v = qlinear(hn, cv, sv, av, bv)
            ctx = _attention(q, k, v, ps.heads_kept, cfg.head_dim)
            h = h + qlinear(ctx, co, so, ao, bo_)
            hn2 = _rmsnorm(h, mn, False)
            gate = jax.nn.silu(qlinear(hn2, cg, sg, ag, bg))
            up = qlinear(hn2, cu, su, au, bu)
            h = h + qlinear(gate * up, cd, sd, ad, bd)
            return h, None

        h, _ = jax.lax.scan(body, h, xs)
        h = _rmsnorm(h, final_norm, False)
        return (h @ head.T,)

    return qfwd


# --------------------------------------------------------------------- #
# shape builders (for lowering + tests)                                 #
# --------------------------------------------------------------------- #

def make_weight_shapes(sh: Shapes):
    cfg, ps = sh
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    A, F = ps.attn_dim(cfg), ps.d_ff_kept
    f32 = jnp.float32
    S = jax.ShapeDtypeStruct
    return (
        S((V, d), f32), S((L, d), f32),
        S((L, A, d), f32), S((L, A, d), f32), S((L, A, d), f32),
        S((L, d, A), f32), S((L, d), f32),
        S((L, F, d), f32), S((L, F, d), f32), S((L, d, F), f32),
        S((d,), f32), S((V, d), f32),
    )


def make_lora_shapes(sh: Shapes):
    cfg, ps = sh
    r = cfg.lora_rank
    out = []
    S = jax.ShapeDtypeStruct
    for p in PROJS:
        o, i = proj_shape(cfg, ps, p)
        out.append(S((cfg.n_layers, r, i), jnp.float32))
        out.append(S((cfg.n_layers, o, r), jnp.float32))
    return tuple(out)


def make_zero_lora(sh: Shapes):
    return tuple(jnp.zeros(s.shape, s.dtype) for s in make_lora_shapes(sh))


def make_qproj_shapes(sh: Shapes):
    cfg, ps = sh
    out = []
    S = jax.ShapeDtypeStruct
    for p in PROJS:
        o, i = proj_shape(cfg, ps, p)
        assert i % 64 == 0, f"qfwd requires in%64==0, got {p}: {i}"
        out.append(S((cfg.n_layers, o, i // 2), jnp.uint8))
        out.append(S((cfg.n_layers, o, i // 64), jnp.float32))
    return tuple(out)


def init_weights(sh: Shapes, seed: int = 0):
    """Random init matching the rust-side initializer (for tests only —
    the real init lives in rust/src/model.rs)."""
    cfg, _ = sh
    key = jax.random.PRNGKey(seed)
    out = []
    for spec in make_weight_shapes(sh):
        key, k = jax.random.split(key)
        if len(spec.shape) == 1 or spec.shape[-1:] == (cfg.d_model,) and len(spec.shape) == 2 and spec.shape[0] == cfg.n_layers:
            out.append(jnp.ones(spec.shape, spec.dtype))
        else:
            fan_in = spec.shape[-1]
            out.append(jax.random.normal(k, spec.shape, spec.dtype)
                       * (fan_in ** -0.5))
    # norms are gains: set to ones
    out[1] = jnp.ones_like(out[1])
    out[6] = jnp.ones_like(out[6])
    out[10] = jnp.ones_like(out[10])
    return tuple(out)
