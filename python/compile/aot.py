"""AOT compiler: lower every L2 entry point to HLO text artifacts.

HLO *text*, not `.serialize()`: the image's xla_extension 0.5.1 rejects
jax>=0.5 serialized protos (64-bit instruction ids); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact naming:  {kind}_{size}_r{rate}.hlo.txt
  kinds: train, pretrain (r0 only), fwd (Pallas kernels inside),
         qfwd (r0 only; NF4 fused dequant path), evalchoices, evalloss,
         calib, grads
Plus standalone kernel artifacts kernel_{name}.hlo.txt for rust-side
kernel integration tests and benches.

A manifest (artifacts/manifest.tsv) records name / #inputs / #outputs /
input shapes so the rust runtime can sanity-check its marshaling.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import SIZES, RATES
from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _flat_shapes(tree):
    return [f"{x.dtype}{list(x.shape)}" for x in jax.tree_util.tree_leaves(tree)]


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, fn, args, n_outputs):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        flat = _flat_shapes(args)
        self.manifest.append(
            f"{name}\t{len(flat)}\t{n_outputs}\t{';'.join(flat)}")
        print(f"  {name}: {len(flat)} inputs, {len(text)} chars")

    def write_manifest(self):
        with open(os.path.join(self.out_dir, "manifest.tsv"), "w") as f:
            f.write("\n".join(self.manifest) + "\n")


def emit_model_artifacts(em, size_name, rates):
    cfg = SIZES[size_name]
    i32, f32 = jnp.int32, jnp.float32
    S = jax.ShapeDtypeStruct

    for rate in rates:
        sh = M.Shapes(cfg, cfg.pruned(rate))
        w = M.make_weight_shapes(sh)
        lo = M.make_lora_shapes(sh)
        scalar = S((), f32)
        toks_train = S((cfg.scan_steps, cfg.batch, cfg.seq + 1), i32)
        toks_fwd = S((cfg.batch, cfg.seq), i32)
        toks_loss = S((cfg.batch, cfg.seq + 1), i32)
        toks_ev = S((cfg.eval_rows, cfg.seq), i32)
        mask_ev = S((cfg.eval_rows, cfg.seq), f32)
        tag = f"{size_name}_r{rate}"

        em.emit(f"train_{tag}", M.make_train(sh),
                (w, lo, lo, lo, scalar, toks_train, scalar),
                1 + 3 * len(lo) + 1)
        em.emit(f"evalchoices_{tag}", M.make_eval_choices(sh),
                (w, lo, toks_ev, mask_ev), 2)
        em.emit(f"evalloss_{tag}", M.make_eval_loss(sh),
                (w, lo, toks_loss), 1)
        em.emit(f"calib_{tag}", M.make_calib(sh), (w, lo, toks_fwd), 2)
        em.emit(f"grads_{tag}", M.make_grads(sh),
                (w, lo, toks_loss), 1 + len(w))

        if rate == 0:
            em.emit(f"pretrain_{tag}", M.make_pretrain(sh),
                    (w, w, w, scalar, toks_train, scalar),
                    1 + 3 * len(w) + 1)
            # fwd carries the Pallas lora_matmul + rmsnorm kernels
            em.emit(f"fwd_{tag}", M.make_fwd(sh, use_kernels=True),
                    (w, lo, toks_fwd), 1)
            # qfwd carries the fused NF4 dequant-matmul kernel
            qp = M.make_qproj_shapes(sh)
            em.emit(
                f"qfwd_{tag}", M.make_qfwd(sh),
                (w[0], w[1], w[6], w[10], w[11], qp, lo, toks_fwd), 1)


def emit_kernel_artifacts(em):
    """Standalone kernel round-trip artifacts (rust integration tests)."""
    from .kernels.qmatmul import qmatmul_nf4, qmatmul_int8
    from .kernels.lora_matmul import lora_matmul
    from .kernels.rmsnorm import rmsnorm

    i8, u8, f32 = jnp.int8, jnp.uint8, jnp.float32
    S = jax.ShapeDtypeStruct
    m, n, k, r = 16, 128, 256, 8

    em.emit("kernel_qmatmul_nf4",
            lambda x, c, s: (qmatmul_nf4(x, c, s),),
            (S((m, k), f32), S((n, k // 2), u8), S((n, k // 64), f32)), 1)
    em.emit("kernel_qmatmul_int8",
            lambda x, c, s: (qmatmul_int8(x, c, s),),
            (S((m, k), f32), S((n, k), i8), S((n, k // 64), f32)), 1)
    em.emit("kernel_lora_matmul",
            lambda x, w, a, b: (lora_matmul(x, w, a, b, 2.0),),
            (S((m, k), f32), S((n, k), f32), S((r, k), f32),
             S((n, r), f32)), 1)
    em.emit("kernel_rmsnorm",
            lambda x, g: (rmsnorm(x, g),),
            (S((m, k), f32), S((k,), f32)), 1)

    from .kernels.attention import causal_attention
    bh, s, hd = 8, 64, 48
    em.emit("kernel_attention",
            lambda q, kk, v: (causal_attention(q, kk, v),),
            (S((bh, s, hd), f32), S((bh, s, hd), f32),
             S((bh, s, hd), f32)), 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="tiny,small,base")
    ap.add_argument("--rates", default=",".join(str(r) for r in RATES))
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()

    em = Emitter(args.out_dir)
    rates = [int(r) for r in args.rates.split(",") if r != ""]
    for size in args.sizes.split(","):
        print(f"[aot] {size}: rates {rates}")
        emit_model_artifacts(em, size, rates)
    if not args.skip_kernels:
        print("[aot] kernel artifacts")
        emit_kernel_artifacts(em)
    em.write_manifest()
    print(f"[aot] wrote {len(em.manifest)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
