"""AOT artifact pipeline checks: manifest consistency and HLO-text
emission (the interchange contract with the rust runtime)."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile.configs import SIZES, RATES

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_produces_parseable_module():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[2,2]" in text
    # return_tuple=True: the entry computation returns a 1-tuple
    assert "->(f32[2,2]" in text


def test_train_arg_count_matches_manifest_formula():
    cfg = SIZES["tiny"]
    sh = M.Shapes(cfg, cfg.pruned(0))
    w = M.make_weight_shapes(sh)
    lo = M.make_lora_shapes(sh)
    # weights + lora + m + v + t + tokens + lr
    expect = len(w) + 3 * len(lo) + 3
    assert expect == 12 + 3 * 14 + 3 == 57


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.tsv")),
                    reason="artifacts not built")
def test_manifest_covers_expected_grid():
    with open(os.path.join(ART_DIR, "manifest.tsv")) as f:
        names = {line.split("\t")[0] for line in f if line.strip()}
    for size in ("tiny", "small", "base"):
        for rate in RATES:
            for kind in ("train", "evalchoices", "evalloss", "calib",
                         "grads"):
                assert f"{kind}_{size}_r{rate}" in names
        for kind in ("pretrain", "fwd", "qfwd"):
            assert f"{kind}_{size}_r0" in names
    for k in ("kernel_qmatmul_nf4", "kernel_qmatmul_int8",
              "kernel_lora_matmul", "kernel_rmsnorm", "kernel_attention"):
        assert k in names


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.tsv")),
                    reason="artifacts not built")
def test_manifest_arities_match_config_arithmetic():
    rows = {}
    with open(os.path.join(ART_DIR, "manifest.tsv")) as f:
        for line in f:
            parts = line.rstrip("\n").split("\t")
            if len(parts) >= 3:
                rows[parts[0]] = (int(parts[1]), int(parts[2]))
    for size in ("tiny", "small", "base"):
        n_in, n_out = rows[f"train_{size}_r20"]
        assert n_in == 57
        assert n_out == 1 + 3 * 14 + 1
        n_in, n_out = rows[f"grads_{size}_r0"]
        assert n_in == 27
        assert n_out == 13
        n_in, n_out = rows[f"pretrain_{size}_r0"]
        assert n_in == 12 * 3 + 3
        assert n_out == 38


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.tsv")),
                    reason="artifacts not built")
def test_artifact_files_exist_and_are_hlo_text():
    with open(os.path.join(ART_DIR, "manifest.tsv")) as f:
        names = [line.split("\t")[0] for line in f if line.strip()]
    assert len(names) >= 60
    for name in names[:5] + names[-5:]:
        path = os.path.join(ART_DIR, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        with open(path) as fh:
            head = fh.read(200)
        assert "HloModule" in head, name
