"""L2 model correctness: shapes, training dynamics, eval semantics,
kernel-model equivalence, quantized-forward fidelity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.configs import SIZES, PROJS, proj_shape, param_count
from compile.kernels.codebooks import (
    NF4_CODEBOOK, quantize_blockwise, pack_nibbles)

CFG = SIZES["tiny"]
SH = M.Shapes(CFG, CFG.pruned(0))
SH20 = M.Shapes(CFG, CFG.pruned(20))


def _weights(sh, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i, spec in enumerate(M.make_weight_shapes(sh)):
        if i in (1, 6, 10):
            out.append(jnp.ones(spec.shape, spec.dtype))
        else:
            fan_in = spec.shape[-1]
            out.append(jnp.asarray(
                rng.standard_normal(spec.shape) * fan_in ** -0.5,
                dtype=spec.dtype))
    return tuple(out)


def _lora(sh, seed=1, zero_b=True):
    rng = np.random.default_rng(seed)
    out = []
    for i, spec in enumerate(M.make_lora_shapes(sh)):
        if zero_b and i % 2 == 1:
            out.append(jnp.zeros(spec.shape, spec.dtype))
        else:
            out.append(jnp.asarray(
                rng.standard_normal(spec.shape) * 0.01, dtype=spec.dtype))
    return tuple(out)


def _tokens(shape, seed=2, vocab=None):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, vocab or CFG.vocab, size=shape), dtype=jnp.int32)


# --------------------------------------------------------------------- #
# forward                                                               #
# --------------------------------------------------------------------- #

def test_forward_shapes():
    w, lo = _weights(SH), M.make_zero_lora(SH)
    toks = _tokens((2, CFG.seq))
    logits = M.forward(SH, w, lo, toks)
    assert logits.shape == (2, CFG.seq, CFG.vocab)
    assert np.all(np.isfinite(logits))


def test_forward_pruned_shapes():
    w, lo = _weights(SH20), M.make_zero_lora(SH20)
    toks = _tokens((2, CFG.seq))
    logits = M.forward(SH20, w, lo, toks)
    assert logits.shape == (2, CFG.seq, CFG.vocab)


def test_forward_is_causal():
    """Changing a future token must not change past logits."""
    w, lo = _weights(SH), M.make_zero_lora(SH)
    toks = _tokens((1, CFG.seq))
    l1 = M.forward(SH, w, lo, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % CFG.vocab)
    l2 = M.forward(SH, w, lo, toks2)
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[0, -1], l2[0, -1])


def test_kernel_forward_matches_jnp_forward():
    """use_kernels=True (Pallas path) == use_kernels=False (pure jnp)."""
    w = _weights(SH)
    lo = _lora(SH, zero_b=False)
    toks = _tokens((2, CFG.seq))
    l_jnp = M.forward(SH, w, lo, toks, use_kernels=False)
    l_ker = M.forward(SH, w, lo, toks, use_kernels=True)
    np.testing.assert_allclose(l_ker, l_jnp, rtol=1e-4, atol=1e-4)


def test_lora_changes_output():
    w = _weights(SH)
    toks = _tokens((1, CFG.seq))
    l0 = M.forward(SH, w, M.make_zero_lora(SH), toks)
    l1 = M.forward(SH, w, _lora(SH, zero_b=False), toks)
    assert not np.allclose(l0, l1)


# --------------------------------------------------------------------- #
# loss / training                                                       #
# --------------------------------------------------------------------- #

def test_loss_near_uniform_at_init():
    """Random init -> CE ~= log(V)."""
    w, lo = _weights(SH), M.make_zero_lora(SH)
    toks = _tokens((4, CFG.seq + 1))
    loss = float(M.lm_loss(SH, w, lo, toks))
    assert abs(loss - np.log(CFG.vocab)) < 1.5


def test_train_scan_reduces_loss_on_fixed_batch():
    w = _weights(SH)
    lo = _lora(SH)  # A random, B zero (standard LoRA init)
    m = tuple(jnp.zeros_like(x) for x in lo)
    v = tuple(jnp.zeros_like(x) for x in lo)
    toks1 = _tokens((1, CFG.batch, CFG.seq + 1), seed=5)
    toks = jnp.tile(toks1, (CFG.scan_steps, 1, 1))
    train = M.make_train(SH)
    out = train(w, lo, m, v, jnp.float32(0.0), toks, jnp.float32(1e-2))
    losses = np.asarray(out[0])
    assert losses.shape == (CFG.scan_steps,)
    assert losses[-1] < losses[0], f"no descent: {losses}"


def test_train_updates_only_lora_state_shapes():
    w = _weights(SH)
    lo = _lora(SH)
    m = tuple(jnp.zeros_like(x) for x in lo)
    v = tuple(jnp.zeros_like(x) for x in lo)
    toks = _tokens((CFG.scan_steps, CFG.batch, CFG.seq + 1), seed=6)
    out = M.make_train(SH)(w, lo, m, v, jnp.float32(0.0), toks,
                           jnp.float32(1e-3))
    n = len(lo)
    new_lora = out[1:1 + n]
    t = out[1 + 3 * n]
    assert float(t) == CFG.scan_steps
    for old, new in zip(lo, new_lora):
        assert old.shape == new.shape
    # at least one adapter actually moved
    moved = any(not np.allclose(o, nw) for o, nw in zip(lo, new_lora))
    assert moved


def test_pretrain_reduces_loss():
    w = _weights(SH, seed=9)
    m = tuple(jnp.zeros_like(x) for x in w)
    v = tuple(jnp.zeros_like(x) for x in w)
    toks1 = _tokens((1, CFG.batch, CFG.seq + 1), seed=10)
    toks = jnp.tile(toks1, (CFG.scan_steps, 1, 1))
    out = M.make_pretrain(SH)(w, m, v, jnp.float32(0.0), toks,
                              jnp.float32(1e-2))
    losses = np.asarray(out[0])
    assert losses[-1] < losses[0]


# --------------------------------------------------------------------- #
# eval_choices                                                          #
# --------------------------------------------------------------------- #

def test_eval_choices_matches_manual_logprob():
    w, lo = _weights(SH), M.make_zero_lora(SH)
    R = CFG.eval_rows
    toks = _tokens((R, CFG.seq), seed=20)
    mask = np.zeros((R, CFG.seq), np.float32)
    mask[:, -4:] = 1.0  # last 4 tokens are "the choice"
    scores, counts = M.make_eval_choices(SH)(w, lo, toks,
                                             jnp.asarray(mask))
    logits = M.forward(SH, w, lo, toks[:, :-1])
    logp = np.asarray(jax.nn.log_softmax(logits, axis=-1))
    tgt = np.asarray(toks[:, 1:])
    want = np.zeros(R)
    for r in range(R):
        for t in range(CFG.seq - 1):
            if mask[r, t + 1] > 0:
                want[r] += logp[r, t, tgt[r, t]]
    np.testing.assert_allclose(np.asarray(scores), want, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(counts), mask[:, 1:].sum(1))


# --------------------------------------------------------------------- #
# calib / grads                                                         #
# --------------------------------------------------------------------- #

def test_calib_shapes_and_distinct_layers():
    w, lo = _weights(SH), M.make_zero_lora(SH)
    toks = _tokens((CFG.batch, CFG.seq), seed=30)
    pooled, last_logits = M.make_calib(SH)(w, lo, toks)
    assert pooled.shape == (CFG.n_layers, CFG.batch, CFG.d_model)
    assert last_logits.shape == (CFG.batch, CFG.vocab)
    assert not np.allclose(pooled[0], pooled[-1])


def test_grads_match_jax_grad():
    w, lo = _weights(SH), M.make_zero_lora(SH)
    toks = _tokens((CFG.batch, CFG.seq + 1), seed=31)
    out = M.make_grads(SH)(w, lo, toks)
    loss, grads = out[0], out[1:]
    direct = jax.grad(lambda ww: M.lm_loss(SH, ww, lo, toks))(w)
    assert len(grads) == len(w)
    for g, d in zip(grads, direct):
        np.testing.assert_allclose(np.asarray(g), np.asarray(d),
                                   rtol=1e-4, atol=1e-5)
    assert np.isfinite(float(loss))


# --------------------------------------------------------------------- #
# qfwd (fused NF4 path)                                                 #
# --------------------------------------------------------------------- #

def test_qfwd_matches_simulated_quant_forward():
    """qfwd over NF4 codes == plain forward over dequantized weights."""
    w = _weights(SH)
    lo = _lora(SH, zero_b=False)
    toks = _tokens((2, CFG.seq), seed=40)

    # quantize the 7 projection stacks (per-matrix along `in` axis)
    from compile.kernels.codebooks import dequantize_blockwise
    qproj, deq_w = [], list(w)
    idx = {"wq": 2, "wk": 3, "wv": 4, "wo": 5,
           "w_gate": 7, "w_up": 8, "w_down": 9}
    for p in PROJS:
        stack = np.asarray(w[idx[p]])
        codes, scales = quantize_blockwise(stack, NF4_CODEBOOK)
        qproj.append(jnp.asarray(pack_nibbles(codes)))
        qproj.append(jnp.asarray(scales))
        deq_w[idx[p]] = jnp.asarray(
            dequantize_blockwise(codes, scales, NF4_CODEBOOK))

    got = M.make_qfwd(SH)(w[0], w[1], w[6], w[10], w[11], tuple(qproj),
                          lo, toks)[0]
    want = M.forward(SH, tuple(deq_w), lo, toks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------- #
# config arithmetic                                                     #
# --------------------------------------------------------------------- #

def test_param_count_matches_actual_arrays():
    total = sum(int(np.prod(s.shape)) for s in M.make_weight_shapes(SH))
    assert total == param_count(CFG, 0)


@pytest.mark.parametrize("size", ["tiny", "small", "base", "large"])
@pytest.mark.parametrize("rate", [0, 20, 30, 50])
def test_pruned_shapes_consistent(size, rate):
    cfg = SIZES[size]
    ps = cfg.pruned(rate)
    assert 1 <= ps.heads_kept <= cfg.n_heads
    assert ps.d_ff_kept % 8 == 0
    assert ps.d_ff_kept <= cfg.d_ff
    for p in PROJS:
        o, i = proj_shape(cfg, ps, p)
        assert o > 0 and i > 0
    if rate == 0:
        assert ps.heads_kept == cfg.n_heads
        assert ps.d_ff_kept == cfg.d_ff
