"""Kernel vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes and value distributions; every Pallas kernel
(interpret mode) must match its ref.py oracle to tight f32 tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.codebooks import (
    NF4_CODEBOOK, FP4_CODEBOOK, BLOCK,
    quantize_blockwise, dequantize_blockwise, pack_nibbles, unpack_nibbles,
    int8_quantize_blockwise, int8_dequantize_blockwise,
)
from compile.kernels.qmatmul import qmatmul_nf4, qmatmul_int8
from compile.kernels.lora_matmul import lora_matmul
from compile.kernels.rmsnorm import rmsnorm

RNG = np.random.default_rng(0)


def _rand(*shape, scale=1.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# --------------------------------------------------------------------- #
# qmatmul_nf4                                                           #
# --------------------------------------------------------------------- #

@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([1, 3, 16]),
    n=st.sampled_from([8, 64, 128, 256]),
    kb=st.sampled_from([1, 2, 4]),   # K in blocks of 64
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_nf4_matches_ref(m, n, kb, seed):
    k = kb * BLOCK
    w = _rand(n, k, seed=seed)
    codes, scales = quantize_blockwise(w, NF4_CODEBOOK)
    packed = pack_nibbles(codes)
    x = _rand(m, k, seed=seed + 1)
    got = np.asarray(qmatmul_nf4(x, packed, scales))
    want = np.asarray(ref.qmatmul_nf4_ref(x, packed, scales))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_qmatmul_nf4_tiling_invariance():
    """Different tile_n choices give identical results."""
    k, n, m = 128, 256, 8
    w = _rand(n, k, seed=7)
    codes, scales = quantize_blockwise(w, NF4_CODEBOOK)
    packed = pack_nibbles(codes)
    x = _rand(m, k, seed=8)
    outs = [np.asarray(qmatmul_nf4(x, packed, scales, tile_n=t))
            for t in (32, 64, 128, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-4)


# --------------------------------------------------------------------- #
# qmatmul_int8                                                          #
# --------------------------------------------------------------------- #

@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([1, 4, 16]),
    n=st.sampled_from([16, 64, 128]),
    kb=st.sampled_from([1, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_int8_matches_ref(m, n, kb, seed):
    k = kb * BLOCK
    w = _rand(n, k, seed=seed)
    codes, scales = int8_quantize_blockwise(w)
    x = _rand(m, k, seed=seed + 1)
    got = np.asarray(qmatmul_int8(x, codes, scales))
    want = np.asarray(ref.qmatmul_int8_ref(x, codes, scales))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


# --------------------------------------------------------------------- #
# lora_matmul                                                           #
# --------------------------------------------------------------------- #

@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([1, 5, 16]),
    n=st.sampled_from([8, 64, 128]),
    k=st.sampled_from([16, 64, 192]),
    r=st.sampled_from([1, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lora_matmul_matches_ref(m, n, k, r, seed):
    x, w = _rand(m, k, seed=seed), _rand(n, k, seed=seed + 1)
    a, b = _rand(r, k, seed=seed + 2), _rand(n, r, seed=seed + 3)
    got = np.asarray(lora_matmul(x, w, a, b, 2.0))
    want = np.asarray(ref.lora_matmul_ref(x, w, a, b, 2.0))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_lora_matmul_zero_adapter_is_base_matmul():
    x, w = _rand(4, 32, seed=1), _rand(16, 32, seed=2)
    a, b = np.zeros((8, 32), np.float32), np.zeros((16, 8), np.float32)
    got = np.asarray(lora_matmul(x, w, a, b, 2.0))
    np.testing.assert_allclose(got, x @ w.T, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------- #
# causal attention                                                      #
# --------------------------------------------------------------------- #

@settings(max_examples=10, deadline=None)
@given(
    bh=st.sampled_from([1, 4, 8]),
    s=st.sampled_from([4, 32, 64]),
    hd=st.sampled_from([16, 48, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_causal_attention_matches_ref(bh, s, hd, seed):
    from compile.kernels.attention import causal_attention
    q = _rand(bh, s, hd, seed=seed)
    k = _rand(bh, s, hd, seed=seed + 1)
    v = _rand(bh, s, hd, seed=seed + 2)
    got = np.asarray(causal_attention(q, k, v))
    want = np.asarray(ref.causal_attention_ref(q, k, v))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_causal_attention_is_causal():
    """Changing the last position's K/V must not change earlier rows."""
    from compile.kernels.attention import causal_attention
    q = _rand(2, 16, 32, seed=41)
    k = _rand(2, 16, 32, seed=42)
    v = _rand(2, 16, 32, seed=43)
    out1 = np.asarray(causal_attention(q, k, v))
    k2, v2 = k.copy(), v.copy()
    k2[:, -1, :] += 5.0
    v2[:, -1, :] -= 5.0
    out2 = np.asarray(causal_attention(q, k2, v2))
    np.testing.assert_allclose(out1[:, :-1], out2[:, :-1],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(out1[:, -1], out2[:, -1])


def test_causal_attention_first_row_is_v0():
    """Position 0 can only attend to itself -> output row 0 == v[0]."""
    from compile.kernels.attention import causal_attention
    q = _rand(1, 8, 16, seed=44)
    k = _rand(1, 8, 16, seed=45)
    v = _rand(1, 8, 16, seed=46)
    out = np.asarray(causal_attention(q, k, v))
    np.testing.assert_allclose(out[0, 0], v[0, 0], rtol=1e-5, atol=1e-5)


def test_causal_attention_softmax_stability():
    """Large score magnitudes must not produce NaNs (max-subtract)."""
    from compile.kernels.attention import causal_attention
    q = _rand(1, 16, 32, seed=47, scale=100.0)
    k = _rand(1, 16, 32, seed=48, scale=100.0)
    v = _rand(1, 16, 32, seed=49)
    out = np.asarray(causal_attention(q, k, v))
    assert np.all(np.isfinite(out))


# --------------------------------------------------------------------- #
# rmsnorm                                                               #
# --------------------------------------------------------------------- #

@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([1, 8, 128, 256]),
    d=st.sampled_from([16, 64, 384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_matches_ref(m, d, seed):
    x, g = _rand(m, d, seed=seed), _rand(d, seed=seed + 1)
    got = np.asarray(rmsnorm(x, g))
    want = np.asarray(ref.rmsnorm_ref(x, g))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rmsnorm_scale_invariant():
    """RMSNorm output is invariant to positive rescaling of the input."""
    x, g = _rand(4, 64, seed=3), _rand(64, seed=4)
    y1 = np.asarray(rmsnorm(x, g))
    y2 = np.asarray(rmsnorm(x * 1000.0, g))
    np.testing.assert_allclose(y1, y2, rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------- #
# quantizer properties (host-side codebooks, mirrored in rust)          #
# --------------------------------------------------------------------- #

def test_nf4_codebook_is_sorted_and_symmetric_endpoints():
    assert np.all(np.diff(NF4_CODEBOOK) > 0)
    assert NF4_CODEBOOK[0] == -1.0 and NF4_CODEBOOK[-1] == 1.0
    assert NF4_CODEBOOK[7] == 0.0


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([1, 3]), k=st.sampled_from([64, 100, 129]),
       seed=st.integers(0, 2**31 - 1),
       cb=st.sampled_from(["nf4", "fp4"]))
def test_blockwise_roundtrip_error_bounded(n, k, seed, cb):
    """|w - dq(q(w))| <= absmax(block) * max_gap(codebook) / 2."""
    codebook = NF4_CODEBOOK if cb == "nf4" else FP4_CODEBOOK
    w = _rand(n, k, seed=seed)
    codes, scales = quantize_blockwise(w, codebook)
    back = dequantize_blockwise(codes, scales, codebook)
    assert back.shape == w.shape
    sorted_cb = np.sort(codebook)
    max_gap = np.max(np.diff(sorted_cb))
    nb = scales.shape[-1]
    pad = nb * BLOCK - k
    wp = np.pad(w, [(0, 0), (0, pad)]).reshape(n, nb, BLOCK)
    bp = np.pad(back, [(0, 0), (0, pad)]).reshape(n, nb, BLOCK)
    bound = scales[..., None] * (max_gap / 2 + 1e-6)
    assert np.all(np.abs(wp - bp) <= bound + 1e-6)


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([2, 5]), k=st.sampled_from([64, 128]),
       seed=st.integers(0, 2**31 - 1))
def test_quantization_idempotent(n, k, seed):
    """Quantizing an already-quantized tensor is a fixed point."""
    w = _rand(n, k, seed=seed)
    codes, scales = quantize_blockwise(w, NF4_CODEBOOK)
    back = dequantize_blockwise(codes, scales, NF4_CODEBOOK)
    codes2, scales2 = quantize_blockwise(back, NF4_CODEBOOK)
    back2 = dequantize_blockwise(codes2, scales2, NF4_CODEBOOK)
    np.testing.assert_allclose(back, back2, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([1, 4]), k=st.sampled_from([64, 256]),
       seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(n, k, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 16, size=(n, k)).astype(np.uint8)
    assert np.array_equal(unpack_nibbles(pack_nibbles(codes)), codes)


def test_int8_roundtrip_relative_error():
    w = _rand(8, 256, seed=11)
    codes, scales = int8_quantize_blockwise(w)
    back = int8_dequantize_blockwise(codes, scales)
    # int8 absmax: error bounded by scale/2 per element
    nb = scales.shape[-1]
    bound = np.repeat(scales, BLOCK, axis=-1)[:, :256] / 2 + 1e-7
    assert np.all(np.abs(w - back) <= bound)


def test_zero_tensor_quantizes_to_zero():
    w = np.zeros((2, 128), np.float32)
    codes, scales = quantize_blockwise(w, NF4_CODEBOOK)
    back = dequantize_blockwise(codes, scales, NF4_CODEBOOK)
    np.testing.assert_array_equal(back, w)
