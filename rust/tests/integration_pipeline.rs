//! Full-pipeline integration tests on the tiny model (seconds each).
//!
//! These exercise pretrain -> prune -> quantize(MI/BO) -> LoftQ ->
//! fine-tune -> eval through the real AOT artifacts. Skipped when
//! artifacts are absent.

use qpruner::coordinator::{Coordinator, Method, PipelineOpts};
use qpruner::data::Language;
use qpruner::experiments::Scale;
use qpruner::finetune::{FinetuneOpts, FinetuneState};
use qpruner::lora::{self, InitMethod, LoraState};
use qpruner::model::ModelConfig;
use qpruner::quant::{BitConfig, QuantFormat};
use qpruner::runtime::Runtime;
use std::path::PathBuf;
use std::sync::OnceLock;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("QPRUNER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    dir.join("manifest.tsv").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

/// Shared pretrained tiny checkpoint (built once per test binary).
fn tiny_store() -> &'static qpruner::model::ParamStore {
    static STORE: OnceLock<qpruner::model::ParamStore> = OnceLock::new();
    STORE.get_or_init(|| {
        let dir = artifacts_dir().expect("artifacts required");
        let rt = Runtime::new(&dir).unwrap();
        let lang = Language::new(256, 1);
        let mut coord = Coordinator::new(rt, lang);
        let cfg = ModelConfig::preset("tiny").unwrap();
        let (store, curve) = coord.pretrain(&cfg, 48, 3e-3, 77).unwrap();
        assert!(
            curve.tail_mean(4) < curve.losses[0],
            "pretraining must reduce loss: {:?} -> {:?}",
            curve.losses.first(),
            curve.tail_mean(4)
        );
        store
    })
}

fn coord() -> Coordinator {
    let dir = artifacts_dir().unwrap();
    let rt = Runtime::new(&dir).unwrap();
    Coordinator::new(rt, Language::new(256, 1))
}

#[test]
fn pretraining_reduces_loss() {
    let _ = require_artifacts!();
    let _ = tiny_store(); // asserts internally
}

#[test]
fn finetune_reduces_loss_after_pruning_and_quant() {
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    let opts = {
        let mut o = PipelineOpts::quick(20, Method::QPruner1);
        Scale::smoke().apply(&mut o);
        o
    };
    let pruned = c.prune(store, &opts.prune, opts.seed).unwrap();
    let bits = BitConfig::uniform(pruned.cfg.n_layers, QuantFormat::Nf4);
    let mut rng = qpruner::rng::Rng::new(5);
    let prep =
        lora::prepare(&pruned, &bits, InitMethod::LoftQ { iters: 1 },
                      &mut rng).unwrap();
    let mut state = FinetuneState::new(prep.lora);
    let mut stream = qpruner::data::CorpusStream::new(&c.lang, 99);
    let ft = FinetuneOpts { steps: 24, lr: 1e-3, warmup: 4, seed: 1 };
    qpruner::finetune::finetune(&mut c.rt, &prep.base, &mut state,
                                &mut stream, &ft).unwrap();
    let first = state.curve.losses[..4].iter().sum::<f32>() / 4.0;
    let last = state.curve.tail_mean(4);
    assert!(
        last < first,
        "fine-tune did not descend: {first:.3} -> {last:.3}"
    );
    assert_eq!(state.steps_done, 24);
}

#[test]
fn pipeline_all_methods_produce_results() {
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    for method in [Method::LlmPruner, Method::QPruner1, Method::QPruner2,
                   Method::QPruner3] {
        let mut opts = PipelineOpts::quick(20, method);
        Scale::smoke().apply(&mut opts);
        let res = c.run(store, &opts).unwrap();
        assert_eq!(res.tasks.len(), 7, "{method:?}");
        assert!(res.mean_accuracy > 0.15, "{method:?}: collapsed accuracy");
        assert!(res.memory_gb > 5.0 && res.memory_gb < 60.0);
        // fp16 baseline must cost more memory than any quantized method
        if method != Method::LlmPruner {
            assert!(res.bits.frac_8bit() <= 0.25 + 1e-9);
        }
        if method == Method::QPruner3 {
            assert!(
                !res.observations.is_empty(),
                "BO must record observations"
            );
        }
    }
}

#[test]
fn quantized_methods_save_memory_vs_fp16() {
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    let mut mems = Vec::new();
    for method in [Method::LlmPruner, Method::QPruner1] {
        let mut opts = PipelineOpts::quick(30, method);
        Scale::smoke().apply(&mut opts);
        mems.push(c.run(store, &opts).unwrap().memory_gb);
    }
    assert!(
        mems[1] < 0.7 * mems[0],
        "paper claims >=30% memory saving: fp16 {} vs nf4 {}",
        mems[0],
        mems[1]
    );
}

#[test]
fn mi_allocation_respects_budget() {
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    let mut opts = PipelineOpts::quick(20, Method::QPruner2);
    Scale::smoke().apply(&mut opts);
    let pruned = c.prune(store, &opts.prune, opts.seed).unwrap();
    let bits = c.allocate_bits_mi(&pruned, &opts.quant, opts.seed).unwrap();
    assert_eq!(bits.n_layers(), pruned.cfg.n_layers);
    assert!(bits.frac_8bit() <= opts.quant.frac8 + 1e-9);
}

#[test]
fn bo_loop_improves_or_matches_warm_start() {
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    let mut opts = PipelineOpts::quick(20, Method::QPruner3);
    Scale::smoke().apply(&mut opts);
    opts.bo.iters = 3;
    let pruned = c.prune(store, &opts.prune, opts.seed).unwrap();
    let b0 = c.allocate_bits_mi(&pruned, &opts.quant, opts.seed).unwrap();
    let (best, obs) = c.bo_loop(&pruned, b0.clone(), &opts)
        .map(|(b, o)| (b, o))
        .unwrap();
    // best is argmax over D, so it cannot be worse than the warm start
    let warm_perf = obs
        .iter()
        .find(|o| o.config.short() == b0.short())
        .map(|o| o.perf)
        .unwrap();
    let best_perf = obs
        .iter()
        .find(|o| o.config.short() == best.short())
        .map(|o| o.perf)
        .unwrap();
    assert!(best_perf >= warm_perf);
    // all observations respect the budget constraint
    for o in &obs {
        assert!(o.config.frac_8bit() <= opts.quant.frac8 + 1e-9);
    }
}

#[test]
fn untuned_eval_beats_chance_on_trained_model() {
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    let results = c.eval_untuned(store, 24).unwrap();
    // chance over the suite: (2+2+4+2+4+4+4)-way -> mean chance ~ 0.36;
    // 48 pretrain steps on the second-order language leaves the model
    // near chance, so this is a no-collapse check, not a quality bar
    let mean: f64 =
        results.iter().map(|r| r.accuracy).sum::<f64>() / 7.0;
    assert!(
        mean > 0.22,
        "tiny model collapsed below chance floor: {mean:.3}"
    );
}

#[test]
fn perplexity_finite_and_improves_with_training() {
    let _ = require_artifacts!();
    let mut c = coord();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let fresh = qpruner::model::ParamStore::init(&cfg, 3);
    let zero_f = LoraState::zeros(&fresh);
    let ppl_fresh = qpruner::eval::perplexity(
        &mut c.rt, &fresh, &zero_f, &c.lang, 42, 3).unwrap();
    let trained = tiny_store();
    let zero_t = LoraState::zeros(trained);
    let ppl_trained = qpruner::eval::perplexity(
        &mut c.rt, trained, &zero_t, &c.lang, 42, 3).unwrap();
    assert!(ppl_fresh.is_finite() && ppl_trained.is_finite());
    assert!(
        ppl_trained < ppl_fresh,
        "training must reduce perplexity: {ppl_fresh:.1} -> {ppl_trained:.1}"
    );
    // fresh model ~ uniform over the vocab
    assert!(ppl_fresh > 0.5 * cfg.vocab as f64);
}

#[test]
fn task_correctness_feeds_bootstrap_ci() {
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    let zero = LoraState::zeros(store);
    let spec = &qpruner::data::paper_suite()[0];
    let correct = qpruner::eval::task_correctness(
        &mut c.rt, store, &zero, &c.lang, spec, 20).unwrap();
    assert_eq!(correct.len(), 20);
    let acc =
        correct.iter().filter(|&&x| x).count() as f64 / correct.len() as f64;
    let (lo, hi) = qpruner::eval::bootstrap_ci(&correct, 300, 5);
    assert!(lo <= acc && acc <= hi);
}

#[test]
fn pruned_model_evaluates_below_or_near_unpruned() {
    // sanity: pruning at 50% shouldn't *improve* the untuned model
    // dramatically (allow noise)
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    let mut opts = PipelineOpts::quick(50, Method::QPruner1);
    Scale::smoke().apply(&mut opts);
    let pruned = c.prune(store, &opts.prune, opts.seed).unwrap();
    let zero = LoraState::zeros(&pruned);
    let full = c.eval_untuned(store, 24).unwrap();
    let cut = qpruner::eval::eval_suite(&mut c.rt, &pruned, &zero, &c.lang,
                                        &qpruner::data::paper_suite(), 24)
        .unwrap();
    let m_full: f64 = full.iter().map(|r| r.accuracy).sum::<f64>() / 7.0;
    let m_cut: f64 = cut.iter().map(|r| r.accuracy).sum::<f64>() / 7.0;
    assert!(
        m_cut <= m_full + 0.15,
        "50% pruning should not massively improve accuracy: {m_full:.3} -> {m_cut:.3}"
    );
}
