//! Artifact round-trip acceptance suite: `export` → save → load →
//! `EngineBuilder::build` must reproduce the reference decode logits
//! (|Δ| < 1e-4 — in practice bit-exact, since the native encodings
//! are a fixed point of the quantizer) for nf4/int8/fp16 weights ×
//! {merged, adjoined} LoRA, and corrupt or version-skewed files must
//! be rejected before any weight is decoded.

use qpruner::artifact::{LoraDelta, LoraMode, ModelArtifact,
                        Provenance, ARTIFACT_VERSION};
use qpruner::lora;
use qpruner::model::{ModelConfig, ParamStore};
use qpruner::quant::{BitConfig, QuantFormat};
use qpruner::rng::Rng;
use qpruner::runtime::Runtime;
use qpruner::serve::engine::{BatchReq, Engine, EngineBuilder};
use qpruner::serve::kv_cache::{KvCachePool, KvPrecision};
use std::path::PathBuf;

const MAX_SEQ: usize = 24;

fn runtime() -> Runtime {
    let dir = std::env::temp_dir().join("qpruner_artifact_rt");
    std::fs::create_dir_all(&dir).unwrap();
    Runtime::new(&dir).unwrap()
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("qpruner_artifact_rt");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn pool_for(engine: &Engine, cfg: &ModelConfig, n: usize)
            -> KvCachePool {
    KvCachePool::with_slots(cfg, engine.attn_dim(), n, MAX_SEQ,
                            KvPrecision::F32, 1.0, n as f64)
}

/// Build the pipeline-style deliverable for one weight format: a
/// LoftQ-prepared quantized base + non-trivial adapters.
fn make_artifact(fmt: QuantFormat, seed: u64, mode: LoraMode)
                 -> (ModelArtifact, BitConfig, ModelConfig) {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, seed);
    let mut bits = BitConfig::uniform(cfg.n_layers, fmt);
    if fmt != QuantFormat::Fp16 {
        // exercise a mixed row too: layer 0 at int8
        bits.layers[0] = QuantFormat::Int8;
    }
    let mut rng = Rng::new(seed ^ 0xAB);
    // LoftQ leaves fp16 layers with zero adapters by construction, so
    // the all-fp16 row uses PiSSA to get non-trivial deltas on every
    // projection
    let prep = if fmt == QuantFormat::Fp16 {
        lora::init_pissa(&store, &bits, &mut rng).unwrap()
    } else {
        lora::init_loftq(&store, &bits, 1, &mut rng).unwrap()
    };
    let art = ModelArtifact::from_pipeline(
        &prep.base,
        &bits,
        Some(LoraDelta::from_state(&prep.lora)),
        mode,
        Provenance {
            method: "QPruner^2".into(),
            seed,
            stages: "prune>mi>recover".into(),
            source: "roundtrip-test".into(),
        },
    )
    .unwrap();
    (art, bits, cfg)
}

/// Decode a fixed prompt + a few steps on an engine's *reference*
/// path; returns per-step logits.
fn reference_decode(rt: &mut Runtime, engine: &Engine,
                    cfg: &ModelConfig) -> Vec<Vec<f32>> {
    let _ = rt;
    let mut pool = pool_for(engine, cfg, 1);
    let id = pool.alloc().unwrap();
    let prompt = [3i32, 9, 14, 5, 7];
    let mut out = Vec::new();
    out.push(
        engine
            .prefill_reference(pool.slot_mut(id), &prompt)
            .unwrap(),
    );
    for step in 0..4 {
        let pos = prompt.len() + step;
        let tok = ((11 + step * 5) % cfg.vocab) as i32;
        out.push(
            engine
                .decode_reference(pool.slot_mut(id), pos, tok)
                .unwrap(),
        );
    }
    out
}

/// Same token stream through the batched path.
fn batched_decode(rt: &mut Runtime, engine: &Engine,
                  cfg: &ModelConfig) -> Vec<Vec<f32>> {
    let mut pool = pool_for(engine, cfg, 1);
    let id = pool.alloc().unwrap();
    let prompt = [3i32, 9, 14, 5, 7];
    let mut out = Vec::new();
    out.push(
        engine.prefill(rt, pool.slot_mut(id), &prompt).unwrap(),
    );
    for step in 0..4 {
        let pos = prompt.len() + step;
        let tok = ((11 + step * 5) % cfg.vocab) as i32;
        let reqs = [BatchReq { slot: id, pos, token: tok }];
        let mut got = Vec::new();
        engine
            .step_batch(&mut pool, &reqs, |_, l| got = l.to_vec())
            .unwrap();
        out.push(got);
    }
    out
}

fn max_abs_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.len(), y.len());
        for (p, q) in x.iter().zip(y) {
            worst = worst.max((p - q).abs());
        }
    }
    worst
}

/// The acceptance matrix: export→save→load→build reproduces the
/// in-memory reference decode to |Δ| < 1e-4 for every format × LoRA
/// deployment mode, on both decode paths.
#[test]
fn roundtrip_reproduces_reference_logits_all_formats_and_modes() {
    for fmt in [QuantFormat::Nf4, QuantFormat::Int8,
                QuantFormat::Fp16] {
        for mode in [LoraMode::Merge, LoraMode::Adjoin] {
            let (art, _bits, cfg) = make_artifact(fmt, 77, mode);
            // reference: engine built from the in-memory artifact
            let mut rt = runtime();
            let eng_ref = EngineBuilder::new()
                .artifact(art.clone())
                .max_seq(MAX_SEQ)
                .build(&mut rt)
                .unwrap();
            let want = reference_decode(&mut rt, &eng_ref, &cfg);

            // disk round-trip, then both decode paths
            let path = tmp(&format!(
                "rt_{}_{}.qpart",
                fmt.label(),
                match mode {
                    LoraMode::Merge => "merge",
                    LoraMode::Adjoin => "adjoin",
                }
            ));
            art.save(&path).unwrap();
            let eng = EngineBuilder::new()
                .artifact_path(path.clone())
                .max_seq(MAX_SEQ)
                .build(&mut rt)
                .unwrap();
            assert_eq!(
                eng.lora_label(),
                match mode {
                    LoraMode::Merge => "merged",
                    LoraMode::Adjoin => "adjoined",
                }
            );
            let got_ref = reference_decode(&mut rt, &eng, &cfg);
            let got_batched = batched_decode(&mut rt, &eng, &cfg);
            let d_ref = max_abs_diff(&got_ref, &want);
            let d_bat = max_abs_diff(&got_batched, &want);
            assert!(
                d_ref < 1e-4,
                "{fmt:?} {mode:?}: reference path drifted {d_ref}"
            );
            assert!(
                d_bat < 1e-4,
                "{fmt:?} {mode:?}: batched path drifted {d_bat}"
            );
            std::fs::remove_file(&path).ok();
        }
    }
}

/// Property sweep (hand-rolled; proptest is not vendored): random
/// seeds and random mixed-precision rows — the deployed store decoded
/// from disk is byte-identical to the in-memory encoding.
#[test]
fn prop_random_mixed_configs_roundtrip_bit_exact() {
    let mut rng = Rng::new(2024);
    for trial in 0..6 {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 100 + trial);
        let mut bits =
            BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
        for l in 0..cfg.n_layers {
            bits.layers[l] = match rng.below(4) {
                0 => QuantFormat::Nf4,
                1 => QuantFormat::Fp4,
                2 => QuantFormat::Int8,
                _ => QuantFormat::Fp16,
            };
        }
        let art = ModelArtifact::from_pipeline(
            &store, &bits, None, LoraMode::Merge,
            Provenance::default(),
        )
        .unwrap();
        let path = tmp(&format!("prop_{trial}.qpart"));
        art.save(&path).unwrap();
        let back = ModelArtifact::load(&path).unwrap();
        assert_eq!(back.bits, bits);
        let a = art.deployed_store().unwrap();
        let b = back.deployed_store().unwrap();
        for (x, y) in a.weights.iter().zip(&b.weights) {
            assert_eq!(x.data(), y.data(), "trial {trial} drifted");
        }
        // and the deployment equals quantize_base numerics
        let want = lora::quantize_base(&store, &bits);
        for (x, y) in b.weights.iter().zip(&want.weights) {
            assert_eq!(x.data(), y.data(), "trial {trial} != simulate");
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn corrupted_checksum_is_rejected_before_build() {
    let (art, _, _) = make_artifact(QuantFormat::Nf4, 5,
                                    LoraMode::Merge);
    let path = tmp("corrupt_build.qpart");
    art.save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let at = bytes.len() - 9; // somewhere in the lora payload
    bytes[at] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    let mut rt = runtime();
    let err = EngineBuilder::new()
        .artifact_path(path.clone())
        .max_seq(MAX_SEQ)
        .build(&mut rt)
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("checksum"),
        "unexpected error: {err:#}"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn version_mismatch_is_rejected_before_build() {
    let (art, _, _) = make_artifact(QuantFormat::Nf4, 6,
                                    LoraMode::Merge);
    let path = tmp("version_build.qpart");
    art.save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[8..12]
        .copy_from_slice(&(ARTIFACT_VERSION + 7).to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    let mut rt = runtime();
    let err = EngineBuilder::new()
        .artifact_path(path.clone())
        .max_seq(MAX_SEQ)
        .build(&mut rt)
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("version"),
        "unexpected error: {err:#}"
    );
    std::fs::remove_file(&path).ok();
}
