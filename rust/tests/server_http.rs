//! End-to-end tests for the HTTP serving front-end: real TCP
//! loopback connections against `Server::run` on its own thread.
//!
//! The centrepiece is the acceptance criterion of the front-end: the
//! tokens a client receives over SSE under concurrent load must be
//! bit-identical to an offline run of the same scheduler stack with
//! the same seed and options — the network layer may not perturb the
//! decode path.

use qpruner::artifact::{LoraMode, ModelArtifact, Provenance};
use qpruner::model::{ModelConfig, ParamStore};
use qpruner::obs::json::Json;
use qpruner::obs::trace_export::validate_events;
use qpruner::quant::{BitConfig, QuantFormat};
use qpruner::rng::Rng;
use qpruner::runtime::Runtime;
use qpruner::serve::engine::EngineBuilder;
use qpruner::serve::kv_cache::KvLayout;
use qpruner::serve::{build_stack, ServeOpts};
use qpruner::server::sse::parse_events;
use qpruner::server::{DrainReport, Server, ServerOpts};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qpruner_http_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn tiny_store(seed: u64) -> (ParamStore, BitConfig) {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, seed);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    (store, bits)
}

/// A server running on its own thread; the test thread plays client.
struct TestServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<DrainReport>,
}

fn start_server(
    tag: &str,
    store: &ParamStore,
    bits: &BitConfig,
    tune: impl FnOnce(&mut ServerOpts),
) -> TestServer {
    let dir = temp_dir(tag);
    let mut opts = ServerOpts::new(ServeOpts::smoke());
    opts.addr = "127.0.0.1:0".to_string();
    opts.serve.stall_prob = 0.0;
    opts.serve.stats_every = 0;
    tune(&mut opts);
    let server = Server::bind(&opts.addr).unwrap();
    let addr = server.local_addr();
    let shutdown = Arc::new(AtomicBool::new(false));
    let flag = shutdown.clone();
    // the builder owns cloned weights, so it moves into the thread
    let builder = EngineBuilder::new().store(store, bits);
    let handle = std::thread::spawn(move || {
        let mut rt = Runtime::new(&dir).unwrap();
        server.run(&mut rt, builder, &opts, flag).unwrap()
    });
    TestServer { addr, shutdown, handle }
}

impl TestServer {
    fn stop(self) -> DrainReport {
        self.shutdown.store(true, Ordering::SeqCst);
        self.handle.join().unwrap()
    }
}

/// One-shot raw HTTP/1.1 exchange: write the request, read to EOF
/// (every server response is `Connection: close`), split head/body.
fn request(addr: SocketAddr, method: &str, path: &str, body: &str)
           -> (u16, String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let raw = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(raw.as_bytes()).unwrap();
    s.flush().unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    let (head, payload) = resp
        .split_once("\r\n\r\n")
        .expect("response has no head/body separator");
    let status: u16 = head
        .lines()
        .next()
        .unwrap()
        .split_whitespace()
        .nth(1)
        .unwrap()
        .parse()
        .unwrap();
    (status, head.to_string(), payload.to_string())
}

fn gen_body(prompt: &[i32], max_new: usize, stream: bool) -> String {
    let toks: Vec<String> =
        prompt.iter().map(|t| t.to_string()).collect();
    format!(
        "{{\"prompt\":[{}],\"max_new\":{max_new},\"seed\":4242,\
         \"temperature\":0.8,\"stream\":{stream}}}",
        toks.join(",")
    )
}

/// Run one streaming generation to completion and decode the SSE
/// frames into (session id, tokens, terminal outcome).
fn sse_generate(addr: SocketAddr, prompt: &[i32], max_new: usize)
                -> (u64, Vec<i32>, String) {
    let (status, head, payload) = request(
        addr,
        "POST",
        "/v1/generate",
        &gen_body(prompt, max_new, true),
    );
    assert_eq!(status, 200, "{payload}");
    assert!(
        head.contains("Content-Type: text/event-stream"),
        "not an SSE response: {head}"
    );
    let events = parse_events(&payload);
    assert!(events.len() >= 2, "stream too short: {payload}");
    let first = Json::parse(&events[0]).unwrap();
    let id = first.get("id").unwrap().as_f64().unwrap() as u64;
    let mut tokens = Vec::new();
    let mut outcome = String::new();
    for ev in &events[1..] {
        let v = Json::parse(ev).unwrap();
        if let Some(t) = v.get("token").and_then(|t| t.as_f64()) {
            tokens.push(t as i32);
        } else if v.get("done").and_then(|d| d.as_bool())
            == Some(true)
        {
            outcome = v
                .get("outcome")
                .and_then(|o| o.as_str())
                .unwrap()
                .to_string();
            assert_eq!(
                v.get("tokens").unwrap().as_f64().unwrap() as usize,
                tokens.len(),
                "done-frame token count disagrees with the stream"
            );
        }
    }
    (id, tokens, outcome)
}

/// Read from the socket until the accumulated bytes contain `needle`
/// — used to hold a stream open mid-generation.
fn read_until(s: &mut TcpStream, needle: &str, buf: &mut Vec<u8>) {
    let mut tmp = [0u8; 1024];
    while !String::from_utf8_lossy(buf).contains(needle) {
        let n = s.read(&mut tmp).unwrap();
        assert!(n > 0, "stream closed before {needle:?}");
        buf.extend_from_slice(&tmp[..n]);
    }
}

/// The acceptance criterion: 8 concurrent SSE clients — half sharing
/// an 8-token prefix to exercise the paged pool's prefix cache —
/// receive exactly the tokens an offline run of the same stack
/// produces for the same (prompt, session id, seed) triples.
#[test]
fn concurrent_sse_streams_replay_bit_identically_offline() {
    let (store, bits) = tiny_store(21);
    let mut prompts: Vec<Vec<i32>> = Vec::new();
    for i in 0..8i32 {
        if i < 4 {
            let mut p: Vec<i32> = (3..11).collect();
            p.push(20 + i);
            prompts.push(p);
        } else {
            prompts.push(vec![40 + i, 50 + i, 60 + i]);
        }
    }
    let tune = |o: &mut ServerOpts| {
        o.serve.kv_layout = KvLayout::Paged;
        o.serve.page_tokens = 4;
        o.serve.max_batch = 4;
        o.serve.max_queue = 16;
    };
    let srv = start_server("identity", &store, &bits, tune);
    let addr = srv.addr;
    let mut results: Vec<(u64, Vec<i32>, Vec<i32>)> =
        std::thread::scope(|sc| {
            let handles: Vec<_> = prompts
                .iter()
                .map(|p| {
                    sc.spawn(move || {
                        let (id, toks, outcome) =
                            sse_generate(addr, p, 6);
                        assert_eq!(outcome, "done");
                        assert_eq!(toks.len(), 6);
                        (id, p.clone(), toks)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
    results.sort_by_key(|(id, _, _)| *id);
    let ids: Vec<u64> = results.iter().map(|r| r.0).collect();
    assert_eq!(ids, (0..8).collect::<Vec<u64>>(),
               "8 admissions must use session ids 0..8");
    let report = srv.stop();
    assert_eq!(report.submitted, 8);
    assert_eq!(report.completed, 8);
    assert_eq!(report.rejected, 0);
    assert_eq!(report.dropped_spans, 0);
    assert!(report.clean(), "unclean drain: {}", report.summary());

    // offline replay: identical stack, prompts submitted in the
    // server's session-id order so each gets the same id and
    // therefore the same per-session RNG stream
    let dir = temp_dir("identity_replay");
    let mut rt = Runtime::new(&dir).unwrap();
    let mut sopts = ServeOpts::smoke();
    let mut wrapper = ServerOpts::new(sopts.clone());
    tune(&mut wrapper);
    sopts = wrapper.serve;
    let builder = EngineBuilder::new().store(&store, &bits);
    let (engine, mut sched) =
        build_stack(&mut rt, builder, &sopts, false).unwrap();
    for (i, (id, prompt, _)) in results.iter().enumerate() {
        let oid = sched
            .submit(i, prompt.clone(), 6, 4242, 0.8)
            .expect("replay submission must admit");
        assert_eq!(oid, *id, "replay assigned a different id");
    }
    let mut rng = Rng::new(0);
    let mut guard = 0;
    while !sched.idle() {
        sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
        guard += 1;
        assert!(guard < 500, "replay failed to drain");
    }
    for (id, _, server_tokens) in &results {
        assert_eq!(
            &sched.table.get(*id).generated,
            server_tokens,
            "session {id}: SSE stream diverged from offline decode"
        );
    }
}

/// With a zero-length wait queue every submission sheds: all 8
/// concurrent posts get a 429 with the deterministic retry hint, and
/// the drain report accounts for every attempt.
#[test]
fn full_queue_sheds_concurrent_posts_with_429() {
    let (store, bits) = tiny_store(22);
    let srv = start_server("burst", &store, &bits, |o| {
        o.serve.max_queue = 0;
    });
    let addr = srv.addr;
    std::thread::scope(|sc| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                sc.spawn(move || {
                    let (status, head, payload) = request(
                        addr,
                        "POST",
                        "/v1/generate",
                        &gen_body(&[4, 5, 6], 4, false),
                    );
                    assert_eq!(status, 429, "{payload}");
                    assert!(head.contains("Retry-After: 1"),
                            "{head}");
                    assert!(payload.contains("queue-full"),
                            "{payload}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    let report = srv.stop();
    assert_eq!(report.submitted, 8);
    assert_eq!(report.rejected, 8);
    assert_eq!(report.completed, 0);
    assert!(report.clean(), "{}", report.summary());
}

/// `/healthz`, `/metrics`, and `/traces` reflect live scheduler
/// state, and their payloads strict-parse under the same validators
/// the offline exports use. Unknown routes and malformed bodies fail
/// with typed errors.
#[test]
fn observability_endpoints_serve_live_state() {
    let (store, bits) = tiny_store(23);
    let srv = start_server("obs", &store, &bits, |_| {});
    let addr = srv.addr;

    for _ in 0..2 {
        let (status, _, payload) = request(
            addr,
            "POST",
            "/v1/generate",
            &gen_body(&[5, 6, 7], 5, false),
        );
        assert_eq!(status, 200, "{payload}");
        let doc = Json::parse(&payload).unwrap();
        assert_eq!(doc.get("outcome").unwrap().as_str(),
                   Some("done"));
        assert_eq!(
            doc.get("tokens").unwrap().as_arr().unwrap().len(),
            5
        );
    }

    let (status, _, payload) = request(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    let doc = Json::parse(&payload).unwrap();
    assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(doc.get("draining").unwrap().as_bool(), Some(false));

    let (status, _, payload) = request(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    let doc = Json::parse(&payload)
        .expect("metrics endpoint must strict-parse");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("qpruner.serve.metrics.v1")
    );
    assert_eq!(
        doc.get("counters")
            .unwrap()
            .get("serve.requests_completed")
            .and_then(|v| v.as_f64()),
        Some(2.0)
    );

    let (status, head, payload) =
        request(addr, "GET", "/traces", "");
    assert_eq!(status, 200);
    assert!(head.contains("application/x-ndjson"), "{head}");
    let summary =
        validate_events(&payload).expect("traces must validate");
    assert_eq!(summary.sessions, 2);
    assert_eq!(summary.complete_sessions, 2);

    let (status, _, _) = request(addr, "GET", "/nope", "");
    assert_eq!(status, 404);
    let (status, _, _) = request(addr, "POST", "/metrics", "");
    assert_eq!(status, 404);
    let (status, _, payload) =
        request(addr, "POST", "/v1/generate", "not json");
    assert_eq!(status, 400);
    assert!(payload.contains("error"), "{payload}");
    let (status, _, payload) = request(
        addr,
        "POST",
        "/v1/generate",
        "{\"prompt\":[99999]}",
    );
    assert_eq!(status, 400);
    assert!(payload.contains("vocab"), "{payload}");

    let report = srv.stop();
    assert_eq!(report.completed, 2);
    assert!(report.clean(), "{}", report.summary());
}

/// `/admin/reload` hot-swaps the engine under a live stream: the
/// in-flight session keeps its KV cache and finishes against the new
/// engine; a missing artifact 400s and a geometry mismatch 409s
/// without touching the serving engine.
#[test]
fn admin_reload_swaps_artifacts_mid_stream() {
    let (store, bits) = tiny_store(24);
    let dir = temp_dir("reload_artifacts");
    let art = ModelArtifact::from_pipeline(
        &store,
        &bits,
        None,
        LoraMode::Merge,
        Provenance::default(),
    )
    .unwrap();
    let good = dir.join("swap.qpart");
    art.save(&good).unwrap();
    // a different vocab changes kv_shape_key -> must be refused
    let mut cfg2 = ModelConfig::preset("tiny").unwrap();
    cfg2.vocab += 16;
    let store2 = ParamStore::init(&cfg2, 24);
    let bits2 =
        BitConfig::uniform(cfg2.n_layers, QuantFormat::Nf4);
    let art2 = ModelArtifact::from_pipeline(
        &store2,
        &bits2,
        None,
        LoraMode::Merge,
        Provenance::default(),
    )
    .unwrap();
    let bad_shape = dir.join("bad_shape.qpart");
    art2.save(&bad_shape).unwrap();

    let srv = start_server("reload", &store, &bits, |_| {});
    let addr = srv.addr;

    // open a stream and hold it mid-generation
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let body = gen_body(&[3, 4, 5, 6], 16, true);
    s.write_all(
        format!(
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )
    .unwrap();
    let mut buf = Vec::new();
    read_until(&mut s, "{\"id\":", &mut buf);

    // swap while that session is decoding
    let (status, _, payload) = request(
        addr,
        "POST",
        "/admin/reload",
        &format!("{{\"artifact\":\"{}\"}}", good.display()),
    );
    assert_eq!(status, 200, "{payload}");
    assert!(payload.contains("\"reloaded\":true"), "{payload}");

    // the in-flight stream survives the swap and completes fully
    let mut rest = String::new();
    s.read_to_string(&mut rest).unwrap();
    let full =
        format!("{}{rest}", String::from_utf8_lossy(&buf));
    let sse_body = full
        .split_once("\r\n\r\n")
        .expect("stream head missing")
        .1;
    let events = parse_events(sse_body);
    let last = Json::parse(events.last().unwrap()).unwrap();
    assert_eq!(last.get("done").and_then(|d| d.as_bool()),
               Some(true));
    assert_eq!(last.get("outcome").and_then(|o| o.as_str()),
               Some("done"));
    assert_eq!(last.get("tokens").and_then(|t| t.as_f64()),
               Some(16.0));

    let (status, _, _) = request(
        addr,
        "POST",
        "/admin/reload",
        "{\"artifact\":\"/nonexistent/x.qpart\"}",
    );
    assert_eq!(status, 400);
    let (status, _, payload) = request(
        addr,
        "POST",
        "/admin/reload",
        &format!("{{\"artifact\":\"{}\"}}", bad_shape.display()),
    );
    assert_eq!(status, 409, "{payload}");
    let (status, _, _) =
        request(addr, "POST", "/admin/reload", "{}");
    assert_eq!(status, 400);

    let report = srv.stop();
    assert_eq!(report.reloads, 1);
    assert_eq!(report.completed, 1);
    assert!(report.clean(), "{}", report.summary());
    std::fs::remove_file(&good).ok();
    std::fs::remove_file(&bad_shape).ok();
}

/// A client that drops its socket mid-SSE is detected at the next
/// sink pump: the session is cancelled with the "disconnect" exit
/// reason, its slot is reclaimed, and the drain stays clean.
#[test]
fn client_disconnect_mid_sse_cancels_session() {
    let (store, bits) = tiny_store(26);
    let srv = start_server("disconnect", &store, &bits, |o| {
        // a long generation so the session is guaranteed to still be
        // decoding when the socket disappears
        o.serve.max_seq = 600;
    });
    let addr = srv.addr;
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let body = gen_body(&[3, 4, 5], 500, true);
        s.write_all(
            format!(
                "POST /v1/generate HTTP/1.1\r\nHost: t\r\n\
                 Content-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
        let mut buf = Vec::new();
        read_until(&mut s, "{\"token\":", &mut buf);
        // socket dropped here, mid-generation
    }
    // the worker hits a write error, the core's next try_send fails,
    // and the session is cancelled; poll the live counter until the
    // cancellation lands
    let mut seen = false;
    for _ in 0..300 {
        let (status, _, payload) =
            request(addr, "GET", "/metrics", "");
        assert_eq!(status, 200);
        let doc = Json::parse(&payload).unwrap();
        if doc
            .get("counters")
            .and_then(|c| c.get("serve.client_disconnects"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
            >= 1.0
        {
            seen = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(seen, "disconnect never surfaced in /metrics");

    // the span closed with the disconnect exit reason
    let (status, _, payload) = request(addr, "GET", "/traces", "");
    assert_eq!(status, 200);
    assert!(
        payload.contains("\"outcome\":\"disconnect\""),
        "no disconnect span in traces: {payload}"
    );

    let report = srv.stop();
    assert_eq!(report.submitted, 1);
    assert_eq!(report.completed, 0);
    assert_eq!(report.evicted, 1);
    assert_eq!(report.disconnects, 1);
    assert!(report.clean(), "leak after disconnect: {}",
            report.summary());
}

/// Per-request deadlines via the HTTP body: a 1 ms deadline on a long
/// generation terminates the stream early with the "deadline"
/// outcome and partial tokens, and the drain report buckets it.
#[test]
fn request_deadline_terminates_stream_with_partial_tokens() {
    let (store, bits) = tiny_store(27);
    let srv = start_server("deadline", &store, &bits, |o| {
        o.serve.max_seq = 600;
    });
    let addr = srv.addr;
    let body = "{\"prompt\":[3,4,5],\"max_new\":500,\"seed\":1,\
                \"temperature\":0.5,\"stream\":true,\
                \"deadline_ms\":1}";
    let (status, head, payload) =
        request(addr, "POST", "/v1/generate", body);
    assert_eq!(status, 200, "{payload}");
    assert!(head.contains("text/event-stream"), "{head}");
    let events = parse_events(&payload);
    let last = Json::parse(events.last().unwrap()).unwrap();
    assert_eq!(last.get("done").and_then(|d| d.as_bool()),
               Some(true));
    assert_eq!(last.get("outcome").and_then(|o| o.as_str()),
               Some("deadline"));
    let tokens =
        last.get("tokens").unwrap().as_f64().unwrap() as usize;
    assert!(tokens < 500, "deadline never fired");

    let report = srv.stop();
    assert_eq!(report.deadline_exceeded, 1);
    assert_eq!(report.evicted, 1);
    assert!(report.clean(), "{}", report.summary());
}

/// SIGTERM semantics via the shared flag: in-flight streams finish
/// (not cut), the drain report leaks nothing, and the listener is
/// gone afterwards.
#[test]
fn graceful_drain_finishes_in_flight_streams() {
    let (store, bits) = tiny_store(25);
    let srv = start_server("drain", &store, &bits, |_| {});
    let addr = srv.addr;
    let mut streams: Vec<TcpStream> = Vec::new();
    for i in 0..2i32 {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(60)))
            .unwrap();
        let body = gen_body(&[3 + i, 4 + i, 5 + i], 20, true);
        s.write_all(
            format!(
                "POST /v1/generate HTTP/1.1\r\nHost: t\r\n\
                 Content-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
        let mut buf = Vec::new();
        read_until(&mut s, "{\"id\":", &mut buf);
        streams.push(s);
    }
    // request shutdown while both sessions are streaming
    srv.shutdown.store(true, Ordering::SeqCst);
    for mut s in streams {
        let mut rest = String::new();
        s.read_to_string(&mut rest).unwrap();
        assert!(rest.contains("\"done\":true"),
                "stream cut off mid-drain: {rest}");
        assert!(rest.contains("\"outcome\":\"done\""), "{rest}");
    }
    let report = srv.stop();
    assert_eq!(report.completed, 2);
    assert_eq!(report.evicted, 0);
    assert_eq!(report.live_spans, 0);
    assert!(report.clean(), "{}", report.summary());
    // drained means the listener is gone too
    assert!(TcpStream::connect(addr).is_err());
}
