//! Seeded differential chaos suite: hundreds of fault schedules
//! against the offline scheduler plus in-process `serve-http` boots,
//! asserting after every run that containment held — no slot or page
//! leaks, no open spans, a terminal outcome for every admitted
//! session — and that an identical seed + plan reproduces an
//! identical event trace.

use qpruner::model::{ModelConfig, ParamStore};
use qpruner::obs::json::Json;
use qpruner::obs::span::Tracer;
use qpruner::quant::{BitConfig, QuantFormat};
use qpruner::rng::Rng;
use qpruner::runtime::Runtime;
use qpruner::serve::admission::{AdmissionPolicy, BrownoutConfig};
use qpruner::serve::engine::{Engine, EngineBuilder};
use qpruner::serve::faults::FaultPlan;
use qpruner::serve::kv_cache::{
    CompactMode, KvCachePool, KvLayout, KvPrecision,
};
use qpruner::serve::scheduler::Scheduler;
use qpruner::serve::ServeOpts;
use qpruner::server::{DrainReport, Server, ServerOpts};
use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

const MAX_SEQ: usize = 24;

fn fixture() -> (Runtime, Engine, ModelConfig) {
    let dir = std::env::temp_dir().join("qpruner_chaos_serve");
    std::fs::create_dir_all(&dir).unwrap();
    let mut rt = Runtime::new(&dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 41);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    let engine = EngineBuilder::new()
        .store(&store, &bits)
        .max_seq(MAX_SEQ)
        .build(&mut rt)
        .unwrap();
    (rt, engine, cfg)
}

/// Deterministic per-schedule fault plan. Probabilities come from
/// fixed string tables (not float arithmetic) so the spec — and
/// therefore the per-point RNG draws — is byte-stable.
fn plan_spec(seed: u64) -> String {
    const DECODE: [&str; 5] = ["0", "0.02", "0.05", "0.1", "0.25"];
    const STARVE: [&str; 4] = ["0", "0.02", "0.05", "0.1"];
    const DROP: [&str; 3] = ["0", "0.03", "0.08"];
    const PREFILL: [&str; 3] = ["0", "0.05", "0.15"];
    const COMPACT: [&str; 3] = ["0", "0.25", "1"];
    format!(
        "seed={seed},decode_err={},page_starve={},client_drop={},\
         prefill_err={},compact_move={}",
        DECODE[(seed % 5) as usize],
        STARVE[((seed / 5) % 4) as usize],
        DROP[((seed / 20) % 3) as usize],
        PREFILL[((seed / 60) % 3) as usize],
        COMPACT[((seed / 7) % 3) as usize],
    )
}

/// Aggregate failure accounting across one schedule, for the
/// suite-level "chaos actually happened" assertions.
#[derive(Default)]
struct Totals {
    completed: usize,
    evicted: usize,
    deadline: usize,
    quarantined: usize,
    disconnects: usize,
    fired: u64,
    compactions: u64,
    pages_reclaimed: u64,
}

/// Run one fault schedule to drain and return its event trace. The
/// trace captures every step's accounting plus the final per-session
/// outcomes — two runs of the same seed must produce identical
/// strings.
fn run_schedule(rt: &mut Runtime, engine: &Engine,
                cfg: &ModelConfig, seed: u64,
                totals: &mut Totals) -> String {
    let paged = seed % 2 == 1;
    let pool = if paged {
        // page_tokens 8 with prompts <= 6 tokens: no *full* prompt
        // page ever publishes. Compaction (below) flips sub-page
        // matching on, so the index pins at most one copied sub-tail
        // page per distinct prompt (5 here) — 3 slots * 3 pages + 5
        // pinned = 14 <= 16, and pinned entries are evictable under
        // pressure, so 16 pages can never legitimately starve 3 slots
        KvCachePool::with_slots_layout(
            cfg,
            engine.attn_dim(),
            3,
            MAX_SEQ,
            KvPrecision::F32,
            1e6,
            1e9,
            KvLayout::Paged,
            8,
            16,
        )
    } else {
        KvCachePool::with_slots(
            cfg,
            engine.attn_dim(),
            3,
            MAX_SEQ,
            KvPrecision::F32,
            1e6,
            1e9,
        )
    };
    let mut sched = Scheduler::new(
        pool,
        AdmissionPolicy::new(8, MAX_SEQ),
        3,
        6,
    );
    sched.set_tracer(Tracer::new(256));
    sched.set_faults(FaultPlan::parse(&plan_spec(seed)).unwrap());
    if paged {
        // threshold compaction + sub-page prefix matching run live
        // under the fault schedules: a single pinned sub-tail page
        // already puts frag_frac at 1/16 > 0.05, so the 0c trigger
        // fires on most steps and every pass draws the per-session
        // `compact_move` fault. Session tails here are always
        // private (sub-tail publish copies into an index-owned
        // page), so injected move failures can never hit — the sweep
        // proves conservation with compaction interleaved, while the
        // dedicated test below exercises the quarantine path
        sched.pool.set_compact_mode(CompactMode::Thresh(0.05));
    }
    // an already-expired deadline is wall-clock independent: every
    // admitted session deterministically exits with the deadline
    // reason at the next sweep
    if seed % 7 == 3 {
        sched.set_default_deadline_ms(Some(0));
    }
    if seed % 5 == 2 {
        sched.set_brownout(Some(BrownoutConfig {
            queue_frac: 0.5,
            occ_frac: 0.9,
            enter_steps: 2,
            exit_steps: 4,
            clamp_max_new: 2,
            retry_after_bump: 2,
        }));
    }

    let mut rng = Rng::new(seed ^ 0xC4A05);
    let mut trace = String::new();
    let mut client = 0usize;
    for ev in 0..30u32 {
        for _ in 0..rng.below(3) {
            let plen = 2 + rng.below(5);
            let mnew = 1 + rng.below(8);
            let prompt: Vec<i32> =
                (0..plen).map(|j| (3 + j) as i32).collect();
            let id = sched.submit(client, prompt, mnew, 7, 0.5);
            client += 1;
            writeln!(trace, "ev={ev} submit={id:?}").unwrap();
        }
        // periodic client-stall bursts exercise TTL eviction on top
        // of the injected faults
        let stall = if ev % 6 == 0 { 0.3 } else { 0.0 };
        sched.step(engine, rt, &mut rng, stall).unwrap();
        assert!(sched.pool.in_use() <= sched.pool.capacity());
        writeln!(
            trace,
            "ev={ev} active={} queue={} in_use={} done={} \
             evicted={} dl={} quar={} disc={} brownout={}",
            sched.active_len(),
            sched.queue_len(),
            sched.pool.in_use(),
            sched.stats.completed,
            sched.stats.evicted,
            sched.stats.deadline_exceeded,
            sched.stats.quarantined,
            sched.stats.disconnects,
            sched.brownout.active(),
        )
        .unwrap();
    }
    let mut guard = 0;
    while !sched.idle() {
        sched.step(engine, rt, &mut Rng::new(0), 0.0).unwrap();
        guard += 1;
        assert!(guard < 2000, "schedule {seed} failed to drain");
    }

    // containment invariants: nothing leaked, everything accounted
    assert_eq!(sched.pool.in_use(), 0,
               "schedule {seed}: slots leaked");
    sched.pool.clear_prefix_index();
    assert_eq!(sched.pool.pages_used(), 0,
               "schedule {seed}: pages leaked");
    let st = &sched.stats;
    assert_eq!(st.submitted, st.admitted + st.rejected,
               "schedule {seed}: submissions lost");
    assert_eq!(st.admitted, st.completed + st.evicted,
               "schedule {seed}: admitted sessions lost");
    assert!(
        st.deadline_exceeded + st.quarantined + st.disconnects
            <= st.evicted,
        "schedule {seed}: failure buckets exceed evictions"
    );
    // every admitted session holds a terminal state AND a recorded
    // exit reason
    let mut finals: Vec<(u64, &'static str, usize)> = sched
        .table
        .iter()
        .map(|s| {
            assert!(s.is_terminal(),
                    "schedule {seed}: session {} not terminal", s.id);
            let label = s
                .outcome
                .expect("terminal session without an outcome")
                .label();
            (s.id, label, s.generated.len())
        })
        .collect();
    finals.sort_unstable();
    for (id, label, tokens) in &finals {
        writeln!(trace, "final id={id} outcome={label} \
                         tokens={tokens}")
            .unwrap();
    }
    totals.completed += st.completed;
    totals.evicted += st.evicted;
    totals.deadline += st.deadline_exceeded;
    totals.quarantined += st.quarantined;
    totals.disconnects += st.disconnects;
    totals.fired += sched.faults().unwrap().total_fired();
    let kv = sched.pool.paged_stats();
    totals.compactions += kv.compactions;
    totals.pages_reclaimed += kv.pages_reclaimed;

    let tracer = sched.take_tracer().unwrap();
    assert_eq!(tracer.live_len(), 0,
               "schedule {seed}: span left open");
    assert_eq!(tracer.dropped(), 0,
               "schedule {seed}: spans dropped");
    trace
}

/// The offline capstone: 200 seeded schedules across slab and paged
/// pools, mixed fault plans, instant deadlines, and brownout — every
/// one drains clean, and replaying a sample of seeds reproduces the
/// event trace byte-for-byte.
#[test]
fn two_hundred_fault_schedules_drain_clean_and_replay() {
    let (mut rt, engine, cfg) = fixture();
    let mut totals = Totals::default();
    let mut traces: Vec<String> = Vec::with_capacity(200);
    for seed in 0..200u64 {
        traces.push(
            run_schedule(&mut rt, &engine, &cfg, seed, &mut totals),
        );
    }
    // the suite exercised every containment path at least once
    assert!(totals.completed > 0, "no schedule completed anything");
    assert!(totals.evicted > 0, "no abnormal exits at all");
    assert!(totals.deadline > 0, "deadline path never exercised");
    assert!(totals.quarantined > 0, "quarantine never exercised");
    assert!(totals.disconnects > 0, "drop injection never landed");
    assert!(totals.fired > 0, "fault plans never fired");
    // compaction ran live inside the fault schedules (paged seeds
    // enable Thresh(0.05)) and actually returned pages — the
    // conservation asserts above therefore held *with* compaction
    // interleaved between decode steps
    assert!(totals.compactions > 0,
            "threshold compaction never triggered in the sweep");
    assert!(totals.pages_reclaimed > 0,
            "compaction never reclaimed a page in the sweep");

    // identical seed + plan => identical event trace
    for &seed in &[0u64, 13, 77, 142, 199] {
        let mut t2 = Totals::default();
        let replay =
            run_schedule(&mut rt, &engine, &cfg, seed, &mut t2);
        assert_eq!(
            traces[seed as usize], replay,
            "schedule {seed} is not reproducible"
        );
    }
    // and different seeds genuinely diverge
    assert_ne!(traces[0], traces[1], "trace insensitive to seed");
}

/// An injected `compact_move` failure during a real migration
/// quarantines exactly the session whose tail was being moved — the
/// pool rolls the move back, the other residents keep decoding, and
/// the drain still conserves every slot and page. Scheduler-driven
/// sessions never naturally hold a *shared* partial tail (publishes
/// share full pages; sub-page matches copy), so the migration is set
/// up explicitly by rewinding a session into its published page.
#[test]
fn compact_move_fault_quarantines_only_the_affected_session() {
    let (mut rt, engine, cfg) = fixture();
    let pool = KvCachePool::with_slots_layout(
        &cfg,
        engine.attn_dim(),
        3,
        MAX_SEQ,
        KvPrecision::F32,
        1e6,
        1e9,
        KvLayout::Paged,
        4,
        16,
    );
    let mut sched = Scheduler::new(
        pool,
        AdmissionPolicy::new(8, MAX_SEQ),
        3,
        6,
    );
    // bare point = probability 1.0: every migration attempt fails.
    // Starve mode keeps compaction enabled without the Thresh(..)
    // step-loop trigger, so the only pass is the explicit one below
    sched.set_faults(FaultPlan::parse("seed=1,compact_move").unwrap());
    sched.pool.set_compact_mode(CompactMode::Starve);

    let mut rng = Rng::new(9);
    let a = sched
        .submit(0, vec![3, 4, 5, 6, 7, 8], 6, 7, 0.5)
        .unwrap();
    sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
    let b = sched
        .submit(1, vec![10, 11, 12, 13, 14], 4, 7, 0.5)
        .unwrap();
    let c = sched
        .submit(2, vec![20, 21, 22, 23, 24], 4, 7, 0.5)
        .unwrap();
    sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
    assert_eq!(sched.active_len(), 3);

    // rewind A into its published (shared) first page: len 2 leaves a
    // partial tail on a page the prefix index also holds, which is
    // exactly the shape compaction must migrate
    let slot_a = sched.table.get(a).slot.expect("A holds a slot");
    sched.pool.slot_mut(slot_a).rewind(2);

    let rep = sched.run_compaction();
    // the injected failure names A's slot and nothing else; A's dead
    // trailing page was still reclaimed before the move was attempted
    assert_eq!(rep.failed, vec![slot_a]);
    assert_eq!(rep.migrated, 0, "B/C tails are private — no moves");
    assert!(rep.pages_reclaimed >= 1, "A's dead page not reclaimed");

    // containment: A quarantined, B and C untouched and still live
    let sa = sched.table.get(a);
    assert!(sa.is_terminal(), "failed migration must quarantine");
    assert_eq!(sa.outcome.unwrap().label(), "quarantined");
    assert!(!sched.table.get(b).is_terminal());
    assert!(!sched.table.get(c).is_terminal());
    assert_eq!(sched.stats.quarantined, 1);
    assert_eq!(sched.active_len(), 2);
    // one draw per resident session, all with probability 1.0
    assert!(sched.faults().unwrap().total_fired() >= 3);

    // B and C drain to completion; nothing leaked
    let mut guard = 0;
    while !sched.idle() {
        sched.step(&engine, &mut rt, &mut Rng::new(0), 0.0).unwrap();
        guard += 1;
        assert!(guard < 2000, "quarantine schedule failed to drain");
    }
    assert_eq!(sched.stats.completed, 2);
    assert_eq!(sched.stats.evicted, 1);
    assert_eq!(sched.pool.in_use(), 0);
    sched.pool.clear_prefix_index();
    assert_eq!(sched.pool.pages_used(), 0, "pages leaked");
}

// ---- in-process serve-http chaos ---------------------------------

struct TestServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<DrainReport>,
}

fn start_server(tag: &str,
                tune: impl FnOnce(&mut ServerOpts)) -> TestServer {
    let dir =
        std::env::temp_dir().join(format!("qpruner_chaos_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 51);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    let mut opts = ServerOpts::new(ServeOpts::smoke());
    opts.addr = "127.0.0.1:0".to_string();
    opts.serve.stall_prob = 0.0;
    opts.serve.stats_every = 0;
    tune(&mut opts);
    let server = Server::bind(&opts.addr).unwrap();
    let addr = server.local_addr();
    let shutdown = Arc::new(AtomicBool::new(false));
    let flag = shutdown.clone();
    let builder = EngineBuilder::new().store(&store, &bits);
    let handle = std::thread::spawn(move || {
        let mut rt = Runtime::new(&dir).unwrap();
        server.run(&mut rt, builder, &opts, flag).unwrap()
    });
    TestServer { addr, shutdown, handle }
}

impl TestServer {
    fn stop(self) -> DrainReport {
        self.shutdown.store(true, Ordering::SeqCst);
        self.handle.join().unwrap()
    }
}

fn request(addr: SocketAddr, method: &str, path: &str, body: &str)
           -> (u16, String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let raw = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(raw.as_bytes()).unwrap();
    s.flush().unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    let (head, payload) = resp
        .split_once("\r\n\r\n")
        .expect("response has no head/body separator");
    let status: u16 = head
        .lines()
        .next()
        .unwrap()
        .split_whitespace()
        .nth(1)
        .unwrap()
        .parse()
        .unwrap();
    (status, head.to_string(), payload.to_string())
}

/// Faulty decode under real HTTP traffic: every client still gets a
/// terminal outcome, the fault counters surface in `/metrics`, and
/// the drain is clean.
#[test]
fn http_chaos_every_client_gets_a_terminal_outcome() {
    let srv = start_server("faulty", |o| {
        o.serve.fault_plan = Some(
            "seed=11,decode_err=0.15,client_drop=0.05,\
             page_starve=0.05,prefill_err=0.05"
                .to_string(),
        );
        o.serve.brownout = Some(BrownoutConfig::default());
    });
    let addr = srv.addr;
    let known = ["done", "evicted", "deadline", "quarantined",
                 "disconnect"];
    let mut saw_failure = false;
    for i in 0..24i32 {
        let body = format!(
            "{{\"prompt\":[{},{},{}],\"max_new\":6,\"seed\":7,\
             \"temperature\":0.5,\"stream\":false}}",
            3 + i % 5,
            4 + i % 3,
            5
        );
        let (status, _, payload) =
            request(addr, "POST", "/v1/generate", &body);
        assert_eq!(status, 200, "{payload}");
        let doc = Json::parse(&payload).unwrap();
        let outcome =
            doc.get("outcome").and_then(|o| o.as_str()).unwrap();
        assert!(known.contains(&outcome),
                "unknown terminal outcome {outcome:?}");
        saw_failure |= outcome != "done";
    }
    assert!(saw_failure,
            "fault plan injected nothing visible in 24 requests");

    let (status, _, payload) = request(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    let doc = Json::parse(&payload).unwrap();
    let counters = doc.get("counters").unwrap();
    let fired = counters
        .get("faults.injected_total")
        .and_then(|v| v.as_f64())
        .expect("fault counters missing with a plan configured");
    assert!(fired >= 1.0, "plan configured but nothing fired");
    assert!(
        doc.get("gauges")
            .and_then(|g| g.get("serve.brownout"))
            .is_some(),
        "brownout gauge missing"
    );

    let report = srv.stop();
    assert_eq!(report.submitted, 24);
    assert_eq!(report.completed + report.evicted, 24);
    assert!(report.faults_injected >= 1);
    assert!(report.clean(), "unclean drain: {}", report.summary());
}

/// Injected artifact corruption on `/admin/reload` fails closed: the
/// reload reports failure, the old engine keeps serving, nothing is
/// swapped.
#[test]
fn injected_reload_corruption_fails_closed() {
    use qpruner::artifact::{LoraMode, ModelArtifact, Provenance};
    let dir = std::env::temp_dir().join("qpruner_chaos_reload");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 51);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    let art = ModelArtifact::from_pipeline(
        &store,
        &bits,
        None,
        LoraMode::Merge,
        Provenance::default(),
    )
    .unwrap();
    let path = dir.join("swap.qpart");
    art.save(&path).unwrap();

    let srv = start_server("reload", |o| {
        // bare point = probability 1.0: every reload attempt sees a
        // corrupt artifact
        o.serve.fault_plan =
            Some("seed=3,reload_corrupt".to_string());
    });
    let addr = srv.addr;
    let (status, _, payload) = request(
        addr,
        "POST",
        "/admin/reload",
        &format!("{{\"artifact\":\"{}\"}}", path.display()),
    );
    assert_eq!(status, 400, "{payload}");
    assert!(payload.contains("injected fault"), "{payload}");

    // the serving engine is untouched and still decodes
    let (status, _, payload) = request(
        addr,
        "POST",
        "/v1/generate",
        "{\"prompt\":[3,4,5],\"max_new\":4,\"seed\":7,\
         \"temperature\":0.5,\"stream\":false}",
    );
    assert_eq!(status, 200, "{payload}");
    assert!(payload.contains("\"outcome\":\"done\""), "{payload}");

    let report = srv.stop();
    assert_eq!(report.reloads, 0, "corrupt reload must not swap");
    assert!(report.faults_injected >= 1);
    assert!(report.clean(), "{}", report.summary());
    std::fs::remove_file(&path).ok();
}

/// A stalling core loop trips the watchdog: `/healthz` turns 503
/// with the "watchdog" state while the loop is wedged, recovers when
/// beats resume, and the trip latches in the drain report.
#[test]
fn stall_plan_trips_watchdog_and_healthz_reports_it() {
    let srv = start_server("watchdog", |o| {
        o.serve.fault_plan =
            Some("seed=5,stall_ms=200".to_string());
        o.watchdog_ms = 25;
    });
    let addr = srv.addr;
    let body = "{\"prompt\":[3,4,5],\"max_new\":4,\"seed\":7,\
                \"temperature\":0.5,\"stream\":false}";
    let saw_watchdog = std::thread::scope(|sc| {
        let gen = sc.spawn(move || {
            let (status, _, payload) =
                request(addr, "POST", "/v1/generate", body);
            assert_eq!(status, 200, "{payload}");
            assert!(payload.contains("\"outcome\":\"done\""),
                    "{payload}");
        });
        // every scheduler step sleeps 200 ms against a 25 ms
        // watchdog: polls during the generation must observe the
        // tripped state
        let mut seen = false;
        for _ in 0..400 {
            let (status, _, payload) =
                request(addr, "GET", "/healthz", "");
            if status == 503
                && payload.contains("\"state\":\"watchdog\"")
            {
                seen = true;
                break;
            }
            if gen.is_finished() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        gen.join().unwrap();
        seen
    });

    let report = srv.stop();
    assert!(
        saw_watchdog || report.watchdog_trips >= 1,
        "watchdog never tripped: {}",
        report.summary()
    );
    assert!(report.watchdog_trips >= 1, "trip did not latch: {}",
            report.summary());
    assert_eq!(report.completed, 1);
    assert!(report.clean(), "{}", report.summary());
}
