//! Runtime <-> artifact integration: the rust side must agree with the
//! Python-side numerics through the AOT kernel artifacts.
//!
//! Requires `make artifacts` (skips gracefully when absent so plain
//! `cargo test` without artifacts still passes the pure-rust suite).

use qpruner::model::{ModelConfig, ParamStore};
use qpruner::quant::{dequantize, quantize, QuantFormat};
use qpruner::rng::Rng;
use qpruner::runtime::{Arg, Runtime};
use qpruner::tensor::Tensor;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("QPRUNER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    dir.join("manifest.tsv").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn host_matmul_wt(x: &Tensor, w: &Tensor) -> Tensor {
    // x [m,k] @ w [n,k]^T
    qpruner::linalg::matmul(x, &w.transpose2())
}

#[test]
fn kernel_qmatmul_nf4_matches_host_quant() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(11);
    let (m, n, k) = (16, 128, 256);
    let w = Tensor::randn(&[n, k], 1.0, &mut rng);
    let x = Tensor::randn(&[m, k], 1.0, &mut rng);
    let q = quantize(&w, QuantFormat::Nf4);
    let scales = Tensor::new(&[n, k / 64], q.scales.clone());
    let out = rt
        .exec_f32(
            "kernel_qmatmul_nf4",
            &[
                Arg::F32(&x),
                Arg::U8(&q.codes, &[n, k / 2]),
                Arg::F32(&scales),
            ],
        )
        .unwrap();
    // host reference: dequantize rust-side, multiply
    let want = host_matmul_wt(&x, &dequantize(&q));
    let got = &out[0];
    assert_eq!(got.shape(), &[m, n]);
    let err = got.sub(&want).max_abs();
    assert!(err < 1e-3, "nf4 kernel vs host dequant: max err {err}");
}

#[test]
fn kernel_qmatmul_int8_matches_host_quant() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(12);
    let (m, n, k) = (16, 128, 256);
    let w = Tensor::randn(&[n, k], 0.5, &mut rng);
    let x = Tensor::randn(&[m, k], 1.0, &mut rng);
    let q = quantize(&w, QuantFormat::Int8);
    let codes_i8: Vec<i8> = q.codes.iter().map(|&b| b as i8).collect();
    let scales = Tensor::new(&[n, k / 64], q.scales.clone());
    let out = rt
        .exec_f32(
            "kernel_qmatmul_int8",
            &[
                Arg::F32(&x),
                Arg::I8(&codes_i8, &[n, k]),
                Arg::F32(&scales),
            ],
        )
        .unwrap();
    let want = host_matmul_wt(&x, &dequantize(&q));
    let err = out[0].sub(&want).max_abs();
    assert!(err < 1e-3, "int8 kernel vs host dequant: max err {err}");
}

#[test]
fn kernel_lora_matmul_matches_host() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(13);
    let (m, n, k, r) = (16, 128, 256, 8);
    let x = Tensor::randn(&[m, k], 1.0, &mut rng);
    let w = Tensor::randn(&[n, k], 1.0, &mut rng);
    let a = Tensor::randn(&[r, k], 0.1, &mut rng);
    let b = Tensor::randn(&[n, r], 0.1, &mut rng);
    let out = rt
        .exec_f32(
            "kernel_lora_matmul",
            &[Arg::F32(&x), Arg::F32(&w), Arg::F32(&a), Arg::F32(&b)],
        )
        .unwrap();
    // scaling fixed to 2.0 in the artifact
    let low = qpruner::linalg::matmul(
        &qpruner::linalg::matmul(&x, &a.transpose2()),
        &b.transpose2(),
    );
    let mut want = host_matmul_wt(&x, &w);
    want.add_assign(&low.scale(2.0));
    let err = out[0].sub(&want).max_abs();
    assert!(err < 2e-3, "lora kernel vs host: max err {err}");
}

#[test]
fn kernel_rmsnorm_matches_host() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(14);
    let (m, d) = (16, 256);
    let x = Tensor::randn(&[m, d], 2.0, &mut rng);
    let g = Tensor::randn(&[d], 1.0, &mut rng);
    let out = rt
        .exec_f32("kernel_rmsnorm", &[Arg::F32(&x), Arg::F32(&g)])
        .unwrap();
    for i in 0..m {
        let row = x.row(i);
        let ms: f32 =
            row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for j in 0..d {
            let want = row[j] * inv * g.data()[j];
            let got = out[0].at2(i, j);
            assert!((want - got).abs() < 1e-4, "[{i},{j}] {want} vs {got}");
        }
    }
}

#[test]
fn kernel_attention_is_causal_and_normalized() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    if !rt.has_artifact("kernel_attention") {
        eprintln!("skipping: kernel_attention not built");
        return;
    }
    let (bh, s, hd) = (8, 64, 48);
    let mut rng = Rng::new(17);
    let q = Tensor::randn(&[bh, s, hd], 1.0, &mut rng);
    let k = Tensor::randn(&[bh, s, hd], 1.0, &mut rng);
    let v = Tensor::randn(&[bh, s, hd], 1.0, &mut rng);
    let out = rt
        .exec_f32("kernel_attention",
                  &[Arg::F32(&q), Arg::F32(&k), Arg::F32(&v)])
        .unwrap();
    assert_eq!(out[0].shape(), &[bh, s, hd]);
    // row 0 attends only to itself -> equals v row 0
    for b in 0..bh {
        for d in 0..hd {
            let got = out[0].data()[b * s * hd + d];
            let want = v.data()[b * s * hd + d];
            assert!((got - want).abs() < 1e-4, "[{b},0,{d}]");
        }
    }
    // outputs are convex combinations of v rows -> bounded by max |v|
    let vmax = v.max_abs();
    assert!(out[0].max_abs() <= vmax + 1e-4);
}

#[test]
fn fwd_artifact_runs_with_pallas_kernels_inside() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 3);
    let lora_shapes = qpruner::lora::LoraState::shapes(&store);
    let lora: Vec<Tensor> =
        lora_shapes.iter().map(|s| Tensor::zeros(s)).collect();
    let tokens: Vec<i32> = (0..cfg.batch * cfg.seq)
        .map(|i| 3 + (i as i32 * 7) % (cfg.vocab as i32 - 3))
        .collect();
    let mut args: Vec<Arg> = Vec::new();
    for w in &store.weights {
        args.push(Arg::F32(w));
    }
    for t in &lora {
        args.push(Arg::F32(t));
    }
    let shape = [cfg.batch, cfg.seq];
    args.push(Arg::I32(&tokens, &shape));
    let out = rt.exec_f32("fwd_tiny_r0", &args).unwrap();
    assert_eq!(out[0].shape(), &[cfg.batch, cfg.seq, cfg.vocab]);
    assert!(out[0].data().iter().all(|x| x.is_finite()));
}

#[test]
fn qfwd_matches_simulated_quant_fwd() {
    // The fused NF4 deployment path must agree with the simulated-
    // quantization path end-to-end at the logits level.
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 4);
    let lora_shapes = qpruner::lora::LoraState::shapes(&store);
    let lora: Vec<Tensor> =
        lora_shapes.iter().map(|s| Tensor::zeros(s)).collect();
    let tokens: Vec<i32> = (0..cfg.batch * cfg.seq)
        .map(|i| 3 + (i as i32 * 11) % (cfg.vocab as i32 - 3))
        .collect();
    let shape = [cfg.batch, cfg.seq];

    // quantize all projection stacks rust-side
    use qpruner::model::{proj_index, PROJS};
    let mut deq = store.clone();
    let mut qcodes: Vec<Vec<u8>> = Vec::new();
    let mut qscales: Vec<Tensor> = Vec::new();
    let mut qshapes: Vec<(Vec<usize>, Vec<usize>)> = Vec::new();
    for p in PROJS {
        let stack = &store.weights[proj_index(p)];
        let (o, i) = cfg.proj_shape(&store.ps, p);
        let l = cfg.n_layers;
        let mut codes = Vec::with_capacity(l * o * i / 2);
        let mut scales = Vec::with_capacity(l * o * i / 64);
        for layer in 0..l {
            let (sh, data) = stack.slab(layer);
            let mat = Tensor::new(sh, data.to_vec());
            let q = quantize(&mat, QuantFormat::Nf4);
            codes.extend_from_slice(&q.codes);
            scales.extend_from_slice(&q.scales);
            deq.set_layer_proj(layer, p, &dequantize(&q));
        }
        qcodes.push(codes);
        qscales.push(Tensor::new(&[l, o, i / 64], scales));
        qshapes.push((vec![l, o, i / 2], vec![l, o, i / 64]));
    }

    // fused qfwd call
    let mut args: Vec<Arg> = vec![
        Arg::F32(&store.weights[0]),
        Arg::F32(&store.weights[1]),
        Arg::F32(&store.weights[6]),
        Arg::F32(&store.weights[10]),
        Arg::F32(&store.weights[11]),
    ];
    for pi in 0..PROJS.len() {
        args.push(Arg::U8(&qcodes[pi], &qshapes[pi].0));
        args.push(Arg::F32(&qscales[pi]));
    }
    for t in &lora {
        args.push(Arg::F32(t));
    }
    args.push(Arg::I32(&tokens, &shape));
    let qfwd = rt.exec_f32("qfwd_tiny_r0", &args).unwrap();

    // simulated-quant fwd call
    let mut args2: Vec<Arg> = Vec::new();
    for w in &deq.weights {
        args2.push(Arg::F32(w));
    }
    for t in &lora {
        args2.push(Arg::F32(t));
    }
    args2.push(Arg::I32(&tokens, &shape));
    let fwd = rt.exec_f32("fwd_tiny_r0", &args2).unwrap();

    let err = qfwd[0].sub(&fwd[0]).max_abs();
    let scale = fwd[0].max_abs().max(1.0);
    assert!(
        err / scale < 5e-3,
        "fused NF4 vs simulated quant: rel err {}",
        err / scale
    );
}

#[test]
fn executable_cache_reuses_compilations() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(15);
    let x = Tensor::randn(&[16, 256], 1.0, &mut rng);
    let g = Tensor::randn(&[256], 1.0, &mut rng);
    for _ in 0..3 {
        rt.exec_f32("kernel_rmsnorm", &[Arg::F32(&x), Arg::F32(&g)])
            .unwrap();
    }
    assert_eq!(rt.loaded_count(), 1);
    assert_eq!(rt.exec_counts["kernel_rmsnorm"], 3);
}

#[test]
fn manifest_guards_arity() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(16);
    let x = Tensor::randn(&[16, 256], 1.0, &mut rng);
    // rmsnorm wants 2 args; pass 1 -> manifest must reject
    let err = rt.exec_f32("kernel_rmsnorm", &[Arg::F32(&x)]);
    assert!(err.is_err());
}
