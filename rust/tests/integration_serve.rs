//! End-to-end serving integration: the `bench-serve --scale smoke`
//! acceptance path, memory-budget enforcement, load shedding, TTL
//! eviction, and determinism. Runs entirely on the native decode
//! backend — no AOT artifacts required.

use qpruner::data::Language;
use qpruner::memory;
use qpruner::metrics::Metrics;
use qpruner::model::{ModelConfig, ParamStore};
use qpruner::quant::{BitConfig, QuantFormat};
use qpruner::runtime::Runtime;
use qpruner::serve::{run_workload, ServeOpts, ServeReport};

fn runtime() -> Runtime {
    let dir = std::env::temp_dir().join("qpruner_serve_it");
    std::fs::create_dir_all(&dir).unwrap();
    Runtime::new(&dir).unwrap()
}

fn tiny_store(seed: u64) -> ParamStore {
    let cfg = ModelConfig::preset("tiny").unwrap();
    ParamStore::init(&cfg, seed)
}

fn nf4(store: &ParamStore) -> BitConfig {
    BitConfig::uniform(store.cfg.n_layers, QuantFormat::Nf4)
}

fn run(store: &ParamStore, bits: &BitConfig, opts: &ServeOpts)
       -> ServeReport {
    let mut rt = runtime();
    let lang = Language::new(store.cfg.vocab, 1);
    let mut metrics = Metrics::new();
    run_workload(&mut rt, store, bits, &lang, opts, &mut metrics)
        .expect("workload must drain")
}

/// All requests are accounted for exactly once.
fn assert_accounted(r: &ServeReport, requests: usize) {
    assert_eq!(r.submitted, requests, "submitted != issued");
    assert_eq!(
        r.completed + r.rejected + r.evicted,
        requests,
        "requests lost or double-counted: completed {} rejected {} \
         evicted {}",
        r.completed,
        r.rejected,
        r.evicted
    );
}

/// The modeled KV memory at peak may never exceed the configured
/// budget (the acceptance criterion).
fn assert_within_budget(r: &ServeReport) {
    assert!(
        r.kv_modeled_peak_bytes <= r.kv_modeled_budget_bytes + 1e-6,
        "KV peak {:.3e} B exceeded budget {:.3e} B",
        r.kv_modeled_peak_bytes,
        r.kv_modeled_budget_bytes
    );
    assert!(r.kv_peak_sessions <= r.kv_capacity_sessions);
}

#[test]
fn smoke_workload_completes_with_continuous_batching() {
    // the bench-serve --scale smoke acceptance path: >= 200 requests
    let store = tiny_store(3);
    let bits = nf4(&store);
    let opts = ServeOpts::smoke();
    assert!(opts.requests >= 200);
    let r = run(&store, &bits, &opts);

    assert_accounted(&r, opts.requests);
    assert_eq!(r.rejected, 0, "smoke defaults should never shed load");
    assert_eq!(r.completed, opts.requests);

    // continuous batching actually batched
    assert!(
        r.mean_occupancy > 1.0,
        "batch occupancy {} never exceeded 1",
        r.mean_occupancy
    );
    assert!(r.max_occupancy > 1 && r.max_occupancy <= opts.max_batch);

    // the closed loop generated real tokens at a finite rate
    assert!(r.generated_tokens >= opts.requests as u64 * 3);
    assert!(r.tokens_per_sec() > 0.0);
    assert!(r.wall_secs > 0.0);

    // latency percentiles are present and ordered
    assert_eq!(r.latency.len(), opts.requests);
    let (p50, p95, p99) = (
        r.latency.percentile_ms(50.0),
        r.latency.percentile_ms(95.0),
        r.latency.percentile_ms(99.0),
    );
    assert!(p50.is_finite() && p50 >= 0.0);
    assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
    assert_eq!(r.ttft.len(), opts.requests);

    assert_within_budget(&r);
}

#[test]
fn kv_budget_is_enforced_under_pressure() {
    // budget for exactly 2 concurrent sessions, 6 clients hammering
    let store = tiny_store(4);
    let bits = nf4(&store);
    let arch = ModelConfig::paper_7b();
    let mut opts = ServeOpts::smoke();
    opts.clients = 6;
    opts.requests = 60;
    opts.max_batch = 6;
    let per = memory::kv_bytes_per_session(&arch, 0, opts.max_seq);
    opts.kv_budget_gb = Some(2.0 * per / 1e9 + 1e-12);
    opts.max_queue = 64; // queue, don't shed
    let r = run(&store, &bits, &opts);

    assert_accounted(&r, 60);
    assert_eq!(r.completed, 60);
    assert_eq!(r.kv_capacity_sessions, 2, "budget sized the pool");
    assert!(r.max_occupancy <= 2, "occupancy broke the memory budget");
    assert_within_budget(&r);
}

#[test]
fn overload_sheds_load_at_admission() {
    let store = tiny_store(5);
    let bits = nf4(&store);
    let arch = ModelConfig::paper_7b();
    let mut opts = ServeOpts::smoke();
    opts.clients = 12;
    opts.requests = 96;
    opts.max_batch = 2;
    let per = memory::kv_bytes_per_session(&arch, 0, opts.max_seq);
    opts.kv_budget_gb = Some(1.0 * per / 1e9 + 1e-12);
    opts.max_queue = 2; // tiny queue -> rejections under burst
    let r = run(&store, &bits, &opts);

    assert_accounted(&r, 96);
    assert!(r.rejected > 0, "overload never shed load");
    assert!(r.completed > 0, "server starved completely");
    assert!(r.rejection_rate() > 0.0 && r.rejection_rate() < 1.0);
    // all shedding here is queue pressure, not oversized requests
    assert_eq!(r.rejected_by, (r.rejected, 0, 0));
    assert!(r.busy_steps <= r.steps);
    assert_within_budget(&r);
}

#[test]
fn oversized_requests_are_shed_as_too_long() {
    // max_seq tight enough that the larger sampled length combinations
    // exceed a KV slot while the smallest still fit
    let store = tiny_store(9);
    let bits = nf4(&store);
    let mut opts = ServeOpts::smoke();
    opts.clients = 4;
    opts.requests = 40;
    opts.max_seq = 12; // prompt 4..10 + new 3..12 straddles this
    let r = run(&store, &bits, &opts);

    assert_accounted(&r, 40);
    assert!(r.rejected_by.1 > 0, "no too-long rejections observed");
    assert_eq!(r.rejected, r.rejected_by.0 + r.rejected_by.1);
    assert!(r.completed > 0);
    assert_within_budget(&r);
}

#[test]
fn stalled_clients_are_ttl_evicted() {
    let store = tiny_store(6);
    let bits = nf4(&store);
    let mut opts = ServeOpts::smoke();
    opts.clients = 4;
    opts.requests = 48;
    opts.stall_prob = 0.05;
    opts.ttl_steps = 4;
    let r = run(&store, &bits, &opts);

    assert_accounted(&r, 48);
    assert!(r.evicted > 0, "stall injection produced no evictions");
    // eviction reclaimed slots: later requests still completed
    assert!(r.completed > r.evicted);
    assert_within_budget(&r);
}

#[test]
fn workload_is_deterministic_given_seed() {
    let store = tiny_store(7);
    let bits = nf4(&store);
    let mut opts = ServeOpts::smoke();
    opts.requests = 40;
    opts.clients = 4;
    opts.stall_prob = 0.02;
    let a = run(&store, &bits, &opts);
    let b = run(&store, &bits, &opts);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.rejected, b.rejected);
    assert_eq!(a.evicted, b.evicted);
    assert_eq!(a.generated_tokens, b.generated_tokens);
    assert_eq!(a.steps, b.steps);
}

#[test]
fn mixed_precision_configs_serve() {
    let store = tiny_store(8);
    let mut bits = nf4(&store);
    bits.layers[0] = QuantFormat::Int8;
    let mut opts = ServeOpts::smoke();
    opts.requests = 24;
    opts.clients = 4;
    let r = run(&store, &bits, &opts);
    assert_eq!(r.completed, 24);
    assert_eq!(r.bits_short, bits.short());
    // int8 layers shrink the inference footprint less than nf4, so the
    // mixed config's derived budget sits between uniform nf4 and fp16
    let b_mixed = qpruner::serve::resolve_kv_budget_gb(&opts, 0, &bits);
    let b_nf4 =
        qpruner::serve::resolve_kv_budget_gb(&opts, 0, &nf4(&store));
    assert!(b_mixed <= b_nf4 + 1e-12);
}
