//! End-to-end serving integration: the `bench-serve --scale smoke`
//! acceptance path, memory-budget enforcement, load shedding, TTL
//! eviction, and determinism. Runs entirely on the native decode
//! backend — no AOT artifacts required.

use qpruner::data::Language;
use qpruner::memory;
use qpruner::metrics::Metrics;
use qpruner::model::{ModelConfig, ParamStore};
use qpruner::quant::{BitConfig, QuantFormat};
use qpruner::rng::Rng;
use qpruner::runtime::Runtime;
use qpruner::serve::admission::AdmissionPolicy;
use qpruner::serve::engine::EngineBuilder;
use qpruner::serve::kv_cache::{KvCachePool, KvPrecision};
use qpruner::serve::scheduler::Scheduler;
use qpruner::serve::{run_workload, ServeOpts, ServeReport};
use std::fmt::Write as _;

fn runtime() -> Runtime {
    let dir = std::env::temp_dir().join("qpruner_serve_it");
    std::fs::create_dir_all(&dir).unwrap();
    Runtime::new(&dir).unwrap()
}

fn tiny_store(seed: u64) -> ParamStore {
    let cfg = ModelConfig::preset("tiny").unwrap();
    ParamStore::init(&cfg, seed)
}

fn nf4(store: &ParamStore) -> BitConfig {
    BitConfig::uniform(store.cfg.n_layers, QuantFormat::Nf4)
}

fn run_p(store: &ParamStore, bits: &BitConfig, opts: &ServeOpts,
         precision: KvPrecision) -> ServeReport {
    let mut rt = runtime();
    let lang = Language::new(store.cfg.vocab, 1);
    let mut metrics = Metrics::new();
    let builder = EngineBuilder::new()
        .store(store, bits)
        .kv_precision(precision);
    run_workload(&mut rt, builder, &lang, opts, &mut metrics)
        .expect("workload must drain")
}

fn run(store: &ParamStore, bits: &BitConfig, opts: &ServeOpts)
       -> ServeReport {
    run_p(store, bits, opts, KvPrecision::F32)
}

/// All requests are accounted for exactly once.
fn assert_accounted(r: &ServeReport, requests: usize) {
    assert_eq!(r.submitted, requests, "submitted != issued");
    assert_eq!(
        r.completed + r.rejected + r.evicted,
        requests,
        "requests lost or double-counted: completed {} rejected {} \
         evicted {}",
        r.completed,
        r.rejected,
        r.evicted
    );
}

/// The modeled KV memory at peak may never exceed the configured
/// budget (the acceptance criterion).
fn assert_within_budget(r: &ServeReport) {
    assert!(
        r.kv_modeled_peak_bytes <= r.kv_modeled_budget_bytes + 1e-6,
        "KV peak {:.3e} B exceeded budget {:.3e} B",
        r.kv_modeled_peak_bytes,
        r.kv_modeled_budget_bytes
    );
    assert!(r.kv_peak_sessions <= r.kv_capacity_sessions);
}

#[test]
fn smoke_workload_completes_with_continuous_batching() {
    // the bench-serve --scale smoke acceptance path: >= 200 requests
    let store = tiny_store(3);
    let bits = nf4(&store);
    let opts = ServeOpts::smoke();
    assert!(opts.requests >= 200);
    let r = run(&store, &bits, &opts);

    assert_accounted(&r, opts.requests);
    assert_eq!(r.rejected, 0, "smoke defaults should never shed load");
    assert_eq!(r.completed, opts.requests);

    // continuous batching actually batched
    assert!(
        r.mean_occupancy > 1.0,
        "batch occupancy {} never exceeded 1",
        r.mean_occupancy
    );
    assert!(r.max_occupancy > 1 && r.max_occupancy <= opts.max_batch);

    // the closed loop generated real tokens at a finite rate
    assert!(r.generated_tokens >= opts.requests as u64 * 3);
    assert!(r.tokens_per_sec() > 0.0);
    assert!(r.wall_secs > 0.0);

    // latency percentiles are present and ordered
    assert_eq!(r.latency.len(), opts.requests);
    let (p50, p95, p99) = (
        r.latency.percentile_ms(50.0),
        r.latency.percentile_ms(95.0),
        r.latency.percentile_ms(99.0),
    );
    assert!(p50.is_finite() && p50 >= 0.0);
    assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
    assert_eq!(r.ttft.len(), opts.requests);

    assert_within_budget(&r);
}

#[test]
fn kv_budget_is_enforced_under_pressure() {
    // budget for exactly 2 concurrent sessions, 6 clients hammering
    let store = tiny_store(4);
    let bits = nf4(&store);
    let arch = ModelConfig::paper_7b();
    let mut opts = ServeOpts::smoke();
    opts.clients = 6;
    opts.requests = 60;
    opts.max_batch = 6;
    let per = memory::kv_bytes_per_session(&arch, 0, opts.max_seq);
    opts.kv_budget_gb = Some(2.0 * per / 1e9 + 1e-12);
    opts.max_queue = 64; // queue, don't shed
    let r = run(&store, &bits, &opts);

    assert_accounted(&r, 60);
    assert_eq!(r.completed, 60);
    assert_eq!(r.kv_capacity_sessions, 2, "budget sized the pool");
    assert!(r.max_occupancy <= 2, "occupancy broke the memory budget");
    assert_within_budget(&r);
}

#[test]
fn overload_sheds_load_at_admission() {
    let store = tiny_store(5);
    let bits = nf4(&store);
    let arch = ModelConfig::paper_7b();
    let mut opts = ServeOpts::smoke();
    opts.clients = 12;
    opts.requests = 96;
    opts.max_batch = 2;
    let per = memory::kv_bytes_per_session(&arch, 0, opts.max_seq);
    opts.kv_budget_gb = Some(1.0 * per / 1e9 + 1e-12);
    opts.max_queue = 2; // tiny queue -> rejections under burst
    let r = run(&store, &bits, &opts);

    assert_accounted(&r, 96);
    assert!(r.rejected > 0, "overload never shed load");
    assert!(r.completed > 0, "server starved completely");
    assert!(r.rejection_rate() > 0.0 && r.rejection_rate() < 1.0);
    // all shedding here is queue pressure, not oversized requests
    assert_eq!(r.rejected_by, (r.rejected, 0, 0));
    assert!(r.busy_steps <= r.steps);
    assert_within_budget(&r);
}

#[test]
fn oversized_requests_are_shed_as_too_long() {
    // max_seq tight enough that the larger sampled length combinations
    // exceed a KV slot while the smallest still fit
    let store = tiny_store(9);
    let bits = nf4(&store);
    let mut opts = ServeOpts::smoke();
    opts.clients = 4;
    opts.requests = 40;
    opts.max_seq = 12; // prompt 4..10 + new 3..12 straddles this
    let r = run(&store, &bits, &opts);

    assert_accounted(&r, 40);
    assert!(r.rejected_by.1 > 0, "no too-long rejections observed");
    assert_eq!(r.rejected, r.rejected_by.0 + r.rejected_by.1);
    assert!(r.completed > 0);
    assert_within_budget(&r);
}

#[test]
fn stalled_clients_are_ttl_evicted() {
    let store = tiny_store(6);
    let bits = nf4(&store);
    let mut opts = ServeOpts::smoke();
    opts.clients = 4;
    opts.requests = 48;
    opts.stall_prob = 0.05;
    opts.ttl_steps = 4;
    let r = run(&store, &bits, &opts);

    assert_accounted(&r, 48);
    assert!(r.evicted > 0, "stall injection produced no evictions");
    // eviction reclaimed slots: later requests still completed
    assert!(r.completed > r.evicted);
    assert_within_budget(&r);
}

#[test]
fn workload_is_deterministic_given_seed() {
    let store = tiny_store(7);
    let bits = nf4(&store);
    let mut opts = ServeOpts::smoke();
    opts.requests = 40;
    opts.clients = 4;
    opts.stall_prob = 0.02;
    let a = run(&store, &bits, &opts);
    let b = run(&store, &bits, &opts);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.rejected, b.rejected);
    assert_eq!(a.evicted, b.evicted);
    assert_eq!(a.generated_tokens, b.generated_tokens);
    assert_eq!(a.steps, b.steps);
}

#[test]
fn int8_kv_serves_same_workload_in_a_smaller_slab() {
    // --kv-bits 8 end to end: identical workload, identical token
    // accounting, >= 3.5x smaller host KV slab than --kv-bits 32
    let store = tiny_store(10);
    let bits = nf4(&store);
    let mut opts = ServeOpts::smoke();
    opts.requests = 48;
    opts.clients = 4;
    let rf = run(&store, &bits, &opts);
    let ri = run_p(&store, &bits, &opts, KvPrecision::Int8);

    assert_accounted(&ri, 48);
    assert_eq!(ri.completed, rf.completed);
    // each session generates exactly its max_new tokens, so the token
    // count is precision-independent even though the logits differ
    assert_eq!(ri.generated_tokens, rf.generated_tokens);
    assert_eq!(rf.kv_bits, 32);
    assert_eq!(ri.kv_bits, 8);
    // same slot count (both capped by max_batch), ~4x less host memory
    assert_eq!(ri.kv_capacity_sessions, rf.kv_capacity_sessions);
    let ratio =
        rf.kv_host_slab_bytes as f64 / ri.kv_host_slab_bytes as f64;
    assert!(ratio >= 3.5, "int8 KV slab only {ratio:.2}x smaller");
    // and the modeled per-session footprint shrinks the same way
    assert!(ri.kv_modeled_peak_bytes < rf.kv_modeled_peak_bytes);
    assert_within_budget(&ri);
}

#[test]
fn decode_workspace_growth_is_bounded_by_batch_not_tokens() {
    // the allocator-churn fix observed through Metrics: scratch buffer
    // growths are bounded by the distinct batch sizes seen (<= max
    // batch), while reuses track the thousands of decoded tokens
    let store = tiny_store(11);
    let bits = nf4(&store);
    let mut opts = ServeOpts::smoke();
    opts.requests = 60;
    opts.clients = 6;
    opts.max_batch = 4;
    let mut rt = runtime();
    let lang = Language::new(store.cfg.vocab, 1);
    let mut metrics = Metrics::new();
    let r = run_workload(&mut rt,
                         EngineBuilder::new().store(&store, &bits),
                         &lang, &opts, &mut metrics)
        .expect("workload must drain");
    let grows = metrics.counter("serve.scratch_grows");
    let reuses = metrics.counter("serve.scratch_reuses");
    assert_eq!(grows, r.scratch_grows);
    assert_eq!(reuses, r.scratch_reuses);
    assert!(grows >= 1, "workspace never sized itself");
    assert!(
        grows <= opts.max_batch as u64,
        "scratch grew {grows} times for max_batch {}",
        opts.max_batch
    );
    // exact accounting: the workspace is touched once per prefill
    // token and once per busy decode step — if this drifts, something
    // on the hot path started resizing (allocating) per token
    assert_eq!(
        grows + reuses,
        r.prefill_tokens + r.busy_steps,
        "workspace touches != prefill tokens + busy steps"
    );
}

/// 200 seeded random admit / finish / TTL-expire events: pool
/// accounting invariants hold at every step and the full event trace
/// is byte-identical across two runs (determinism).
#[test]
fn scheduler_fuzz_is_deterministic_and_never_leaks_slots() {
    fn run_trace(seed: u64) -> (String, usize, usize) {
        let dir = std::env::temp_dir().join("qpruner_serve_fuzz");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 31);
        let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
        let max_seq = 24;
        let engine = EngineBuilder::new()
            .store(&store, &bits)
            .max_seq(max_seq)
            .build(&mut rt)
            .unwrap();
        let pool = KvCachePool::with_slots(
            &cfg,
            engine.attn_dim(),
            3,
            max_seq,
            KvPrecision::F32,
            1e6,
            3e6,
        );
        let mut sched = Scheduler::new(
            pool,
            AdmissionPolicy::new(16, max_seq),
            3,
            3,
        );
        let mut rng = Rng::new(seed);
        let mut trace = String::new();
        let mut client = 0usize;

        let check_invariants = |sched: &Scheduler| {
            assert!(sched.pool.in_use() <= sched.pool.capacity());
            assert!(sched.pool.peak_in_use() <= sched.pool.capacity());
            let mut held: Vec<usize> = sched
                .table
                .iter()
                .filter_map(|s| s.slot)
                .collect();
            let n = held.len();
            held.sort_unstable();
            held.dedup();
            assert_eq!(n, held.len(), "slot double-allocated");
            assert_eq!(
                held.len(),
                sched.pool.in_use(),
                "sessions hold {} slots but pool says {}",
                held.len(),
                sched.pool.in_use()
            );
        };

        for ev in 0..200u32 {
            for _ in 0..rng.below(3) {
                let plen = 2 + rng.below(5);
                let mnew = 1 + rng.below(6);
                let prompt: Vec<i32> =
                    (0..plen).map(|j| (3 + j) as i32).collect();
                let id = sched.submit(client, prompt, mnew, 7, 0.5);
                client += 1;
                writeln!(trace, "ev={ev} submit={id:?}").unwrap();
            }
            // periodic client-disconnect bursts feed the TTL-expire path
            let stall = if ev % 5 == 0 { 0.5 } else { 0.0 };
            sched.step(&engine, &mut rt, &mut rng, stall).unwrap();
            check_invariants(&sched);
            writeln!(
                trace,
                "ev={ev} step={} active={} queue={} in_use={} \
                 done={} evicted={} tokens={}",
                sched.step_no(),
                sched.active_len(),
                sched.queue_len(),
                sched.pool.in_use(),
                sched.stats.completed,
                sched.stats.evicted,
                sched.stats.generated_tokens,
            )
            .unwrap();
        }
        // drain what's left (no new submissions, no stalls)
        let mut guard = 0;
        while !sched.idle() {
            sched.step(&engine, &mut rt, &mut Rng::new(0), 0.0).unwrap();
            check_invariants(&sched);
            guard += 1;
            assert!(guard < 2000, "fuzz scheduler failed to drain");
        }
        writeln!(
            trace,
            "final done={} evicted={} rejected={} in_use={}",
            sched.stats.completed,
            sched.stats.evicted,
            sched.stats.rejected,
            sched.pool.in_use(),
        )
        .unwrap();
        assert_eq!(sched.pool.in_use(), 0, "slots leaked after drain");
        (trace, sched.stats.completed, sched.stats.evicted)
    }

    let (ta, done_a, evicted_a) = run_trace(0xF00D);
    let (tb, done_b, evicted_b) = run_trace(0xF00D);
    assert_eq!(ta, tb, "event trace diverged between identical runs");
    assert_eq!((done_a, evicted_a), (done_b, evicted_b));
    assert!(done_a > 0, "fuzz run completed nothing");
    assert!(evicted_a > 0, "fuzz run exercised no TTL expirations");
    // a different seed produces a different trajectory (the trace
    // actually encodes scheduler behaviour, not constants)
    let (tc, _, _) = run_trace(0xBEEF);
    assert_ne!(ta, tc, "trace insensitive to the seed");
}

/// Satellite of the HTTP front-end: a burst of N simultaneous
/// submissions against a 1-slot pool must make deterministic
/// admission decisions (exactly `max_queue` admitted before any step
/// runs), then drain with zero dropped spans and zero leaked slots —
/// the scheduler-level contract the server's 429/drain behaviour sits
/// on.
#[test]
fn simultaneous_burst_admits_deterministically_and_drains_clean() {
    use qpruner::obs::span::Tracer;

    let dir = std::env::temp_dir().join("qpruner_serve_burst");
    std::fs::create_dir_all(&dir).unwrap();
    let mut rt = Runtime::new(&dir).unwrap();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 17);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    let max_seq = 24;
    let engine = EngineBuilder::new()
        .store(&store, &bits)
        .max_seq(max_seq)
        .build(&mut rt)
        .unwrap();
    let mut run_burst = || {
        let pool = KvCachePool::with_slots(
            &cfg,
            engine.attn_dim(),
            1,
            max_seq,
            KvPrecision::F32,
            1e6,
            1e6,
        );
        let mut sched = Scheduler::new(
            pool,
            AdmissionPolicy::new(2, max_seq),
            1,
            8,
        );
        sched.set_tracer(Tracer::new(64));
        // 8 submissions land before any scheduler step — the HTTP
        // analogue of 8 connections hitting POST /v1/generate at once
        let verdicts: Vec<bool> = (0..8)
            .map(|c| {
                sched
                    .submit(c, vec![3, 4, 5, 6], 4, 7, 0.5)
                    .is_some()
            })
            .collect();
        assert_eq!(
            verdicts,
            [true, true, false, false, false, false, false, false],
            "admission under burst must be deterministic"
        );
        assert_eq!(sched.stats.rejected, 6);
        assert_eq!(
            sched.admission.retry_after_secs(sched.queue_len()),
            sched.admission.retry_after_secs(2),
            "retry hint must derive from the live queue depth"
        );
        let mut rng = Rng::new(0);
        let mut guard = 0;
        while !sched.idle() {
            sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
            guard += 1;
            assert!(guard < 200, "burst failed to drain");
        }
        assert_eq!(sched.stats.completed, 2);
        assert_eq!(sched.pool.in_use(), 0, "slots leaked");
        let tracer = sched.take_tracer().unwrap();
        assert_eq!(tracer.spans().len(), 2, "admitted spans missing");
        assert_eq!(tracer.live_len(), 0, "span left open after drain");
        assert_eq!(tracer.dropped(), 0, "spans dropped under burst");
        (sched.stats.completed, sched.stats.generated_tokens)
    };
    assert_eq!(run_burst(), run_burst());
}

/// `build_stack` + `metrics_registry` are the exact components the
/// HTTP server serves through: the stack must admit work, and the
/// registry snapshot must strict-parse with the serve + idle-prefix
/// gauges present.
#[test]
fn build_stack_and_metrics_registry_back_the_http_server() {
    use qpruner::obs::json::Json;
    use qpruner::serve::{build_stack, metrics_registry};

    let store = tiny_store(13);
    let bits = nf4(&store);
    let mut rt = runtime();
    let mut opts = ServeOpts::smoke();
    opts.max_batch = 2;
    let builder = EngineBuilder::new().store(&store, &bits);
    let (engine, mut sched) =
        build_stack(&mut rt, builder, &opts, true).unwrap();
    assert!(sched.tracer().is_some(), "tracer must be installed");

    for c in 0..3 {
        assert!(
            sched.submit(c, vec![4, 5, 6], 4, opts.seed, 0.5).is_some()
        );
    }
    let mut rng = Rng::new(1);
    let mut guard = 0;
    while !sched.idle() {
        sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
        guard += 1;
        assert!(guard < 200);
    }
    let (g, r) = engine.scratch_stats();
    let reg = metrics_registry(&sched, g, r, 0.5);
    let doc = Json::parse(&reg.snapshot_json())
        .expect("metrics snapshot must strict-parse");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("qpruner.serve.metrics.v1")
    );
    let counters = doc.get("counters").unwrap();
    assert_eq!(
        counters.get("serve.requests_completed").and_then(|v| v.as_f64()),
        Some(3.0)
    );
    let gauges = doc.get("gauges").unwrap();
    for key in ["kv.prefix_idle_entries", "kv.prefix_idle_bytes",
                "serve.kv_pages_total", "serve.mean_occupancy"] {
        assert!(
            gauges.get(key).and_then(|v| v.as_f64()).is_some(),
            "gauge {key} missing from snapshot"
        );
    }
    assert!(doc
        .get("histograms")
        .and_then(|h| h.get("serve.latency_ms"))
        .is_some());
}

#[test]
fn exported_artifact_serves_end_to_end_with_lora() {
    // the `export` -> `serve --artifact` path: a pipeline-style
    // artifact (quantized base + LoftQ adapters) boots through the
    // builder and drains a full smoke workload in both LoRA modes
    use qpruner::artifact::{LoraDelta, LoraMode, ModelArtifact,
                            Provenance};
    let store = tiny_store(12);
    let bits = nf4(&store);
    let mut rng = Rng::new(7);
    let prep =
        qpruner::lora::init_loftq(&store, &bits, 1, &mut rng).unwrap();
    let art = ModelArtifact::from_pipeline(
        &prep.base,
        &bits,
        Some(LoraDelta::from_state(&prep.lora)),
        LoraMode::Merge,
        Provenance::default(),
    )
    .unwrap();
    let path = std::env::temp_dir()
        .join("qpruner_serve_it")
        .join("e2e_lora.qpart");
    art.save(&path).unwrap();

    let mut opts = ServeOpts::smoke();
    opts.requests = 32;
    opts.clients = 4;
    for (mode, label) in [(LoraMode::Merge, "merged"),
                          (LoraMode::Adjoin, "adjoined")] {
        let mut rt = runtime();
        let lang = Language::new(store.cfg.vocab, 1);
        let mut metrics = Metrics::new();
        let builder = EngineBuilder::new()
            .artifact_path(path.clone())
            .lora(mode);
        let r = run_workload(&mut rt, builder, &lang, &opts,
                             &mut metrics)
            .expect("artifact workload must drain");
        assert_eq!(r.completed, 32, "{label}");
        assert_eq!(r.lora, label);
        assert_eq!(r.bits_short, bits.short());
        assert_within_budget(&r);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn mixed_precision_configs_serve() {
    let store = tiny_store(8);
    let mut bits = nf4(&store);
    bits.layers[0] = QuantFormat::Int8;
    let mut opts = ServeOpts::smoke();
    opts.requests = 24;
    opts.clients = 4;
    let r = run(&store, &bits, &opts);
    assert_eq!(r.completed, 24);
    assert_eq!(r.bits_short, bits.short());
    // int8 layers shrink the inference footprint less than nf4, so the
    // mixed config's derived budget sits between uniform nf4 and fp16
    let b_mixed = qpruner::serve::resolve_kv_budget_gb(&opts, 0, &bits);
    let b_nf4 =
        qpruner::serve::resolve_kv_budget_gb(&opts, 0, &nf4(&store));
    assert!(b_mixed <= b_nf4 + 1e-12);
}
