//! Table/figure harness integration (smoke fidelity, tiny model).

use qpruner::coordinator::Coordinator;
use qpruner::data::Language;
use qpruner::experiments::{self, Scale};
use qpruner::model::ModelConfig;
use qpruner::runtime::Runtime;
use std::path::PathBuf;
use std::sync::OnceLock;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("QPRUNER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    dir.join("manifest.tsv").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn tiny_store() -> &'static qpruner::model::ParamStore {
    static STORE: OnceLock<qpruner::model::ParamStore> = OnceLock::new();
    STORE.get_or_init(|| {
        let dir = artifacts_dir().expect("artifacts required");
        let rt = Runtime::new(&dir).unwrap();
        let mut coord = Coordinator::new(rt, Language::new(256, 1));
        let cfg = ModelConfig::preset("tiny").unwrap();
        coord.pretrain(&cfg, 48, 3e-3, 78).unwrap().0
    })
}

fn coord() -> Coordinator {
    let dir = artifacts_dir().unwrap();
    Coordinator::new(Runtime::new(&dir).unwrap(), Language::new(256, 1))
}

#[test]
fn table1_generates_all_rows() {
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    let t = experiments::table1(&mut c, &[("tiny-sim", store)], &[20, 50],
                                &Scale::smoke())
        .unwrap();
    // 1 untuned row + 2 rates x 4 methods
    assert_eq!(t.rows.len(), 1 + 2 * 4);
    let md = t.to_markdown();
    assert!(md.contains("LLM-Pruner"));
    assert!(md.contains("QPruner^3"));
    // memory column: every quantized row below the fp16 row per rate
    let mem_col = t.headers.iter().position(|h| h == "Mem(GB)").unwrap();
    let fp16: f64 = t.rows[1][mem_col].parse().unwrap();
    let q1: f64 = t.rows[2][mem_col].parse().unwrap();
    assert!(q1 < fp16);
}

#[test]
fn table2_covers_all_ablations() {
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    let t = experiments::table2_ablation(&mut c, store, &Scale::smoke())
        .unwrap();
    // 2 dtypes + 3 inits + 3 iter counts + 2 importance orders
    assert_eq!(t.rows.len(), 10);
    let md = t.to_markdown();
    for needle in ["nf4", "fp4", "gaussian", "pissa", "iter=4",
                   "element^1", "element^2"] {
        assert!(md.contains(needle), "missing {needle} in table 2");
    }
}

#[test]
fn table3_uses_13b_memory_arch() {
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    let t = experiments::table3_13b(&mut c, store, &Scale::smoke()).unwrap();
    assert_eq!(t.rows.len(), 1 + 3);
    let mem_col = t.headers.iter().position(|h| h == "Mem(GB)").unwrap();
    let fp16: f64 = t.rows[1][mem_col].parse().unwrap();
    // 13B fp16 @50% must be well above the 7B-scale numbers
    assert!(fp16 > 25.0, "13B fp16 memory {fp16}");
}

#[test]
fn fig1_shows_quantized_memory_savings() {
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    let t = experiments::fig1_motivating(&mut c, store, &Scale::smoke())
        .unwrap();
    assert_eq!(t.rows.len(), 3);
    let mem_col = t.headers.len() - 1;
    let lora: f64 = t.rows[0][mem_col].parse().unwrap();
    let loftq: f64 = t.rows[1][mem_col].parse().unwrap();
    let loftq_star: f64 = t.rows[2][mem_col].parse().unwrap();
    assert!(loftq < lora, "Figure 1: LoftQ must use less memory than LoRA");
    assert!(loftq_star < lora);
}

#[test]
fn fig3_produces_pareto_fronts() {
    let _ = require_artifacts!();
    let store = tiny_store();
    let mut c = coord();
    let data = experiments::fig3_pareto(&mut c, store, 50, 6, 3,
                                        &Scale::smoke())
        .unwrap();
    assert_eq!(data.per_task.len(), 7);
    assert!(data.n_evals >= 3);
    for (task, rows) in &data.per_task {
        assert_eq!(rows.len(), data.n_evals, "{task}");
        let front_n = rows.iter().filter(|r| r.3).count();
        assert!(front_n >= 1, "{task}: empty Pareto front");
        // non-dominated check on the flagged points
        for (i, a) in rows.iter().enumerate() {
            if a.3 {
                for (j, b) in rows.iter().enumerate() {
                    if i != j {
                        assert!(
                            !(b.1 > a.1 && b.0 < a.0),
                            "{task}: flagged point {i} strictly dominated by {j}"
                        );
                    }
                }
            }
        }
    }
}
