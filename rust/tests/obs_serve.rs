//! Serving-observability integration: request-lifecycle spans must
//! agree exactly with the scheduler's latency recorders, traced runs
//! must export a Perfetto-loadable trace + parseable JSONL event log
//! + metrics snapshot, and the sampled phase profiler's lap tiling
//! must cover the decode wall it measured. Runs entirely on the
//! native decode backend — no AOT artifacts required.

use qpruner::data::Language;
use qpruner::metrics::Metrics;
use qpruner::model::{ModelConfig, ParamStore};
use qpruner::obs::json::Json;
use qpruner::obs::span::{SpanOutcome, Tracer};
use qpruner::obs::trace_export::validate_trace;
use qpruner::quant::{BitConfig, QuantFormat};
use qpruner::rng::Rng;
use qpruner::runtime::Runtime;
use qpruner::serve::admission::AdmissionPolicy;
use qpruner::serve::engine::{Engine, EngineBuilder};
use qpruner::serve::kv_cache::{KvCachePool, KvLayout, KvPrecision};
use qpruner::serve::scheduler::Scheduler;
use qpruner::serve::{metrics_registry, run_workload, ServeOpts};
use std::time::Duration;

const MAX_SEQ: usize = 24;

fn runtime() -> Runtime {
    let dir = std::env::temp_dir().join("qpruner_obs_serve_t");
    std::fs::create_dir_all(&dir).unwrap();
    Runtime::new(&dir).unwrap()
}

fn setup(n_slots: usize, max_batch: usize)
         -> (Runtime, Engine, Scheduler) {
    let mut rt = runtime();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 21);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    let engine = EngineBuilder::new()
        .store(&store, &bits)
        .max_seq(MAX_SEQ)
        .build(&mut rt)
        .unwrap();
    let pool = KvCachePool::with_slots(
        &cfg,
        engine.attn_dim(),
        n_slots,
        MAX_SEQ,
        KvPrecision::F32,
        1e6,
        n_slots as f64 * 1e6,
    );
    let sched = Scheduler::new(
        pool,
        AdmissionPolicy::new(16, MAX_SEQ),
        max_batch,
        8,
    );
    (rt, engine, sched)
}

fn drain(rt: &mut Runtime, engine: &Engine, sched: &mut Scheduler) {
    let mut rng = Rng::new(99);
    let mut guard = 0;
    while !sched.idle() {
        sched.step(engine, rt, &mut rng, 0.0).unwrap();
        guard += 1;
        assert!(guard < 500, "scheduler failed to drain");
    }
}

/// Staggered two-session workload through one KV slot: the span the
/// tracer records for each session must reproduce the TTFT the
/// scheduler measured — same `Instant`s, so *exactly* equal, not
/// approximately — and the queued session's span must show it waited
/// for the first one's slot.
#[test]
fn staggered_sessions_ttft_equals_span_delta() {
    let (mut rt, engine, mut sched) = setup(1, 1);
    sched.set_tracer(Tracer::new(64));
    let mut rng = Rng::new(9);
    let a = sched.submit(0, vec![3, 4, 5], 6, 7, 0.8).unwrap();
    sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
    let b = sched.submit(1, vec![4, 5], 4, 7, 0.8).unwrap();
    // b now waits in queue: this stagger is queueing delay that must
    // show up in b's TTFT
    std::thread::sleep(Duration::from_millis(2));
    drain(&mut rt, &engine, &mut sched);

    let tracer = sched.take_tracer().expect("tracer installed");
    assert_eq!(tracer.spans().len(), 2);
    assert_eq!(tracer.live_len(), 0);
    for span in tracer.spans() {
        assert_eq!(span.outcome, SpanOutcome::Done);
        let s = sched.table.get(span.id);
        // span instants are the scheduler's own instants
        let table_ttft = s
            .first_token_at
            .unwrap()
            .duration_since(s.submitted_at)
            .as_secs_f64()
            * 1e3;
        let span_ttft = span.ttft_ms().unwrap();
        assert!(
            (span_ttft - table_ttft).abs() < 1e-12,
            "session {}: span ttft {span_ttft} != scheduler ttft \
             {table_ttft}",
            span.id
        );
        assert_eq!(span.tokens, s.generated.len() as u64);
    }
    // with one slot, b can only be admitted after a released it
    let span_a = tracer.spans().iter().find(|s| s.id == a).unwrap();
    let span_b = tracer.spans().iter().find(|s| s.id == b).unwrap();
    assert!(
        span_b.admitted.unwrap() >= span_a.finished,
        "queued session was admitted before the slot was free"
    );
    // b's ttft includes a's whole decode plus the 2 ms stagger
    assert!(span_b.ttft_ms().unwrap() >= 2.0);

    // both TTFTs landed in the histogram; ITL has one sample per
    // token after each session's first, and ordered percentiles
    assert_eq!(sched.ttft.len(), 2);
    assert_eq!(
        sched.itl.len() as u64,
        sched.stats.generated_tokens - sched.stats.completed as u64
    );
    let p = sched.itl.percentiles_ms(&[50.0, 95.0, 99.0]);
    assert!(p.iter().all(|v| v.is_finite() && *v >= 0.0));
    assert!(p[0] <= p[1] && p[1] <= p[2]);
}

/// TTL-evicted sessions close their span with the `Evicted` outcome
/// instead of leaking an open span.
#[test]
fn evicted_sessions_close_their_spans() {
    let (mut rt, engine, mut sched) = setup(1, 1);
    sched.set_tracer(Tracer::new(64));
    sched.submit(0, vec![3, 4], 8, 7, 0.0).unwrap();
    sched.submit(1, vec![5, 6], 3, 7, 0.0).unwrap();
    let mut rng = Rng::new(1);
    // force-stall whoever is active, then run the TTL out
    sched.step(&engine, &mut rt, &mut rng, 1.0).unwrap();
    drain(&mut rt, &engine, &mut sched);
    let tracer = sched.take_tracer().unwrap();
    assert_eq!(tracer.live_len(), 0, "open span leaked");
    assert_eq!(tracer.spans().len(), 2);
    let evicted = tracer
        .spans()
        .iter()
        .filter(|s| s.outcome == SpanOutcome::Evicted)
        .count();
    assert_eq!(evicted, sched.stats.evicted);
    assert_eq!(sched.stats.evicted, 1);
}

/// Full traced workload: the Chrome trace parses and contains complete
/// session spans and decode phase events, every JSONL event line
/// parses, the metrics snapshot carries the serve.* histograms, and
/// the sampled phase laps tile the decode wall they measured.
#[test]
fn traced_workload_exports_valid_artifacts() {
    let dir = std::env::temp_dir().join("qpruner_obs_serve_export");
    std::fs::create_dir_all(&dir).unwrap();
    let trace_path = dir.join("trace.json");
    let events_path = dir.join("events.jsonl");
    let metrics_path = dir.join("metrics.json");

    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 5);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    let mut opts = ServeOpts::smoke();
    opts.clients = 4;
    opts.requests = 24;
    opts.trace_out = Some(trace_path.clone());
    opts.events_out = Some(events_path.clone());
    opts.metrics_out = Some(metrics_path.clone());

    let mut rt = runtime();
    let lang = Language::new(cfg.vocab, 1);
    let mut metrics = Metrics::new();
    let builder = EngineBuilder::new()
        .store(&store, &bits)
        .profile_every(1);
    let r = run_workload(&mut rt, builder, &lang, &opts, &mut metrics)
        .expect("workload must drain");
    assert_eq!(r.completed, opts.requests);

    // ITL surfaced in the report: one sample per post-first token,
    // finite ordered percentiles
    assert_eq!(
        r.itl.len() as u64,
        r.generated_tokens - r.completed as u64
    );
    let p = r.itl.percentiles_ms(&[50.0, 95.0, 99.0]);
    assert!(p.iter().all(|v| v.is_finite()));
    assert!(p[0] <= p[1] && p[1] <= p[2]);

    // phase profiler sampled every step; laps tile the sampled wall
    assert!(r.phases.sampled_steps > 0);
    assert_eq!(r.phases.total_steps, r.phases.sampled_steps);
    let cov = r.phases.coverage();
    assert!(
        cov > 0.90 && cov < 1.01,
        "phase sum must be within 10% of the sampled decode wall \
         (coverage {cov})"
    );
    assert!(r.phases.phase_sum_secs() > 0.0);
    // the report JSON carries the observability fields and parses
    let j = r.to_json("traced_smoke");
    let doc = Json::parse(&j).unwrap();
    assert!(doc.get("itl_p50_ms").unwrap().as_f64().is_some());
    assert!(doc.get("phase_coverage").unwrap().as_f64().is_some());

    // Chrome trace: parseable, >= 1 complete session span, >= 1
    // decode phase event (the CI gate runs the same validation via
    // `qpruner trace-check`)
    let body = std::fs::read_to_string(&trace_path).unwrap();
    let summary = validate_trace(&body).expect("trace must validate");
    assert!(summary.sessions >= opts.requests);
    assert!(summary.complete_sessions >= opts.requests);
    assert!(summary.phase_events >= 1, "no phase events in trace");

    // JSONL event log: every line is one parseable JSON object, and
    // the meta line declares the schema
    let events = std::fs::read_to_string(&events_path).unwrap();
    let mut lines = events.lines();
    let meta = Json::parse(lines.next().unwrap()).unwrap();
    assert_eq!(
        meta.get("schema").and_then(|s| s.as_str()),
        Some("qpruner.serve.events.v1")
    );
    let mut session_lines = 0usize;
    for line in lines {
        let ev = Json::parse(line).unwrap();
        if ev.get("type").and_then(|t| t.as_str()) == Some("session") {
            session_lines += 1;
        }
    }
    assert!(session_lines >= opts.requests);

    // metrics snapshot: stable schema, serve.* histograms populated
    let m = std::fs::read_to_string(&metrics_path).unwrap();
    let m = Json::parse(&m).unwrap();
    assert_eq!(
        m.get("schema").and_then(|s| s.as_str()),
        Some("qpruner.serve.metrics.v1")
    );
    let hists = m.get("histograms").expect("histograms section");
    let lat = hists.get("serve.latency_ms").expect("latency hist");
    assert_eq!(
        lat.get("count").unwrap().as_f64(),
        Some(r.completed as f64)
    );
    assert!(hists.get("serve.itl_ms").is_some());
    let counters = m.get("counters").expect("counters section");
    assert_eq!(
        counters.get("serve.generated_tokens").unwrap().as_f64(),
        Some(r.generated_tokens as f64)
    );
}

/// Prefix-cache accounting end-to-end through the scheduler: N
/// sessions sharing one prompt produce exactly N-1 prefix hits (the
/// first session publishes, every later one resumes), the reused-token
/// count is page-granular, and the modeled bytes-saved line agrees
/// with the `memory.rs` page model exactly.
#[test]
fn shared_prefix_accounting_matches_memory_model() {
    const PAGE_TOKENS: usize = 4;
    const N: usize = 4;
    let mut rt = runtime();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 21);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    let engine = EngineBuilder::new()
        .store(&store, &bits)
        .max_seq(MAX_SEQ)
        .build(&mut rt)
        .unwrap();
    // modeled per-session bytes from the paper-arch accounting, so the
    // pool's per-page model is the exact page fraction of it
    let arch = ModelConfig::paper_7b();
    let modeled_bps = qpruner::memory::kv_bytes_per_session_at(
        &arch, 0, MAX_SEQ, 4.0);
    let pool = KvCachePool::with_slots_layout(
        &cfg, engine.attn_dim(), N, MAX_SEQ, KvPrecision::F32,
        modeled_bps, N as f64 * modeled_bps, KvLayout::Paged,
        PAGE_TOKENS, 12,
    );
    let mut sched = Scheduler::new(
        pool, AdmissionPolicy::new(16, MAX_SEQ), N, 8);

    // one shared 9-token prompt: 2 full pages published, prefill
    // resumes at token 8 for every follower
    let prompt: Vec<i32> = (0..9).collect();
    for c in 0..N {
        sched.submit(c, prompt.clone(), 3, 7, 0.8).unwrap();
    }
    drain(&mut rt, &engine, &mut sched);
    assert_eq!(sched.stats.completed, N);

    let stats = sched.pool.paged_stats();
    assert_eq!(stats.prefix_misses, 1, "first session must miss");
    assert_eq!(stats.prefix_hits, (N - 1) as u64,
               "every follower must hit");
    let reused_per_hit = 2 * PAGE_TOKENS as u64; // both full pages
    assert_eq!(stats.prefix_tokens_reused,
               (N - 1) as u64 * reused_per_hit);
    // the first session prefilled all 9 tokens; followers computed
    // only the single non-cached position
    assert_eq!(sched.stats.prefill_tokens,
               prompt.len() as u64 + (N - 1) as u64);

    // bytes-saved agrees with memory.rs's page model: reused tokens
    // at the modeled per-page cost
    let page_bytes =
        qpruner::memory::kv_page_bytes(&arch, 0, PAGE_TOKENS, 4.0);
    let want = (N - 1) as f64 * 2.0 * page_bytes;
    let got = sched.pool.prefix_bytes_saved_modeled();
    assert!(
        ((got - want) / want).abs() < 1e-9,
        "bytes saved {got} != modeled {want}"
    );

    // after the drain only the published pages stay resident, held by
    // the prefix index for the next wave
    assert_eq!(sched.pool.prefix_index_len(), 2);
    assert_eq!(sched.pool.pages_used(), 2);
}

/// Sub-page prefix accounting end-to-end through the scheduler: N
/// sessions share only a 3-token prefix — *below* page granularity —
/// so every follower's resume is a sub-page hit, the reused-token
/// count is token-granular (not rounded to pages), and the modeled
/// bytes-saved line agrees with `memory::kv_token_bytes` exactly.
#[test]
fn subpage_shared_prefix_accounting_matches_memory_model() {
    const PAGE_TOKENS: usize = 4;
    const N: usize = 4;
    let mut rt = runtime();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 21);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    let engine = EngineBuilder::new()
        .store(&store, &bits)
        .max_seq(MAX_SEQ)
        .build(&mut rt)
        .unwrap();
    let arch = ModelConfig::paper_7b();
    let modeled_bps = qpruner::memory::kv_bytes_per_session_at(
        &arch, 0, MAX_SEQ, 4.0);
    let mut pool = KvCachePool::with_slots_layout(
        &cfg, engine.attn_dim(), N, MAX_SEQ, KvPrecision::F32,
        modeled_bps, N as f64 * modeled_bps, KvLayout::Paged,
        PAGE_TOKENS, 12,
    );
    pool.set_subpage_prefix(true);
    let mut sched = Scheduler::new(
        pool, AdmissionPolicy::new(16, MAX_SEQ), N, 8);

    // the leader's whole 3-token prompt fits inside one page: its
    // publish stores a copied sub-tail entry, never a full page
    let seed_prompt: Vec<i32> = vec![0, 1, 2];
    sched.submit(0, seed_prompt.clone(), 3, 7, 0.8).unwrap();
    let mut rng = Rng::new(3);
    sched.step(&engine, &mut rt, &mut rng, 0.0).unwrap();
    // followers share the 3-token prefix, then diverge immediately
    for c in 1..N {
        let mut p = seed_prompt.clone();
        p.push(10 + c as i32);
        sched.submit(c, p, 3, 7, 0.8).unwrap();
    }
    drain(&mut rt, &engine, &mut sched);
    assert_eq!(sched.stats.completed, N);

    let stats = sched.pool.paged_stats();
    assert_eq!(stats.prefix_misses, 1, "leader must miss");
    assert_eq!(stats.prefix_hits, (N - 1) as u64,
               "every follower must hit below page granularity");
    assert_eq!(stats.prefix_subpage_hits, (N - 1) as u64);
    assert_eq!(stats.prefix_subpage_tokens, 3 * (N - 1) as u64,
               "each follower resumes past the 3 shared tokens");
    assert_eq!(stats.prefix_tokens_reused, 0,
               "no whole page was ever reusable");
    // the leader prefilled its 3 tokens; each follower computed only
    // its single divergent position
    assert_eq!(sched.stats.prefill_tokens,
               seed_prompt.len() as u64 + (N - 1) as u64);

    // bytes-saved agrees with memory.rs's *token* model: sub-page
    // spans save per-token KV, not per-page
    let tok_bytes = qpruner::memory::kv_token_bytes(&arch, 0, 4.0);
    let want = 3.0 * (N - 1) as f64 * tok_bytes;
    let got = sched.pool.prefix_bytes_saved_modeled();
    assert!(
        ((got - want) / want).abs() < 1e-9,
        "bytes saved {got} != modeled {want}"
    );
}

/// The drained-state gauge contract behind the server's shutdown
/// ordering: `kv.prefix_idle_*` and `kv.frag_pages` are recomputed
/// from live pool state at snapshot time, so a snapshot taken after
/// `clear_prefix_index` reports the drained pool (and a snapshot
/// taken before would not) — the server clears *then* snapshots.
#[test]
fn metrics_snapshot_after_prefix_clear_reports_drained_gauges() {
    const PAGE_TOKENS: usize = 4;
    let mut rt = runtime();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 21);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    let engine = EngineBuilder::new()
        .store(&store, &bits)
        .max_seq(MAX_SEQ)
        .build(&mut rt)
        .unwrap();
    let pool = KvCachePool::with_slots_layout(
        &cfg, engine.attn_dim(), 2, MAX_SEQ, KvPrecision::F32,
        1e6, 2e6, KvLayout::Paged, PAGE_TOKENS, 12,
    );
    let mut sched = Scheduler::new(
        pool, AdmissionPolicy::new(16, MAX_SEQ), 2, 8);
    // one 9-token session publishes two full prefix pages that are
    // never re-hit: after the drain they are exactly the idle set
    let prompt: Vec<i32> = (0..9).collect();
    sched.submit(0, prompt, 2, 7, 0.8).unwrap();
    drain(&mut rt, &engine, &mut sched);
    assert_eq!(sched.stats.completed, 1);
    assert_eq!(sched.pool.prefix_index_len(), 2);

    let gauge = |snap: &str, name: &str| -> f64 {
        Json::parse(snap)
            .unwrap()
            .get("gauges")
            .and_then(|g| g.get(name))
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| panic!("gauge {name} missing"))
    };
    let before =
        metrics_registry(&sched, 0, 0, 1.0).snapshot_json();
    assert_eq!(gauge(&before, "kv.prefix_idle_entries"), 2.0);
    assert!(gauge(&before, "kv.prefix_idle_bytes") > 0.0);
    assert_eq!(gauge(&before, "kv.frag_pages"), 2.0,
               "idle index pages are the only fragmentation left");

    sched.pool.clear_prefix_index();
    assert_eq!(sched.pool.pages_used(), 0);
    let after =
        metrics_registry(&sched, 0, 0, 1.0).snapshot_json();
    assert_eq!(gauge(&after, "kv.prefix_idle_entries"), 0.0);
    assert_eq!(gauge(&after, "kv.prefix_idle_bytes"), 0.0);
    assert_eq!(gauge(&after, "kv.frag_pages"), 0.0,
               "post-clear snapshot must republish drained gauges");
    // counters are cumulative and must survive the clear untouched
    let counter = |snap: &str, name: &str| -> f64 {
        Json::parse(snap)
            .unwrap()
            .get("counters")
            .and_then(|c| c.get(name))
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| panic!("counter {name} missing"))
    };
    assert_eq!(counter(&after, "serve.prefix_misses"),
               counter(&before, "serve.prefix_misses"));
}

/// Copy-on-write divergence safety at the pool level: a session that
/// rewrites positions covered by shared pages gets private copies, and
/// neither the co-resident session nor the prefix index observes the
/// new values.
#[test]
fn cow_divergence_never_mutates_shared_pages() {
    const PAGE_TOKENS: usize = 4;
    let cfg = ModelConfig::preset("tiny").unwrap();
    let mut pool = KvCachePool::with_slots_layout(
        &cfg, 8, 3, MAX_SEQ, KvPrecision::F32, 1.0, 3.0,
        KvLayout::Paged, PAGE_TOKENS, 12,
    );
    let prompt: Vec<i32> = (100..109).collect();
    let write = |pool: &mut KvCachePool, id: usize, t: usize,
                 val: f32| {
        let slot = pool.slot_mut(id);
        let k = vec![val; 8];
        let v = vec![-val; 8];
        for layer in 0..cfg.n_layers {
            slot.write(layer, t, &k, &v);
        }
        slot.advance_to(t + 1);
    };

    let a = pool.admit(&prompt, true).unwrap();
    assert_eq!(a.cached_tokens, 0);
    pool.ensure_capacity(a.slot, prompt.len()).unwrap();
    for t in 0..prompt.len() {
        write(&mut pool, a.slot, t, t as f32 + 1.0);
    }
    pool.publish_prefix(a.slot, &prompt);
    assert_eq!(pool.prefix_index_len(), 2);

    let b = pool.admit(&prompt, true).unwrap();
    assert_eq!(b.cached_tokens, 2 * PAGE_TOKENS);
    // B shares pages 0 and 1 with A and the index (strong count 3)
    for (idx, &(_, strong)) in
        pool.slot_page_refs(b.slot).iter().enumerate()
    {
        assert_eq!(strong, 3, "page {idx} should be 3-way shared");
    }

    // B diverges from token 4 on: page 1 must be privatized, page 0
    // stays shared
    pool.slot_mut(b.slot).advance_to(PAGE_TOKENS);
    pool.ensure_capacity(b.slot, prompt.len()).unwrap();
    for t in PAGE_TOKENS..prompt.len() {
        write(&mut pool, b.slot, t, 1000.0 + t as f32);
    }
    assert!(pool.paged_stats().cow_copies >= 1, "CoW did not fire");
    let b_refs = pool.slot_page_refs(b.slot);
    assert_eq!(b_refs[0].1, 3, "page 0 must stay shared");
    assert_eq!(b_refs[1].1, 1, "page 1 must be private after CoW");

    // A's values (and therefore the published pages) are untouched;
    // B reads its own divergent copy
    for t in PAGE_TOKENS..2 * PAGE_TOKENS {
        assert_eq!(pool.slot(a.slot).k_at(0, t)[0], t as f32 + 1.0,
                   "shared page mutated under CoW");
        assert_eq!(pool.slot(b.slot).k_at(0, t)[0], 1000.0 + t as f32);
    }
    // a third session still reuses the *original* prefix pages
    let c = pool.admit(&prompt, true).unwrap();
    assert_eq!(c.cached_tokens, 2 * PAGE_TOKENS);
    for t in 0..2 * PAGE_TOKENS {
        assert_eq!(pool.slot(c.slot).k_at(0, t)[0], t as f32 + 1.0);
    }
}

/// An untraced run must not pay for tracing: no trace files, no raw
/// events retained, and the default sampled profiler still fills the
/// report's phase table.
#[test]
fn untraced_run_keeps_default_profiling_cheap() {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 6);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    let mut opts = ServeOpts::smoke();
    opts.clients = 2;
    opts.requests = 8;
    let mut rt = runtime();
    let lang = Language::new(cfg.vocab, 1);
    let mut metrics = Metrics::new();
    let builder =
        EngineBuilder::new().store(&store, &bits);
    let r = run_workload(&mut rt, builder, &lang, &opts, &mut metrics)
        .unwrap();
    assert_eq!(r.completed, 8);
    // default sampling (every 4th step) still produced a breakdown
    assert!(r.phases.sampled_steps > 0);
    assert!(r.phases.sampled_steps <= r.phases.total_steps);
    assert!(r.phases.phase_sum_secs() > 0.0);
    // sampled subset still tiles its own wall
    let cov = r.phases.coverage();
    assert!(cov > 0.90 && cov < 1.01, "coverage {cov}");
}
