//! Differential parity suite: the batched fused-kernel decode path
//! (`Engine::step_batch` — quantized-residency GEMMs consuming
//! nf4/fp4/int8 codes directly, output rows and sessions split across
//! the `parallel.rs` thread pool) must reproduce the per-session
//! matvec reference path (`Engine::prefill_reference` /
//! `Engine::decode_reference`) to |delta| < 1e-4 on every logit
//! (tighter than the 1e-3 the acceptance criteria demand), for
//! batches of 1, 3 and 8 sessions with staggered prompt lengths,
//! across nf4, int8 and fp16 weight formats, × 1/2/8 pool lanes,
//! × merged/adjoined LoRA.
//!
//! The two paths share accumulation order by construction (the fused
//! kernels decode with the dequantize expressions and dot
//! left-to-right exactly like the per-row matvec), so in debug builds
//! the agreement is bitwise; the 1e-4 envelope exists to catch
//! fast-math-ish divergence under `--release` (CI runs this suite in
//! both profiles). On top of the envelope,
//! `decode_is_bit_identical_across_thread_counts` pins the parallel
//! runtime's determinism contract: the static row partition makes
//! 1 vs 2 vs 8 workers produce *bit-identical* logits.

use qpruner::artifact::{LoraDelta, LoraMode, ModelArtifact,
                        Provenance};
use qpruner::model::{ModelConfig, ParamStore};
use qpruner::quant::{BitConfig, QuantFormat};
use qpruner::runtime::Runtime;
use qpruner::serve::engine::{BatchReq, Engine, EngineBuilder};
use qpruner::serve::kv_cache::{KvCachePool, KvLayout, KvPrecision};

const MAX_SEQ: usize = 24;
const DECODE_STEPS: usize = 6;
/// staggered prompt lengths; batches of size n take the first n
const PROMPT_LENS: [usize; 8] = [3, 5, 8, 4, 6, 9, 3, 7];

fn parity_runtime() -> Runtime {
    let dir = std::env::temp_dir().join("qpruner_parity_decode");
    std::fs::create_dir_all(&dir).unwrap();
    Runtime::new(&dir).unwrap()
}

fn engine_for_t(fmt: QuantFormat, threads: Option<usize>)
                -> (Runtime, Engine, ModelConfig) {
    let mut rt = parity_runtime();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 1234);
    let bits = BitConfig::uniform(cfg.n_layers, fmt);
    let mut builder = EngineBuilder::new()
        .store(&store, &bits)
        .max_seq(MAX_SEQ);
    if let Some(t) = threads {
        builder = builder.threads(t);
    }
    let engine = builder.build(&mut rt).unwrap();
    assert!(engine.is_native(), "parity needs the native backend");
    (rt, engine, cfg)
}

fn engine_for(fmt: QuantFormat) -> (Runtime, Engine, ModelConfig) {
    engine_for_t(fmt, None)
}

/// Engine with trained-looking (LoftQ) LoRA deltas deployed from an
/// artifact in the given mode — the merged-LoRA-GEMMs-vs-reference
/// stake of the ModelArtifact redesign.
fn lora_engine_for_t(fmt: QuantFormat, mode: LoraMode,
                     threads: Option<usize>)
                     -> (Runtime, Engine, ModelConfig) {
    let mut rt = parity_runtime();
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 1234);
    let bits = BitConfig::uniform(cfg.n_layers, fmt);
    let mut rng = qpruner::rng::Rng::new(55);
    let prep =
        qpruner::lora::init_loftq(&store, &bits, 1, &mut rng).unwrap();
    let art = ModelArtifact::from_pipeline(
        &prep.base,
        &bits,
        Some(LoraDelta::from_state(&prep.lora)),
        mode,
        Provenance::default(),
    )
    .unwrap();
    let mut builder = EngineBuilder::new()
        .artifact(art)
        .max_seq(MAX_SEQ);
    if let Some(t) = threads {
        builder = builder.threads(t);
    }
    let engine = builder.build(&mut rt).unwrap();
    assert!(engine.is_native(), "parity needs the native backend");
    (rt, engine, cfg)
}

fn lora_engine_for(fmt: QuantFormat, mode: LoraMode)
                   -> (Runtime, Engine, ModelConfig) {
    lora_engine_for_t(fmt, mode, None)
}

fn pool_for(engine: &Engine, cfg: &ModelConfig, n: usize,
            precision: KvPrecision) -> KvCachePool {
    KvCachePool::with_slots(cfg, engine.attn_dim(), n, MAX_SEQ,
                            precision, 1.0, n as f64)
}

/// Paged pool with enough pages for `n` full-length sessions.
fn paged_pool_for(engine: &Engine, cfg: &ModelConfig, n: usize,
                  precision: KvPrecision, page_tokens: usize)
                  -> KvCachePool {
    let n_pages = n * MAX_SEQ.div_ceil(page_tokens);
    KvCachePool::with_slots_layout(cfg, engine.attn_dim(), n, MAX_SEQ,
                                   precision, 1.0, n as f64,
                                   KvLayout::Paged, page_tokens,
                                   n_pages)
}

/// Deterministic prompt / generated-token streams (parity feeds fixed
/// tokens rather than sampling, so both paths see identical inputs).
fn prompt_for(session: usize, vocab: usize) -> Vec<i32> {
    let len = PROMPT_LENS[session % PROMPT_LENS.len()];
    (0..len)
        .map(|j| ((3 + session * 31 + j * 7) % vocab) as i32)
        .collect()
}

fn gen_token(session: usize, step: usize, vocab: usize) -> i32 {
    ((11 + session * 13 + step * 5) % vocab) as i32
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Run `batch` concurrent sessions through both decode paths at the
/// given KV precision and assert per-step logit parity.
fn assert_parity(fmt: QuantFormat, batch: usize,
                 precision: KvPrecision) {
    let (rt, engine, cfg) = engine_for(fmt);
    assert_parity_engine(rt, engine, cfg, batch, precision,
                         &format!("{fmt:?}"));
}

/// Core differential check against a prepared engine (base or
/// LoRA-deployed): batched GEMM decode vs the per-session reference.
fn assert_parity_engine(mut rt: Runtime, engine: Engine,
                        cfg: ModelConfig, batch: usize,
                        precision: KvPrecision, tag: &str) {
    let fmt = tag;
    let vocab = cfg.vocab;

    // --- reference: per-session matvec decode ---
    let mut ref_pool = pool_for(&engine, &cfg, batch, precision);
    let mut ref_logits: Vec<Vec<Vec<f32>>> = Vec::new(); // [step][session]
    let mut ref_prefill: Vec<Vec<f32>> = Vec::new();
    let ref_ids: Vec<usize> =
        (0..batch).map(|_| ref_pool.alloc().unwrap()).collect();
    for (s, &id) in ref_ids.iter().enumerate() {
        let prompt = prompt_for(s, vocab);
        ref_prefill.push(
            engine
                .prefill_reference(ref_pool.slot_mut(id), &prompt)
                .unwrap(),
        );
    }
    for step in 0..DECODE_STEPS {
        let mut per_session = Vec::new();
        for (s, &id) in ref_ids.iter().enumerate() {
            let pos = prompt_for(s, vocab).len() + step;
            let tok = gen_token(s, step, vocab);
            per_session.push(
                engine
                    .decode_reference(ref_pool.slot_mut(id), pos, tok)
                    .unwrap(),
            );
        }
        ref_logits.push(per_session);
    }

    // --- batched GEMM path ---
    let mut pool = pool_for(&engine, &cfg, batch, precision);
    let ids: Vec<usize> =
        (0..batch).map(|_| pool.alloc().unwrap()).collect();
    for (s, &id) in ids.iter().enumerate() {
        let prompt = prompt_for(s, vocab);
        let logits =
            engine.prefill(&mut rt, pool.slot_mut(id), &prompt).unwrap();
        let d = max_abs_diff(&logits, &ref_prefill[s]);
        assert!(
            d < 1e-4,
            "{fmt:?} b{batch} {precision:?}: prefill session {s} \
             diverged by {d}"
        );
    }
    for step in 0..DECODE_STEPS {
        let reqs: Vec<BatchReq> = ids
            .iter()
            .enumerate()
            .map(|(s, &id)| BatchReq {
                slot: id,
                pos: prompt_for(s, vocab).len() + step,
                token: gen_token(s, step, vocab),
            })
            .collect();
        let mut got: Vec<Vec<f32>> = vec![Vec::new(); batch];
        engine
            .step_batch(&mut pool, &reqs, |i, logits| {
                got[i] = logits.to_vec();
            })
            .unwrap();
        for (s, logits) in got.iter().enumerate() {
            let d = max_abs_diff(logits, &ref_logits[step][s]);
            assert!(
                d < 1e-4,
                "{fmt:?} b{batch} {precision:?}: step {step} session \
                 {s} diverged by {d}"
            );
        }
    }
}

#[test]
fn parity_nf4_weights_batches_1_3_8() {
    for batch in [1usize, 3, 8] {
        assert_parity(QuantFormat::Nf4, batch, KvPrecision::F32);
    }
}

#[test]
fn parity_int8_weights_batches_1_3_8() {
    for batch in [1usize, 3, 8] {
        assert_parity(QuantFormat::Int8, batch, KvPrecision::F32);
    }
}

#[test]
fn parity_fp16_weights_batches_1_3_8() {
    for batch in [1usize, 3, 8] {
        assert_parity(QuantFormat::Fp16, batch, KvPrecision::F32);
    }
}

#[test]
fn parity_merged_lora_batches_1_3_8() {
    // merged-LoRA deployment: s*BA folded into the quantized base at
    // build — the fused GEMM decode must still match the per-session
    // reference exactly
    for batch in [1usize, 3, 8] {
        let (rt, engine, cfg) =
            lora_engine_for(QuantFormat::Nf4, LoraMode::Merge);
        assert_parity_engine(rt, engine, cfg, batch,
                             KvPrecision::F32, "nf4+merged");
    }
}

#[test]
fn parity_adjoined_lora_batches_1_3_8() {
    // adjoined deployment: the low-rank side path runs inside both
    // the batched and the reference decode with shared accumulation
    // order, so parity must hold at the same 1e-4 envelope
    for batch in [1usize, 3, 8] {
        let (rt, engine, cfg) =
            lora_engine_for(QuantFormat::Nf4, LoraMode::Adjoin);
        assert_parity_engine(rt, engine, cfg, batch,
                             KvPrecision::F32, "nf4+adjoined");
    }
}

#[test]
fn parity_lora_int8_weights_and_int8_kv() {
    for mode in [LoraMode::Merge, LoraMode::Adjoin] {
        let (rt, engine, cfg) =
            lora_engine_for(QuantFormat::Int8, mode);
        assert_parity_engine(rt, engine, cfg, 3, KvPrecision::Int8,
                             "int8+lora");
    }
}

#[test]
fn parity_holds_with_int8_kv_cache() {
    // both paths read/write the same quantized KV representation, so
    // the GEMM restructuring must not add error on top of it
    for batch in [1usize, 3] {
        assert_parity(QuantFormat::Nf4, batch, KvPrecision::Int8);
    }
}

/// The acceptance matrix of the fused-kernel PR: every quantized
/// residency format × 1/2/8 pool lanes holds the parity envelope
/// against the per-session reference oracle.
#[test]
fn parity_quantized_kernels_across_thread_counts() {
    for fmt in [QuantFormat::Nf4, QuantFormat::Int8,
                QuantFormat::Fp16] {
        for threads in [1usize, 2, 8] {
            let (rt, engine, cfg) = engine_for_t(fmt, Some(threads));
            assert_parity_engine(
                rt, engine, cfg, 3, KvPrecision::F32,
                &format!("{fmt:?}+t{threads}"),
            );
        }
    }
}

/// Merged (re-quantized fold) and adjoined LoRA deployments hold the
/// same envelope at every lane count.
#[test]
fn parity_lora_modes_across_thread_counts() {
    for mode in [LoraMode::Merge, LoraMode::Adjoin] {
        for threads in [1usize, 2, 8] {
            let (rt, engine, cfg) =
                lora_engine_for_t(QuantFormat::Nf4, mode,
                                  Some(threads));
            assert_parity_engine(
                rt, engine, cfg, 3, KvPrecision::F32,
                &format!("nf4+{mode:?}+t{threads}"),
            );
        }
    }
}

/// Determinism contract of `parallel.rs`: the static partition plus
/// fixed per-element accumulation order makes different worker counts
/// produce **bit-identical** logits — not merely close ones.
#[test]
fn decode_is_bit_identical_across_thread_counts() {
    let batch = 3usize;
    let mut baseline: Option<Vec<Vec<f32>>> = None;
    for threads in [1usize, 2, 8] {
        let (mut rt, engine, cfg) =
            engine_for_t(QuantFormat::Nf4, Some(threads));
        let vocab = cfg.vocab;
        let mut pool = pool_for(&engine, &cfg, batch,
                                KvPrecision::F32);
        let ids: Vec<usize> =
            (0..batch).map(|_| pool.alloc().unwrap()).collect();
        let mut all: Vec<Vec<f32>> = Vec::new();
        for (s, &id) in ids.iter().enumerate() {
            let prompt = prompt_for(s, vocab);
            all.push(
                engine
                    .prefill(&mut rt, pool.slot_mut(id), &prompt)
                    .unwrap(),
            );
        }
        for step in 0..DECODE_STEPS {
            let reqs: Vec<BatchReq> = ids
                .iter()
                .enumerate()
                .map(|(s, &id)| BatchReq {
                    slot: id,
                    pos: prompt_for(s, vocab).len() + step,
                    token: gen_token(s, step, vocab),
                })
                .collect();
            let mut got: Vec<Vec<f32>> =
                vec![Vec::new(); batch];
            engine
                .step_batch(&mut pool, &reqs, |i, l| {
                    got[i] = l.to_vec();
                })
                .unwrap();
            all.extend(got);
        }
        match &baseline {
            None => baseline = Some(all),
            Some(b) => assert_eq!(
                &all, b,
                "{threads} workers changed the logits"
            ),
        }
    }
}

/// Observability must not perturb the math: an engine profiling every
/// decode step (with raw phase-event capture on) produces logits
/// bit-identical to an unprofiled engine, at 1/2/8 pool lanes. The
/// instrumentation only reads clocks between phases — it never touches
/// the accumulation order the determinism contract depends on.
#[test]
fn profiling_does_not_perturb_logits() {
    let batch = 3usize;
    let run = |threads: usize, every: u32| -> Vec<Vec<f32>> {
        let mut rt = parity_runtime();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 1234);
        let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
        let engine = EngineBuilder::new()
            .store(&store, &bits)
            .max_seq(MAX_SEQ)
            .threads(threads)
            .profile_every(every)
            .profile_events(every != 0)
            .build(&mut rt)
            .unwrap();
        let vocab = cfg.vocab;
        let mut pool = pool_for(&engine, &cfg, batch,
                                KvPrecision::F32);
        let ids: Vec<usize> =
            (0..batch).map(|_| pool.alloc().unwrap()).collect();
        let mut all: Vec<Vec<f32>> = Vec::new();
        for (s, &id) in ids.iter().enumerate() {
            let prompt = prompt_for(s, vocab);
            all.push(
                engine
                    .prefill(&mut rt, pool.slot_mut(id), &prompt)
                    .unwrap(),
            );
        }
        for step in 0..DECODE_STEPS {
            let reqs: Vec<BatchReq> = ids
                .iter()
                .enumerate()
                .map(|(s, &id)| BatchReq {
                    slot: id,
                    pos: prompt_for(s, vocab).len() + step,
                    token: gen_token(s, step, vocab),
                })
                .collect();
            let mut got: Vec<Vec<f32>> = vec![Vec::new(); batch];
            engine
                .step_batch(&mut pool, &reqs, |i, l| {
                    got[i] = l.to_vec();
                })
                .unwrap();
            all.extend(got);
        }
        if every == 1 {
            // the profiled run must actually have profiled something
            let snap = engine.phase_snapshot();
            assert!(snap.sampled_steps > 0, "profiler sampled nothing");
            assert!(snap.phase_sum_secs() > 0.0);
        }
        all
    };
    let baseline = run(1, 0);
    for threads in [1usize, 2, 8] {
        for every in [0u32, 1, 4] {
            assert_eq!(
                run(threads, every),
                baseline,
                "t{threads} profile_every={every} changed the logits"
            );
        }
    }
}

/// Drive prefill + DECODE_STEPS fused steps on a prepared pool and
/// collect every logit vector (prefill first, then step-major).
fn run_layout(rt: &mut Runtime, engine: &Engine, vocab: usize,
              batch: usize, pool: &mut KvCachePool) -> Vec<Vec<f32>> {
    let ids: Vec<usize> =
        (0..batch).map(|_| pool.alloc().unwrap()).collect();
    let mut all: Vec<Vec<f32>> = Vec::new();
    for (s, &id) in ids.iter().enumerate() {
        let prompt = prompt_for(s, vocab);
        // map the prompt's pages before writing (bounds-check no-op on
        // the slab layout; the scheduler does the same before prefill)
        pool.ensure_capacity(id, prompt.len()).unwrap();
        all.push(
            engine.prefill(rt, pool.slot_mut(id), &prompt).unwrap(),
        );
    }
    for step in 0..DECODE_STEPS {
        let reqs: Vec<BatchReq> = ids
            .iter()
            .enumerate()
            .map(|(s, &id)| BatchReq {
                slot: id,
                pos: prompt_for(s, vocab).len() + step,
                token: gen_token(s, step, vocab),
            })
            .collect();
        let mut got: Vec<Vec<f32>> = vec![Vec::new(); batch];
        engine
            .step_batch(pool, &reqs, |i, l| {
                got[i] = l.to_vec();
            })
            .unwrap();
        all.extend(got);
    }
    all
}

/// The paged-KV acceptance matrix: the paged layout must produce
/// **bit-identical** logits to the slab layout — not merely close —
/// for batches 1/3/8 × f32/int8 KV × 1/2/8 pool lanes. `page_tokens`
/// = 5 makes the staggered PROMPT_LENS straddle page boundaries
/// (lengths 4/5/6 = page−1 / page / page+1), so row addressing across
/// the page seam is exercised on every run. Bit-identity is structural
/// (both layouts write/read through the same KvStore row kernels);
/// this test pins it.
#[test]
fn paged_decode_is_bit_identical_to_slab() {
    const PAGE_TOKENS: usize = 5;
    for threads in [1usize, 2, 8] {
        for precision in [KvPrecision::F32, KvPrecision::Int8] {
            let (mut rt, engine, cfg) =
                engine_for_t(QuantFormat::Nf4, Some(threads));
            let vocab = cfg.vocab;
            for batch in [1usize, 3, 8] {
                let mut slab =
                    pool_for(&engine, &cfg, batch, precision);
                let want = run_layout(&mut rt, &engine, vocab, batch,
                                      &mut slab);
                let mut paged = paged_pool_for(&engine, &cfg, batch,
                                               precision, PAGE_TOKENS);
                let got = run_layout(&mut rt, &engine, vocab, batch,
                                     &mut paged);
                assert_eq!(
                    got, want,
                    "paged layout changed the logits (t{threads} \
                     {precision:?} b{batch})"
                );
            }
        }
    }
}

/// Prefix reuse must not change the math either: a session admitted
/// with cached prefix pages resumes prefill mid-prompt, and its
/// prefill logits and every subsequent decode step are bit-identical
/// to a cold session with the same prompt.
#[test]
fn prefix_reuse_resume_is_bit_identical_to_cold_prefill() {
    const PAGE_TOKENS: usize = 4;
    let (mut rt, engine, cfg) = engine_for(QuantFormat::Nf4);
    let vocab = cfg.vocab;
    // 9 tokens = 2 full pages + 1: reuse spans 8 tokens, prefill
    // resumes at position 8
    let prompt: Vec<i32> =
        (0..9).map(|j| ((3 + j * 7) % vocab) as i32).collect();
    for precision in [KvPrecision::F32, KvPrecision::Int8] {
        let mut pool =
            paged_pool_for(&engine, &cfg, 2, precision, PAGE_TOKENS);

        let a = pool.admit(&prompt, true).unwrap();
        assert_eq!(a.cached_tokens, 0, "cold admit found a prefix");
        pool.ensure_capacity(a.slot, prompt.len()).unwrap();
        let cold = engine
            .prefill(&mut rt, pool.slot_mut(a.slot), &prompt)
            .unwrap();
        pool.publish_prefix(a.slot, &prompt);

        let b = pool.admit(&prompt, true).unwrap();
        assert_eq!(b.cached_tokens, 2 * PAGE_TOKENS,
                   "second admit must map both published pages");
        pool.ensure_capacity(b.slot, prompt.len()).unwrap();
        let resumed = engine
            .prefill(&mut rt, pool.slot_mut(b.slot), &prompt)
            .unwrap();
        assert_eq!(resumed, cold,
                   "resumed prefill diverged ({precision:?})");

        // decode both sessions in one fused batch; logits per step
        // must match each other exactly (identical history)
        for step in 0..DECODE_STEPS {
            let tok = gen_token(0, step, vocab);
            let reqs = [
                BatchReq { slot: a.slot, pos: prompt.len() + step,
                           token: tok },
                BatchReq { slot: b.slot, pos: prompt.len() + step,
                           token: tok },
            ];
            let mut got: Vec<Vec<f32>> = vec![Vec::new(); 2];
            engine
                .step_batch(&mut pool, &reqs, |i, l| {
                    got[i] = l.to_vec();
                })
                .unwrap();
            assert_eq!(got[0], got[1],
                       "shared-prefix sessions diverged at step \
                        {step} ({precision:?})");
        }
    }
}

/// Paged twin of `run_layout` that stresses compaction mid-flight.
/// Prompts are published into the prefix index; the longest session
/// is then rewound *into* its published (shared) page, leaving a
/// shared partial tail plus a dead page. A compact pass must migrate
/// the tail into a private dense page (never writing the shared
/// original) and reclaim the dead page; prefill then re-derives the
/// rolled-back span from the migrated rows. One more compact pass
/// runs between every decode step. The collected logits (same order
/// as `run_layout`: prefill per session, then step-major) must be
/// bit-identical to the slab run.
fn run_layout_compacting(rt: &mut Runtime, engine: &Engine,
                         vocab: usize, batch: usize,
                         pool: &mut KvCachePool, page_tokens: usize)
                         -> Vec<Vec<f32>> {
    let ids: Vec<usize> =
        (0..batch).map(|_| pool.alloc().unwrap()).collect();
    let mut all: Vec<Vec<f32>> = Vec::new();
    for (s, &id) in ids.iter().enumerate() {
        let prompt = prompt_for(s, vocab);
        pool.ensure_capacity(id, prompt.len()).unwrap();
        all.push(
            engine.prefill(rt, pool.slot_mut(id), &prompt).unwrap(),
        );
        pool.publish_prefix(id, &prompt);
    }
    // roll the longest session back into its first (published, hence
    // shared) page: its tail becomes a shared partial page and its
    // later pages go dead
    let vs = (0..batch)
        .max_by_key(|&s| prompt_for(s, vocab).len())
        .expect("non-empty batch");
    let vid = ids[vs];
    let vprompt = prompt_for(vs, vocab);
    assert!(vprompt.len() > page_tokens,
            "victim prompt must span more than one page");
    pool.slot_mut(vid).rewind(page_tokens - 1);
    let pairs: Vec<(usize, bool)> =
        ids.iter().map(|&id| (id, false)).collect();
    let rep = pool.compact(&pairs);
    assert!(rep.migrated >= 1,
            "rewound shared tail was not migrated");
    assert!(rep.pages_reclaimed >= 1, "dead page was not reclaimed");
    // resume-prefill re-derives the rolled-back span on top of the
    // migrated rows; the full-prompt logits must come out unchanged
    pool.ensure_capacity(vid, vprompt.len()).unwrap();
    let again = engine
        .prefill(rt, pool.slot_mut(vid), &vprompt)
        .unwrap();
    assert_eq!(again, all[vs],
               "prefill diverged after tail migration");
    for step in 0..DECODE_STEPS {
        let reqs: Vec<BatchReq> = ids
            .iter()
            .enumerate()
            .map(|(s, &id)| BatchReq {
                slot: id,
                pos: prompt_for(s, vocab).len() + step,
                token: gen_token(s, step, vocab),
            })
            .collect();
        let mut got: Vec<Vec<f32>> = vec![Vec::new(); batch];
        engine
            .step_batch(pool, &reqs, |i, l| {
                got[i] = l.to_vec();
            })
            .unwrap();
        all.extend(got);
        pool.compact(&pairs);
    }
    all
}

/// The compaction axis of the paged acceptance matrix: with a compact
/// pass forced between every decode step — including one real tail
/// migration and a dead-page reclaim before decode begins — the paged
/// layout stays **bit-identical** to the slab oracle, for f32/int8 KV
/// × 1/8 pool lanes, with the staggered PROMPT_LENS straddling page
/// seams (page_tokens = 5 puts lengths 3/5/8 at page−2 / page /
/// page+3).
#[test]
fn paged_compaction_between_steps_is_bit_identical_to_slab() {
    const PAGE_TOKENS: usize = 5;
    let batch = 3usize;
    for threads in [1usize, 8] {
        for precision in [KvPrecision::F32, KvPrecision::Int8] {
            let (mut rt, engine, cfg) =
                engine_for_t(QuantFormat::Nf4, Some(threads));
            let vocab = cfg.vocab;
            let mut slab = pool_for(&engine, &cfg, batch, precision);
            let want =
                run_layout(&mut rt, &engine, vocab, batch, &mut slab);
            let mut paged = paged_pool_for(&engine, &cfg, batch,
                                           precision, PAGE_TOKENS);
            let got = run_layout_compacting(&mut rt, &engine, vocab,
                                            batch, &mut paged,
                                            PAGE_TOKENS);
            assert_eq!(
                got, want,
                "compaction changed the logits (t{threads} \
                 {precision:?})"
            );
            let stats = paged.paged_stats();
            assert_eq!(stats.compactions, DECODE_STEPS as u64 + 1,
                       "one pass after the rewind plus one per step");
            assert!(stats.pages_reclaimed >= 1);
        }
    }
}

/// Sub-page prefix matching must not change the math: a session whose
/// admit maps a verified token span *inside* the first differing page
/// (a shared prefix below one page, and one ending mid-page) resumes
/// prefill from that offset with logits — and every subsequent decode
/// step — bit-identical to a cold prefill of the same prompt.
#[test]
fn subpage_prefix_resume_is_bit_identical_to_cold_prefill() {
    const PAGE_TOKENS: usize = 4;
    let (mut rt, engine, cfg) = engine_for(QuantFormat::Nf4);
    let vocab = cfg.vocab;
    // 6 tokens = 1 full page + a 2-token tail: publishing adds a
    // full-page entry and an index-owned sub-page tail copy
    let seed: Vec<i32> =
        (0..6).map(|j| ((3 + j * 7) % vocab) as i32).collect();
    // diverges at token 2: shares a 2-token span below one page
    let mut below: Vec<i32> = seed[..2].to_vec();
    below.extend((2..5).map(|j| (seed[j] + 1 + j as i32)
                            % vocab as i32));
    // shares all 6 seed tokens: the match ends mid-page at offset 2
    // of the second page
    let mut mid: Vec<i32> = seed.clone();
    mid.extend((0..3).map(|j| ((40 + j * 9) % vocab) as i32));
    for precision in [KvPrecision::F32, KvPrecision::Int8] {
        let mut pool =
            paged_pool_for(&engine, &cfg, 3, precision, PAGE_TOKENS);
        pool.set_subpage_prefix(true);

        let a = pool.admit(&seed, true).unwrap();
        assert_eq!(a.cached_tokens, 0, "seed admit found a prefix");
        pool.ensure_capacity(a.slot, seed.len()).unwrap();
        engine
            .prefill(&mut rt, pool.slot_mut(a.slot), &seed)
            .unwrap();
        pool.publish_prefix(a.slot, &seed);

        for (prompt, want_cached) in
            [(&below, 2usize), (&mid, 6usize)]
        {
            let cold = pool.admit(prompt, false).unwrap();
            assert_eq!(cold.cached_tokens, 0);
            pool.ensure_capacity(cold.slot, prompt.len()).unwrap();
            let want = engine
                .prefill(&mut rt, pool.slot_mut(cold.slot), prompt)
                .unwrap();
            let warm = pool.admit(prompt, true).unwrap();
            assert_eq!(warm.cached_tokens, want_cached,
                       "sub-page scan mapped the wrong span \
                        ({precision:?})");
            pool.ensure_capacity(warm.slot, prompt.len()).unwrap();
            let got = engine
                .prefill(&mut rt, pool.slot_mut(warm.slot), prompt)
                .unwrap();
            assert_eq!(got, want,
                       "sub-page resume diverged from cold prefill \
                        at {want_cached} cached tokens \
                        ({precision:?})");
            // identical history ⇒ identical logits on every fused
            // decode step
            for step in 0..DECODE_STEPS {
                let tok = gen_token(0, step, vocab);
                let reqs = [
                    BatchReq { slot: cold.slot,
                               pos: prompt.len() + step, token: tok },
                    BatchReq { slot: warm.slot,
                               pos: prompt.len() + step, token: tok },
                ];
                let mut got: Vec<Vec<f32>> = vec![Vec::new(); 2];
                engine
                    .step_batch(&mut pool, &reqs, |i, l| {
                        got[i] = l.to_vec();
                    })
                    .unwrap();
                assert_eq!(got[0], got[1],
                           "cold/warm sessions diverged at step \
                            {step} ({precision:?})");
            }
            pool.release(cold.slot);
            pool.release(warm.slot);
        }
        let stats = pool.paged_stats();
        assert_eq!(stats.prefix_subpage_hits, 2,
                   "both warm admits must hit the sub-page scan");
        assert_eq!(stats.prefix_subpage_tokens, 4,
                   "2 + 2 sub-page tokens must be accounted");
    }
}

#[test]
fn batched_kv_state_matches_reference_after_steps() {
    // beyond logits: the cached KV lengths advance identically
    let (mut rt, engine, cfg) = engine_for(QuantFormat::Nf4);
    let vocab = cfg.vocab;
    let mut pool = pool_for(&engine, &cfg, 3, KvPrecision::F32);
    let ids: Vec<usize> =
        (0..3).map(|_| pool.alloc().unwrap()).collect();
    for (s, &id) in ids.iter().enumerate() {
        let prompt = prompt_for(s, vocab);
        engine.prefill(&mut rt, pool.slot_mut(id), &prompt).unwrap();
    }
    for step in 0..2 {
        let reqs: Vec<BatchReq> = ids
            .iter()
            .enumerate()
            .map(|(s, &id)| BatchReq {
                slot: id,
                pos: prompt_for(s, vocab).len() + step,
                token: gen_token(s, step, vocab),
            })
            .collect();
        engine.step_batch(&mut pool, &reqs, |_, _| {}).unwrap();
    }
    for (s, &id) in ids.iter().enumerate() {
        assert_eq!(pool.slot(id).len, prompt_for(s, vocab).len() + 2);
    }
}
