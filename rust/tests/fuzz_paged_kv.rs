//! Seeded fuzz suite for the paged KV allocator: hundreds of random
//! admit / write / decode-grow / release / rewind / compact /
//! index-clear events against a small page pool under real pressure
//! (fewer pages than the slots could demand), with the allocator's
//! conservation invariants checked after **every** event:
//!
//! 1. no page is mapped twice within one session's table;
//! 2. every page's `Arc` strong count equals the number of page
//!    tables plus prefix-index entries referencing it (the free list
//!    holds the only reference to a free page);
//! 3. free pages are disjoint from referenced pages, and
//!    `free + distinct-referenced == pages_total` — pages are neither
//!    leaked nor double-issued;
//! 4. a session's cached length never exceeds its mapped pages;
//! 5. the fragmentation gauges (`frag_slots` / `frag_pages`) equal an
//!    independent recount from the raw page-table observables;
//! 6. every cached row reads back bit-identical to a per-position
//!    oracle — an in-place write to a shared page, a botched tail
//!    migration, or a mis-copied sub-page span is caught at the byte
//!    level on the very next event.
//!
//! After the final drain (release every session, clear the prefix
//! index) the pool must be fully reclaimed: zero used pages, empty
//! prefix index, every page back on the free list.
//!
//! The event mix deliberately reuses a few canonical "system prompt"
//! prefixes so the prefix index gets hits, copy-on-write triggers on
//! decode divergence, and page-pressure eviction fires (`KvSlot::write`
//! panics if copy-on-write ever under-privatizes, so that failure mode
//! is caught here too). Sub-page prefix matching is enabled for the
//! whole run: truncated canonical prompts miss the page-granular
//! chain and resume through the sub-page scan, and prompts with
//! partial tails publish index-owned sub-page entries. Compaction
//! passes (with occasional injected `compact_move` faults) interleave
//! with decode: dead pages reclaim, shared tails migrate into private
//! dense pages — never in place — and a faulted slot's table must
//! come through untouched.

use qpruner::model::ModelConfig;
use qpruner::rng::Rng;
use qpruner::serve::kv_cache::{KvCachePool, KvLayout, KvPrecision};
use std::collections::{HashMap, HashSet};

const ATTN_DIM: usize = 8;
const MAX_SEQ: usize = 16;
const PAGE_TOKENS: usize = 4;
const N_SLOTS: usize = 6;
/// fewer pages than the slots could demand (6 * 4 = 24), so faulting
/// hits the free-list-empty path and prefix eviction under pressure
const N_PAGES: usize = 20;
const EVENTS: usize = 650;

fn paged_pool(precision: KvPrecision) -> (ModelConfig, KvCachePool) {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let pool = KvCachePool::with_slots_layout(
        &cfg, ATTN_DIM, N_SLOTS, MAX_SEQ, precision, 1.0,
        N_SLOTS as f64, KvLayout::Paged, PAGE_TOKENS, N_PAGES,
    );
    (cfg, pool)
}

/// A live fuzz session: its slot id and cached token count.
struct Live {
    id: usize,
    len: usize,
}

/// Write one deterministic KV row per layer at position `t`.
fn write_token(pool: &mut KvCachePool, n_layers: usize, id: usize,
               t: usize) {
    let k = vec![t as f32 + 1.0; ATTN_DIM];
    let v = vec![-(t as f32) - 1.0; ATTN_DIM];
    let slot = pool.slot_mut(id);
    for layer in 0..n_layers {
        slot.write(layer, t, &k, &v);
    }
    slot.advance_to(t + 1);
}

/// Per-position row payloads as the engine reads them back (exact
/// for f32, the deterministic quantization round-trip for int8),
/// captured once from a scratch pool. Every write at position `t`
/// stores the same row, so any cached row must compare bit-equal to
/// this oracle no matter how many CoW copies, sub-page span copies,
/// or tail migrations it has been through.
struct Expected {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

fn expected_rows(precision: KvPrecision, n_layers: usize) -> Expected {
    let (_, mut pool) = paged_pool(precision);
    let prompt: Vec<i32> = (0..MAX_SEQ as i32).collect();
    let info = pool.admit(&prompt, true).expect("oracle admit");
    pool.ensure_capacity(info.slot, MAX_SEQ).expect("oracle pages");
    for t in 0..MAX_SEQ {
        write_token(&mut pool, n_layers, info.slot, t);
    }
    let mut scratch = vec![0.0f32; ATTN_DIM];
    let slot = pool.slot(info.slot);
    let k = (0..MAX_SEQ)
        .map(|t| slot.k_row(0, t, &mut scratch).to_vec())
        .collect();
    let v = (0..MAX_SEQ)
        .map(|t| slot.v_row(0, t, &mut scratch).to_vec())
        .collect();
    Expected { k, v }
}

/// Independent recount of the fragmentation gauges from the raw
/// page-table observables: stranded slack in private partial tails,
/// plus dead table entries past the live length, plus pages held only
/// by the prefix index.
fn recount_frag(pool: &KvCachePool, live: &[Live]) -> (usize, usize) {
    let mut slots = 0usize;
    let mut pages = 0usize;
    for s in live {
        let refs = pool.slot_page_refs(s.id);
        pages += refs.len().saturating_sub(s.len.div_ceil(PAGE_TOKENS));
        if s.len % PAGE_TOKENS != 0 {
            if let Some(&(_, strong)) = refs.get(s.len / PAGE_TOKENS) {
                if strong == 1 {
                    slots += PAGE_TOKENS - s.len % PAGE_TOKENS;
                }
            }
        }
    }
    pages += pool
        .prefix_page_refs()
        .iter()
        .filter(|&&(_, strong)| strong == 1)
        .count();
    (slots, pages)
}

/// The allocator conservation invariants, checked after every event.
fn check_invariants(pool: &KvCachePool, live: &[Live], exp: &Expected,
                    n_layers: usize, ctx: &str) {
    // how many holders reference each page id right now
    let mut held: HashMap<u32, usize> = HashMap::new();
    // (page id, strong count) observations to verify against `held`
    let mut observed: Vec<(u32, usize)> = Vec::new();

    for s in live {
        let refs = pool.slot_page_refs(s.id);
        // 1. no double-assignment within one table
        let distinct: HashSet<u32> =
            refs.iter().map(|&(id, _)| id).collect();
        assert_eq!(distinct.len(), refs.len(),
                   "{ctx}: slot {} maps a page twice: {refs:?}", s.id);
        // 4. cached length is backed by mapped pages
        assert!(refs.len() * PAGE_TOKENS >= s.len,
                "{ctx}: slot {} caches {} tokens over {} pages",
                s.id, s.len, refs.len());
        for (id, strong) in refs {
            *held.entry(id).or_insert(0) += 1;
            observed.push((id, strong));
        }
    }
    for (id, strong) in pool.prefix_page_refs() {
        *held.entry(id).or_insert(0) += 1;
        observed.push((id, strong));
    }
    // 2. strong counts equal the number of referencing holders
    for (id, strong) in observed {
        assert_eq!(
            strong,
            held[&id],
            "{ctx}: page {id} has strong count {strong} but {} \
             holders reference it",
            held[&id]
        );
    }
    // 3. free pages are unique, disjoint from referenced pages, and
    // conservation holds: free + distinct-referenced == total
    let free = pool.free_page_ids();
    let free_set: HashSet<u32> = free.iter().copied().collect();
    assert_eq!(free_set.len(), free.len(),
               "{ctx}: duplicate page on the free list: {free:?}");
    for id in held.keys() {
        assert!(!free_set.contains(id),
                "{ctx}: page {id} is both free and referenced");
    }
    assert_eq!(
        free.len() + held.len(),
        pool.pages_total(),
        "{ctx}: page conservation broken (free {} + used {} != \
         total {})",
        free.len(),
        held.len(),
        pool.pages_total()
    );
    assert_eq!(pool.pages_free() + pool.pages_used(),
               pool.pages_total(), "{ctx}: free/used accounting");
    assert_eq!(pool.pages_used(), held.len(),
               "{ctx}: pages_used() disagrees with the tables");
    // 5. the fragmentation gauges match an independent recount
    let (fs, fp) = recount_frag(pool, live);
    assert_eq!(pool.frag_slots(), fs,
               "{ctx}: frag_slots gauge drifted from recount");
    assert_eq!(pool.frag_pages(), fp,
               "{ctx}: frag_pages gauge drifted from recount");
    // 6. every cached row is byte-identical to the position oracle
    let mut scratch = vec![0.0f32; ATTN_DIM];
    for s in live {
        let slot = pool.slot(s.id);
        for layer in 0..n_layers {
            for t in 0..s.len {
                assert_eq!(slot.k_row(layer, t, &mut scratch),
                           &exp.k[t][..],
                           "{ctx}: slot {} K row {t} layer {layer} \
                            corrupted", s.id);
                assert_eq!(slot.v_row(layer, t, &mut scratch),
                           &exp.v[t][..],
                           "{ctx}: slot {} V row {t} layer {layer} \
                            corrupted", s.id);
            }
        }
    }
}

/// Canonical shared prefixes (2 full pages each) the workload reuses,
/// plus per-event random tails — the mix that drives prefix hits,
/// verified lookups, and CoW divergence.
fn make_prompt(rng: &mut Rng) -> Vec<i32> {
    let shared = rng.below(4) as i32;
    let mut prompt: Vec<i32> = if shared < 3 {
        // 1-in-3 canonical admissions truncate below the full two
        // pages: the page-granular chain misses, so only the
        // sub-page scan can map the common span
        let keep = if rng.below(3) == 0 {
            1 + rng.below(2 * PAGE_TOKENS - 1)
        } else {
            2 * PAGE_TOKENS
        };
        (0..keep as i32).map(|j| 100 * shared + j).collect()
    } else {
        // unshared prompt, random length >= 1
        (0..1 + rng.below(4)).map(|j| 7_000 + j as i32).collect()
    };
    for j in 0..rng.below(MAX_SEQ - prompt.len() - 2) {
        prompt.push(50_000 + rng.below(1_000) as i32 + j as i32);
    }
    prompt
}

fn run_fuzz(precision: KvPrecision, seed: u64) {
    let (cfg, mut pool) = paged_pool(precision);
    let n_layers = cfg.n_layers;
    let exp = expected_rows(precision, n_layers);
    pool.set_subpage_prefix(true);
    let mut rng = Rng::new(seed);
    let mut live: Vec<Live> = Vec::new();
    let mut admitted = 0usize;
    let mut grew = 0usize;

    // Deterministic warm-up before the random schedule: prove the
    // sub-page scan and the compaction grace window end-to-end, so
    // the end-of-run stats assertions can't be starved by an unlucky
    // event mix. Publish two full canonical pages, then admit a
    // 4-token prompt sharing only 3 tokens — below one page, so only
    // the sub-page scan can resume it.
    let full: Vec<i32> = (0..2 * PAGE_TOKENS as i32).collect();
    let a = pool.admit(&full, true).expect("warm-up admit");
    pool.ensure_capacity(a.slot, full.len()).expect("warm-up pages");
    for t in 0..full.len() {
        write_token(&mut pool, n_layers, a.slot, t);
    }
    pool.publish_prefix(a.slot, &full);
    let part: Vec<i32> = vec![0, 1, 2, 9_999];
    let b = pool.admit(&part, true).expect("warm-up sub admit");
    assert_eq!(b.cached_tokens, 3,
               "sub-page scan must map the 3-token span inside the \
                first differing page");
    pool.ensure_capacity(b.slot, part.len()).expect("warm-up sub page");
    for t in b.cached_tokens..part.len() {
        write_token(&mut pool, n_layers, b.slot, t);
    }
    pool.release(a.slot);
    pool.release(b.slot);
    // grace window: the first pass only arms the sweep, the second
    // reclaims the now-idle published pages
    assert_eq!(pool.compact(&[]).pages_reclaimed, 0,
               "freshly published entries must survive one pass");
    let swept = pool.compact(&[]).pages_reclaimed;
    assert!(swept >= 2, "stale sweep reclaimed only {swept} pages");
    let mut compact_passes = 2u64;
    check_invariants(&pool, &live, &exp, n_layers, "warm-up");

    for ev in 0..EVENTS {
        let ctx = format!("{precision:?} seed {seed} event {ev}");
        match rng.below(13) {
            // admit a session, prefill-write its non-cached span,
            // publish its prompt pages
            0..=3 => {
                let prompt = make_prompt(&mut rng);
                if let Some(info) = pool.admit(&prompt, true) {
                    assert!(info.cached_tokens < prompt.len(),
                            "{ctx}: reuse must leave >= 1 token to \
                             compute");
                    // with sub-page matching on, reuse is
                    // token-granular: a non-multiple of PAGE_TOKENS
                    // means the sub-page scan mapped a span inside
                    // the first differing page
                    // the admit gate promised the prompt is mappable
                    pool.ensure_capacity(info.slot, prompt.len())
                        .unwrap_or_else(|e| panic!(
                            "{ctx}: admit-gated fault failed: {e}"));
                    for t in info.cached_tokens..prompt.len() {
                        write_token(&mut pool, n_layers, info.slot, t);
                    }
                    pool.publish_prefix(info.slot, &prompt);
                    live.push(Live { id: info.slot,
                                     len: prompt.len() });
                    admitted += 1;
                }
            }
            // decode-grow a random session by one token (CoW fires
            // when its next page is shared); preempt on page OOM
            4..=6 => {
                if !live.is_empty() {
                    let i = rng.below(live.len());
                    if live[i].len < MAX_SEQ {
                        let (id, len) = (live[i].id, live[i].len);
                        if pool.ensure_capacity(id, len + 1).is_ok() {
                            write_token(&mut pool, n_layers, id, len);
                            live[i].len += 1;
                            grew += 1;
                        } else {
                            pool.release(id);
                            live.swap_remove(i);
                        }
                    }
                }
            }
            // finish a random session
            7 => {
                if !live.is_empty() {
                    let i = rng.below(live.len());
                    pool.release(live[i].id);
                    live.swap_remove(i);
                }
            }
            // rewind & rewrite: re-derive a suffix of the cache (the
            // bench's slot-reuse pattern; also how a speculative
            // rollback would look). The write range now overlaps
            // published pages, so this is the event that forces
            // copy-on-write — `KvSlot::write` panics if
            // `ensure_capacity` under-privatizes
            8 => {
                if !live.is_empty() {
                    let i = rng.below(live.len());
                    let (id, len) = (live[i].id, live[i].len);
                    // a rewind event may have left len == 0; the
                    // rewrite then degenerates to a harmless no-op
                    let cut = if len > 0 { rng.below(len) } else { 0 };
                    pool.slot_mut(id).advance_to(cut);
                    if pool.ensure_capacity(id, len).is_ok() {
                        for t in cut..len {
                            write_token(&mut pool, n_layers, id, t);
                        }
                    } else {
                        // page OOM privatizing: preempt like serving
                        pool.release(id);
                        live.swap_remove(i);
                    }
                }
            }
            // rare: drop the whole prefix index mid-run
            9 => {
                if rng.below(8) == 0 {
                    pool.clear_prefix_index();
                }
            }
            // compact every live session, occasionally injecting a
            // `compact_move` fault. Direct checks on top of the
            // global invariants: only the partial tail page may be
            // replaced (shared pages are never migrated in place —
            // a migrated tail is a fresh private page), slots can
            // only fail with an injected fault, and a faulted
            // slot's live pages come through untouched
            10..=11 => {
                let before: Vec<(usize, usize, Vec<u32>)> = live
                    .iter()
                    .map(|s| (s.id, s.len,
                              pool.slot_page_refs(s.id)
                                  .into_iter()
                                  .map(|(pid, _)| pid)
                                  .collect()))
                    .collect();
                let ids: Vec<(usize, bool)> = live
                    .iter()
                    .map(|s| (s.id, rng.below(8) == 0))
                    .collect();
                let injected: HashSet<usize> = ids
                    .iter()
                    .filter(|&&(_, f)| f)
                    .map(|&(id, _)| id)
                    .collect();
                let rep = pool.compact(&ids);
                compact_passes += 1;
                for id in &rep.failed {
                    assert!(injected.contains(id),
                            "{ctx}: slot {id} failed without an \
                             injected fault");
                }
                for (id, len, old) in before {
                    let now = pool.slot_page_refs(id);
                    assert_eq!(now.len(),
                               len.div_ceil(PAGE_TOKENS),
                               "{ctx}: slot {id} not compacted to \
                                its live pages");
                    let tail = if len % PAGE_TOKENS != 0 {
                        Some(len / PAGE_TOKENS)
                    } else {
                        None
                    };
                    for (i, &(pid, strong)) in now.iter().enumerate()
                    {
                        if Some(i) == tail {
                            if pid != old[i] {
                                assert_eq!(
                                    strong, 1,
                                    "{ctx}: slot {id} migrated tail \
                                     page is shared"
                                );
                                assert!(
                                    !rep.failed.contains(&id),
                                    "{ctx}: faulted slot {id} still \
                                     migrated its tail"
                                );
                            }
                        } else {
                            assert_eq!(pid, old[i],
                                       "{ctx}: slot {id} full page \
                                        {i} was replaced");
                        }
                    }
                }
            }
            // rewind only (speculative rollback without rewrite):
            // pages past the new tail stay mapped as dead-page
            // fragmentation until a compact pass or a re-extension —
            // the frag recount keeps the gauges honest meanwhile
            _ => {
                if !live.is_empty() {
                    let i = rng.below(live.len());
                    let cut = rng.below(live[i].len + 1);
                    pool.slot_mut(live[i].id).rewind(cut);
                    live[i].len = cut;
                }
            }
        }
        check_invariants(&pool, &live, &exp, n_layers, &ctx);
    }

    // the mix must actually have exercised the interesting paths
    assert!(admitted > 30, "only {admitted} admissions — dead mix");
    assert!(grew > 30, "only {grew} decode growths — dead mix");
    assert!(compact_passes > 20,
            "only {compact_passes} compaction passes — dead mix");
    let stats = pool.paged_stats();
    assert!(stats.prefix_hits > 0, "prefix cache never hit");
    assert!(stats.cow_copies > 0, "copy-on-write never fired");
    assert!(stats.page_faults > 0, "no page was ever faulted");
    assert_eq!(stats.compactions, compact_passes,
               "every compaction pass is counted exactly once");
    assert!(stats.prefix_subpage_hits >= 1,
            "the sub-page scan never matched");
    assert!(stats.prefix_subpage_tokens >= 3,
            "sub-page reuse tokens were not accounted");
    assert!(stats.pages_reclaimed >= 2,
            "compaction never reclaimed a page");

    // final drain: everything must come back
    for s in live.drain(..) {
        pool.release(s.id);
    }
    pool.clear_prefix_index();
    check_invariants(&pool, &[], &exp, n_layers, "post-drain");
    assert_eq!(pool.pages_used(), 0, "pages leaked after drain");
    assert_eq!(pool.pages_free(), pool.pages_total());
    assert_eq!(pool.prefix_index_len(), 0);
    assert_eq!(pool.in_use(), 0, "slots leaked after drain");
}

#[test]
fn fuzz_paged_allocator_f32() {
    for seed in [7u64, 1311] {
        run_fuzz(KvPrecision::F32, seed);
    }
}

#[test]
fn fuzz_paged_allocator_int8() {
    for seed in [23u64, 4242] {
        run_fuzz(KvPrecision::Int8, seed);
    }
}
