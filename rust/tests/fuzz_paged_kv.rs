//! Seeded fuzz suite for the paged KV allocator: hundreds of random
//! admit / write / decode-grow / release / index-clear events against
//! a small page pool under real pressure (fewer pages than the slots
//! could demand), with the allocator's conservation invariants checked
//! after **every** event:
//!
//! 1. no page is mapped twice within one session's table;
//! 2. every page's `Arc` strong count equals the number of page
//!    tables plus prefix-index entries referencing it (the free list
//!    holds the only reference to a free page);
//! 3. free pages are disjoint from referenced pages, and
//!    `free + distinct-referenced == pages_total` — pages are neither
//!    leaked nor double-issued;
//! 4. a session's cached length never exceeds its mapped pages.
//!
//! After the final drain (release every session, clear the prefix
//! index) the pool must be fully reclaimed: zero used pages, empty
//! prefix index, every page back on the free list.
//!
//! The event mix deliberately reuses a few canonical "system prompt"
//! prefixes so the prefix index gets hits, copy-on-write triggers on
//! decode divergence, and page-pressure eviction fires (`KvSlot::write`
//! panics if copy-on-write ever under-privatizes, so that failure mode
//! is caught here too).

use qpruner::model::ModelConfig;
use qpruner::rng::Rng;
use qpruner::serve::kv_cache::{KvCachePool, KvLayout, KvPrecision};
use std::collections::{HashMap, HashSet};

const ATTN_DIM: usize = 8;
const MAX_SEQ: usize = 16;
const PAGE_TOKENS: usize = 4;
const N_SLOTS: usize = 6;
/// fewer pages than the slots could demand (6 * 4 = 24), so faulting
/// hits the free-list-empty path and prefix eviction under pressure
const N_PAGES: usize = 20;
const EVENTS: usize = 650;

fn paged_pool(precision: KvPrecision) -> (ModelConfig, KvCachePool) {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let pool = KvCachePool::with_slots_layout(
        &cfg, ATTN_DIM, N_SLOTS, MAX_SEQ, precision, 1.0,
        N_SLOTS as f64, KvLayout::Paged, PAGE_TOKENS, N_PAGES,
    );
    (cfg, pool)
}

/// A live fuzz session: its slot id and cached token count.
struct Live {
    id: usize,
    len: usize,
}

/// Write one deterministic KV row per layer at position `t`.
fn write_token(pool: &mut KvCachePool, n_layers: usize, id: usize,
               t: usize) {
    let k = vec![t as f32 + 1.0; ATTN_DIM];
    let v = vec![-(t as f32) - 1.0; ATTN_DIM];
    let slot = pool.slot_mut(id);
    for layer in 0..n_layers {
        slot.write(layer, t, &k, &v);
    }
    slot.advance_to(t + 1);
}

/// The allocator conservation invariants, checked after every event.
fn check_invariants(pool: &KvCachePool, live: &[Live], ctx: &str) {
    // how many holders reference each page id right now
    let mut held: HashMap<u32, usize> = HashMap::new();
    // (page id, strong count) observations to verify against `held`
    let mut observed: Vec<(u32, usize)> = Vec::new();

    for s in live {
        let refs = pool.slot_page_refs(s.id);
        // 1. no double-assignment within one table
        let distinct: HashSet<u32> =
            refs.iter().map(|&(id, _)| id).collect();
        assert_eq!(distinct.len(), refs.len(),
                   "{ctx}: slot {} maps a page twice: {refs:?}", s.id);
        // 4. cached length is backed by mapped pages
        assert!(refs.len() * PAGE_TOKENS >= s.len,
                "{ctx}: slot {} caches {} tokens over {} pages",
                s.id, s.len, refs.len());
        for (id, strong) in refs {
            *held.entry(id).or_insert(0) += 1;
            observed.push((id, strong));
        }
    }
    for (id, strong) in pool.prefix_page_refs() {
        *held.entry(id).or_insert(0) += 1;
        observed.push((id, strong));
    }
    // 2. strong counts equal the number of referencing holders
    for (id, strong) in observed {
        assert_eq!(
            strong,
            held[&id],
            "{ctx}: page {id} has strong count {strong} but {} \
             holders reference it",
            held[&id]
        );
    }
    // 3. free pages are unique, disjoint from referenced pages, and
    // conservation holds: free + distinct-referenced == total
    let free = pool.free_page_ids();
    let free_set: HashSet<u32> = free.iter().copied().collect();
    assert_eq!(free_set.len(), free.len(),
               "{ctx}: duplicate page on the free list: {free:?}");
    for id in held.keys() {
        assert!(!free_set.contains(id),
                "{ctx}: page {id} is both free and referenced");
    }
    assert_eq!(
        free.len() + held.len(),
        pool.pages_total(),
        "{ctx}: page conservation broken (free {} + used {} != \
         total {})",
        free.len(),
        held.len(),
        pool.pages_total()
    );
    assert_eq!(pool.pages_free() + pool.pages_used(),
               pool.pages_total(), "{ctx}: free/used accounting");
    assert_eq!(pool.pages_used(), held.len(),
               "{ctx}: pages_used() disagrees with the tables");
}

/// Canonical shared prefixes (2 full pages each) the workload reuses,
/// plus per-event random tails — the mix that drives prefix hits,
/// verified lookups, and CoW divergence.
fn make_prompt(rng: &mut Rng) -> Vec<i32> {
    let shared = rng.below(4) as i32;
    let mut prompt: Vec<i32> = if shared < 3 {
        (0..2 * PAGE_TOKENS as i32)
            .map(|j| 100 * shared + j)
            .collect()
    } else {
        // unshared prompt, random length >= 1
        (0..1 + rng.below(4)).map(|j| 7_000 + j as i32).collect()
    };
    for j in 0..rng.below(MAX_SEQ - prompt.len() - 2) {
        prompt.push(50_000 + rng.below(1_000) as i32 + j as i32);
    }
    prompt
}

fn run_fuzz(precision: KvPrecision, seed: u64) {
    let (cfg, mut pool) = paged_pool(precision);
    let n_layers = cfg.n_layers;
    let mut rng = Rng::new(seed);
    let mut live: Vec<Live> = Vec::new();
    let mut admitted = 0usize;
    let mut grew = 0usize;

    for ev in 0..EVENTS {
        let ctx = format!("{precision:?} seed {seed} event {ev}");
        match rng.below(10) {
            // admit a session, prefill-write its non-cached span,
            // publish its prompt pages
            0..=3 => {
                let prompt = make_prompt(&mut rng);
                if let Some(info) = pool.admit(&prompt, true) {
                    assert!(info.cached_tokens < prompt.len(),
                            "{ctx}: reuse must leave >= 1 token to \
                             compute");
                    assert_eq!(info.cached_tokens % PAGE_TOKENS, 0,
                               "{ctx}: reuse is page-granular");
                    // the admit gate promised the prompt is mappable
                    pool.ensure_capacity(info.slot, prompt.len())
                        .unwrap_or_else(|e| panic!(
                            "{ctx}: admit-gated fault failed: {e}"));
                    for t in info.cached_tokens..prompt.len() {
                        write_token(&mut pool, n_layers, info.slot, t);
                    }
                    pool.publish_prefix(info.slot, &prompt);
                    live.push(Live { id: info.slot,
                                     len: prompt.len() });
                    admitted += 1;
                }
            }
            // decode-grow a random session by one token (CoW fires
            // when its next page is shared); preempt on page OOM
            4..=6 => {
                if !live.is_empty() {
                    let i = rng.below(live.len());
                    if live[i].len < MAX_SEQ {
                        let (id, len) = (live[i].id, live[i].len);
                        if pool.ensure_capacity(id, len + 1).is_ok() {
                            write_token(&mut pool, n_layers, id, len);
                            live[i].len += 1;
                            grew += 1;
                        } else {
                            pool.release(id);
                            live.swap_remove(i);
                        }
                    }
                }
            }
            // finish a random session
            7 => {
                if !live.is_empty() {
                    let i = rng.below(live.len());
                    pool.release(live[i].id);
                    live.swap_remove(i);
                }
            }
            // rewind & rewrite: re-derive a suffix of the cache (the
            // bench's slot-reuse pattern; also how a speculative
            // rollback would look). The write range now overlaps
            // published pages, so this is the event that forces
            // copy-on-write — `KvSlot::write` panics if
            // `ensure_capacity` under-privatizes
            8 => {
                if !live.is_empty() {
                    let i = rng.below(live.len());
                    let (id, len) = (live[i].id, live[i].len);
                    let cut = rng.below(len);
                    pool.slot_mut(id).advance_to(cut);
                    if pool.ensure_capacity(id, len).is_ok() {
                        for t in cut..len {
                            write_token(&mut pool, n_layers, id, t);
                        }
                    } else {
                        // page OOM privatizing: preempt like serving
                        pool.release(id);
                        live.swap_remove(i);
                    }
                }
            }
            // rare: drop the whole prefix index mid-run
            _ => {
                if rng.below(8) == 0 {
                    pool.clear_prefix_index();
                }
            }
        }
        check_invariants(&pool, &live, &ctx);
    }

    // the mix must actually have exercised the interesting paths
    assert!(admitted > 30, "only {admitted} admissions — dead mix");
    assert!(grew > 30, "only {grew} decode growths — dead mix");
    let stats = pool.paged_stats();
    assert!(stats.prefix_hits > 0, "prefix cache never hit");
    assert!(stats.cow_copies > 0, "copy-on-write never fired");
    assert!(stats.page_faults > 0, "no page was ever faulted");

    // final drain: everything must come back
    for s in live.drain(..) {
        pool.release(s.id);
    }
    pool.clear_prefix_index();
    check_invariants(&pool, &[], "post-drain");
    assert_eq!(pool.pages_used(), 0, "pages leaked after drain");
    assert_eq!(pool.pages_free(), pool.pages_total());
    assert_eq!(pool.prefix_index_len(), 0);
    assert_eq!(pool.in_use(), 0, "slots leaked after drain");
}

#[test]
fn fuzz_paged_allocator_f32() {
    for seed in [7u64, 1311] {
        run_fuzz(KvPrecision::F32, seed);
    }
}

#[test]
fn fuzz_paged_allocator_int8() {
    for seed in [23u64, 4242] {
        run_fuzz(KvPrecision::Int8, seed);
    }
}
