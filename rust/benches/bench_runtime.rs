//! PJRT runtime benchmarks: artifact compile latency, execute latency
//! for the kernel and model artifacts, and host<->device marshaling
//! overhead. These bound the L3 hot path: one `train_*` execute per
//! scan window is the unit of fine-tuning work.

#[path = "harness.rs"]
mod harness;

use qpruner::model::{ModelConfig, ParamStore};
use qpruner::rng::Rng;
use qpruner::runtime::{Arg, Runtime};
use qpruner::tensor::Tensor;

fn main() {
    let Some(dir) = harness::artifacts_dir() else {
        println!("SKIP bench_runtime: artifacts not built");
        return;
    };

    // compile latency (fresh runtime each iteration)
    harness::bench("compile_kernel_rmsnorm", 1, 5, || {
        let mut rt = Runtime::new(&dir).unwrap();
        rt.load("kernel_rmsnorm").unwrap();
    });
    harness::bench("compile_train_tiny_r20", 1, 3, || {
        let mut rt = Runtime::new(&dir).unwrap();
        rt.load("train_tiny_r20").unwrap();
    });

    // execute latency, cached executables
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(4);
    let x = Tensor::randn(&[16, 256], 1.0, &mut rng);
    let g = Tensor::randn(&[256], 1.0, &mut rng);
    rt.exec_f32("kernel_rmsnorm", &[Arg::F32(&x), Arg::F32(&g)]).unwrap();
    harness::bench("exec_kernel_rmsnorm", 3, 30, || {
        std::hint::black_box(
            rt.exec_f32("kernel_rmsnorm", &[Arg::F32(&x), Arg::F32(&g)])
                .unwrap(),
        );
    });

    // full tiny fwd (27 inputs: marshaling + execute)
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 5);
    let lora: Vec<Tensor> = qpruner::lora::LoraState::shapes(&store)
        .iter()
        .map(|s| Tensor::zeros(s))
        .collect();
    let tokens: Vec<i32> = (0..cfg.batch * cfg.seq)
        .map(|i| 3 + (i as i32) % 250)
        .collect();
    let shape = [cfg.batch, cfg.seq];
    let run_fwd = |rt: &mut Runtime| {
        let mut args: Vec<Arg> = Vec::new();
        for w in &store.weights {
            args.push(Arg::F32(w));
        }
        for t in &lora {
            args.push(Arg::F32(t));
        }
        args.push(Arg::I32(&tokens, &shape));
        rt.exec_f32("fwd_tiny_r0", &args).unwrap()
    };
    run_fwd(&mut rt);
    harness::bench("exec_fwd_tiny_27_inputs", 2, 20, || {
        std::hint::black_box(run_fwd(&mut rt));
    });

    // marshaling alone: build+drop the literals without executing
    harness::bench("marshal_tiny_weights_to_literals", 3, 30, || {
        for w in &store.weights {
            std::hint::black_box(qpruner::runtime::lit_f32(w).unwrap());
        }
    });

    // one scan-window train step (the fine-tuning unit of work)
    let m: Vec<Tensor> =
        lora.iter().map(|t| Tensor::zeros(t.shape())).collect();
    let v = m.clone();
    let k = cfg.scan_steps;
    let train_tokens: Vec<i32> = (0..k * cfg.batch * (cfg.seq + 1))
        .map(|i| 3 + (i as i32) % 250)
        .collect();
    let tshape = [k, cfg.batch, cfg.seq + 1];
    let run_train = |rt: &mut Runtime| {
        let mut args: Vec<Arg> = Vec::new();
        for w in &store.weights {
            args.push(Arg::F32(w));
        }
        for t in &lora {
            args.push(Arg::F32(t));
        }
        for t in &m {
            args.push(Arg::F32(t));
        }
        for t in &v {
            args.push(Arg::F32(t));
        }
        args.push(Arg::Scalar(0.0));
        args.push(Arg::I32(&train_tokens, &tshape));
        args.push(Arg::Scalar(1e-3));
        rt.exec("train_tiny_r0", &args).unwrap()
    };
    run_train(&mut rt);
    harness::bench(
        &format!("exec_train_tiny_scan{k}_per_call"), 2, 10,
        || {
            std::hint::black_box(run_train(&mut rt));
        },
    );
}
