//! Serving-path benchmarks: closed-loop throughput/latency of the
//! continuous-batching scheduler + native KV decode engine, plus the
//! per-token decode hot path in isolation.
//!
//! Like the other benches this needs no artifacts — the engine falls
//! back to the native backend. Output format:
//!   BENCH <name> iters=<n> mean=<ms> p50=<ms> p95=<ms>
//!   SERVE <name> tokens_per_sec=<..> p50=<..>ms p99=<..>ms occ=<..>

#[path = "harness.rs"]
mod harness;

use qpruner::data::Language;
use qpruner::metrics::Metrics;
use qpruner::model::{ModelConfig, ParamStore};
use qpruner::quant::{BitConfig, QuantFormat};
use qpruner::runtime::Runtime;
use qpruner::serve::engine::Engine;
use qpruner::serve::kv_cache::KvCachePool;
use qpruner::serve::{run_workload, ServeOpts};

fn runtime() -> Runtime {
    let dir = std::env::temp_dir().join("qpruner_serve_bench");
    std::fs::create_dir_all(&dir).unwrap();
    Runtime::new(&dir).unwrap()
}

fn main() {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 1);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    let mut rt = runtime();

    // 1. isolated decode hot path: one token through the KV engine
    let max_seq = 28;
    let engine = Engine::new(&mut rt, &store, &bits, max_seq).unwrap();
    let mut pool = KvCachePool::with_slots(&cfg, engine.attn_dim(), 1,
                                           max_seq, 1.0, 1.0);
    let slot = pool.alloc().unwrap();
    let prompt: Vec<i32> = (0..8).map(|i| 3 + i).collect();
    harness::bench("serve_prefill8_tiny", 3, 50, || {
        let s = pool.slot_mut(slot);
        s.advance_to(0);
        let logits = engine.prefill(&mut rt, s, &prompt).unwrap();
        std::hint::black_box(logits);
    });

    // 2. closed-loop workloads at increasing concurrency
    for (name, clients, max_batch) in
        [("c1_b1", 1usize, 1usize), ("c4_b4", 4, 4), ("c8_b8", 8, 8)]
    {
        let mut opts = ServeOpts::smoke();
        opts.clients = clients;
        opts.max_batch = max_batch;
        opts.requests = 64;
        opts.seed = 7;
        let lang = Language::new(cfg.vocab, 1);
        let mut metrics = Metrics::new();
        let report = run_workload(&mut rt, &store, &bits, &lang, &opts,
                                  &mut metrics)
            .unwrap();
        println!(
            "SERVE {name} tokens_per_sec={:.1} p50={:.3}ms p99={:.3}ms \
             occ={:.2} completed={}",
            report.tokens_per_sec(),
            report.latency.percentile_ms(50.0),
            report.latency.percentile_ms(99.0),
            report.mean_occupancy,
            report.completed
        );
        assert_eq!(report.completed, 64);
    }
}
