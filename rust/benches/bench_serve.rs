//! Serving-path benchmarks: closed-loop throughput/latency of the
//! continuous-batching scheduler + native KV decode engine, the decode
//! hot path in isolation (fused quantized-residency kernels on all
//! cores vs. the PR-3 f32-GEMM single-lane baseline vs. the
//! per-session matvec reference), and the KV-cache footprint at 32-
//! vs 8-bit storage.
//!
//! Like the other benches this needs no artifacts — the engine falls
//! back to the native backend. Output format:
//!   BENCH <name> iters=<n> mean=<ms> p50=<ms> p95=<ms>
//!   SERVE <name> tokens_per_sec=<..> p50=<..>ms p99=<..>ms occ=<..>
//!   SERVE decode_b<B> fused_...=<..> f32_gemm_...=<..> matvec_...
//!   SERVE decode_paged_b<B> paged_...=<..> slab_...=<..>
//!   SERVE kv_bits=<32|8> sessions=<..> host_slab_bytes=<..>
//!
//! Every config also lands in `results/BENCH_serve.json` — the
//! machine-readable perf trajectory CI uploads per run. The
//! `decode_b{1,4,8}` entries carry both the fused-kernel line and the
//! f32-GEMM baseline line the acceptance criteria compare (fused at
//! batch 8 on nf4 must be >= 2x the baseline).

#[path = "harness.rs"]
mod harness;

use qpruner::data::Language;
use qpruner::memory;
use qpruner::metrics::Metrics;
use qpruner::model::{ModelConfig, ParamStore};
use qpruner::quant::{BitConfig, QuantFormat};
use qpruner::runtime::Runtime;
use qpruner::serve::engine::{BatchReq, Engine, EngineBuilder};
use qpruner::serve::kv_cache::{KvCachePool, KvLayout, KvPrecision};
use qpruner::serve::{bench_json, bench_json_append_obj, run_workload,
                     ServeOpts, ServeReport};
use std::time::Instant;

fn runtime() -> Runtime {
    let dir = std::env::temp_dir().join("qpruner_serve_bench");
    std::fs::create_dir_all(&dir).unwrap();
    Runtime::new(&dir).unwrap()
}

/// Best-of-`rounds` decode throughput over `steps` tokens per session:
/// each round re-prefills every slot, then times one decode window on
/// either the batched GEMM path or the per-session matvec baseline.
fn decode_tokens_per_sec(
    engine: &Engine,
    rt: &mut Runtime,
    pool: &mut KvCachePool,
    ids: &[usize],
    prompt: &[i32],
    steps: usize,
    rounds: usize,
    batched: bool,
) -> f64 {
    let mut best = 0.0f64;
    for _ in 0..rounds {
        for &id in ids {
            pool.slot_mut(id).advance_to(0);
            // map the prompt span up front (faults pages on the paged
            // layout; a pure bounds check on the slab)
            pool.ensure_capacity(id, prompt.len()).unwrap();
            if batched {
                engine.prefill(rt, pool.slot_mut(id), prompt).unwrap();
            } else {
                engine
                    .prefill_reference(pool.slot_mut(id), prompt)
                    .unwrap();
            }
        }
        let t0 = Instant::now();
        for step in 0..steps {
            if batched {
                let reqs: Vec<BatchReq> = ids
                    .iter()
                    .enumerate()
                    .map(|(i, &id)| BatchReq {
                        slot: id,
                        pos: prompt.len() + step,
                        token: ((7 + i * 13 + step) % 200) as i32,
                    })
                    .collect();
                engine
                    .step_batch(pool, &reqs, |_, logits| {
                        std::hint::black_box(logits);
                    })
                    .unwrap();
            } else {
                for (i, &id) in ids.iter().enumerate() {
                    let logits = engine
                        .decode_reference(
                            pool.slot_mut(id),
                            prompt.len() + step,
                            ((7 + i * 13 + step) % 200) as i32,
                        )
                        .unwrap();
                    std::hint::black_box(&logits);
                }
            }
        }
        let tps =
            (steps * ids.len()) as f64 / t0.elapsed().as_secs_f64();
        best = best.max(tps);
    }
    best
}

fn main() {
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 1);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    let mut rt = runtime();

    // 1. isolated prefill hot path: 8 tokens through the KV engine
    let max_seq = 28;
    let engine = EngineBuilder::new()
        .store(&store, &bits)
        .max_seq(max_seq)
        .build(&mut rt)
        .unwrap();
    let mut pool = KvCachePool::with_slots(&cfg, engine.attn_dim(), 1,
                                           max_seq, KvPrecision::F32,
                                           1.0, 1.0);
    let slot = pool.alloc().unwrap();
    let prompt: Vec<i32> = (0..8).map(|i| 3 + i).collect();
    harness::bench("serve_prefill8_tiny", 3, 50, || {
        let s = pool.slot_mut(slot);
        s.advance_to(0);
        let logits = engine.prefill(&mut rt, s, &prompt).unwrap();
        std::hint::black_box(logits);
    });

    // 2. decode hot path on the `small` preset (enough arithmetic for
    // the pool to matter): three engines over identical nf4 numerics —
    //   fused    quantized residency, fused kernels, all cores
    //   f32_gemm the PR-3 baseline: materialized f32 GEMMs, 1 lane
    //   matvec   the per-session reference path (PR-2 baseline)
    // The acceptance line: fused >= 2x f32_gemm at batch 8.
    let dcfg = ModelConfig::preset("small").unwrap();
    let dstore = ParamStore::init(&dcfg, 2);
    let dbits = BitConfig::uniform(dcfg.n_layers, QuantFormat::Nf4);
    let fused_eng = EngineBuilder::new()
        .store(&dstore, &dbits)
        .max_seq(max_seq)
        .build(&mut rt)
        .unwrap();
    let base_eng = EngineBuilder::new()
        .store(&dstore, &dbits)
        .max_seq(max_seq)
        .f32_residency()
        .threads(1)
        .build(&mut rt)
        .unwrap();
    assert_eq!(fused_eng.residency_label(), "quantized");
    assert_eq!(base_eng.residency_label(), "f32");
    let short_prompt: Vec<i32> = (0..4).map(|i| 3 + i).collect();
    let steps = max_seq - short_prompt.len() - 1;
    let mut decode_entries: Vec<String> = Vec::new();
    for &batch in &[1usize, 4, 8] {
        let mut p = KvCachePool::with_slots(
            &dcfg,
            fused_eng.attn_dim(),
            batch,
            max_seq,
            KvPrecision::F32,
            1.0,
            batch as f64,
        );
        let ids: Vec<usize> =
            (0..batch).map(|_| p.alloc().unwrap()).collect();
        let rounds = 8;
        let fused = decode_tokens_per_sec(&fused_eng, &mut rt, &mut p,
                                          &ids, &short_prompt, steps,
                                          rounds, true);
        let f32_gemm = decode_tokens_per_sec(&base_eng, &mut rt,
                                             &mut p, &ids,
                                             &short_prompt, steps,
                                             rounds, true);
        let matvec = decode_tokens_per_sec(&base_eng, &mut rt, &mut p,
                                           &ids, &short_prompt, steps,
                                           rounds, false);
        let speedup = fused / f32_gemm.max(1e-9);
        println!(
            "SERVE decode_b{batch} fused_tokens_per_sec={fused:.0} \
             f32_gemm_tokens_per_sec={f32_gemm:.0} \
             matvec_tokens_per_sec={matvec:.0} \
             fused_speedup_vs_f32_gemm={speedup:.2}x \
             threads={}",
            fused_eng.threads()
        );
        decode_entries.push(format!(
            "{{\"name\":\"decode_b{batch}\",\"weights\":\"nf4\",\
             \"residency\":\"quantized\",\
             \"fused_tokens_per_sec\":{fused:.1},\
             \"f32_gemm_tokens_per_sec\":{f32_gemm:.1},\
             \"matvec_tokens_per_sec\":{matvec:.1},\
             \"fused_speedup_vs_f32_gemm\":{speedup:.3},\
             \"threads\":{}}}",
            fused_eng.threads()
        ));
    }

    // 2a. paged-KV decode vs the slab baseline on the same fused
    // engine and numerics: the per-row page indirection must not cost
    // measurable decode throughput (logits are bit-identical either
    // way — tests/parity_decode.rs pins that down; this line pins the
    // perf trajectory so a paged regression shows up in CI's JSON)
    {
        let page_tokens = 8usize;
        for &batch in &[1usize, 8] {
            let n_pages = batch * max_seq.div_ceil(page_tokens);
            let mut p = KvCachePool::with_slots_layout(
                &dcfg,
                fused_eng.attn_dim(),
                batch,
                max_seq,
                KvPrecision::F32,
                1.0,
                batch as f64,
                KvLayout::Paged,
                page_tokens,
                n_pages,
            );
            let ids: Vec<usize> =
                (0..batch).map(|_| p.alloc().unwrap()).collect();
            let paged = decode_tokens_per_sec(&fused_eng, &mut rt,
                                              &mut p, &ids,
                                              &short_prompt, steps, 8,
                                              true);
            let mut s = KvCachePool::with_slots(
                &dcfg,
                fused_eng.attn_dim(),
                batch,
                max_seq,
                KvPrecision::F32,
                1.0,
                batch as f64,
            );
            let sids: Vec<usize> =
                (0..batch).map(|_| s.alloc().unwrap()).collect();
            let slab = decode_tokens_per_sec(&fused_eng, &mut rt,
                                             &mut s, &sids,
                                             &short_prompt, steps, 8,
                                             true);
            let ratio = paged / slab.max(1e-9);
            println!(
                "SERVE decode_paged_b{batch} \
                 paged_tokens_per_sec={paged:.0} \
                 slab_tokens_per_sec={slab:.0} \
                 paged_vs_slab={ratio:.2}x page_tokens={page_tokens}"
            );
            decode_entries.push(format!(
                "{{\"name\":\"decode_paged_b{batch}\",\
                 \"weights\":\"nf4\",\"kv_layout\":\"paged\",\
                 \"page_tokens\":{page_tokens},\
                 \"paged_tokens_per_sec\":{paged:.1},\
                 \"slab_tokens_per_sec\":{slab:.1},\
                 \"paged_vs_slab\":{ratio:.3},\
                 \"threads\":{}}}",
                fused_eng.threads()
            ));
        }
    }

    // 2a-bis. page compaction under slot churn: batch 8 sessions
    // decode to near max_seq, finish, and their slots are reused by
    // fresh short requests — the classic fragmentation shape (dead
    // trailing pages held by rewound slots). One run compacts after
    // every churn cycle, one never does; the JSON line carries both
    // throughputs (the compaction passes are inside the timed window,
    // so their cost is visible) and the pages the compacting run
    // handed back. CI asserts this entry exists in BENCH_serve.json.
    {
        let page_tokens = 8usize;
        let batch = 8usize;
        let n_pages = batch * max_seq.div_ceil(page_tokens);
        let churn_prompt: Vec<i32> = (0..4).map(|i| 3 + i).collect();
        let churn_steps = max_seq - churn_prompt.len() - 1;
        let cycles = 6usize;
        let mut run = |compact: bool| -> (f64, u64, u64) {
            let mut p = KvCachePool::with_slots_layout(
                &dcfg,
                fused_eng.attn_dim(),
                batch,
                max_seq,
                KvPrecision::F32,
                1.0,
                batch as f64,
                KvLayout::Paged,
                page_tokens,
                n_pages,
            );
            let ids: Vec<usize> =
                (0..batch).map(|_| p.alloc().unwrap()).collect();
            for &id in &ids {
                p.ensure_capacity(id, churn_prompt.len()).unwrap();
                fused_eng
                    .prefill(&mut rt, p.slot_mut(id), &churn_prompt)
                    .unwrap();
            }
            let pairs: Vec<(usize, bool)> =
                ids.iter().map(|&id| (id, false)).collect();
            let t0 = Instant::now();
            for _ in 0..cycles {
                for step in 0..churn_steps {
                    let reqs: Vec<BatchReq> = ids
                        .iter()
                        .enumerate()
                        .map(|(i, &id)| BatchReq {
                            slot: id,
                            pos: churn_prompt.len() + step,
                            token: ((7 + i * 13 + step) % 200) as i32,
                        })
                        .collect();
                    fused_eng
                        .step_batch(&mut p, &reqs, |_, logits| {
                            std::hint::black_box(logits);
                        })
                        .unwrap();
                }
                // churn: every slot is handed to a fresh request that
                // starts over at the prompt — the decoded tail pages
                // are dead weight until a compaction pass frees them
                for &id in &ids {
                    p.slot_mut(id).rewind(churn_prompt.len());
                }
                if compact {
                    p.compact(&pairs);
                }
            }
            let tps = (cycles * churn_steps * batch) as f64
                / t0.elapsed().as_secs_f64();
            let st = p.paged_stats();
            (tps, st.pages_reclaimed, st.compactions)
        };
        let (on, reclaimed, passes) = run(true);
        let (off, off_reclaimed, _) = run(false);
        assert!(reclaimed > 0, "churn workload reclaimed nothing");
        assert_eq!(off_reclaimed, 0);
        assert_eq!(passes, cycles as u64);
        let ratio = on / off.max(1e-9);
        println!(
            "SERVE decode_paged_compact_b{batch} \
             compact_tokens_per_sec={on:.0} \
             off_tokens_per_sec={off:.0} compact_vs_off={ratio:.2}x \
             pages_reclaimed={reclaimed} page_tokens={page_tokens}"
        );
        decode_entries.push(format!(
            "{{\"name\":\"decode_paged_compact_b{batch}\",\
             \"weights\":\"nf4\",\"kv_layout\":\"paged\",\
             \"page_tokens\":{page_tokens},\
             \"compact_tokens_per_sec\":{on:.1},\
             \"off_tokens_per_sec\":{off:.1},\
             \"compact_vs_off\":{ratio:.3},\
             \"pages_reclaimed\":{reclaimed},\
             \"compactions\":{passes},\
             \"threads\":{}}}",
            fused_eng.threads()
        ));
    }

    // 2b. phase-profiler overhead: the same fused engine config with
    // the sampled step timer on *every* decode step (the worst case —
    // serving defaults to every 4th) vs. profiling off. The
    // acceptance bar for the observability layer is < 2% throughput
    // regression; the measured ratio lands in BENCH_serve.json so the
    // trajectory is tracked across PRs. Also checks the lap-tiling
    // invariant: the per-phase times must sum to ~the sampled wall.
    {
        let prof_eng = EngineBuilder::new()
            .store(&dstore, &dbits)
            .max_seq(max_seq)
            .profile_every(1)
            .build(&mut rt)
            .unwrap();
        let batch = 8usize;
        let mut p = KvCachePool::with_slots(
            &dcfg,
            fused_eng.attn_dim(),
            batch,
            max_seq,
            KvPrecision::F32,
            1.0,
            batch as f64,
        );
        let ids: Vec<usize> =
            (0..batch).map(|_| p.alloc().unwrap()).collect();
        let rounds = 8;
        let off = decode_tokens_per_sec(&fused_eng, &mut rt, &mut p,
                                        &ids, &short_prompt, steps,
                                        rounds, true);
        let on = decode_tokens_per_sec(&prof_eng, &mut rt, &mut p,
                                       &ids, &short_prompt, steps,
                                       rounds, true);
        let overhead_pct = 100.0 * (1.0 - on / off.max(1e-9));
        let snap = prof_eng.phase_snapshot();
        assert!(snap.sampled_steps > 0, "profiler sampled nothing");
        let cov = snap.coverage();
        assert!(
            cov > 0.90 && cov < 1.01,
            "phase laps must tile the sampled wall (coverage {cov})"
        );
        println!(
            "SERVE profile_overhead_b8 tokens_per_sec_off={off:.0} \
             tokens_per_sec_on={on:.0} overhead_pct={overhead_pct:.2} \
             phase_coverage={cov:.4} sampled_steps={}",
            snap.sampled_steps
        );
        decode_entries.push(format!(
            "{{\"name\":\"profile_overhead_b8\",\
             \"tokens_per_sec_off\":{off:.1},\
             \"tokens_per_sec_on\":{on:.1},\
             \"overhead_pct\":{overhead_pct:.3},\
             \"phase_coverage\":{cov:.4},\
             \"sampled_steps\":{}}}",
            snap.sampled_steps
        ));
    }

    // 3. KV-cache precision footprint at a fixed modeled budget:
    // sessions admitted and host slab bytes for --kv-bits 32 vs 8
    let paper = ModelConfig::paper_7b();
    let per32 = memory::kv_bytes_per_session(&paper, 0, max_seq);
    let budget_gb = 8.0 * per32 / 1e9 + 1e-12;
    for (kv_bits, prec) in
        [(32u32, KvPrecision::F32), (8, KvPrecision::Int8)]
    {
        let p = KvCachePool::for_budget(&cfg, engine.attn_dim(),
                                        &paper, 0, max_seq, prec,
                                        budget_gb, 1024)
            .unwrap();
        println!(
            "SERVE kv_bits={kv_bits} sessions={} \
             host_slab_bytes={} modeled_budget_gb={:.3}",
            p.capacity(),
            p.host_slab_bytes(),
            p.modeled_budget_bytes() / 1e9
        );
    }
    // weights-side footprint twin: native residency vs f32
    println!(
        "SERVE weights residency=quantized host_bytes={} \
         f32_host_bytes={} modeled_native_gb={:.3}",
        fused_eng.weight_host_bytes(),
        base_eng.weight_host_bytes(),
        memory::weight_bytes_at(&paper, 0,
                                &memory::stretch_bits(&dbits,
                                                      paper.n_layers))
            / 1e9
    );

    // 4. closed-loop workloads at increasing concurrency, plus the
    // int8-KV variant at the highest concurrency; every config also
    // lands in results/BENCH_serve.json so the perf trajectory is
    // machine-readable across PRs
    let mut reports: Vec<(String, ServeReport)> = Vec::new();
    for (name, clients, max_batch, prec) in [
        ("c1_b1", 1usize, 1usize, KvPrecision::F32),
        ("c4_b4", 4, 4, KvPrecision::F32),
        ("c8_b8", 8, 8, KvPrecision::F32),
        ("c8_b8_kv8", 8, 8, KvPrecision::Int8),
    ] {
        let mut opts = ServeOpts::smoke();
        opts.clients = clients;
        opts.max_batch = max_batch;
        opts.requests = 64;
        opts.seed = 7;
        let lang = Language::new(cfg.vocab, 1);
        let mut metrics = Metrics::new();
        let builder = EngineBuilder::new()
            .store(&store, &bits)
            .kv_precision(prec);
        let report = run_workload(&mut rt, builder, &lang, &opts,
                                  &mut metrics)
            .unwrap();
        println!(
            "SERVE {name} tokens_per_sec={:.1} p50={:.3}ms p99={:.3}ms \
             occ={:.2} completed={} kv_bits={} kv_slab_bytes={} \
             weight_bytes={} threads={}",
            report.tokens_per_sec(),
            report.latency.percentile_ms(50.0),
            report.latency.percentile_ms(99.0),
            report.mean_occupancy,
            report.completed,
            report.kv_bits,
            report.kv_host_slab_bytes,
            report.weight_resident_bytes,
            report.threads
        );
        assert_eq!(report.completed, 64);
        reports.push((name.to_string(), report));
    }
    let entries: Vec<(String, &ServeReport)> = reports
        .iter()
        .map(|(n, r)| (n.clone(), r))
        .collect();
    let out_dir = std::path::Path::new("results");
    std::fs::create_dir_all(out_dir).unwrap();
    let json_path = out_dir.join("BENCH_serve.json");
    // workload entries first, then the decode-kernel lines appended
    // into the same trajectory array
    let mut body = bench_json(&entries);
    for e in &decode_entries {
        body = bench_json_append_obj(Some(&body), e);
    }
    std::fs::write(&json_path, body).unwrap();
    println!("wrote {json_path:?}");
}
