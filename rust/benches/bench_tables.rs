//! Table/figure regeneration benchmarks: one timing per paper artifact
//! (tiny model, smoke fidelity). Ensures every table and figure in the
//! evaluation section has a measured regeneration path.

#[path = "harness.rs"]
mod harness;

use qpruner::coordinator::Coordinator;
use qpruner::data::Language;
use qpruner::experiments::{self, Scale};
use qpruner::model::ModelConfig;
use qpruner::runtime::Runtime;

fn main() {
    let Some(dir) = harness::artifacts_dir() else {
        println!("SKIP bench_tables: artifacts not built");
        return;
    };
    let mut coord =
        Coordinator::new(Runtime::new(&dir).unwrap(), Language::new(256, 1));
    let cfg = ModelConfig::preset("tiny").unwrap();
    let (store, _) = coord.pretrain(&cfg, 48, 3e-3, 12).unwrap();
    let scale = Scale::smoke();

    harness::bench("fig1_motivating", 0, 2, || {
        std::hint::black_box(
            experiments::fig1_motivating(&mut coord, &store, &scale)
                .unwrap(),
        );
    });
    harness::bench("table1_one_rate", 0, 2, || {
        std::hint::black_box(
            experiments::table1(&mut coord, &[("tiny", &store)], &[20],
                                &scale)
                .unwrap(),
        );
    });
    harness::bench("table2_ablations", 0, 1, || {
        std::hint::black_box(
            experiments::table2_ablation(&mut coord, &store, &scale)
                .unwrap(),
        );
    });
    harness::bench("table3_13b", 0, 1, || {
        std::hint::black_box(
            experiments::table3_13b(&mut coord, &store, &scale).unwrap(),
        );
    });
    harness::bench("fig3_pareto_6pts", 0, 1, || {
        std::hint::black_box(
            experiments::fig3_pareto(&mut coord, &store, 50, 6, 3, &scale)
                .unwrap(),
        );
    });
}
