//! End-to-end pipeline stage benchmarks (tiny model, smoke fidelity):
//! prune / MI-allocate / LoftQ-prepare / fine-tune / eval / full run
//! per method. This is the App.-D-style cost accounting of Algorithm 1.

#[path = "harness.rs"]
mod harness;

use qpruner::coordinator::{Coordinator, Method, PipelineOpts};
use qpruner::data::Language;
use qpruner::experiments::Scale;
use qpruner::model::ModelConfig;
use qpruner::runtime::Runtime;

fn main() {
    let Some(dir) = harness::artifacts_dir() else {
        println!("SKIP bench_pipeline: artifacts not built");
        return;
    };
    let mut coord =
        Coordinator::new(Runtime::new(&dir).unwrap(), Language::new(256, 1));
    let cfg = ModelConfig::preset("tiny").unwrap();
    let (store, _) = coord.pretrain(&cfg, 48, 3e-3, 11).unwrap();

    let mut opts = PipelineOpts::quick(20, Method::QPruner2);
    Scale::smoke().apply(&mut opts);

    harness::bench("stage_prune_taylor_compact", 1, 5, || {
        std::hint::black_box(
            coord.prune(&store, &opts.prune, opts.seed).unwrap());
    });

    let pruned = coord.prune(&store, &opts.prune, opts.seed).unwrap();
    harness::bench("stage_mi_allocate", 1, 5, || {
        std::hint::black_box(
            coord.allocate_bits_mi(&pruned, &opts.quant, opts.seed)
                .unwrap());
    });

    let bits =
        coord.allocate_bits_mi(&pruned, &opts.quant, opts.seed).unwrap();
    harness::bench("stage_bo_candidate_eval", 1, 5, || {
        let mut rng = qpruner::rng::Rng::new(9);
        std::hint::black_box(
            coord.evaluate_candidate(&pruned, &bits, &opts, &mut rng)
                .unwrap(),
        );
    });

    for method in [Method::LlmPruner, Method::QPruner1, Method::QPruner2,
                   Method::QPruner3] {
        let mut o = PipelineOpts::quick(20, method);
        Scale::smoke().apply(&mut o);
        harness::bench(
            &format!("pipeline_full_{}", method.label()
                         .to_lowercase().replace(['^', '-'], "")),
            0, 3,
            || {
                std::hint::black_box(coord.run(&store, &o).unwrap());
            },
        );
    }
}
