//! Quantizer hot-path benchmarks.
//!
//! NF4/FP4/INT8 blockwise quantize + dequantize throughput on
//! base-model-sized projection stacks, plus LoftQ/PiSSA init cost —
//! these run once per BO candidate, so they gate Algorithm 1's
//! wall-clock (paper App. D reports ~25 min/candidate at 7B on GPU;
//! our per-candidate budget at simulator scale is < 1 s host work).

#[path = "harness.rs"]
mod harness;

use qpruner::lora::{init_loftq, InitMethod};
use qpruner::model::{ModelConfig, ParamStore};
use qpruner::quant::{dequantize, quantize, simulate, BitConfig, QuantFormat};
use qpruner::rng::Rng;
use qpruner::tensor::Tensor;

fn main() {
    let mut rng = Rng::new(1);
    // one base-model w_gate stack slab: [1024, 384]
    let w = Tensor::randn(&[1024, 384], 0.05, &mut rng);
    let bytes = w.len() * 4;

    for fmt in [QuantFormat::Nf4, QuantFormat::Fp4, QuantFormat::Int8] {
        harness::bench_throughput(
            &format!("quantize_{}_1024x384", fmt.label()),
            2, 10, bytes,
            || {
                std::hint::black_box(quantize(&w, fmt));
            },
        );
        let q = quantize(&w, fmt);
        harness::bench_throughput(
            &format!("dequantize_{}_1024x384", fmt.label()),
            2, 10, bytes,
            || {
                std::hint::black_box(dequantize(&q));
            },
        );
        harness::bench(
            &format!("simulate_roundtrip_{}_1024x384", fmt.label()),
            1, 5,
            || {
                std::hint::black_box(simulate(&w, fmt));
            },
        );
    }

    // LoftQ init over a whole tiny model (56 projection matrices)
    let cfg = ModelConfig::preset("tiny").unwrap();
    let store = ParamStore::init(&cfg, 2);
    let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
    harness::bench("loftq_init_tiny_model", 1, 5, || {
        let mut r = Rng::new(3);
        std::hint::black_box(init_loftq(&store, &bits, 1, &mut r).unwrap());
    });
    let _ = InitMethod::Gaussian;
}
