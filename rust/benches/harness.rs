//! Minimal bench harness (criterion is not vendored offline).
//!
//! Reports mean / p50 / p95 over timed iterations after warmup, in a
//! stable machine-greppable format:
//!
//!   BENCH <name> iters=<n> mean=<ms> p50=<ms> p95=<ms> [thrpt=<...>]

#![allow(dead_code)]

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        p50_ms: p50,
        p95_ms: p95,
    };
    println!(
        "BENCH {name} iters={iters} mean={mean:.3}ms p50={p50:.3}ms p95={p95:.3}ms"
    );
    r
}

/// Like `bench` but also prints throughput given bytes processed per
/// iteration.
pub fn bench_throughput<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                                    bytes_per_iter: usize, f: F)
                                    -> BenchResult {
    let r = bench(name, warmup, iters, f);
    let mbps = bytes_per_iter as f64 / (r.mean_ms / 1e3) / 1e6;
    println!("BENCH {name} thrpt={mbps:.1}MB/s");
    r
}

/// Artifact dir shared by runtime-dependent benches; None -> skip.
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::env::var("QPRUNER_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts")
        });
    dir.join("manifest.tsv").exists().then_some(dir)
}
