//! Bayesian-optimization surrogate benchmarks (paper Appendix D: the
//! GP suggestion step took ~7 s and ~187 MB at 32 layers / 50 points;
//! our rust GP should be orders of magnitude cheaper).

#[path = "harness.rs"]
mod harness;

use qpruner::bo::{self, Acquisition, Gp, Observation};
use qpruner::quant::{BitConfig, QuantFormat};
use qpruner::rng::Rng;

fn synth_observations(n: usize, n_layers: usize, rng: &mut Rng)
                      -> Vec<Observation> {
    let mut out: Vec<Observation> = Vec::new();
    while out.len() < n {
        let n8 = rng.below(n_layers / 2 + 1);
        let mut c = BitConfig::uniform(n_layers, QuantFormat::Nf4);
        for i in rng.choose_k(n_layers, n8) {
            c.layers[i] = QuantFormat::Int8;
        }
        if out.iter().any(|o| o.config.short() == c.short()) {
            continue;
        }
        let perf = 0.5
            + 0.02 * c.features().iter().sum::<f64>()
            + 0.01 * rng.normal();
        let mem = 20.0 + c.mean_bits();
        out.push(Observation { config: c, perf, memory_gb: mem });
    }
    out
}

fn main() {
    let mut rng = Rng::new(7);
    // paper-scale: 32 layers, growing dataset sizes
    for n in [10usize, 25, 50] {
        let obs = synth_observations(n, 32, &mut rng);
        let xs: Vec<Vec<f64>> =
            obs.iter().map(|o| o.config.features()).collect();
        let ys: Vec<f64> = obs.iter().map(|o| o.perf).collect();
        harness::bench(&format!("gp_fit_n{n}_l32"), 2, 20, || {
            std::hint::black_box(Gp::fit(&xs, &ys, 4.0, 1e-4).unwrap());
        });
        let gp = Gp::fit(&xs, &ys, 4.0, 1e-4).unwrap();
        let probe = obs[0].config.features();
        harness::bench(&format!("gp_predict_n{n}_l32"), 10, 100, || {
            std::hint::black_box(gp.predict(&probe));
        });
        let mut r2 = Rng::new(n as u64);
        harness::bench(&format!("bo_suggest_n{n}_l32"), 1, 10, || {
            std::hint::black_box(
                bo::suggest(&obs, Acquisition::Ei, QuantFormat::Nf4, 0.25,
                            &mut r2)
                    .unwrap(),
            );
        });
        harness::bench(&format!("pareto_front_n{n}"), 5, 50, || {
            std::hint::black_box(bo::pareto_front(&obs));
        });
    }
}
