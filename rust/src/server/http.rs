//! Minimal HTTP/1.1 request parsing and response writing over any
//! `Read`/`Write` pair — std-only, like the rest of the serving stack.
//!
//! Scope is deliberately small: one request per connection
//! (`Connection: close` on every response), request heads capped at
//! 16 KB and bodies at 1 MB, no chunked transfer encoding, no
//! keep-alive. That is all the serving front-end needs, and every
//! byte of it is testable against an in-memory `Cursor`.

use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

/// Request-head cap (request line + headers). A head that exceeds
/// this is a malformed or hostile client; the connection is dropped
/// with a 400 before any allocation proportional to its input.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Body cap. The largest legitimate body is a `/v1/generate` prompt
/// of `max_seq` token ids, which is orders of magnitude below this.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// One parsed HTTP/1.1 request. Header names are lowercased at parse
/// time so lookups are case-insensitive, per RFC 9110.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// path with any query string stripped
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == want)
            .map(|(_, v)| v.as_str())
    }
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Read and parse one request. Errors on oversized heads/bodies,
/// truncated streams, and malformed request lines — the caller maps
/// any error to a 400 and closes.
pub fn read_request<R: Read>(r: &mut R) -> Result<Request> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        ensure!(buf.len() <= MAX_HEAD_BYTES,
                "request head exceeds {MAX_HEAD_BYTES} bytes");
        let n = r.read(&mut tmp).context("reading request head")?;
        ensure!(n > 0, "connection closed mid-request");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .context("request head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .context("empty request line")?
        .to_string();
    let target = parts.next().context("request line has no target")?;
    let version = parts.next().context("request line has no version")?;
    ensure!(version.starts_with("HTTP/1."),
            "unsupported protocol {version:?}");
    let path = target
        .split('?')
        .next()
        .unwrap_or(target)
        .to_string();
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once(':')
            .with_context(|| format!("malformed header {line:?}"))?;
        headers.push((
            k.trim().to_ascii_lowercase(),
            v.trim().to_string(),
        ));
    }
    let content_len: usize = match headers
        .iter()
        .find(|(k, _)| k == "content-length")
    {
        None => 0,
        Some((_, v)) => v
            .parse()
            .with_context(|| format!("bad Content-Length {v:?}"))?,
    };
    ensure!(content_len <= MAX_BODY_BYTES,
            "body of {content_len} bytes exceeds {MAX_BODY_BYTES}");
    let mut body = buf[head_end + 4..].to_vec();
    if body.len() > content_len {
        bail!("body longer than Content-Length");
    }
    while body.len() < content_len {
        let want = (content_len - body.len()).min(tmp.len());
        let n = r.read(&mut tmp[..want]).context("reading body")?;
        ensure!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&tmp[..n]);
    }
    Ok(Request { method, path, headers, body })
}

pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        422 => "Unprocessable Content",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one complete response (status + headers + body) and flush.
/// Every response carries `Content-Length` and `Connection: close`.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
) -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", status, reason_phrase(status))?;
    write!(w, "Content-Type: {content_type}\r\n")?;
    write!(w, "Content-Length: {}\r\n", body.len())?;
    write!(w, "Connection: close\r\n")?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// JSON-body convenience wrapper over [`write_response`].
pub fn write_json<W: Write>(
    w: &mut W,
    status: u16,
    extra_headers: &[(&str, String)],
    body: &str,
) -> std::io::Result<()> {
    write_response(w, status, "application/json", extra_headers,
                   body.as_bytes())
}

/// Error-body convenience: `{"error":"..."}` with proper escaping.
pub fn write_error<W: Write>(
    w: &mut W,
    status: u16,
    extra_headers: &[(&str, String)],
    msg: &str,
) -> std::io::Result<()> {
    let body =
        format!("{{\"error\":\"{}\"}}", crate::obs::json::escape(msg));
    write_json(w, status, extra_headers, &body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Request> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_get_with_query_and_headers() {
        let r = parse(
            "GET /metrics?x=1 HTTP/1.1\r\nHost: a\r\n\
             X-Thing:  padded \r\n\r\n",
        )
        .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/metrics");
        assert_eq!(r.header("x-thing"), Some("padded"));
        assert_eq!(r.header("X-THING"), Some("padded"));
        assert_eq!(r.header("absent"), None);
        assert!(r.body.is_empty());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let r = parse(
            "POST /v1/generate HTTP/1.1\r\nContent-Length: 11\r\n\r\n\
             {\"a\":[1,2]}",
        )
        .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(std::str::from_utf8(&r.body).unwrap(),
                   "{\"a\":[1,2]}");
    }

    #[test]
    fn rejects_malformed_and_oversized() {
        assert!(parse("garbage\r\n\r\n").is_err());
        assert!(parse("GET /x HTTP/2\r\n\r\n").is_err());
        assert!(parse("GET /x HTTP/1.1\r\nnocolon\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/1.1\r\nContent-Length: z\r\n\r\n")
            .is_err());
        // truncated body
        assert!(parse(
            "POST / HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort"
        )
        .is_err());
        // oversized head: never terminates within the cap
        let huge = format!("GET / HTTP/1.1\r\nX: {}\r\n\r\n",
                           "a".repeat(MAX_HEAD_BYTES + 10));
        assert!(parse(&huge).is_err());
        // declared body above the cap is refused before reading it
        let big = format!(
            "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        assert!(parse(&big).is_err());
    }

    #[test]
    fn response_writer_emits_complete_http() {
        let mut out = Vec::new();
        write_json(&mut out, 429,
                   &[("Retry-After", "3".to_string())],
                   "{\"error\":\"queue-full\"}")
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(s.contains("Content-Type: application/json\r\n"));
        assert!(s.contains("Content-Length: 22\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.contains("Retry-After: 3\r\n"));
        assert!(s.ends_with("{\"error\":\"queue-full\"}"));
    }

    #[test]
    fn error_writer_escapes_messages() {
        let mut out = Vec::new();
        write_error(&mut out, 400, &[], "bad \"prompt\"\nline").unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.contains("{\"error\":\"bad \\\"prompt\\\"\\nline\"}"));
    }
}
