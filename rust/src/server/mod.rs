//! Network serving front-end: a dependency-free TCP/HTTP-1.1 server
//! that turns the continuous-batching scheduler (`crate::serve`) into
//! an online service.
//!
//! Architecture: the thread that calls [`Server::run`] owns the
//! engine, the scheduler, and the runtime — none of them ever cross a
//! thread boundary, so the decode path is byte-identical to the
//! offline workload driver's. A listener thread accepts connections
//! (bounded by `max_conns`; excess connections get an immediate 503)
//! and hands each one to a short-lived worker thread. Workers parse
//! the request and talk to the core loop over one bounded command
//! channel; the core drains commands between scheduler steps, so
//! admission decisions always see a consistent queue. Token streaming
//! runs the other way: each admitted session gets a bounded
//! per-session channel the core pushes freshly sampled tokens into
//! after every step, and the worker frames them as SSE events (or
//! collects them for a single JSON response). A send failure means
//! the client is gone — the core cancels the session so its KV slot
//! frees immediately instead of decoding into the void.
//!
//! Shutdown (SIGTERM/SIGINT via [`drain`], or a test flipping the
//! shared flag) stops the accept loop, sheds new submissions with
//! 503s, finishes or TTL-evicts everything in flight, flushes the
//! configured trace/metrics exports, and returns a [`DrainReport`]
//! whose leak counters the CLI turns into the process exit code.

pub mod drain;
pub mod http;
pub mod router;
pub mod sse;

use crate::artifact::{LoraMode, ModelArtifact};
use crate::obs::span::SpanOutcome;
use crate::obs::trace_export;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::serve::engine::{Engine, EngineBuilder};
use crate::serve::faults::FaultPoint;
use crate::serve::kv_cache::KvPrecision;
use crate::serve::scheduler::Scheduler;
use crate::serve::{self, ServeOpts};
use anyhow::{Context, Result};
use router::{GenerateDefaults, GenerateRequest, Route};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize,
                        Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender,
                      TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Hard bound on scheduler steps spent draining after shutdown — far
/// above any legitimate in-flight work (a session generates at most
/// `max_seq` tokens); hitting it force-cancels whatever remains so
/// the process always exits.
const MAX_DRAIN_STEPS: u64 = 100_000;

/// Engine knobs the server must be able to re-apply when it rebuilds
/// an engine for `/admin/reload` — the builder itself is consumed by
/// `build`, so the template is what survives.
#[derive(Clone, Copy, Debug)]
pub struct EngineTemplate {
    pub kv_precision: KvPrecision,
    pub lora: Option<LoraMode>,
    pub threads: Option<usize>,
    pub profile_every: Option<u32>,
}

impl Default for EngineTemplate {
    fn default() -> EngineTemplate {
        EngineTemplate {
            kv_precision: KvPrecision::F32,
            lora: None,
            threads: None,
            profile_every: None,
        }
    }
}

impl EngineTemplate {
    /// Stamp every configured knob onto a fresh builder.
    pub fn apply(&self, mut b: EngineBuilder) -> EngineBuilder {
        b = b.kv_precision(self.kv_precision);
        if let Some(m) = self.lora {
            b = b.lora(m);
        }
        if let Some(n) = self.threads {
            b = b.threads(n);
        }
        if let Some(n) = self.profile_every {
            b = b.profile_every(n);
        }
        b
    }
}

/// Front-end knobs wrapping the shared serving options.
#[derive(Clone, Debug)]
pub struct ServerOpts {
    /// bind address; port 0 picks an ephemeral port (tests, CI)
    pub addr: String,
    /// concurrent-connection cap; excess connections get 503
    pub max_conns: usize,
    /// scheduler / pool / workload knobs shared with `serve`
    pub serve: ServeOpts,
    /// engine knobs re-applied on artifact reload
    pub template: EngineTemplate,
    /// per-connection read AND write timeout; 0 disables both
    pub io_timeout_secs: u64,
    /// watchdog trips when the core loop misses heartbeats for this
    /// long; 0 disables the watchdog thread
    pub watchdog_ms: u64,
}

impl ServerOpts {
    pub fn new(serve: ServeOpts) -> ServerOpts {
        ServerOpts {
            addr: "127.0.0.1:8080".to_string(),
            max_conns: 64,
            serve,
            template: EngineTemplate::default(),
            io_timeout_secs: 10,
            watchdog_ms: 1000,
        }
    }
}

/// Shared liveness/readiness state: the core loop publishes, the
/// watchdog thread and connection workers read. Everything is
/// lock-free so a wedged core loop can still be observed.
pub struct ServerHealth {
    queue_len: AtomicUsize,
    active: AtomicUsize,
    step_no: AtomicU64,
    brownout: AtomicBool,
    tripped: AtomicBool,
    trips: AtomicU64,
    /// Retry-After hint workers attach to every shed response,
    /// published by the core so it reflects admission + brownout state
    retry_after: AtomicU64,
    /// microseconds since `epoch` of the last core-loop heartbeat
    last_beat_us: AtomicU64,
    epoch: Instant,
}

impl ServerHealth {
    fn new() -> ServerHealth {
        ServerHealth {
            queue_len: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            step_no: AtomicU64::new(0),
            brownout: AtomicBool::new(false),
            tripped: AtomicBool::new(false),
            trips: AtomicU64::new(0),
            retry_after: AtomicU64::new(1),
            last_beat_us: AtomicU64::new(0),
            epoch: Instant::now(),
        }
    }

    fn us_now(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn beat(&self, queue_len: usize, active: usize, step_no: u64,
            brownout: bool, retry_after: u64) {
        self.queue_len.store(queue_len, Ordering::Relaxed);
        self.active.store(active, Ordering::Relaxed);
        self.step_no.store(step_no, Ordering::Relaxed);
        self.brownout.store(brownout, Ordering::Relaxed);
        self.retry_after.store(retry_after.max(1), Ordering::Relaxed);
        self.last_beat_us.store(self.us_now(), Ordering::Relaxed);
    }

    fn retry_after(&self) -> u64 {
        self.retry_after.load(Ordering::Relaxed)
    }

    pub fn brownout(&self) -> bool {
        self.brownout.load(Ordering::Relaxed)
    }

    pub fn watchdog_tripped(&self) -> bool {
        self.tripped.load(Ordering::Relaxed)
    }

    pub fn watchdog_trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// One-line diagnostic of the last published core-loop state,
    /// logged when the watchdog trips.
    fn snapshot(&self) -> String {
        format!(
            "step {} queue {} active {} brownout {}",
            self.step_no.load(Ordering::Relaxed),
            self.queue_len.load(Ordering::Relaxed),
            self.active.load(Ordering::Relaxed),
            self.brownout.load(Ordering::Relaxed),
        )
    }
}

/// Watch the core loop's heartbeat from a side thread. A missed beat
/// longer than `threshold_ms` trips the watchdog: the last published
/// scheduler state is logged and `/healthz` turns not-ready until
/// beats resume. The trip counter latches so a flap is still visible
/// in the drain report after recovery.
fn spawn_watchdog(
    health: Arc<ServerHealth>,
    stop: Arc<AtomicBool>,
    threshold_ms: u64,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let poll = Duration::from_millis(
            (threshold_ms / 4).clamp(1, 250),
        );
        loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            let stale_us = health.us_now().saturating_sub(
                health.last_beat_us.load(Ordering::Relaxed),
            );
            let stale =
                stale_us > threshold_ms.saturating_mul(1000);
            let was = health.tripped.swap(stale, Ordering::Relaxed);
            if stale && !was {
                health.trips.fetch_add(1, Ordering::Relaxed);
                eprintln!(
                    "[serve-http] watchdog: no heartbeat for \
                     {} ms — {}",
                    stale_us / 1000,
                    health.snapshot(),
                );
            } else if !stale && was {
                eprintln!(
                    "[serve-http] watchdog: heartbeat recovered \
                     — {}",
                    health.snapshot(),
                );
            }
            std::thread::sleep(poll);
        }
    })
}

/// Readiness contract for `GET /healthz`: 200 only while the server
/// is able to take new work ("serving"). Draining, brownout, and a
/// tripped watchdog all report 503 with a distinct `state` label so
/// load balancers stop routing while the process stays observable.
fn healthz_body(draining: bool,
                health: &ServerHealth) -> (u16, String) {
    let state = if draining {
        "draining"
    } else if health.watchdog_tripped() {
        "watchdog"
    } else if health.brownout() {
        "brownout"
    } else {
        "serving"
    };
    let ready = state == "serving";
    let status = if ready { 200 } else { 503 };
    let body = format!(
        "{{\"ok\":{ready},\"state\":\"{state}\",\
         \"draining\":{draining}}}"
    );
    (status, body)
}

/// What the core loop pushes into a session's stream channel.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TokenEvent {
    Token(i32),
    Done {
        /// terminal outcome label: "done" | "evicted" |
        /// "deadline" | "quarantined" | "disconnect"
        outcome: &'static str,
        tokens: usize,
    },
}

/// Core-loop answer to a `/v1/generate` submission.
pub enum SubmitResult {
    Admitted { id: u64, rx: Receiver<TokenEvent> },
    Rejected { reason: &'static str, retry_after: u64 },
    /// server is shutting down; shed with 503
    Draining,
}

enum ReloadResult {
    Swapped(String),
    Incompatible(String),
    Failed(String),
}

/// Worker → core commands. One bounded channel carries all of them,
/// so `/metrics` and `/traces` can never be starved behind an
/// unbounded submit flood — the flood saturates the same bound.
enum Cmd {
    Submit {
        req: GenerateRequest,
        resp: SyncSender<SubmitResult>,
    },
    Metrics {
        resp: SyncSender<String>,
    },
    Traces {
        resp: SyncSender<String>,
    },
    Reload {
        path: PathBuf,
        resp: SyncSender<ReloadResult>,
    },
    /// run one KV page-compaction pass; `Err` = compaction disabled
    /// (`--compact off`), mapped to a 409 at the HTTP layer
    Compact {
        resp: SyncSender<Result<String, String>>,
    },
}

/// End-of-life accounting for one server run. `clean()` gates the
/// CLI's exit code and the integration tests' drain assertions.
#[derive(Clone, Debug)]
pub struct DrainReport {
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    pub evicted: usize,
    /// sub-buckets of `evicted`, keyed by failure reason
    pub deadline_exceeded: usize,
    pub quarantined: usize,
    pub disconnects: usize,
    pub generated_tokens: u64,
    pub steps: u64,
    pub reloads: u64,
    pub watchdog_trips: u64,
    /// faults the configured `--fault-plan` actually injected
    pub faults_injected: u64,
    pub wall_secs: f64,
    /// KV slots still held after drain — must be 0
    pub leaked_slots: usize,
    /// KV pages still held after drain (prefix index cleared) — 0
    pub leaked_pages: usize,
    /// spans left open in the tracer — must be 0
    pub live_spans: usize,
    pub dropped_spans: u64,
}

impl DrainReport {
    pub fn clean(&self) -> bool {
        self.leaked_slots == 0 && self.leaked_pages == 0
            && self.live_spans == 0
    }

    pub fn summary(&self) -> String {
        format!(
            "submitted {} completed {} rejected {} evicted {} \
             deadline {} quarantined {} disconnects {} \
             tokens {} steps {} reloads {} watchdog_trips {} \
             faults_injected {} leaked_slots {} \
             leaked_pages {} live_spans {} dropped_spans {}",
            self.submitted, self.completed, self.rejected,
            self.evicted, self.deadline_exceeded, self.quarantined,
            self.disconnects, self.generated_tokens, self.steps,
            self.reloads, self.watchdog_trips, self.faults_injected,
            self.leaked_slots, self.leaked_pages,
            self.live_spans, self.dropped_spans
        )
    }
}

/// Per-session stream state held by the core loop.
struct Sink {
    tx: SyncSender<TokenEvent>,
    /// tokens already pushed (index into `Session::generated`)
    cursor: usize,
}

/// Read-only context each connection worker gets.
#[derive(Clone)]
struct ConnCtx {
    cmd_tx: SyncSender<Cmd>,
    shutdown: Arc<AtomicBool>,
    vocab: usize,
    defaults: GenerateDefaults,
    health: Arc<ServerHealth>,
    /// read AND write timeout applied to accepted sockets
    io_timeout: Option<Duration>,
}

impl ConnCtx {
    fn draining(&self) -> bool {
        self.shutdown.load(Ordering::Relaxed) || drain::signaled()
    }

    /// Retry hint for shed responses (503/429 without a scheduler
    /// verdict), as last published by the core loop.
    fn retry_after(&self) -> u64 {
        self.health.retry_after()
    }
}

/// A bound-but-not-yet-running server. Splitting bind from run lets
/// callers learn the ephemeral port before the core loop takes over
/// the thread.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
}

impl Server {
    pub fn bind(addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        Ok(Server { listener, addr })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until `shutdown` (or a process signal) requests a drain,
    /// then drain and report. Consumes the server; the calling thread
    /// becomes the core loop.
    pub fn run(
        self,
        rt: &mut Runtime,
        builder: EngineBuilder,
        opts: &ServerOpts,
        shutdown: Arc<AtomicBool>,
    ) -> Result<DrainReport> {
        // the tracer is always installed: it feeds GET /traces and
        // the drain-time exports
        let (mut engine, mut sched) =
            serve::build_stack(rt, builder, &opts.serve, true)?;

        let (cmd_tx, cmd_rx) =
            sync_channel::<Cmd>(opts.serve.max_queue.max(1) + 16);
        let health = Arc::new(ServerHealth::new());
        health.beat(0, 0, 0, false, 1);
        let ctx = ConnCtx {
            cmd_tx,
            shutdown: shutdown.clone(),
            vocab: engine.cfg().vocab,
            defaults: GenerateDefaults {
                max_new: opts.serve.max_new.1,
                temperature: opts.serve.temperature,
                seed: opts.serve.seed,
            },
            health: health.clone(),
            io_timeout: match opts.io_timeout_secs {
                0 => None,
                s => Some(Duration::from_secs(s)),
            },
        };
        let watchdog_stop = Arc::new(AtomicBool::new(false));
        let watchdog = if opts.watchdog_ms > 0 {
            Some(spawn_watchdog(
                health.clone(),
                watchdog_stop.clone(),
                opts.watchdog_ms,
            ))
        } else {
            None
        };

        let listener = self.listener;
        listener
            .set_nonblocking(true)
            .context("listener nonblocking")?;
        let max_conns = opts.max_conns.max(1);
        // the accept loop takes the only long-lived sender; channel
        // disconnect then means "listener exited and every worker
        // finished"
        let accept_handle = std::thread::spawn(move || {
            accept_loop(listener, ctx, max_conns);
        });

        let mut sinks: HashMap<u64, Sink> = HashMap::new();
        let mut workload_rng = Rng::new(opts.serve.seed ^ 0x5E47E);
        let t0 = Instant::now();
        let mut reloads = 0u64;
        let mut next_client = 0usize;
        let mut drain_steps = 0u64;

        loop {
            let draining =
                shutdown.load(Ordering::Relaxed) || drain::signaled();
            health.beat(
                sched.queue_len(),
                sched.active_len(),
                sched.step_no(),
                sched.brownout.active(),
                sched.retry_after_secs(sched.queue_len()),
            );

            let mut cmds: Vec<Cmd> = Vec::new();
            loop {
                match cmd_rx.try_recv() {
                    Ok(c) => cmds.push(c),
                    Err(TryRecvError::Empty)
                    | Err(TryRecvError::Disconnected) => break,
                }
            }
            if cmds.is_empty() && sched.idle() {
                if draining {
                    break;
                }
                // idle: block briefly for the next command instead of
                // spinning
                match cmd_rx
                    .recv_timeout(Duration::from_millis(2))
                {
                    Ok(c) => cmds.push(c),
                    Err(e) => {
                        if matches!(
                            e,
                            std::sync::mpsc::RecvTimeoutError::Disconnected
                        ) {
                            // accept loop died with nothing in flight
                            break;
                        }
                    }
                }
            }

            for cmd in cmds {
                match cmd {
                    Cmd::Submit { req, resp } => {
                        if draining {
                            let _ = resp.send(SubmitResult::Draining);
                            continue;
                        }
                        let qlen = sched.queue_len();
                        let decision = sched.admission.decide(
                            req.prompt.len(),
                            req.max_new,
                            qlen,
                        );
                        let client = next_client;
                        next_client += 1;
                        match sched.submit_req(
                            client,
                            req.prompt,
                            req.max_new,
                            req.seed,
                            req.temperature,
                            req.deadline_ms,
                        ) {
                            Some(id) => {
                                let (tx, rx) =
                                    sync_channel(req.max_new + 2);
                                if resp
                                    .send(SubmitResult::Admitted {
                                        id,
                                        rx,
                                    })
                                    .is_ok()
                                {
                                    sinks.insert(
                                        id,
                                        Sink { tx, cursor: 0 },
                                    );
                                } else {
                                    // worker died before hearing the
                                    // verdict: don't decode for a
                                    // ghost
                                    sched.cancel(id);
                                    sched.table.remove(id);
                                }
                            }
                            None => {
                                use crate::serve::admission::Decision;
                                let reason = match decision {
                                    Decision::Reject(r) => r.label(),
                                    Decision::Admit => "rejected",
                                };
                                let _ = resp.send(
                                    SubmitResult::Rejected {
                                        reason,
                                        retry_after: sched
                                            .retry_after_secs(qlen),
                                    },
                                );
                            }
                        }
                    }
                    Cmd::Metrics { resp } => {
                        let (g, r) = engine.scratch_stats();
                        let mut reg = serve::metrics_registry(
                            &sched,
                            g,
                            r,
                            t0.elapsed().as_secs_f64(),
                        );
                        reg.counter_add(
                            "serve.watchdog_trips",
                            health.watchdog_trips(),
                        );
                        let _ = resp.send(reg.snapshot_json());
                    }
                    Cmd::Traces { resp } => {
                        let body = match sched.tracer() {
                            Some(tr) => {
                                trace_export::events_jsonl(tr, &[])
                            }
                            None => String::new(),
                        };
                        let _ = resp.send(body);
                    }
                    Cmd::Reload { path, resp } => {
                        if sched.fire_fault(FaultPoint::ReloadCorrupt)
                        {
                            // simulated torn/corrupt artifact read:
                            // the old engine must keep serving
                            let _ = resp.send(ReloadResult::Failed(
                                "injected fault: artifact \
                                 corruption"
                                    .to_string(),
                            ));
                            continue;
                        }
                        let result = reload_engine(
                            rt, &path, opts, &engine,
                        );
                        let _ = resp.send(match result {
                            Ok(new_engine) => {
                                let label = format!(
                                    "{} ({} layers, vocab {})",
                                    path.display(),
                                    new_engine.cfg().n_layers,
                                    new_engine.cfg().vocab,
                                );
                                engine = new_engine;
                                reloads += 1;
                                ReloadResult::Swapped(label)
                            }
                            Err(ReloadError::Incompatible(m)) => {
                                ReloadResult::Incompatible(m)
                            }
                            Err(ReloadError::Failed(m)) => {
                                ReloadResult::Failed(m)
                            }
                        });
                    }
                    Cmd::Compact { resp } => {
                        let msg = if !sched
                            .pool
                            .compact_mode()
                            .enabled()
                        {
                            Err("compaction disabled (--compact off)"
                                .to_string())
                        } else {
                            let rep = sched.run_compaction();
                            Ok(format!(
                                "{{\"compactions\":1,\
                                 \"pages_reclaimed\":{},\
                                 \"migrated\":{},\
                                 \"quarantined\":{}}}",
                                rep.pages_reclaimed,
                                rep.migrated,
                                rep.failed.len(),
                            ))
                        };
                        let _ = resp.send(msg);
                    }
                }
            }

            if !sched.idle() {
                if let Err(e) = sched.step(
                    &engine,
                    rt,
                    &mut workload_rng,
                    opts.serve.stall_prob,
                ) {
                    // the scheduler already evicted the failing
                    // sessions; their sinks see Done{evicted} on the
                    // next pump
                    eprintln!("[serve-http] step error: {e:#}");
                }
                pump_sinks(&mut sched, &mut sinks);
                if opts.serve.stats_every > 0
                    && sched.step_no() % opts.serve.stats_every == 0
                {
                    eprintln!(
                        "[serve-http] step {:>6}  done {:>5}  \
                         active {:>3}  queue {:>3}  streams {:>3}",
                        sched.step_no(),
                        sched.stats.completed,
                        sched.active_len(),
                        sched.queue_len(),
                        sinks.len(),
                    );
                }
                if draining {
                    drain_steps += 1;
                    if drain_steps > MAX_DRAIN_STEPS {
                        eprintln!(
                            "[serve-http] drain guard tripped; \
                             cancelling {} live sessions",
                            sinks.len()
                        );
                        let ids: Vec<u64> =
                            sinks.keys().copied().collect();
                        for id in ids {
                            sched.cancel(id);
                        }
                        pump_sinks(&mut sched, &mut sinks);
                        break;
                    }
                }
            }
        }

        // notify any stream that survived the loop (drain guard or
        // listener death), then flush exports and account for leaks
        let ids: Vec<u64> = sinks.keys().copied().collect();
        for id in ids {
            sched.cancel(id);
        }
        pump_sinks(&mut sched, &mut sinks);
        watchdog_stop.store(true, Ordering::Relaxed);
        if let Some(h) = watchdog {
            let _ = h.join();
        }
        let _ = accept_handle.join();

        let wall = t0.elapsed().as_secs_f64();
        let (scratch_grows, scratch_reuses) = engine.scratch_stats();
        let tracer =
            sched.take_tracer().expect("server tracer installed");
        let phase_events = engine.profiler().take_events();
        if let Some(path) = &opts.serve.trace_out {
            let body =
                trace_export::chrome_trace(&tracer, &phase_events);
            std::fs::write(path, body).with_context(|| {
                format!("writing trace to {}", path.display())
            })?;
        }
        if let Some(path) = &opts.serve.events_out {
            let body =
                trace_export::events_jsonl(&tracer, &phase_events);
            std::fs::write(path, body).with_context(|| {
                format!("writing event log to {}", path.display())
            })?;
        }
        // prefix pages are pinned by design while serving; a drain
        // must hand every page back before the leak check — and the
        // clear has to land BEFORE the final snapshot so the
        // `kv.prefix_idle_{entries,bytes}` gauges report the drained
        // state instead of a stale pre-clear reading
        sched.pool.clear_prefix_index();
        if let Some(path) = &opts.serve.metrics_out {
            let mut reg = serve::metrics_registry(
                &sched,
                scratch_grows,
                scratch_reuses,
                wall,
            );
            reg.counter_add(
                "serve.watchdog_trips",
                health.watchdog_trips(),
            );
            std::fs::write(path, reg.snapshot_json()).with_context(
                || {
                    format!(
                        "writing metrics snapshot to {}",
                        path.display()
                    )
                },
            )?;
        }

        Ok(DrainReport {
            submitted: sched.stats.submitted,
            completed: sched.stats.completed,
            rejected: sched.stats.rejected,
            evicted: sched.stats.evicted,
            deadline_exceeded: sched.stats.deadline_exceeded,
            quarantined: sched.stats.quarantined,
            disconnects: sched.stats.disconnects,
            generated_tokens: sched.stats.generated_tokens,
            steps: sched.step_no(),
            reloads,
            watchdog_trips: health.watchdog_trips(),
            faults_injected: sched
                .faults()
                .map(|f| f.total_fired())
                .unwrap_or(0),
            wall_secs: wall,
            leaked_slots: sched.pool.in_use(),
            leaked_pages: sched.pool.pages_used(),
            live_spans: tracer.live_len(),
            dropped_spans: tracer.dropped(),
        })
    }
}

enum ReloadError {
    Incompatible(String),
    Failed(String),
}

/// Load + build a replacement engine for `/admin/reload`. The new
/// engine must agree with the old one on the KV geometry
/// (`kv_shape_key`) — the live pool's slots were sized for it and
/// in-flight sessions keep decoding against their existing caches.
fn reload_engine(
    rt: &mut Runtime,
    path: &std::path::Path,
    opts: &ServerOpts,
    current: &Engine,
) -> std::result::Result<Engine, ReloadError> {
    let art = ModelArtifact::load(path)
        .map_err(|e| ReloadError::Failed(format!("{e:#}")))?;
    let builder = opts
        .template
        .apply(EngineBuilder::new().artifact(art))
        .max_seq(opts.serve.max_seq)
        .profile_events(true);
    let new_engine = builder
        .build(rt)
        .map_err(|e| ReloadError::Failed(format!("{e:#}")))?;
    if new_engine.kv_shape_key() != current.kv_shape_key() {
        return Err(ReloadError::Incompatible(format!(
            "artifact KV geometry {:?} != serving geometry {:?}",
            new_engine.kv_shape_key(),
            current.kv_shape_key()
        )));
    }
    Ok(new_engine)
}

/// Push newly sampled tokens to every stream, close finished ones,
/// and cancel sessions whose client disappeared.
fn pump_sinks(sched: &mut Scheduler, sinks: &mut HashMap<u64, Sink>) {
    let mut done: Vec<u64> = Vec::new();
    let mut dead: Vec<u64> = Vec::new();
    for (&id, sink) in sinks.iter_mut() {
        if !sched.table.contains(id) {
            done.push(id);
            continue;
        }
        let (fresh, terminal, outcome) = {
            let s = sched.table.get(id);
            (
                s.generated[sink.cursor..].to_vec(),
                s.is_terminal(),
                // the scheduler records the precise terminal reason
                // ("done" | "evicted" | "deadline" | "quarantined"
                // | "disconnect"); fall back for states that predate
                // the outcome field
                s.outcome.map(|o| o.label()).unwrap_or(
                    match s.state {
                        crate::serve::session::SessionState::Evicted
                            => "evicted",
                        _ => "done",
                    },
                ),
            )
        };
        let mut client_gone = false;
        for t in fresh {
            if sink.tx.try_send(TokenEvent::Token(t)).is_err() {
                client_gone = true;
                break;
            }
            sink.cursor += 1;
        }
        if client_gone {
            dead.push(id);
        } else if terminal {
            let _ = sink.tx.try_send(TokenEvent::Done {
                outcome,
                tokens: sink.cursor,
            });
            done.push(id);
        }
    }
    for id in dead {
        sched.cancel_as(id, SpanOutcome::Disconnected);
        sched.table.remove(id);
        sinks.remove(&id);
    }
    for id in done {
        if sched.table.contains(id) {
            sched.table.remove(id);
        }
        sinks.remove(&id);
    }
}

fn accept_loop(listener: TcpListener, ctx: ConnCtx,
               max_conns: usize) {
    let active = Arc::new(AtomicUsize::new(0));
    loop {
        if ctx.draining() {
            return;
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                let n = active.fetch_add(1, Ordering::SeqCst);
                if n >= max_conns {
                    active.fetch_sub(1, Ordering::SeqCst);
                    let _ = http::write_error(
                        &mut stream,
                        503,
                        &[(
                            "Retry-After",
                            ctx.retry_after().to_string(),
                        )],
                        "connection limit reached",
                    );
                    continue;
                }
                let conn_ctx = ctx.clone();
                let active = active.clone();
                std::thread::spawn(move || {
                    handle_conn(stream, conn_ctx);
                    active.fetch_sub(1, Ordering::SeqCst);
                });
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => return,
        }
    }
}

fn handle_conn(mut stream: TcpStream, ctx: ConnCtx) {
    // both directions time out: a reader that never sends a request
    // AND a consumer that stops reading its stream release the
    // worker thread instead of pinning it forever
    let _ = stream.set_read_timeout(ctx.io_timeout);
    let _ = stream.set_write_timeout(ctx.io_timeout);
    let _ = stream.set_nodelay(true);
    let req = match http::read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let _ = http::write_error(&mut stream, 400, &[],
                                      &format!("{e:#}"));
            return;
        }
    };
    match router::route(&req.method, &req.path) {
        Route::Healthz => {
            let (status, body) =
                healthz_body(ctx.draining(), &ctx.health);
            let _ =
                http::write_json(&mut stream, status, &[], &body);
        }
        Route::Metrics => {
            match ask(&ctx, |resp| Cmd::Metrics { resp }) {
                Some(body) => {
                    let _ = http::write_json(&mut stream, 200, &[],
                                             &body);
                }
                None => {
                    let _ = busy(&mut stream, ctx.retry_after());
                }
            }
        }
        Route::Traces => {
            match ask(&ctx, |resp| Cmd::Traces { resp }) {
                Some(body) => {
                    let _ = http::write_response(
                        &mut stream,
                        200,
                        "application/x-ndjson",
                        &[],
                        body.as_bytes(),
                    );
                }
                None => {
                    let _ = busy(&mut stream, ctx.retry_after());
                }
            }
        }
        Route::Generate => handle_generate(stream, &req, &ctx),
        Route::Reload => handle_reload(stream, &req, &ctx),
        Route::Compact => {
            match ask(&ctx, |resp| Cmd::Compact { resp }) {
                Some(Ok(body)) => {
                    let _ = http::write_json(&mut stream, 200, &[],
                                             &body);
                }
                Some(Err(e)) => {
                    let _ =
                        http::write_error(&mut stream, 409, &[], &e);
                }
                None => {
                    let _ = busy(&mut stream, ctx.retry_after());
                }
            }
        }
        Route::NotFound => {
            let _ = http::write_error(
                &mut stream,
                404,
                &[],
                &format!("no route {} {}", req.method, req.path),
            );
        }
    }
}

/// One-shot request/response round trip with the core loop. `None`
/// means the command channel was full or the core is gone.
fn ask<T>(ctx: &ConnCtx,
          make: impl FnOnce(SyncSender<T>) -> Cmd) -> Option<T> {
    let (tx, rx) = sync_channel(1);
    ctx.cmd_tx.try_send(make(tx)).ok()?;
    rx.recv().ok()
}

fn busy(stream: &mut TcpStream,
        retry_after: u64) -> std::io::Result<()> {
    http::write_error(
        stream,
        503,
        &[("Retry-After", retry_after.to_string())],
        "server busy",
    )
}

fn handle_generate(mut stream: TcpStream, req: &http::Request,
                   ctx: &ConnCtx) {
    let body = match std::str::from_utf8(&req.body) {
        Ok(s) => s,
        Err(_) => {
            let _ = http::write_error(&mut stream, 400, &[],
                                      "body is not UTF-8");
            return;
        }
    };
    let greq = match router::parse_generate(body, &ctx.defaults) {
        Ok(g) => g,
        Err(e) => {
            let _ = http::write_error(&mut stream, 400, &[], &e);
            return;
        }
    };
    if let Some(&bad) = greq
        .prompt
        .iter()
        .find(|&&t| t < 0 || t as usize >= ctx.vocab)
    {
        let _ = http::write_error(
            &mut stream,
            400,
            &[],
            &format!("token id {bad} outside vocab 0..{}", ctx.vocab),
        );
        return;
    }
    let stream_mode = greq.stream;
    let (rtx, rrx) = sync_channel(1);
    if ctx
        .cmd_tx
        .try_send(Cmd::Submit { req: greq, resp: rtx })
        .is_err()
    {
        // submit queue full: the backpressure contract is a 429 with
        // a deterministic retry hint
        let _ = http::write_error(
            &mut stream,
            429,
            &[("Retry-After", ctx.retry_after().to_string())],
            "submit queue full",
        );
        return;
    }
    match rrx.recv() {
        Err(_) => {
            let _ = http::write_error(&mut stream, 500, &[],
                                      "server loop unavailable");
        }
        Ok(SubmitResult::Draining) => {
            let _ = http::write_error(
                &mut stream,
                503,
                &[("Retry-After", ctx.retry_after().to_string())],
                "draining",
            );
        }
        Ok(SubmitResult::Rejected { reason, retry_after }) => {
            if reason == "queue-full" {
                let _ = http::write_error(
                    &mut stream,
                    429,
                    &[("Retry-After", retry_after.to_string())],
                    reason,
                );
            } else {
                let _ =
                    http::write_error(&mut stream, 400, &[], reason);
            }
        }
        Ok(SubmitResult::Admitted { id, rx }) => {
            if stream_mode {
                stream_tokens(&mut stream, id, rx);
            } else {
                collect_tokens(&mut stream, id, rx);
            }
        }
    }
}

fn handle_reload(mut stream: TcpStream, req: &http::Request,
                 ctx: &ConnCtx) {
    let body = std::str::from_utf8(&req.body).unwrap_or("");
    let path = match crate::obs::json::Json::parse(body)
        .ok()
        .as_ref()
        .and_then(|d| d.get("artifact"))
        .and_then(|p| p.as_str())
    {
        Some(p) if !p.is_empty() => PathBuf::from(p),
        _ => {
            let _ = http::write_error(
                &mut stream,
                400,
                &[],
                "body must be {\"artifact\":\"path\"}",
            );
            return;
        }
    };
    match ask(ctx, |resp| Cmd::Reload { path, resp }) {
        None => {
            let _ = busy(&mut stream, ctx.retry_after());
        }
        Some(ReloadResult::Swapped(label)) => {
            let body = format!(
                "{{\"reloaded\":true,\"artifact\":\"{}\"}}",
                crate::obs::json::escape(&label)
            );
            let _ = http::write_json(&mut stream, 200, &[], &body);
        }
        Some(ReloadResult::Incompatible(msg)) => {
            let _ = http::write_error(&mut stream, 409, &[], &msg);
        }
        Some(ReloadResult::Failed(msg)) => {
            let _ = http::write_error(&mut stream, 400, &[], &msg);
        }
    }
}

fn stream_tokens(stream: &mut TcpStream, id: u64,
                 rx: Receiver<TokenEvent>) {
    if sse::write_headers(stream).is_err() {
        return; // dropping rx cancels the session at the next pump
    }
    if sse::write_event(stream, &format!("{{\"id\":{id}}}")).is_err()
    {
        return;
    }
    for ev in rx.iter() {
        let frame = match ev {
            TokenEvent::Token(t) => format!("{{\"token\":{t}}}"),
            TokenEvent::Done { outcome, tokens } => {
                let f = format!(
                    "{{\"done\":true,\"outcome\":\"{outcome}\",\
                     \"tokens\":{tokens}}}"
                );
                let _ = sse::write_event(stream, &f);
                return;
            }
        };
        if sse::write_event(stream, &frame).is_err() {
            return;
        }
    }
}

fn collect_tokens(stream: &mut TcpStream, id: u64,
                  rx: Receiver<TokenEvent>) {
    let mut tokens: Vec<i32> = Vec::new();
    let mut outcome = "unknown";
    for ev in rx.iter() {
        match ev {
            TokenEvent::Token(t) => tokens.push(t),
            TokenEvent::Done { outcome: o, .. } => {
                outcome = o;
                break;
            }
        }
    }
    let toks: Vec<String> =
        tokens.iter().map(|t| t.to_string()).collect();
    let body = format!(
        "{{\"id\":{id},\"outcome\":\"{outcome}\",\"tokens\":[{}]}}",
        toks.join(",")
    );
    let _ = http::write_json(stream, 200, &[], &body);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_opts_defaults() {
        let o = ServerOpts::new(ServeOpts::smoke());
        assert_eq!(o.addr, "127.0.0.1:8080");
        assert_eq!(o.max_conns, 64);
        assert!(o.template.lora.is_none());
        assert_eq!(o.template.kv_precision, KvPrecision::F32);
        assert_eq!(o.io_timeout_secs, 10);
        assert_eq!(o.watchdog_ms, 1000);
    }

    #[test]
    fn drain_report_clean_gate() {
        let mut r = DrainReport {
            submitted: 4,
            completed: 3,
            rejected: 1,
            evicted: 0,
            deadline_exceeded: 0,
            quarantined: 0,
            disconnects: 0,
            generated_tokens: 12,
            steps: 9,
            reloads: 1,
            watchdog_trips: 0,
            faults_injected: 0,
            wall_secs: 0.1,
            leaked_slots: 0,
            leaked_pages: 0,
            live_spans: 0,
            dropped_spans: 0,
        };
        assert!(r.clean());
        let s = r.summary();
        assert!(s.contains("completed 3"));
        assert!(s.contains("reloads 1"));
        assert!(s.contains("watchdog_trips 0"));
        r.leaked_pages = 2;
        assert!(!r.clean());
        r.leaked_pages = 0;
        r.live_spans = 1;
        assert!(!r.clean());
    }

    #[test]
    fn healthz_readiness_states() {
        let h = ServerHealth::new();
        let (code, body) = healthz_body(false, &h);
        assert_eq!(code, 200);
        assert!(body.contains("\"ok\":true"), "{body}");
        assert!(body.contains("\"state\":\"serving\""), "{body}");
        assert!(body.contains("\"draining\":false"), "{body}");

        h.brownout.store(true, Ordering::Relaxed);
        let (code, body) = healthz_body(false, &h);
        assert_eq!(code, 503);
        assert!(body.contains("\"state\":\"brownout\""), "{body}");

        // a tripped watchdog outranks brownout
        h.tripped.store(true, Ordering::Relaxed);
        let (code, body) = healthz_body(false, &h);
        assert_eq!(code, 503);
        assert!(body.contains("\"state\":\"watchdog\""), "{body}");

        // draining outranks everything
        let (code, body) = healthz_body(true, &h);
        assert_eq!(code, 503);
        assert!(body.contains("\"state\":\"draining\""), "{body}");
        assert!(body.contains("\"ok\":false"), "{body}");
        assert!(body.contains("\"draining\":true"), "{body}");
    }

    #[test]
    fn retry_hint_tracks_core_beats() {
        let h = ServerHealth::new();
        // before any beat the hint is the conservative floor
        assert_eq!(h.retry_after(), 1);
        h.beat(7, 3, 42, true, 5);
        assert_eq!(h.retry_after(), 5);
        assert!(h.brownout());
        // a zero hint is clamped: Retry-After: 0 invites a stampede
        h.beat(0, 0, 43, false, 0);
        assert_eq!(h.retry_after(), 1);
    }

    #[test]
    fn watchdog_trips_and_recovers_on_heartbeat() {
        let h = Arc::new(ServerHealth::new());
        h.beat(0, 0, 0, false, 1);
        let stop = Arc::new(AtomicBool::new(false));
        let handle = spawn_watchdog(h.clone(), stop.clone(), 5);
        // stop beating: the 5 ms threshold must trip well within
        // the generous wait even on a loaded machine
        let mut tripped = false;
        for _ in 0..200 {
            std::thread::sleep(Duration::from_millis(5));
            if h.watchdog_tripped() {
                tripped = true;
                break;
            }
        }
        assert!(tripped, "watchdog never tripped");
        assert!(h.watchdog_trips() >= 1);
        // resume beating: the trip flag clears, the counter latches
        let trips = h.watchdog_trips();
        let mut recovered = false;
        for _ in 0..200 {
            h.beat(0, 0, 1, false, 1);
            std::thread::sleep(Duration::from_millis(2));
            if !h.watchdog_tripped() {
                recovered = true;
                break;
            }
        }
        assert!(recovered, "watchdog never recovered");
        assert!(h.watchdog_trips() >= trips);
        stop.store(true, Ordering::Relaxed);
        handle.join().unwrap();
    }

    #[test]
    fn bind_picks_ephemeral_port() {
        let s = Server::bind("127.0.0.1:0").unwrap();
        assert_ne!(s.local_addr().port(), 0);
        // a second bind to the same explicit port fails loudly
        let taken = format!("127.0.0.1:{}", s.local_addr().port());
        assert!(Server::bind(&taken).is_err());
    }
}
