//! SIGTERM/SIGINT handling for graceful drain, with no libc crate:
//! the handler is installed through the C runtime's `signal` symbol
//! directly and does nothing but flip one atomic flag — the only
//! async-signal-safe action a handler can take here. The server's
//! core loop polls [`signaled`] and runs its ordinary drain path, so
//! a `kill -TERM` and a test calling `request_shutdown` exercise the
//! exact same code.

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod sys {
    pub const SIGINT: i32 = 2;
    pub const SIGTERM: i32 = 15;
    // sighandler_t is pointer-sized on every unix Rust targets; the
    // return value (the previous handler) is ignored.
    extern "C" {
        pub fn signal(signum: i32,
                      handler: extern "C" fn(i32)) -> usize;
    }
}

#[cfg(unix)]
extern "C" fn on_signal(_sig: i32) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Install the drain handler for SIGINT and SIGTERM. Idempotent; a
/// no-op on non-unix targets (ctrl-c then terminates the process,
/// which is still a correct, if abrupt, outcome).
pub fn install_signal_handlers() {
    #[cfg(unix)]
    unsafe {
        sys::signal(sys::SIGINT, on_signal);
        sys::signal(sys::SIGTERM, on_signal);
    }
}

/// Has a drain been requested (by signal or [`request_shutdown`])?
pub fn signaled() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Programmatic equivalent of receiving SIGTERM. The flag is
/// process-global and sticky — meant for the CLI path and for smoke
/// scripts, not for tests that share a process (those pass their own
/// shutdown flag to `Server::run`).
pub fn request_shutdown() {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_is_idempotent() {
        // must not panic or alter behavior when called repeatedly
        install_signal_handlers();
        install_signal_handlers();
    }
}
