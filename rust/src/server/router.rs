//! Route table and typed request parsing for the serving front-end.
//!
//! Routing is a closed enum — the connection handler matches on
//! [`Route`] so every endpoint the server exposes is visible in one
//! place. Body parsing goes through the strict in-tree JSON parser
//! (`obs::json`), so malformed requests fail with a message instead
//! of panicking or silently defaulting.

use crate::obs::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// `POST /v1/generate` — submit a prompt, stream or batch tokens
    Generate,
    /// `GET /metrics` — live `qpruner.serve.metrics.v1` snapshot
    Metrics,
    /// `GET /traces` — completed session spans as
    /// `qpruner.serve.events.v1` JSONL
    Traces,
    /// `GET /healthz` — combined liveness/readiness probe.
    /// Contract: the endpoint always answers (liveness — the accept
    /// loop and workers are alive even when the core loop wedges),
    /// but the status code carries readiness: 200 only in the
    /// `"serving"` state; 503 with `"state"` of `"draining"`,
    /// `"watchdog"`, or `"brownout"` when new work should be routed
    /// elsewhere. Precedence: draining > watchdog > brownout.
    Healthz,
    /// `POST /admin/reload` — hot-swap the model artifact
    Reload,
    /// `POST /admin/compact` — run one KV page-compaction pass now
    /// (requires a `--compact` mode other than `off`)
    Compact,
    NotFound,
}

pub fn route(method: &str, path: &str) -> Route {
    match (method, path) {
        ("POST", "/v1/generate") => Route::Generate,
        ("GET", "/metrics") => Route::Metrics,
        ("GET", "/traces") => Route::Traces,
        ("GET", "/healthz") => Route::Healthz,
        ("POST", "/admin/reload") => Route::Reload,
        ("POST", "/admin/compact") => Route::Compact,
        _ => Route::NotFound,
    }
}

/// Server-side defaults for the optional `/v1/generate` fields,
/// derived from the serve options the process booted with.
#[derive(Clone, Copy, Debug)]
pub struct GenerateDefaults {
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
}

/// One typed generation request. `prompt` is raw token ids — the
/// server speaks the same representation the offline workload driver
/// does, which is what makes streams replayable bit-for-bit.
#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
    pub stream: bool,
    /// per-request deadline in milliseconds from admission; `None`
    /// falls back to the server's `--deadline-ms` (if any)
    pub deadline_ms: Option<u64>,
}

fn uint_field(doc: &Json, key: &str, max: f64)
              -> Result<Option<u64>, String> {
    match doc.get(key) {
        None => Ok(None),
        Some(v) => {
            let f = v
                .as_f64()
                .ok_or_else(|| format!("{key} must be a number"))?;
            if f.fract() != 0.0 || f < 0.0 || f > max {
                return Err(format!(
                    "{key} must be an integer in [0, {max:.0}]"
                ));
            }
            Ok(Some(f as u64))
        }
    }
}

/// Parse a `/v1/generate` body. Errors are client-facing strings
/// (mapped to 400s); the prompt's vocabulary bound is checked by the
/// caller, which knows the engine.
pub fn parse_generate(body: &str, d: &GenerateDefaults)
                      -> Result<GenerateRequest, String> {
    let doc = Json::parse(body)
        .map_err(|e| format!("invalid JSON: {e}"))?;
    let arr = doc
        .get("prompt")
        .and_then(|p| p.as_arr())
        .ok_or("missing \"prompt\" array of token ids")?;
    if arr.is_empty() {
        return Err("empty prompt".into());
    }
    let mut prompt = Vec::with_capacity(arr.len());
    for v in arr {
        let f = v
            .as_f64()
            .ok_or("prompt entries must be integer token ids")?;
        if f.fract() != 0.0 || f < 0.0 || f > i32::MAX as f64 {
            return Err(
                "prompt entries must be non-negative integers".into()
            );
        }
        prompt.push(f as i32);
    }
    let max_new = match uint_field(&doc, "max_new", 1e9)? {
        None => d.max_new,
        Some(0) => return Err("max_new must be >= 1".into()),
        Some(n) => n as usize,
    };
    let temperature = match doc.get("temperature") {
        None => d.temperature,
        Some(v) => {
            let t = v
                .as_f64()
                .ok_or("temperature must be a number")?;
            if !t.is_finite() || t < 0.0 {
                return Err("temperature must be finite and >= 0".into());
            }
            t as f32
        }
    };
    let seed =
        uint_field(&doc, "seed", u64::MAX as f64)?.unwrap_or(d.seed);
    let stream = match doc.get("stream") {
        None => false,
        Some(v) => v
            .as_bool()
            .ok_or("stream must be a boolean")?,
    };
    let deadline_ms = match uint_field(&doc, "deadline_ms", 1e12)? {
        Some(0) => return Err("deadline_ms must be >= 1".into()),
        other => other,
    };
    Ok(GenerateRequest {
        prompt,
        max_new,
        temperature,
        seed,
        stream,
        deadline_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const D: GenerateDefaults = GenerateDefaults {
        max_new: 8,
        temperature: 0.8,
        seed: 42,
    };

    #[test]
    fn routes_are_exact() {
        assert_eq!(route("POST", "/v1/generate"), Route::Generate);
        assert_eq!(route("GET", "/metrics"), Route::Metrics);
        assert_eq!(route("GET", "/traces"), Route::Traces);
        assert_eq!(route("GET", "/healthz"), Route::Healthz);
        assert_eq!(route("POST", "/admin/reload"), Route::Reload);
        assert_eq!(route("POST", "/admin/compact"), Route::Compact);
        // wrong method or unknown path both 404
        assert_eq!(route("GET", "/v1/generate"), Route::NotFound);
        assert_eq!(route("GET", "/admin/compact"), Route::NotFound);
        assert_eq!(route("POST", "/metrics"), Route::NotFound);
        assert_eq!(route("GET", "/nope"), Route::NotFound);
    }

    #[test]
    fn parse_applies_defaults() {
        let r = parse_generate("{\"prompt\":[1,2,3]}", &D).unwrap();
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new, 8);
        assert_eq!(r.seed, 42);
        assert!((r.temperature - 0.8).abs() < 1e-6);
        assert!(!r.stream);
        assert_eq!(r.deadline_ms, None);
    }

    #[test]
    fn parse_accepts_deadline_ms() {
        let r = parse_generate(
            "{\"prompt\":[1],\"deadline_ms\":250}",
            &D,
        )
        .unwrap();
        assert_eq!(r.deadline_ms, Some(250));
    }

    #[test]
    fn parse_honors_explicit_fields() {
        let r = parse_generate(
            "{\"prompt\":[5],\"max_new\":3,\"temperature\":0,\
             \"seed\":7,\"stream\":true}",
            &D,
        )
        .unwrap();
        assert_eq!(r.max_new, 3);
        assert_eq!(r.seed, 7);
        assert_eq!(r.temperature, 0.0);
        assert!(r.stream);
    }

    #[test]
    fn parse_rejects_malformed_requests() {
        for bad in [
            "not json",
            "{}",
            "{\"prompt\":[]}",
            "{\"prompt\":\"hi\"}",
            "{\"prompt\":[1.5]}",
            "{\"prompt\":[-2]}",
            "{\"prompt\":[1],\"max_new\":0}",
            "{\"prompt\":[1],\"max_new\":2.5}",
            "{\"prompt\":[1],\"temperature\":-1}",
            "{\"prompt\":[1],\"stream\":\"yes\"}",
            "{\"prompt\":[1],\"seed\":-3}",
            "{\"prompt\":[1],\"deadline_ms\":0}",
            "{\"prompt\":[1],\"deadline_ms\":1.5}",
        ] {
            assert!(parse_generate(bad, &D).is_err(), "accepted {bad}");
        }
    }
}
