//! Server-Sent Events framing for `/v1/generate` token streaming.
//!
//! Each event is one single-line JSON object framed as
//! `data: {...}\n\n` and flushed immediately, so a client sees every
//! token the moment the scheduler samples it. The stream rides a
//! `Connection: close` response with no `Content-Length` — the
//! connection closing is the end-of-stream signal, which keeps the
//! protocol implementable without chunked encoding.

use std::io::Write;

/// Write the SSE response head. After this the connection speaks
/// only `data:` frames until close.
pub fn write_headers<W: Write>(w: &mut W) -> std::io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\n\
          Content-Type: text/event-stream\r\n\
          Cache-Control: no-cache\r\n\
          Connection: close\r\n\r\n",
    )?;
    w.flush()
}

/// Frame one event. `data` must be a single line (the serving layer
/// only ever passes compact JSON objects); embedded newlines would
/// split the frame, so they are rejected loudly in debug builds.
pub fn write_event<W: Write>(w: &mut W, data: &str)
                             -> std::io::Result<()> {
    debug_assert!(!data.contains('\n'), "SSE data must be one line");
    w.write_all(b"data: ")?;
    w.write_all(data.as_bytes())?;
    w.write_all(b"\n\n")?;
    w.flush()
}

/// Client-side inverse of [`write_event`]: split a raw SSE body into
/// its `data:` payloads. Shared by the integration tests and any
/// scripted client; tolerant of the `\r\n` line endings some proxies
/// introduce.
pub fn parse_events(body: &str) -> Vec<String> {
    body.lines()
        .filter_map(|l| l.strip_prefix("data:"))
        .map(|p| p.trim().to_string())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_round_trip() {
        let mut out = Vec::new();
        write_headers(&mut out).unwrap();
        write_event(&mut out, "{\"id\":3}").unwrap();
        write_event(&mut out, "{\"token\":17}").unwrap();
        write_event(&mut out, "{\"done\":true}").unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Type: text/event-stream\r\n"));
        let (_head, body) = s.split_once("\r\n\r\n").unwrap();
        let ev = parse_events(body);
        assert_eq!(ev, vec!["{\"id\":3}", "{\"token\":17}",
                            "{\"done\":true}"]);
    }

    #[test]
    fn parse_ignores_non_data_lines() {
        let ev = parse_events(
            ": comment\ndata: {\"a\":1}\n\nretry: 100\ndata: {\"b\":2}\n\n",
        );
        assert_eq!(ev, vec!["{\"a\":1}", "{\"b\":2}"]);
    }
}
