//! Minimal dense f32 tensor: contiguous row-major storage + shape.
//!
//! This is deliberately small — heavy compute runs inside the AOT XLA
//! executables; the host side only needs marshaling, compaction
//! (structured pruning), quantization staging, and small linear algebra
//! (GP posterior, LoftQ SVD).

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::rng::Rng) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar");
        self.data[0]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        if shape.iter().product::<usize>() != self.data.len() {
            bail!("reshape {:?} -> {:?} mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }

    /// `[i, j]` of a 2-D tensor.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Slab `t[i]` of the leading axis (any rank >= 1), as (shape, slice).
    pub fn slab(&self, i: usize) -> (&[usize], &[f32]) {
        assert!(self.ndim() >= 1);
        let inner: usize = self.shape[1..].iter().product();
        (&self.shape[1..], &self.data[i * inner..(i + 1) * inner])
    }

    pub fn slab_mut(&mut self, i: usize) -> &mut [f32] {
        let inner: usize = self.shape[1..].iter().product();
        &mut self.data[i * inner..(i + 1) * inner]
    }

    /// Transpose a 2-D tensor.
    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(&[c, r], out)
    }

    /// Keep only `rows` (2-D), in the given order.
    pub fn gather_rows(&self, rows: &[usize]) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        let mut out = Vec::with_capacity(rows.len() * c);
        for &r in rows {
            out.extend_from_slice(self.row(r));
        }
        Tensor::new(&[rows.len(), c], out)
    }

    /// Keep only `cols` (2-D), in the given order.
    pub fn gather_cols(&self, cols: &[usize]) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Vec::with_capacity(r * cols.len());
        for i in 0..r {
            for &j in cols {
                debug_assert!(j < c);
                out.push(self.data[i * c + j]);
            }
        }
        Tensor::new(&[r, cols.len()], out)
    }

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Tensor::new(&self.shape, data)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        Tensor::new(&self.shape, self.data.iter().map(|x| x * s).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(0, 2), 3.0);
        assert_eq!(t.at2(1, 0), 4.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(&[2, 2], vec![1.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.transpose2().transpose2();
        assert_eq!(t, tt);
    }

    #[test]
    fn gather_rows_cols() {
        let t = Tensor::new(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let r = t.gather_rows(&[2, 0]);
        assert_eq!(r.data(), &[5., 6., 1., 2.]);
        let c = t.gather_cols(&[1]);
        assert_eq!(c.data(), &[2., 4., 6.]);
        assert_eq!(c.shape(), &[3, 1]);
    }

    #[test]
    fn slab_of_stack() {
        let t = Tensor::new(&[2, 2, 2], (0..8).map(|x| x as f32).collect());
        let (sh, s) = t.slab(1);
        assert_eq!(sh, &[2, 2]);
        assert_eq!(s, &[4., 5., 6., 7.]);
    }

    #[test]
    fn reshape_checks_len() {
        let t = Tensor::zeros(&[4]);
        assert!(t.clone().reshape(&[2, 2]).is_ok());
        assert!(t.reshape(&[3]).is_err());
    }

    #[test]
    fn arithmetic() {
        let a = Tensor::new(&[2], vec![1., 2.]);
        let b = Tensor::new(&[2], vec![3., 5.]);
        assert_eq!(b.sub(&a).data(), &[2., 3.]);
        assert_eq!(a.scale(2.0).data(), &[2., 4.]);
        let mut c = a.clone();
        c.add_assign(&b);
        assert_eq!(c.data(), &[4., 7.]);
    }

    #[test]
    fn norms() {
        let t = Tensor::new(&[2], vec![3., -4.]);
        assert!((t.frobenius_norm() - 5.0).abs() < 1e-6);
        assert_eq!(t.max_abs(), 4.0);
    }
}
