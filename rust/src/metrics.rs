//! Timing + counter metrics and loss-curve logging.

use std::collections::BTreeMap;
use std::time::Instant;

/// Aggregated stage timings / counters for one pipeline run.
#[derive(Default, Debug)]
pub struct Metrics {
    timers: BTreeMap<String, f64>,
    counters: BTreeMap<String, u64>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Time a closure, accumulating under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        *self.timers.entry(name.to_string()).or_insert(0.0) +=
            t0.elapsed().as_secs_f64();
        out
    }

    pub fn add_time(&mut self, name: &str, secs: f64) {
        *self.timers.entry(name.to_string()).or_insert(0.0) += secs;
    }

    pub fn incr(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set a counter to an externally-accumulated value (gauge
    /// semantics, last write wins). Used for counters owned by another
    /// component — e.g. the serving engine's scratch-buffer reuse
    /// statistics (`serve.scratch_grows` / `serve.scratch_reuses`),
    /// which the decode workspace tracks itself and the workload
    /// driver snapshots at the end of a run.
    pub fn set_counter(&mut self, name: &str, value: u64) {
        self.counters.insert(name.to_string(), value);
    }

    pub fn timer(&self, name: &str) -> f64 {
        self.timers.get(name).copied().unwrap_or(0.0)
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.timers {
            out.push_str(&format!("  {k:<32} {v:>9.3}s\n"));
        }
        for (k, v) in &self.counters {
            out.push_str(&format!("  {k:<32} {v:>9}\n"));
        }
        out
    }
}

/// Reservoir size of [`LatencyStats`]: below this every sample is
/// kept and percentiles are exact; above it a uniform reservoir
/// (Algorithm R) bounds memory and percentiles become estimates.
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

/// Latency sample recorder with nearest-rank percentiles, used by the
/// offline benches. (The serving hot path records into
/// `obs::hist::Hist` instead — O(1), fixed memory, mergeable.)
///
/// Memory is bounded: up to [`LATENCY_RESERVOIR_CAP`] raw samples are
/// retained. Past the cap, reservoir sampling keeps a uniform subset,
/// so `percentile_ms` is a consistent estimator whose error shrinks
/// as the cap grows; `len`, `mean_ms` stay exact (tracked on the
/// side), and `min`/`max` order statistics are only approximate.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
    /// total recorded samples (exact, even past the cap)
    count: u64,
    /// exact running sum for `mean_ms`
    sum_ms: f64,
    /// xorshift64 state for reservoir replacement (deterministic —
    /// never zero, which would be a fixed point)
    rng_state: u64,
}

impl Default for LatencyStats {
    fn default() -> LatencyStats {
        LatencyStats {
            samples_ms: Vec::new(),
            count: 0,
            sum_ms: 0.0,
            rng_state: 0x9E37_79B9_7F4A_7C15,
        }
    }
}

impl LatencyStats {
    pub fn new() -> LatencyStats {
        LatencyStats::default()
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.rng_state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng_state = x;
        x
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.count += 1;
        self.sum_ms += ms;
        if self.samples_ms.len() < LATENCY_RESERVOIR_CAP {
            self.samples_ms.push(ms);
            return;
        }
        // Algorithm R: after n records, every sample has been kept
        // with probability cap/n
        let j = self.next_u64() % self.count;
        if (j as usize) < LATENCY_RESERVOIR_CAP {
            self.samples_ms[j as usize] = ms;
        }
    }

    /// Total samples recorded (exact — not the reservoir size).
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Raw samples currently held (== `len()` until the cap).
    pub fn reservoir_len(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum_ms / self.count as f64
    }

    /// Nearest-rank percentiles for several `q`s in (0, 100] at once,
    /// sorting the samples a single time. NaN entries when empty.
    pub fn percentiles_ms(&self, qs: &[f64]) -> Vec<f64> {
        if self.samples_ms.is_empty() {
            return vec![f64::NAN; qs.len()];
        }
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        qs.iter()
            .map(|q| {
                let rank = ((q / 100.0) * n as f64).ceil() as usize;
                s[rank.clamp(1, n) - 1]
            })
            .collect()
    }

    /// Nearest-rank percentile, `q` in (0, 100]. NaN when empty. For
    /// several percentiles of the same snapshot use `percentiles_ms`,
    /// which sorts once.
    pub fn percentile_ms(&self, q: f64) -> f64 {
        self.percentiles_ms(&[q])[0]
    }

    /// "p50=..ms p95=..ms p99=..ms mean=..ms n=.." summary line.
    pub fn summary(&self) -> String {
        let p = self.percentiles_ms(&[50.0, 95.0, 99.0]);
        format!(
            "p50={:.3}ms p95={:.3}ms p99={:.3}ms mean={:.3}ms n={}",
            p[0],
            p[1],
            p[2],
            self.mean_ms(),
            self.len()
        )
    }
}

/// Append-friendly loss curve that can be dumped as CSV.
#[derive(Default, Debug, Clone)]
pub struct LossCurve {
    pub steps: Vec<u64>,
    pub losses: Vec<f32>,
}

impl LossCurve {
    pub fn push(&mut self, step: u64, loss: f32) {
        self.steps.push(step);
        self.losses.push(loss);
    }

    pub fn last(&self) -> Option<f32> {
        self.losses.last().copied()
    }

    /// Mean of the final `k` points (smoothed terminal loss).
    pub fn tail_mean(&self, k: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let n = self.losses.len();
        let k = k.min(n);
        self.losses[n - k..].iter().sum::<f32>() / k as f32
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss\n");
        for (st, l) in self.steps.iter().zip(&self.losses) {
            s.push_str(&format!("{st},{l}\n"));
        }
        s
    }

    pub fn save_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timers_accumulate() {
        let mut m = Metrics::new();
        m.time("x", || std::thread::sleep(std::time::Duration::from_millis(5)));
        m.time("x", || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(m.timer("x") >= 0.009);
        assert_eq!(m.timer("missing"), 0.0);
    }

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.incr("n", 2);
        m.incr("n", 3);
        assert_eq!(m.counter("n"), 5);
    }

    #[test]
    fn set_counter_overwrites() {
        let mut m = Metrics::new();
        m.set_counter("g", 10);
        m.set_counter("g", 4);
        assert_eq!(m.counter("g"), 4);
        // and can seed a counter later incremented
        m.incr("g", 1);
        assert_eq!(m.counter("g"), 5);
    }

    #[test]
    fn latency_percentiles_nearest_rank() {
        let mut l = LatencyStats::new();
        for i in 1..=100 {
            l.record_ms(i as f64);
        }
        assert_eq!(l.percentile_ms(50.0), 50.0);
        assert_eq!(l.percentile_ms(95.0), 95.0);
        assert_eq!(l.percentile_ms(99.0), 99.0);
        assert_eq!(l.percentile_ms(100.0), 100.0);
        assert!((l.mean_ms() - 50.5).abs() < 1e-9);
        // ordered: p50 <= p95 <= p99
        assert!(l.percentile_ms(50.0) <= l.percentile_ms(95.0));
        assert!(l.percentile_ms(95.0) <= l.percentile_ms(99.0));
    }

    #[test]
    fn latency_empty_and_single() {
        let l = LatencyStats::new();
        assert!(l.is_empty());
        assert!(l.percentile_ms(50.0).is_nan());
        let mut one = LatencyStats::new();
        one.record_ms(7.5);
        assert_eq!(one.percentile_ms(50.0), 7.5);
        assert_eq!(one.percentile_ms(99.0), 7.5);
        assert!(one.summary().contains("n=1"));
    }

    #[test]
    fn latency_reservoir_bounds_memory() {
        let mut l = LatencyStats::new();
        let n = 10 * LATENCY_RESERVOIR_CAP;
        for i in 1..=n {
            l.record_ms(i as f64);
        }
        // exact aggregates survive the cap
        assert_eq!(l.len(), n);
        assert_eq!(l.reservoir_len(), LATENCY_RESERVOIR_CAP);
        let exact_mean = (n + 1) as f64 / 2.0;
        assert!((l.mean_ms() - exact_mean).abs() < 1e-6);
        // percentile estimates stay in the right neighbourhood (the
        // reservoir is a uniform subset; deterministic rng makes this
        // assertion stable)
        let p50 = l.percentile_ms(50.0);
        assert!(
            p50 > 0.4 * n as f64 && p50 < 0.6 * n as f64,
            "p50 estimate {p50} far from {exact_mean}"
        );
        let p = l.percentiles_ms(&[50.0, 95.0, 99.0]);
        assert!(p[0] <= p[1] && p[1] <= p[2]);
    }

    #[test]
    fn loss_curve_csv_and_tail() {
        let mut c = LossCurve::default();
        for i in 0..10u64 {
            c.push(i, 10.0 - i as f32);
        }
        assert_eq!(c.last(), Some(1.0));
        assert!((c.tail_mean(2) - 1.5).abs() < 1e-6);
        let csv = c.to_csv();
        assert!(csv.starts_with("step,loss\n"));
        assert_eq!(csv.lines().count(), 11);
    }
}
