//! Timing + counter metrics and loss-curve logging.

use std::collections::BTreeMap;
use std::time::Instant;

/// Aggregated stage timings / counters for one pipeline run.
#[derive(Default, Debug)]
pub struct Metrics {
    timers: BTreeMap<String, f64>,
    counters: BTreeMap<String, u64>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Time a closure, accumulating under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        *self.timers.entry(name.to_string()).or_insert(0.0) +=
            t0.elapsed().as_secs_f64();
        out
    }

    pub fn add_time(&mut self, name: &str, secs: f64) {
        *self.timers.entry(name.to_string()).or_insert(0.0) += secs;
    }

    pub fn incr(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn timer(&self, name: &str) -> f64 {
        self.timers.get(name).copied().unwrap_or(0.0)
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.timers {
            out.push_str(&format!("  {k:<32} {v:>9.3}s\n"));
        }
        for (k, v) in &self.counters {
            out.push_str(&format!("  {k:<32} {v:>9}\n"));
        }
        out
    }
}

/// Append-friendly loss curve that can be dumped as CSV.
#[derive(Default, Debug, Clone)]
pub struct LossCurve {
    pub steps: Vec<u64>,
    pub losses: Vec<f32>,
}

impl LossCurve {
    pub fn push(&mut self, step: u64, loss: f32) {
        self.steps.push(step);
        self.losses.push(loss);
    }

    pub fn last(&self) -> Option<f32> {
        self.losses.last().copied()
    }

    /// Mean of the final `k` points (smoothed terminal loss).
    pub fn tail_mean(&self, k: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let n = self.losses.len();
        let k = k.min(n);
        self.losses[n - k..].iter().sum::<f32>() / k as f32
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss\n");
        for (st, l) in self.steps.iter().zip(&self.losses) {
            s.push_str(&format!("{st},{l}\n"));
        }
        s
    }

    pub fn save_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timers_accumulate() {
        let mut m = Metrics::new();
        m.time("x", || std::thread::sleep(std::time::Duration::from_millis(5)));
        m.time("x", || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(m.timer("x") >= 0.009);
        assert_eq!(m.timer("missing"), 0.0);
    }

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.incr("n", 2);
        m.incr("n", 3);
        assert_eq!(m.counter("n"), 5);
    }

    #[test]
    fn loss_curve_csv_and_tail() {
        let mut c = LossCurve::default();
        for i in 0..10u64 {
            c.push(i, 10.0 - i as f32);
        }
        assert_eq!(c.last(), Some(1.0));
        assert!((c.tail_mean(2) - 1.5).abs() < 1e-6);
        let csv = c.to_csv();
        assert!(csv.starts_with("step,loss\n"));
        assert_eq!(csv.lines().count(), 11);
    }
}
