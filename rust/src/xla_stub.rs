//! Host-side stand-in for the `xla` crate (PJRT bindings), which is not
//! vendored in this offline environment.
//!
//! The API mirrors the exact subset `runtime.rs` consumes so that the
//! module can be swapped for the real crate by changing one `use` line
//! (`use crate::xla_stub as xla;` -> `use xla;`). Behavior:
//!
//! * **Literal marshaling is fully functional** — typed host buffers
//!   round-trip through `Literal` exactly as with the real bindings, so
//!   every pure-host code path (and its tests) behaves identically.
//! * **Compilation/execution of HLO artifacts returns a clear error** —
//!   there is no XLA compiler here. Callers that probe
//!   `Runtime::has_artifact` / handle `exec` errors degrade gracefully;
//!   the serving engine falls back to its native decode path.

use std::fmt;

/// Error type; implements `std::error::Error` so `?` lifts it into
/// `anyhow::Result`.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

const NO_BACKEND: &str = "PJRT/XLA backend is not linked in this build \
     (the `xla` crate is not vendored offline); HLO artifacts cannot be \
     compiled. Host paths and the native serving engine are unaffected. \
     To enable artifact execution, swap `crate::xla_stub` for the real \
     `xla` crate in runtime.rs";

/// Element dtypes crossing the boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U8,
    S8,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::U8 | ElementType::S8 => 1,
        }
    }
}

/// Host scalar types storable in a `Literal`.
pub trait NativeType: Sized + Copy {
    const TY: ElementType;
    fn from_le_bytes(b: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_bytes(b: &[u8]) -> Self {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_bytes(b: &[u8]) -> Self {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
    fn from_le_bytes(b: &[u8]) -> Self {
        b[0]
    }
}

impl NativeType for i8 {
    const TY: ElementType = ElementType::S8;
    fn from_le_bytes(b: &[u8]) -> Self {
        b[0] as i8
    }
}

/// Array shape of a non-tuple literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Typed host value: dense array or tuple of literals.
#[derive(Clone, Debug)]
pub enum Literal {
    Array {
        ty: ElementType,
        dims: Vec<i64>,
        bytes: Vec<u8>,
    },
    Tuple(Vec<Literal>),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let count: usize = dims.iter().product();
        if count * ty.byte_size() != data.len() {
            return Err(XlaError(format!(
                "literal shape {dims:?} x {ty:?} wants {} bytes, got {}",
                count * ty.byte_size(),
                data.len()
            )));
        }
        Ok(Literal::Array {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
        })
    }

    pub fn scalar(v: f32) -> Literal {
        Literal::Array {
            ty: ElementType::F32,
            dims: Vec::new(),
            bytes: v.to_le_bytes().to_vec(),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { dims, .. } => {
                Ok(ArrayShape { dims: dims.clone() })
            }
            Literal::Tuple(_) => {
                Err(XlaError("array_shape on tuple literal".into()))
            }
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Literal::Array { ty, bytes, .. } => bytes.len() / ty.byte_size(),
            Literal::Tuple(xs) => xs.len(),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { ty, bytes, .. } => {
                if *ty != T::TY {
                    return Err(XlaError(format!(
                        "to_vec dtype mismatch: literal {ty:?}, requested \
                         {:?}",
                        T::TY
                    )));
                }
                Ok(bytes
                    .chunks_exact(ty.byte_size())
                    .map(T::from_le_bytes)
                    .collect())
            }
            Literal::Tuple(_) => {
                Err(XlaError("to_vec on tuple literal".into()))
            }
        }
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(xs) => Ok(std::mem::take(xs)),
            Literal::Array { .. } => {
                Err(XlaError("decompose_tuple on array literal".into()))
            }
        }
    }
}

/// Parsed HLO module (opaque; parsing requires the real backend).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError(NO_BACKEND.into()))
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError(NO_BACKEND.into()))
    }
}

/// PJRT client handle. Creation succeeds (so environment probing like
/// the `info` subcommand works); compilation reports the missing
/// backend.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "host-stub (PJRT not linked)".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(XlaError(NO_BACKEND.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_typed_roundtrip() {
        let data = [1i32, -2, 3, 4];
        let bytes: Vec<u8> =
            data.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &[2, 2],
            &bytes,
        )
        .unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, -2, 3, 4]);
        assert!(lit.to_vec::<f32>().is_err());
        let dims: Vec<i64> = lit.array_shape().unwrap().dims().to_vec();
        assert_eq!(dims, vec![2, 2]);
    }

    #[test]
    fn literal_rejects_byte_mismatch() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &[0u8; 8],
        )
        .is_err());
    }

    #[test]
    fn tuple_decomposes_once() {
        let mut t = Literal::Tuple(vec![Literal::scalar(1.0),
                                        Literal::scalar(2.0)]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![1.0]);
    }

    #[test]
    fn compile_reports_missing_backend() {
        let client = PjRtClient::cpu().unwrap();
        let proto_err = HloModuleProto::from_text_file("x.hlo.txt");
        assert!(proto_err.is_err());
        let comp = XlaComputation { _priv: () };
        let err = client.compile(&comp).unwrap_err();
        assert!(format!("{err}").contains("PJRT"));
    }
}
