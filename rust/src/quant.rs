//! Quantization substrate: bitsandbytes-style blockwise absmax
//! quantizers (NF4 / FP4 / INT8 / uniform INT-k) plus the per-layer
//! mixed-precision configuration type the allocator and BO loop search
//! over.
//!
//! Codebooks are bit-identical to python/compile/kernels/codebooks.py —
//! the rust-quantized codes feed the AOT Pallas qmatmul artifacts, so
//! the two sides must agree exactly.

use crate::tensor::Tensor;

/// QLoRA 4-bit NormalFloat codebook (Dettmers et al., 2023).
pub const NF4_CODEBOOK: [f32; 16] = [
    -1.0,
    -0.696_192_8,
    -0.525_073_05,
    -0.394_917_5,
    -0.284_441_38,
    -0.184_773_43,
    -0.091_050_036,
    0.0,
    0.079_580_3,
    0.160_930_2,
    0.246_112_3,
    0.337_915_24,
    0.440_709_83,
    0.562_617,
    0.722_956_84,
    1.0,
];

/// bitsandbytes FP4 (E2M1 + sign); codes 0..8 positive, 8..16 mirrored.
pub const FP4_CODEBOOK: [f32; 16] = [
    0.0,
    0.005_208_333_5,
    0.166_666_67,
    0.25,
    0.333_333_34,
    0.5,
    0.666_666_7,
    1.0,
    -0.0,
    -0.005_208_333_5,
    -0.166_666_67,
    -0.25,
    -0.333_333_34,
    -0.5,
    -0.666_666_7,
    -1.0,
];

/// Quantization block length along the `in` (last) axis.
pub const BLOCK: usize = 64;

/// Storage format of one layer's weight matrices.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantFormat {
    /// 16-bit, no quantization (the LLM-Pruner baseline precision).
    Fp16,
    /// 4-bit NormalFloat, blockwise absmax.
    Nf4,
    /// 4-bit E2M1 float, blockwise absmax.
    Fp4,
    /// 8-bit symmetric integer, blockwise absmax.
    Int8,
}

impl QuantFormat {
    /// Storage bits per weight element, *including* the per-block f32
    /// absmax scale amortized over the block (the paper's memory
    /// accounting counts these quant constants).
    pub fn bits_per_param(self) -> f64 {
        match self {
            QuantFormat::Fp16 => 16.0,
            QuantFormat::Nf4 | QuantFormat::Fp4 => 4.0 + 32.0 / BLOCK as f64,
            QuantFormat::Int8 => 8.0 + 32.0 / BLOCK as f64,
        }
    }

    pub fn is_quantized(self) -> bool {
        self != QuantFormat::Fp16
    }

    pub fn label(self) -> &'static str {
        match self {
            QuantFormat::Fp16 => "fp16",
            QuantFormat::Nf4 => "nf4",
            QuantFormat::Fp4 => "fp4",
            QuantFormat::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fp16" | "16" => Some(QuantFormat::Fp16),
            "nf4" | "4" => Some(QuantFormat::Nf4),
            "fp4" => Some(QuantFormat::Fp4),
            "int8" | "8" => Some(QuantFormat::Int8),
            _ => None,
        }
    }
}

/// Per-layer bit-width assignment — the configuration vector `b` of
/// paper Eq. 8. One entry per transformer block.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BitConfig {
    pub layers: Vec<QuantFormat>,
}

impl BitConfig {
    pub fn uniform(n_layers: usize, fmt: QuantFormat) -> Self {
        BitConfig { layers: vec![fmt; n_layers] }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Fraction of layers at 8-bit (paper constraint: <= 25 %).
    pub fn frac_8bit(&self) -> f64 {
        let n8 = self
            .layers
            .iter()
            .filter(|f| **f == QuantFormat::Int8)
            .count();
        n8 as f64 / self.layers.len() as f64
    }

    /// Mean storage bits per projection parameter.
    pub fn mean_bits(&self) -> f64 {
        self.layers.iter().map(|f| f.bits_per_param()).sum::<f64>()
            / self.layers.len() as f64
    }

    /// Compact string like "44848448" (4/8 per layer; F for fp16).
    pub fn short(&self) -> String {
        self.layers
            .iter()
            .map(|f| match f {
                QuantFormat::Fp16 => 'F',
                QuantFormat::Nf4 => '4',
                QuantFormat::Fp4 => 'f',
                QuantFormat::Int8 => '8',
            })
            .collect()
    }

    /// Inverse of [`BitConfig::short`]: parse a per-layer string like
    /// "84448444" ('4' = NF4, 'f' = FP4, '8' = INT8, 'F' = fp16). Used
    /// by the `serve` CLI to pin a mixed-precision deployment config.
    pub fn parse_short(s: &str) -> Option<BitConfig> {
        let layers = s
            .chars()
            .map(|c| match c {
                'F' => Some(QuantFormat::Fp16),
                '4' => Some(QuantFormat::Nf4),
                'f' => Some(QuantFormat::Fp4),
                '8' => Some(QuantFormat::Int8),
                _ => None,
            })
            .collect::<Option<Vec<_>>>()?;
        if layers.is_empty() {
            return None;
        }
        Some(BitConfig { layers })
    }

    /// Feature encoding for the GP: one value per layer, 0.0 for 4-bit,
    /// 1.0 for 8-bit (fp16 = 2.0; never appears inside BO search).
    pub fn features(&self) -> Vec<f64> {
        self.layers
            .iter()
            .map(|f| match f {
                QuantFormat::Nf4 | QuantFormat::Fp4 => 0.0,
                QuantFormat::Int8 => 1.0,
                QuantFormat::Fp16 => 2.0,
            })
            .collect()
    }
}

/// Blockwise quantization result for one matrix.
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    pub fmt: QuantFormat,
    pub rows: usize,
    pub cols: usize,
    /// 4-bit formats: packed nibbles, len rows*cols/2 (cols even).
    /// INT8: one byte per element (two's complement).
    pub codes: Vec<u8>,
    /// per-(row, block) absmax scales, len rows * ceil(cols/BLOCK)
    pub scales: Vec<f32>,
}

impl QuantizedMatrix {
    pub fn blocks_per_row(&self) -> usize {
        self.cols.div_ceil(BLOCK)
    }

    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() * 4
    }
}

/// One weight matrix in its deployment-resident encoding — the unit
/// the serving engine keeps per (projection, layer) so decode streams
/// 0.5–1 byte/param instead of re-materialized f32, and the unit
/// `artifact::ModelArtifact` serializes (the file *is* the residency).
///
/// `F32` holds fp16-format layers (the simulator's fp16 is exact f32)
/// and the forced representation of the f32-residency parity/bench
/// oracle; `Packed` holds nf4/fp4/int8 codes + per-block absmax scales.
#[derive(Clone, Debug)]
pub enum QuantSlab {
    /// full-precision layer, stored as raw f32 host-side
    F32(Tensor),
    /// blockwise codes + absmax scales in their native encoding
    Packed(QuantizedMatrix),
}

impl QuantSlab {
    /// Encode an f32 `[out, in]` matrix for residency at `fmt`.
    pub fn from_f32(w: &Tensor, fmt: QuantFormat) -> QuantSlab {
        match fmt {
            QuantFormat::Fp16 => QuantSlab::F32(w.clone()),
            fmt => QuantSlab::Packed(quantize(w, fmt)),
        }
    }

    pub fn dims(&self) -> (usize, usize) {
        match self {
            QuantSlab::F32(t) => (t.shape()[0], t.shape()[1]),
            QuantSlab::Packed(q) => (q.rows, q.cols),
        }
    }

    pub fn rows(&self) -> usize {
        self.dims().0
    }

    pub fn cols(&self) -> usize {
        self.dims().1
    }

    /// Host bytes this slab actually pins (codes + f32 scales for
    /// packed encodings, 4 B/elem raw) — the residency the
    /// `memory::weight_bytes_at` model accounts for.
    pub fn storage_bytes(&self) -> usize {
        match self {
            QuantSlab::F32(t) => t.len() * 4,
            QuantSlab::Packed(q) => q.storage_bytes(),
        }
    }

    /// Materialize the f32 deployment numerics (dequantized codes, or
    /// a clone for raw layers). Oracle/build-time use only — the
    /// decode hot path consumes slabs through the fused kernels in
    /// `linalg` without ever calling this.
    pub fn dequantized(&self) -> Tensor {
        match self {
            QuantSlab::F32(t) => t.clone(),
            QuantSlab::Packed(q) => dequantize(q),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            QuantSlab::F32(_) => "f32",
            QuantSlab::Packed(q) => q.fmt.label(),
        }
    }
}

fn codebook_for(fmt: QuantFormat) -> &'static [f32; 16] {
    match fmt {
        QuantFormat::Nf4 => &NF4_CODEBOOK,
        QuantFormat::Fp4 => &FP4_CODEBOOK,
        _ => panic!("codebook_for: {fmt:?} is not a 4-bit format"),
    }
}

/// Public codebook accessor for the fused 4-bit decode kernels in
/// `linalg` (panics for non-4-bit formats, like [`codebook_for`]).
pub fn codebook(fmt: QuantFormat) -> &'static [f32; 16] {
    codebook_for(fmt)
}

/// Reference nearest-code scan (kept as the oracle for
/// `classifier_matches_linear_scan`).
#[cfg_attr(not(test), allow(dead_code))]
fn nearest_code(cb: &[f32; 16], x: f32) -> u8 {
    let mut best = 0u8;
    let mut bd = f32::INFINITY;
    for (i, &c) in cb.iter().enumerate() {
        let d = (x - c).abs();
        if d < bd {
            bd = d;
            best = i as u8;
        }
    }
    best
}

/// Precomputed nearest-code classifier: the codebook sorted by value
/// with the 15 midpoint decision thresholds. Classification is a
/// branch-light binary search instead of a 16-way distance scan —
/// §Perf: lifted NF4 quantization from ~120 MB/s to several hundred
/// MB/s, which gates the per-candidate cost of the BO loop.
struct CodeClassifier {
    /// midpoints between consecutive sorted codebook values
    thresholds: [f32; 15],
    /// original code id per sorted slot
    codes: [u8; 16],
}

impl CodeClassifier {
    fn new(cb: &[f32; 16]) -> CodeClassifier {
        let mut pairs: Vec<(f32, u8)> =
            cb.iter().enumerate().map(|(i, &v)| (v, i as u8)).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut thresholds = [0.0f32; 15];
        let mut codes = [0u8; 16];
        for (i, &(v, c)) in pairs.iter().enumerate() {
            codes[i] = c;
            if i > 0 {
                thresholds[i - 1] = (pairs[i - 1].0 + v) / 2.0;
            }
        }
        CodeClassifier { thresholds, codes }
    }

    #[inline]
    fn classify(&self, x: f32) -> u8 {
        // branchless-ish binary search over 15 thresholds (4 levels)
        let t = &self.thresholds;
        let mut lo = 0usize; // first slot whose threshold might exceed x
        // manual 4-step binary search (16 slots)
        if x >= t[7] {
            lo = 8;
        }
        if x >= t[lo + 3] {
            lo += 4;
        }
        if x >= t[lo + 1] {
            lo += 2;
        }
        if lo < 15 && x >= t[lo] {
            lo += 1;
        }
        self.codes[lo]
    }
}

/// Quantize a 2-D tensor `[rows, cols]` blockwise along the last axis.
pub fn quantize(w: &Tensor, fmt: QuantFormat) -> QuantizedMatrix {
    assert_eq!(w.ndim(), 2, "quantize expects a matrix");
    let (rows, cols) = (w.shape()[0], w.shape()[1]);
    let nb = cols.div_ceil(BLOCK);
    let mut scales = vec![0.0f32; rows * nb];

    match fmt {
        QuantFormat::Fp16 => panic!("quantize called with Fp16"),
        QuantFormat::Int8 => {
            let mut codes = vec![0u8; rows * cols];
            for r in 0..rows {
                let row = w.row(r);
                for b in 0..nb {
                    let lo = b * BLOCK;
                    let hi = (lo + BLOCK).min(cols);
                    let absmax =
                        row[lo..hi].iter().fold(0.0f32, |m, x| m.max(x.abs()));
                    let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
                    scales[r * nb + b] = scale;
                    for (j, &x) in row[lo..hi].iter().enumerate() {
                        let q = (x / scale).round().clamp(-127.0, 127.0) as i8;
                        codes[r * cols + lo + j] = q as u8;
                    }
                }
            }
            QuantizedMatrix { fmt, rows, cols, codes, scales }
        }
        QuantFormat::Nf4 | QuantFormat::Fp4 => {
            assert!(cols % 2 == 0, "4-bit packing needs even cols");
            let cls = CodeClassifier::new(codebook_for(fmt));
            let mut codes = vec![0u8; rows * cols / 2];
            for r in 0..rows {
                let row = w.row(r);
                // per-block scales
                for b in 0..nb {
                    let lo = b * BLOCK;
                    let hi = (lo + BLOCK).min(cols);
                    let absmax =
                        row[lo..hi].iter().fold(0.0f32, |m, x| m.max(x.abs()));
                    scales[r * nb + b] = if absmax > 0.0 { absmax } else { 1.0 };
                }
                // codes, packed two per byte (even idx = low nibble);
                // whole blocks share one scale, so process per block
                // with the reciprocal hoisted out of the inner loop
                for b in 0..nb {
                    let lo = b * BLOCK;
                    let hi = (lo + BLOCK).min(cols);
                    let inv = 1.0 / scales[r * nb + b];
                    let mut j = lo;
                    while j < hi {
                        let c0 = cls.classify(row[j] * inv);
                        let c1 = if j + 1 < hi {
                            cls.classify(row[j + 1] * inv)
                        } else {
                            // odd block boundary cannot happen: BLOCK
                            // is even and cols is even
                            0
                        };
                        codes[(r * cols + j) / 2] = c0 | (c1 << 4);
                        j += 2;
                    }
                }
            }
            QuantizedMatrix { fmt, rows, cols, codes, scales }
        }
    }
}

/// Dequantize back to f32 (the "simulated quantization" path, paper
/// §2.1: stored codes are expanded to a high-precision matrix before
/// the GEMM).
pub fn dequantize(q: &QuantizedMatrix) -> Tensor {
    let (rows, cols) = (q.rows, q.cols);
    let nb = q.blocks_per_row();
    let mut out = vec![0.0f32; rows * cols];
    match q.fmt {
        QuantFormat::Fp16 => unreachable!(),
        QuantFormat::Int8 => {
            for r in 0..rows {
                for j in 0..cols {
                    let s = q.scales[r * nb + j / BLOCK];
                    out[r * cols + j] = (q.codes[r * cols + j] as i8) as f32 * s;
                }
            }
        }
        QuantFormat::Nf4 | QuantFormat::Fp4 => {
            let cb = codebook_for(q.fmt);
            for r in 0..rows {
                for j2 in 0..cols / 2 {
                    let byte = q.codes[r * cols / 2 + j2];
                    let j0 = 2 * j2;
                    let j1 = j0 + 1;
                    let s0 = q.scales[r * nb + j0 / BLOCK];
                    let s1 = q.scales[r * nb + j1 / BLOCK];
                    out[r * cols + j0] = cb[(byte & 0x0F) as usize] * s0;
                    out[r * cols + j1] = cb[(byte >> 4) as usize] * s1;
                }
            }
        }
    }
    Tensor::new(&[rows, cols], out)
}

/// Simulated quantization: w -> dequantize(quantize(w)). Identity for
/// Fp16.
pub fn simulate(w: &Tensor, fmt: QuantFormat) -> Tensor {
    if fmt == QuantFormat::Fp16 {
        return w.clone();
    }
    dequantize(&quantize(w, fmt))
}

/// Generic symmetric uniform INT-k blockwise quantization (k in 2..=8).
///
/// The paper restricts the search space to {4, 8} bits, noting that
/// 2-bit "does not reduce memory usage" in their bitsandbytes stack;
/// this generic path lets the repo *measure* the other half of that
/// argument — the error explosion below 4 bits (see the `quantize`
/// CLI subcommand and `intk_error_grows_as_bits_shrink`).
pub fn quantize_uniform_k(w: &Tensor, k_bits: u32) -> QuantizedMatrix {
    assert!((2..=8).contains(&k_bits), "k_bits in 2..=8");
    let (rows, cols) = (w.shape()[0], w.shape()[1]);
    let nb = cols.div_ceil(BLOCK);
    let qmax = ((1i32 << (k_bits - 1)) - 1) as f32; // e.g. 127, 7, 1
    let mut scales = vec![0.0f32; rows * nb];
    let mut codes = vec![0u8; rows * cols];
    for r in 0..rows {
        let row = w.row(r);
        for b in 0..nb {
            let lo = b * BLOCK;
            let hi = (lo + BLOCK).min(cols);
            let absmax =
                row[lo..hi].iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let scale = if absmax > 0.0 { absmax / qmax } else { 1.0 };
            scales[r * nb + b] = scale;
            for (j, &x) in row[lo..hi].iter().enumerate() {
                let q = (x / scale).round().clamp(-qmax, qmax) as i8;
                codes[r * cols + lo + j] = q as u8;
            }
        }
    }
    QuantizedMatrix { fmt: QuantFormat::Int8, rows, cols, codes, scales }
}

/// Dequantize a `quantize_uniform_k` result (codes are signed bytes).
pub fn dequantize_uniform_k(q: &QuantizedMatrix) -> Tensor {
    dequantize(q) // same signed-byte * blockwise-scale layout
}

/// Quantize one f32 row to signed int8 codes with per-[`BLOCK`] absmax
/// scales — the same numerics as `quantize(.., QuantFormat::Int8)` on a
/// one-row matrix, but writing into caller-owned buffers so the int8
/// KV-cache write path (`serve/kv_cache.rs`) never allocates.
/// `codes.len() == row.len()`, `scales.len() == row.len().div_ceil(BLOCK)`.
pub fn quantize_row_i8(row: &[f32], codes: &mut [i8], scales: &mut [f32]) {
    let nb = row.len().div_ceil(BLOCK);
    assert_eq!(codes.len(), row.len(), "codes buffer mismatch");
    assert_eq!(scales.len(), nb, "scales buffer mismatch");
    for b in 0..nb {
        let lo = b * BLOCK;
        let hi = (lo + BLOCK).min(row.len());
        let absmax =
            row[lo..hi].iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
        scales[b] = scale;
        for (c, &x) in codes[lo..hi].iter_mut().zip(&row[lo..hi]) {
            *c = (x / scale).round().clamp(-127.0, 127.0) as i8;
        }
    }
}

/// Inverse of [`quantize_row_i8`] into a caller-owned buffer (the int8
/// KV-cache read path; zero allocations).
pub fn dequantize_row_i8(codes: &[i8], scales: &[f32], out: &mut [f32]) {
    assert_eq!(codes.len(), out.len(), "out buffer mismatch");
    debug_assert_eq!(scales.len(), codes.len().div_ceil(BLOCK));
    for (j, (&c, o)) in codes.iter().zip(out.iter_mut()).enumerate() {
        *o = c as f32 * scales[j / BLOCK];
    }
}

/// RMS and max absolute round-trip error of a quantizer on a matrix.
pub fn error_stats(w: &Tensor, back: &Tensor) -> (f64, f64) {
    let mut sq = 0.0f64;
    let mut mx = 0.0f64;
    for (a, b) in w.data().iter().zip(back.data()) {
        let e = (a - b).abs() as f64;
        sq += e * e;
        mx = mx.max(e);
    }
    ((sq / w.len() as f64).sqrt(), mx)
}

/// Double quantization (QLoRA §3): the per-block f32 absmax scales are
/// themselves INT8-quantized per group of 256 with one f32 meta-scale,
/// shrinking the quant-constant overhead from 32/BLOCK to
/// ~(8 + 32/256)/BLOCK bits per weight.
#[derive(Clone, Debug)]
pub struct DoubleQuantScales {
    pub codes: Vec<u8>,
    pub meta: Vec<f32>,
    pub group: usize,
    pub len: usize,
}

pub const DQ_GROUP: usize = 256;

pub fn double_quantize_scales(scales: &[f32]) -> DoubleQuantScales {
    let group = DQ_GROUP;
    let n_groups = scales.len().div_ceil(group);
    let mut codes = vec![0u8; scales.len()];
    let mut meta = vec![0.0f32; n_groups];
    for g in 0..n_groups {
        let lo = g * group;
        let hi = (lo + group).min(scales.len());
        let absmax = scales[lo..hi].iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let s = if absmax > 0.0 { absmax / 255.0 } else { 1.0 };
        meta[g] = s;
        for (j, &x) in scales[lo..hi].iter().enumerate() {
            // scales are positive absmax values -> unsigned u8 range
            codes[lo + j] = (x / s).round().clamp(0.0, 255.0) as u8;
        }
    }
    DoubleQuantScales { codes, meta, group, len: scales.len() }
}

pub fn double_dequantize_scales(dq: &DoubleQuantScales) -> Vec<f32> {
    (0..dq.len)
        .map(|i| dq.codes[i] as f32 * dq.meta[i / dq.group])
        .collect()
}

/// Effective bits/param including double-quantized scale overhead.
pub fn bits_per_param_dq(fmt: QuantFormat) -> f64 {
    match fmt {
        QuantFormat::Fp16 => 16.0,
        QuantFormat::Nf4 | QuantFormat::Fp4 => {
            4.0 + (8.0 + 32.0 / DQ_GROUP as f64) / BLOCK as f64
        }
        QuantFormat::Int8 => {
            8.0 + (8.0 + 32.0 / DQ_GROUP as f64) / BLOCK as f64
        }
    }
}

/// Worst-case |w - simulate(w)| bound for one matrix under absmax
/// blockwise quantization: max_gap(codebook)/2 * blockwise absmax.
pub fn roundtrip_error_bound(w: &Tensor, fmt: QuantFormat) -> f32 {
    let gap = match fmt {
        QuantFormat::Fp16 => return 0.0,
        QuantFormat::Int8 => 2.0 / 254.0,
        QuantFormat::Nf4 | QuantFormat::Fp4 => {
            let cb = codebook_for(fmt);
            let mut sorted = *cb;
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            sorted.windows(2).map(|w| w[1] - w[0]).fold(0.0f32, f32::max)
        }
    };
    w.max_abs() * gap / 2.0 + 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randmat(r: usize, c: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::randn(&[r, c], 1.0, &mut rng)
    }

    #[test]
    fn nf4_codebook_matches_python() {
        assert_eq!(NF4_CODEBOOK[0], -1.0);
        assert_eq!(NF4_CODEBOOK[7], 0.0);
        assert_eq!(NF4_CODEBOOK[15], 1.0);
        assert!((NF4_CODEBOOK[1] + 0.696_192_8).abs() < 1e-7);
    }

    #[test]
    fn roundtrip_error_bounded_nf4() {
        let w = randmat(8, 256, 1);
        let q = quantize(&w, QuantFormat::Nf4);
        let back = dequantize(&q);
        // per-block bound
        let nb = q.blocks_per_row();
        for r in 0..8 {
            for j in 0..256 {
                let s = q.scales[r * nb + j / BLOCK];
                let gap = 0.2; // > max NF4 gap (0.159)
                let err = (w.at2(r, j) - back.at2(r, j)).abs();
                assert!(err <= s * gap, "err {err} scale {s}");
            }
        }
    }

    #[test]
    fn roundtrip_int8_tight() {
        let w = randmat(4, 200, 2); // ragged final block (200 = 3*64+8)
        let q = quantize(&w, QuantFormat::Int8);
        let back = dequantize(&q);
        let nb = q.blocks_per_row();
        assert_eq!(nb, 4);
        for r in 0..4 {
            for j in 0..200 {
                let s = q.scales[r * nb + j / BLOCK];
                let err = (w.at2(r, j) - back.at2(r, j)).abs();
                assert!(err <= s * 0.5 + 1e-6);
            }
        }
    }

    #[test]
    fn quantization_idempotent() {
        for fmt in [QuantFormat::Nf4, QuantFormat::Fp4, QuantFormat::Int8] {
            let w = randmat(6, 128, 3);
            let once = simulate(&w, fmt);
            let twice = simulate(&once, fmt);
            let diff = once.sub(&twice).max_abs();
            assert!(diff < 1e-5, "{fmt:?} not idempotent: {diff}");
        }
    }

    #[test]
    fn zero_matrix_roundtrips_exactly() {
        let w = Tensor::zeros(&[3, 64]);
        for fmt in [QuantFormat::Nf4, QuantFormat::Fp4, QuantFormat::Int8] {
            let back = simulate(&w, fmt);
            assert_eq!(back.max_abs(), 0.0, "{fmt:?}");
        }
    }

    #[test]
    fn scales_are_per_block_absmax() {
        let mut data = vec![0.0f32; 128];
        data[3] = 2.0; // block 0 absmax = 2
        data[70] = -5.0; // block 1 absmax = 5
        let w = Tensor::new(&[1, 128], data);
        let q = quantize(&w, QuantFormat::Nf4);
        assert_eq!(q.scales, vec![2.0, 5.0]);
    }

    #[test]
    fn int8_preserves_sign_and_extremes() {
        let w = Tensor::new(&[1, 64], {
            let mut v = vec![0.1f32; 64];
            v[0] = -3.0;
            v[1] = 3.0;
            v
        });
        let back = simulate(&w, QuantFormat::Int8);
        assert!((back.at2(0, 0) + 3.0).abs() < 0.02);
        assert!((back.at2(0, 1) - 3.0).abs() < 0.02);
    }

    #[test]
    fn bits_accounting() {
        assert_eq!(QuantFormat::Fp16.bits_per_param(), 16.0);
        assert!((QuantFormat::Nf4.bits_per_param() - 4.5).abs() < 1e-12);
        assert!((QuantFormat::Int8.bits_per_param() - 8.5).abs() < 1e-12);
    }

    #[test]
    fn bitconfig_helpers() {
        let mut c = BitConfig::uniform(8, QuantFormat::Nf4);
        assert_eq!(c.frac_8bit(), 0.0);
        c.layers[0] = QuantFormat::Int8;
        c.layers[4] = QuantFormat::Int8;
        assert!((c.frac_8bit() - 0.25).abs() < 1e-12);
        assert_eq!(c.short(), "84448444");
        assert_eq!(c.features()[0], 1.0);
        assert_eq!(c.features()[1], 0.0);
    }

    #[test]
    fn short_parse_roundtrip() {
        let mut c = BitConfig::uniform(6, QuantFormat::Nf4);
        c.layers[1] = QuantFormat::Int8;
        c.layers[3] = QuantFormat::Fp16;
        c.layers[5] = QuantFormat::Fp4;
        let s = c.short();
        assert_eq!(BitConfig::parse_short(&s), Some(c));
        assert!(BitConfig::parse_short("").is_none());
        assert!(BitConfig::parse_short("44x4").is_none());
    }

    #[test]
    fn storage_bytes_nf4_half_of_int8() {
        let w = randmat(16, 256, 9);
        let q4 = quantize(&w, QuantFormat::Nf4);
        let q8 = quantize(&w, QuantFormat::Int8);
        assert_eq!(q4.codes.len() * 2, q8.codes.len());
        assert_eq!(q4.scales.len(), q8.scales.len());
    }

    #[test]
    fn intk_error_grows_as_bits_shrink() {
        let w = randmat(8, 256, 33);
        let mut last_rms = 0.0f64;
        for k in [8u32, 6, 4, 3, 2] {
            let q = quantize_uniform_k(&w, k);
            let back = dequantize_uniform_k(&q);
            let (rms, _) = error_stats(&w, &back);
            assert!(
                rms > last_rms,
                "k={k}: rms {rms} not worse than {last_rms}"
            );
            last_rms = rms;
        }
        // and 2-bit is catastrophically worse than 4-bit (the flip
        // side of the paper's {4,8}-only search space)
        let e2 = {
            let q = quantize_uniform_k(&w, 2);
            error_stats(&w, &dequantize_uniform_k(&q)).0
        };
        let e4 = {
            let q = quantize_uniform_k(&w, 4);
            error_stats(&w, &dequantize_uniform_k(&q)).0
        };
        assert!(e2 > 3.0 * e4, "2-bit rms {e2} vs 4-bit {e4}");
    }

    #[test]
    fn intk_8_matches_int8_quantizer() {
        let w = randmat(4, 128, 34);
        let a = dequantize(&quantize(&w, QuantFormat::Int8));
        let b = dequantize_uniform_k(&quantize_uniform_k(&w, 8));
        assert!(a.sub(&b).max_abs() < 1e-6);
    }

    #[test]
    fn nf4_beats_uniform_int4_on_gaussian_weights() {
        // the reason QLoRA's NF4 exists: codebook matched to N(0,1)
        let w = randmat(16, 512, 35);
        let e_nf4 = {
            let back = simulate(&w, QuantFormat::Nf4);
            error_stats(&w, &back).0
        };
        let e_u4 = {
            let q = quantize_uniform_k(&w, 4);
            error_stats(&w, &dequantize_uniform_k(&q)).0
        };
        assert!(e_nf4 < e_u4, "nf4 {e_nf4} !< uniform-int4 {e_u4}");
    }

    #[test]
    fn row_i8_matches_matrix_int8_quantizer() {
        let mut rng = Rng::new(71);
        // ragged final block: 200 = 3*64 + 8
        let w = Tensor::randn(&[1, 200], 2.0, &mut rng);
        let q = quantize(&w, QuantFormat::Int8);
        let mut codes = vec![0i8; 200];
        let mut scales = vec![0.0f32; 4];
        quantize_row_i8(w.row(0), &mut codes, &mut scales);
        assert_eq!(scales, q.scales);
        let matrix_codes: Vec<i8> =
            q.codes.iter().map(|&c| c as i8).collect();
        assert_eq!(codes, matrix_codes);
        let mut back = vec![0.0f32; 200];
        dequantize_row_i8(&codes, &scales, &mut back);
        assert_eq!(back, dequantize(&q).data());
    }

    #[test]
    fn row_i8_roundtrip_within_bound() {
        let mut rng = Rng::new(72);
        for _ in 0..20 {
            let n = 1 + rng.below(190);
            let scale = rng.uniform_in(0.01, 5.0);
            let w = Tensor::randn(&[1, n], scale, &mut rng);
            let nb = n.div_ceil(BLOCK);
            let mut codes = vec![0i8; n];
            let mut scales = vec![0.0f32; nb];
            quantize_row_i8(w.row(0), &mut codes, &mut scales);
            let mut back = vec![0.0f32; n];
            dequantize_row_i8(&codes, &scales, &mut back);
            let bound = roundtrip_error_bound(&w, QuantFormat::Int8);
            for (a, b) in w.row(0).iter().zip(&back) {
                assert!((a - b).abs() <= bound,
                        "row err {} > bound {bound}", (a - b).abs());
            }
        }
    }

    #[test]
    fn double_quant_scales_roundtrip_tight() {
        let mut rng = Rng::new(91);
        let scales: Vec<f32> =
            (0..1000).map(|_| rng.uniform_in(0.001, 3.0)).collect();
        let dq = double_quantize_scales(&scales);
        let back = double_dequantize_scales(&dq);
        assert_eq!(back.len(), scales.len());
        for (g, chunk) in scales.chunks(DQ_GROUP).enumerate() {
            let absmax = chunk.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            for (j, (&a, &b)) in
                chunk.iter().zip(&back[g * DQ_GROUP..]).enumerate()
            {
                let tol = absmax / 255.0 / 2.0 + 1e-6;
                assert!((a - b).abs() <= tol, "[{g},{j}] {a} vs {b}");
            }
        }
    }

    #[test]
    fn double_quant_reduces_overhead_bits() {
        // 4.5 bits/param (plain) vs ~4.127 (double-quantized)
        assert!(bits_per_param_dq(QuantFormat::Nf4)
                < QuantFormat::Nf4.bits_per_param());
        assert!((bits_per_param_dq(QuantFormat::Nf4) - 4.127).abs() < 0.01);
        assert_eq!(bits_per_param_dq(QuantFormat::Fp16), 16.0);
    }

    #[test]
    fn classifier_matches_linear_scan() {
        let mut rng = Rng::new(55);
        for cb in [&NF4_CODEBOOK, &FP4_CODEBOOK] {
            let cls = CodeClassifier::new(cb);
            for _ in 0..5000 {
                let x = rng.uniform_in(-1.2, 1.2);
                let fast = cls.classify(x);
                let slow = nearest_code(cb, x);
                // ties at midpoints may pick either neighbour; accept
                // equal distance
                let d_fast = (cb[fast as usize] - x).abs();
                let d_slow = (cb[slow as usize] - x).abs();
                assert!(
                    (d_fast - d_slow).abs() < 1e-6,
                    "x={x}: fast {fast} ({d_fast}) vs slow {slow} ({d_slow})"
                );
            }
            // exact codebook values map to themselves
            for (i, &v) in cb.iter().enumerate() {
                let c = cls.classify(v) as usize;
                assert!(
                    (cb[c] - v).abs() < 1e-7,
                    "codebook value {i} misclassified"
                );
            }
        }
    }

    /// Property sweep (hand-rolled; proptest is not vendored): random
    /// shapes and scales, assert the analytic round-trip bound.
    #[test]
    fn prop_roundtrip_error_bound_holds() {
        let mut rng = Rng::new(77);
        for trial in 0..25 {
            let rows = 1 + rng.below(6);
            let cols = 2 * (1 + rng.below(160)); // even, up to 320
            let scale = rng.uniform_in(0.01, 10.0);
            let mut w = Tensor::randn(&[rows, cols], scale, &mut rng);
            // occasionally inject zeros / outliers
            if trial % 3 == 0 {
                w.data_mut()[0] = 0.0;
            }
            if trial % 4 == 0 {
                let n = w.len();
                w.data_mut()[n - 1] = 50.0 * scale;
            }
            for fmt in [QuantFormat::Nf4, QuantFormat::Fp4, QuantFormat::Int8] {
                let back = simulate(&w, fmt);
                let bound = roundtrip_error_bound(&w, fmt);
                let err = w.sub(&back).max_abs();
                assert!(
                    err <= bound,
                    "trial {trial} fmt {fmt:?}: err {err} > bound {bound}"
                );
            }
        }
    }
}
