//! Paper-style table rendering (markdown + CSV) and scatter dumps.

/// A simple column-aligned table with a title, rendered as markdown.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",") + "\n";
        for row in &self.rows {
            out.push_str(&(row.join(",") + "\n"));
        }
        out
    }

    pub fn save(&self, dir: &std::path::Path, stem: &str)
                -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.md")), self.to_markdown())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())
    }
}

/// Format a fraction as a percentage with 2 decimals (paper style).
pub fn pct(x: f64) -> String {
    format!("{:.2}", 100.0 * x)
}

/// Format GB with 2 decimals.
pub fn gb(x: f64) -> String {
    format!("{x:.2}")
}

/// (x, y, label) scatter dump for the Pareto figures.
pub fn scatter_csv(points: &[(f64, f64, String)]) -> String {
    let mut out = String::from("memory_gb,perf,label\n");
    for (x, y, l) in points {
        out.push_str(&format!("{x:.4},{y:.4},{l}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_is_aligned() {
        let mut t = Table::new("T", &["a", "longheader"]);
        t.push_row(vec!["x".into(), "1".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| a "));
        let lines: Vec<&str> = md.lines().collect();
        // header, separator, row have equal width
        assert_eq!(lines[2].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("T", &["a", "b"]);
        t.push_row(vec!["x".into()]);
    }

    #[test]
    fn csv_roundtrip_lines() {
        let mut t = Table::new("T", &["a", "b"]);
        t.push_row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn pct_gb_format() {
        assert_eq!(pct(0.6311), "63.11");
        assert_eq!(gb(35.0612), "35.06");
    }
}
