//! Small dense linear algebra for host-side math.
//!
//! Used by the GP surrogate (Cholesky posterior) and the LoftQ / PiSSA
//! adapter initializers (truncated SVD). Sizes here are tiny (GP n <= a
//! few hundred; SVD on per-layer weight matrices up to ~2k x 1k), so
//! straightforward cache-friendly implementations suffice.

use crate::parallel::{chunk_range, SyncPtr, ThreadPool};
use crate::quant::{self, QuantFormat, QuantSlab, QuantizedMatrix,
                   BLOCK};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// C = A[m,k] @ B[k,n]
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dim mismatch");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    // ikj loop order: streams B rows, accumulates into C row.
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    Tensor::new(&[m, n], out)
}

/// out[m, n] = x[m, k] @ w[n, k]^T with `w` row-major `[n, k]`
/// (weights-as-rows, the projection-stack layout of `ParamStore`).
///
/// This is the f32 serving GEMM: it writes into a caller-owned
/// buffer (`serve/workspace.rs` holds reusable scratch) so a decode
/// step performs zero allocations. The weight-row-outer / batch-inner
/// loop order streams each weight row exactly once per call and reuses
/// it across every row of `x`, which is where the batched GEMM beats
/// per-session matvecs for batch >= 2. Each (weight row, x row) dot
/// accumulates left-to-right exactly like a per-row `matvec`, so the
/// batched and per-session decode paths track each other to the
/// |Δlogit| < 1e-4 envelope `tests/parity_decode.rs` enforces (the
/// shared order makes debug builds agree exactly; the envelope is what
/// the suites actually pin, and what the blocked quantized kernels
/// below are held to as well).
pub fn matmul_nt_into(x: &[f32], m: usize, k: usize, w: &[f32],
                      n: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m * k, "x is not [m, k]");
    assert_eq!(w.len(), n * k, "w is not [n, k]");
    assert_eq!(out.len(), m * n, "out is not [m, n]");
    for r in 0..n {
        let wrow = &w[r * k..(r + 1) * k];
        for i in 0..m {
            let xrow = &x[i * k..(i + 1) * k];
            let mut s = 0.0f32;
            for (a, b) in wrow.iter().zip(xrow) {
                s += a * b;
            }
            out[i * n + r] = s;
        }
    }
}

/// out[m, n] += scale * (x[m, k] @ w[n, k]^T) — the accumulating
/// variant of [`matmul_nt_into`], used by the serving engine's
/// adjoined-LoRA side path (y += s * (x A^T) B^T on top of the base
/// GEMM). Each dot accumulates left-to-right and is scaled *before*
/// the add, exactly mirroring the per-row reference matvec
/// (`y[o] += s * dot(B[o], tmp)`), so the batched and per-session
/// adjoin paths stay inside the same |Δlogit| < 1e-4 parity envelope
/// the base paths are tested to.
pub fn matmul_nt_scaled_acc_into(x: &[f32], m: usize, k: usize,
                                 w: &[f32], n: usize, scale: f32,
                                 out: &mut [f32]) {
    assert_eq!(x.len(), m * k, "x is not [m, k]");
    assert_eq!(w.len(), n * k, "w is not [n, k]");
    assert_eq!(out.len(), m * n, "out is not [m, n]");
    for r in 0..n {
        let wrow = &w[r * k..(r + 1) * k];
        for i in 0..m {
            let xrow = &x[i * k..(i + 1) * k];
            let mut s = 0.0f32;
            for (a, b) in wrow.iter().zip(xrow) {
                s += a * b;
            }
            out[i * n + r] += scale * s;
        }
    }
}

// ---------------------------------------------------------------------
// fused quantized-weight decode kernels
//
// The serving engine keeps projection weights in their native
// encodings (`quant::QuantSlab`: nf4/fp4 packed nibbles, int8 codes,
// or raw f32) and the GEMMs below consume them *directly* — codes are
// dequantized block-wise into a [BLOCK]-float register tile inside the
// kernel, decoded once per weight row per batch tile and reused across
// every row of `x`. Weight traffic per token drops 4–8x vs an f32
// materialization, which is exactly the memory-bandwidth the paper's
// formats were chosen to save.
//
// Numerics: each (weight row, x row) pair keeps ONE running f32
// accumulator walked left-to-right across blocks, and each decoded
// element is `codebook[code] * scale` / `(code as i8) as f32 * scale`
// — the very expressions `quant::dequantize` uses. The fused kernels
// therefore reproduce `matmul_nt_into(x, .., dequantize(q), ..)`
// bit-for-bit (pinned by unit tests below), and the engine-level
// parity suites keep their |Δlogit| envelopes unchanged.
//
// Parallelism: output rows are partitioned statically per lane via
// `parallel::chunk_range`; every output element is produced by exactly
// one lane with the fixed order above, so results are identical for
// any thread count (1 vs 2 vs 8 bit-identical — tested).
// ---------------------------------------------------------------------

/// Batch-rows-per-tile of the quantized micro-kernels: one decoded
/// block is reused across this many rows of `x` before re-decoding.
/// Sized to keep the accumulators in registers.
const TILE_M: usize = 16;

/// f32 rows [r0, r1) of `out[m, n] = x[m, k] @ w[n, k]^T` — the
/// per-lane core shared by [`par_matmul_nt_into`] and the `F32` slab
/// arm. Identical per-element op order to [`matmul_nt_into`].
///
/// Safety: `out` writes are `out[i*n + r]` for `r` in `rows` only —
/// disjoint across lanes by construction.
fn nt_rows_f32(x: &[f32], m: usize, k: usize, w: &[f32], n: usize,
               rows: std::ops::Range<usize>, out: &SyncPtr) {
    for r in rows {
        let wrow = &w[r * k..(r + 1) * k];
        for i in 0..m {
            let xrow = &x[i * k..(i + 1) * k];
            let mut s = 0.0f32;
            for (a, b) in wrow.iter().zip(xrow) {
                s += a * b;
            }
            unsafe { out.write(i * n + r, s) };
        }
    }
}

/// nf4/fp4 rows [r0, r1): packed nibbles are decoded per 64-element
/// block into a stack tile (`codebook[code] * scale`, the dequantize
/// expression) and reused across up to [`TILE_M`] batch rows.
fn nt_rows_q4(x: &[f32], m: usize, k: usize, q: &QuantizedMatrix,
              rows: std::ops::Range<usize>, out: &SyncPtr) {
    debug_assert!(k % 2 == 0, "4-bit rows need even cols");
    let cb = quant::codebook(q.fmt);
    let n = q.rows;
    let nb = q.blocks_per_row();
    let half = k / 2;
    let mut dec = [0.0f32; BLOCK];
    for r in rows {
        let codes = &q.codes[r * half..(r + 1) * half];
        let scales = &q.scales[r * nb..(r + 1) * nb];
        let mut i0 = 0;
        while i0 < m {
            let tile = (m - i0).min(TILE_M);
            let mut acc = [0.0f32; TILE_M];
            for (b, &scale) in scales.iter().enumerate() {
                let lo = b * BLOCK;
                let hi = (lo + BLOCK).min(k);
                for (j2, &byte) in
                    codes[lo / 2..hi / 2].iter().enumerate()
                {
                    dec[2 * j2] = cb[(byte & 0x0F) as usize] * scale;
                    dec[2 * j2 + 1] = cb[(byte >> 4) as usize] * scale;
                }
                let blen = hi - lo;
                for (t, a) in acc[..tile].iter_mut().enumerate() {
                    let xrow =
                        &x[(i0 + t) * k + lo..(i0 + t) * k + hi];
                    let mut s = *a;
                    for (d, xv) in dec[..blen].iter().zip(xrow) {
                        s += d * xv;
                    }
                    *a = s;
                }
            }
            for (t, &a) in acc[..tile].iter().enumerate() {
                unsafe { out.write((i0 + t) * n + r, a) };
            }
            i0 += tile;
        }
    }
}

/// int8 rows [r0, r1): same tiling as [`nt_rows_q4`], decoding
/// `(code as i8) as f32 * scale` per element.
fn nt_rows_i8(x: &[f32], m: usize, k: usize, q: &QuantizedMatrix,
              rows: std::ops::Range<usize>, out: &SyncPtr) {
    let n = q.rows;
    let nb = q.blocks_per_row();
    let mut dec = [0.0f32; BLOCK];
    for r in rows {
        let codes = &q.codes[r * k..(r + 1) * k];
        let scales = &q.scales[r * nb..(r + 1) * nb];
        let mut i0 = 0;
        while i0 < m {
            let tile = (m - i0).min(TILE_M);
            let mut acc = [0.0f32; TILE_M];
            for (b, &scale) in scales.iter().enumerate() {
                let lo = b * BLOCK;
                let hi = (lo + BLOCK).min(k);
                for (d, &c) in
                    dec.iter_mut().zip(&codes[lo..hi])
                {
                    *d = (c as i8) as f32 * scale;
                }
                let blen = hi - lo;
                for (t, a) in acc[..tile].iter_mut().enumerate() {
                    let xrow =
                        &x[(i0 + t) * k + lo..(i0 + t) * k + hi];
                    let mut s = *a;
                    for (d, xv) in dec[..blen].iter().zip(xrow) {
                        s += d * xv;
                    }
                    *a = s;
                }
            }
            for (t, &a) in acc[..tile].iter().enumerate() {
                unsafe { out.write((i0 + t) * n + r, a) };
            }
            i0 += tile;
        }
    }
}

/// Dispatch one lane's row range of one slab onto the matching core.
fn nt_rows_slab(x: &[f32], m: usize, k: usize, slab: &QuantSlab,
                rows: std::ops::Range<usize>, out: &SyncPtr) {
    if rows.is_empty() {
        return;
    }
    match slab {
        QuantSlab::F32(t) => {
            nt_rows_f32(x, m, k, t.data(), slab.rows(), rows, out)
        }
        QuantSlab::Packed(q) => match q.fmt {
            QuantFormat::Nf4 | QuantFormat::Fp4 => {
                nt_rows_q4(x, m, k, q, rows, out)
            }
            QuantFormat::Int8 => nt_rows_i8(x, m, k, q, rows, out),
            QuantFormat::Fp16 => {
                unreachable!("fp16 never packs into a QuantizedMatrix")
            }
        },
    }
}

/// `out[m, n] = x[m, k] @ slab[n, k]^T` with the weights consumed in
/// their native encoding — the quantized-residency replacement for
/// [`matmul_nt_into`] on the serving hot path. Output rows are split
/// across the pool's lanes (deterministic static partition; results
/// are thread-count-invariant and bit-identical to
/// `matmul_nt_into(x, .., dequantize(slab), ..)`).
pub fn matmul_nt_slab_into(pool: &ThreadPool, x: &[f32], m: usize,
                           k: usize, slab: &QuantSlab,
                           out: &mut [f32]) {
    matmul_nt_slabs_into(pool, x, m, k, &mut [(slab, out)]);
}

/// Most slabs one dispatch carries (q/k/v is 3; gate/up is 2). A
/// stack-array bound so the hot path stays allocation-free.
const MAX_SLAB_JOBS: usize = 8;

/// Several independent `x @ slabᵀ` products sharing one `x` (q/k/v, or
/// gate/up) fused into a single pool dispatch: each lane walks its row
/// chunk of *every* slab, halving fork/join overhead per layer. Same
/// numerics as per-slab [`matmul_nt_slab_into`] calls. Performs no
/// heap allocation — the decode step's no-per-token-allocation
/// invariant (`serve.scratch_*`) runs through here.
pub fn matmul_nt_slabs_into(pool: &ThreadPool, x: &[f32], m: usize,
                            k: usize,
                            jobs: &mut [(&QuantSlab, &mut [f32])]) {
    assert_eq!(x.len(), m * k, "x is not [m, k]");
    assert!(jobs.len() <= MAX_SLAB_JOBS, "too many fused slab jobs");
    let mut triples: [Option<(&QuantSlab, usize, SyncPtr)>;
        MAX_SLAB_JOBS] = [None; MAX_SLAB_JOBS];
    for (slot, (slab, out)) in
        triples.iter_mut().zip(jobs.iter_mut())
    {
        let (n, kk) = slab.dims();
        assert_eq!(kk, k, "slab is not [n, k]");
        assert_eq!(out.len(), m * n, "out is not [m, n]");
        // the &mut reborrow ends here; lanes write disjoint row sets
        // through the raw pointer while `run` keeps them on this frame
        *slot = Some((*slab, n, SyncPtr::new(&mut **out)));
    }
    let lanes = pool.threads();
    pool.run(&|lane| {
        for &(slab, n, ptr) in triples.iter().flatten() {
            nt_rows_slab(x, m, k, slab,
                         chunk_range(n, lane, lanes), &ptr);
        }
    });
}

/// Serial one-row product `y[n] = slab[n, k] @ x[k]` consuming the
/// slab's native encoding — the per-session *reference* (oracle)
/// decode path. Allocates its result (oracle paths may); numerically
/// identical to `matvec(dequantize(slab), x)` by the shared
/// accumulation order of the fused cores.
pub fn matvec_slab(slab: &QuantSlab, x: &[f32]) -> Vec<f32> {
    let (n, k) = slab.dims();
    assert_eq!(x.len(), k, "x is not [k]");
    let mut y = vec![0.0f32; n];
    let ptr = SyncPtr::new(&mut y);
    nt_rows_slab(x, 1, k, slab, 0..n, &ptr);
    y
}

/// Pool-parallel [`matmul_nt_into`] over a raw f32 weight slice (the
/// lm_head / vocab projection — always resident in f32). Bit-identical
/// to the serial kernel at any thread count.
pub fn par_matmul_nt_into(pool: &ThreadPool, x: &[f32], m: usize,
                          k: usize, w: &[f32], n: usize,
                          out: &mut [f32]) {
    assert_eq!(x.len(), m * k, "x is not [m, k]");
    assert_eq!(w.len(), n * k, "w is not [n, k]");
    assert_eq!(out.len(), m * n, "out is not [m, n]");
    let lanes = pool.threads();
    let ptr = SyncPtr::new(out);
    pool.run(&|lane| {
        nt_rows_f32(x, m, k, w, n, chunk_range(n, lane, lanes), &ptr);
    });
}

/// y = A[m,n] @ x[n]
pub fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    assert_eq!(n, x.len());
    let mut y = vec![0.0f32; m];
    for i in 0..m {
        let row = a.row(i);
        let mut s = 0.0f32;
        for j in 0..n {
            s += row[j] * x[j];
        }
        y[i] = s;
    }
    y
}

/// In-place lower Cholesky of a symmetric positive-definite matrix
/// (f64 for GP numerical stability). Returns L with A = L L^T.
pub fn cholesky(a: &[f64], n: usize) -> Result<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    bail!("cholesky: matrix not positive definite (pivot {s} at {i})");
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Solve L y = b (lower triangular, forward substitution).
pub fn solve_lower(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    y
}

/// Solve L^T x = y (backward substitution over a lower-triangular L).
pub fn solve_lower_t(l: &[f64], n: usize, y: &[f64]) -> Vec<f64> {
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

/// Solve A x = b for SPD A via Cholesky.
pub fn solve_spd(a: &[f64], n: usize, b: &[f64]) -> Result<Vec<f64>> {
    let l = cholesky(a, n)?;
    Ok(solve_lower_t(&l, n, &solve_lower(&l, n, b)))
}

/// Truncated SVD via one-sided Jacobi on A^T A eigen-structure.
///
/// Returns (U[m,r], S[r], V[n,r]) with A ~= U diag(S) V^T, singular
/// values in descending order. Intended for r << min(m, n) (LoRA ranks).
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub v: Tensor,
}

pub fn svd_truncated(a: &Tensor, r: usize, sweeps: usize) -> Svd {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let r = r.min(m).min(n);

    // One-sided Jacobi on columns of a working copy W (m x n): rotate
    // column pairs until near-orthogonal; then column norms are the
    // singular values and W/sigma the left vectors. V accumulates the
    // rotations. O(sweeps * n^2 * m) — fine for the per-matrix sizes
    // LoftQ touches; for the largest stacks we subsample sweeps.
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let col = |w: &Vec<f64>, j: usize, i: usize| w[i * n + j];

    for _ in 0..sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in p + 1..n {
                // 2x2 Gram block
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let wp = col(&w, p, i);
                    let wq = col(&w, q, i);
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                off += apq * apq;
                if apq.abs() < 1e-12 * (app * aqq).sqrt().max(1e-30) {
                    continue;
                }
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w[i * n + p];
                    let wq = w[i * n + q];
                    w[i * n + p] = c * wp - s * wq;
                    w[i * n + q] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-18 {
            break;
        }
    }

    // singular values = column norms, sorted desc
    let mut sig: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let mut s = 0.0f64;
            for i in 0..m {
                s += w[i * n + j] * w[i * n + j];
            }
            (s.sqrt(), j)
        })
        .collect();
    sig.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u = vec![0.0f32; m * r];
    let mut s_out = vec![0.0f32; r];
    let mut v_out = vec![0.0f32; n * r];
    for (k, &(sv, j)) in sig.iter().take(r).enumerate() {
        s_out[k] = sv as f32;
        let inv = if sv > 1e-12 { 1.0 / sv } else { 0.0 };
        for i in 0..m {
            u[i * r + k] = (w[i * n + j] * inv) as f32;
        }
        for i in 0..n {
            v_out[i * r + k] = v[i * n + j] as f32;
        }
    }
    Svd {
        u: Tensor::new(&[m, r], u),
        s: s_out,
        v: Tensor::new(&[n, r], v_out),
    }
}

/// Thin QR by modified Gram-Schmidt: A[m,k] -> Q[m,k] with
/// orthonormal columns (R discarded). Rank-deficient columns are
/// replaced by zeros.
pub fn orthonormalize_cols(a: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let mut q: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    for j in 0..k {
        for p in 0..j {
            let mut dot = 0.0f64;
            for i in 0..m {
                dot += q[i * k + p] * q[i * k + j];
            }
            for i in 0..m {
                q[i * k + j] -= dot * q[i * k + p];
            }
        }
        let mut norm = 0.0f64;
        for i in 0..m {
            norm += q[i * k + j] * q[i * k + j];
        }
        let norm = norm.sqrt();
        if norm > 1e-10 {
            for i in 0..m {
                q[i * k + j] /= norm;
            }
        } else {
            for i in 0..m {
                q[i * k + j] = 0.0;
            }
        }
    }
    Tensor::new(&[m, k], q.into_iter().map(|x| x as f32).collect())
}

/// Randomized truncated SVD (Halko et al.): much cheaper than Jacobi
/// for rank r << n. Used by LoftQ/PiSSA inside the BO loop where a
/// full SVD per candidate would dominate the wall-clock.
pub fn randomized_svd(a: &Tensor, r: usize, oversample: usize,
                      power_iters: usize,
                      rng: &mut crate::rng::Rng) -> Svd {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let k = (r + oversample).min(m).min(n);
    // range finder: Y = (A A^T)^q A Omega
    let omega = Tensor::randn(&[n, k], 1.0, rng);
    let mut y = matmul(a, &omega); // [m, k]
    let at = a.transpose2();
    for _ in 0..power_iters {
        y = orthonormalize_cols(&y);
        let z = matmul(&at, &y); // [n, k]
        y = matmul(a, &orthonormalize_cols(&z));
    }
    let q = orthonormalize_cols(&y); // [m, k]
    // small projected problem: B = Q^T A  (k x n)
    let b = matmul(&q.transpose2(), a);
    // exact Jacobi SVD on the small B^T (n x k -> only k columns)
    let svd_small = svd_truncated(&b.transpose2(), r, 40);
    // B^T = Ub S Vb^T  =>  A ~ Q B = Q (Vb S Ub^T)^T = (Q Vb) S Ub^T... careful:
    // svd_small: B^T [n,k] = U_s [n,r] S V_s [k,r]
    // => B = V_s S U_s^T  => A ~ Q V_s S U_s^T
    // so U = Q V_s [m,r], V = U_s [n,r]
    let u = matmul(&q, &svd_small.v);
    Svd { u, s: svd_small.s, v: svd_small.u }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::ThreadPool;
    use crate::rng::Rng;

    #[test]
    fn matmul_known() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(&[2, 2], vec![5., 6., 7., 8.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matvec_known() {
        let a = Tensor::new(&[2, 3], vec![1., 0., 2., 0., 1., 0.]);
        assert_eq!(matvec(&a, &[1., 2., 3.]), vec![7., 2.]);
    }

    #[test]
    fn matmul_nt_into_matches_per_row_matvec_bitwise() {
        let mut rng = Rng::new(21);
        let (m, k, n) = (5, 48, 17);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let w = Tensor::randn(&[n, k], 1.0, &mut rng);
        let mut out = vec![0.0f32; m * n];
        matmul_nt_into(x.data(), m, k, w.data(), n, &mut out);
        for i in 0..m {
            let y = matvec(&w, x.row(i));
            assert_eq!(&out[i * n..(i + 1) * n], &y[..],
                       "row {i} diverged from matvec");
        }
    }

    #[test]
    fn matmul_nt_scaled_acc_adds_on_top() {
        // out starts non-zero; the scaled product accumulates onto it
        let x = [1.0f32, 2.0];
        let w = [3.0f32, 4.0, 5.0, 6.0];
        let mut out = [10.0f32, 20.0];
        matmul_nt_scaled_acc_into(&x, 1, 2, &w, 2, 0.5, &mut out);
        assert_eq!(out, [10.0 + 0.5 * 11.0, 20.0 + 0.5 * 17.0]);
        // scale 0 is a no-op
        let before = out;
        matmul_nt_scaled_acc_into(&x, 1, 2, &w, 2, 0.0, &mut out);
        assert_eq!(out, before);
    }

    #[test]
    fn matmul_nt_into_known_values() {
        // x [1,2] @ w [2,2]^T, w rows are the output neurons
        let x = [1.0f32, 2.0];
        let w = [3.0f32, 4.0, 5.0, 6.0];
        let mut out = [0.0f32; 2];
        matmul_nt_into(&x, 1, 2, &w, 2, &mut out);
        assert_eq!(out, [11.0, 17.0]);
    }

    /// Every fused kernel must reproduce the two-step
    /// dequantize-then-GEMM reference *exactly*: the kernels decode
    /// with the same expressions and accumulate in the same order, so
    /// there is no tolerance to spend (the int8/fp16 bound the suite
    /// documents is |Δ| < 1e-5; nf4/fp4 share the block dequant order
    /// and must be bit-exact — in practice all formats are).
    #[test]
    fn fused_slab_gemm_matches_dequantized_reference() {
        let pool = ThreadPool::new(1);
        let mut rng = Rng::new(41);
        // k values exercise ragged final blocks (int8) and multi-block
        // rows (4-bit needs even k)
        for (m, k, n) in [(1usize, 64usize, 9usize), (3, 130, 17),
                          (8, 200, 12), (5, 64, 33)] {
            let x = Tensor::randn(&[m, k], 1.0, &mut rng);
            let w = Tensor::randn(&[n, k], 0.7, &mut rng);
            for fmt in [QuantFormat::Nf4, QuantFormat::Fp4,
                        QuantFormat::Int8] {
                if fmt != QuantFormat::Int8 && k % 2 != 0 {
                    continue;
                }
                let slab = QuantSlab::from_f32(&w, fmt);
                let mut fused = vec![0.0f32; m * n];
                matmul_nt_slab_into(&pool, x.data(), m, k, &slab,
                                    &mut fused);
                let deq = slab.dequantized();
                let mut want = vec![0.0f32; m * n];
                matmul_nt_into(x.data(), m, k, deq.data(), n,
                               &mut want);
                // bit-exact is the gate — stronger than the 1e-5
                // (int8/fp16) / exact (nf4 shared-block dequant
                // order) bounds the suite documents
                assert_eq!(
                    fused, want,
                    "{fmt:?} m={m} k={k} n={n} diverged from \
                     dequantize()+matmul_nt_into"
                );
            }
            // raw f32 slab arm == matmul_nt_into verbatim
            let slab = QuantSlab::from_f32(&w, QuantFormat::Fp16);
            let mut fused = vec![0.0f32; m * n];
            matmul_nt_slab_into(&pool, x.data(), m, k, &slab,
                                &mut fused);
            let mut want = vec![0.0f32; m * n];
            matmul_nt_into(x.data(), m, k, w.data(), n, &mut want);
            assert_eq!(fused, want, "f32 slab arm diverged");
        }
    }

    /// Thread-count invariance: the static row partition plus fixed
    /// per-element accumulation order makes 1, 2 and 8 lanes produce
    /// bit-identical outputs for every slab encoding.
    #[test]
    fn fused_gemm_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(42);
        let (m, k, n) = (4usize, 128usize, 23usize);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let w = Tensor::randn(&[n, k], 1.0, &mut rng);
        for fmt in [QuantFormat::Nf4, QuantFormat::Int8,
                    QuantFormat::Fp16] {
            let slab = QuantSlab::from_f32(&w, fmt);
            let mut base: Option<Vec<f32>> = None;
            for threads in [1usize, 2, 8] {
                let pool = ThreadPool::new(threads);
                let mut out = vec![0.0f32; m * n];
                matmul_nt_slab_into(&pool, x.data(), m, k, &slab,
                                    &mut out);
                match &base {
                    None => base = Some(out),
                    Some(b) => assert_eq!(
                        &out, b,
                        "{fmt:?}: {threads} threads changed the result"
                    ),
                }
            }
            // the raw-slice parallel kernel too
            let deq = slab.dequantized();
            let mut serial = vec![0.0f32; m * n];
            matmul_nt_into(x.data(), m, k, deq.data(), n, &mut serial);
            let pool = ThreadPool::new(8);
            let mut par = vec![0.0f32; m * n];
            par_matmul_nt_into(&pool, x.data(), m, k, deq.data(), n,
                               &mut par);
            assert_eq!(par, serial, "{fmt:?}: par f32 kernel diverged");
        }
    }

    /// One fused dispatch over several slabs equals per-slab calls.
    #[test]
    fn multi_slab_dispatch_matches_single_calls() {
        let mut rng = Rng::new(43);
        let (m, k) = (3usize, 64usize);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let wq = Tensor::randn(&[10, k], 1.0, &mut rng);
        let wk = Tensor::randn(&[10, k], 1.0, &mut rng);
        let wv = Tensor::randn(&[14, k], 1.0, &mut rng);
        let sq = QuantSlab::from_f32(&wq, QuantFormat::Nf4);
        let sk = QuantSlab::from_f32(&wk, QuantFormat::Int8);
        let sv = QuantSlab::from_f32(&wv, QuantFormat::Fp16);
        let pool = ThreadPool::new(3);
        let (mut oq, mut ok, mut ov) =
            (vec![0.0f32; 30], vec![0.0f32; 30], vec![0.0f32; 42]);
        matmul_nt_slabs_into(&pool, x.data(), m, k,
                             &mut [(&sq, &mut oq[..]),
                                   (&sk, &mut ok[..]),
                                   (&sv, &mut ov[..])]);
        for (slab, got) in [(&sq, &oq), (&sk, &ok), (&sv, &ov)] {
            let mut want = vec![0.0f32; got.len()];
            matmul_nt_slab_into(&pool, x.data(), m, k, slab,
                                &mut want);
            assert_eq!(got.as_slice(), want.as_slice());
        }
    }

    #[test]
    fn cholesky_roundtrip() {
        // A = L0 L0^T with L0 = [[2,0],[1,3]]
        let a = [4.0, 2.0, 2.0, 10.0];
        let l = cholesky(&a, 2).unwrap();
        assert!((l[0] - 2.0).abs() < 1e-12);
        assert!((l[2] - 1.0).abs() < 1e-12);
        assert!((l[3] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_err());
    }

    #[test]
    fn spd_solve() {
        let a = [4.0, 2.0, 2.0, 10.0];
        let x = solve_spd(&a, 2, &[8.0, 26.0]).unwrap();
        // A x = b -> x = [1, 2.4]? check: 4*1+2*2.4=8.8 no. solve exactly:
        // [4 2; 2 10] x = [8; 26] => x = [(8*10-2*26)/(40-4), ...] = [28/36*... ]
        let r0 = 4.0 * x[0] + 2.0 * x[1];
        let r1 = 2.0 * x[0] + 10.0 * x[1];
        assert!((r0 - 8.0).abs() < 1e-10 && (r1 - 26.0).abs() < 1e-10);
    }

    #[test]
    fn svd_reconstructs_low_rank() {
        // Build an exactly rank-2 matrix and check recovery.
        let mut rng = Rng::new(1);
        let u = Tensor::randn(&[20, 2], 1.0, &mut rng);
        let vt = Tensor::randn(&[2, 15], 1.0, &mut rng);
        let a = matmul(&u, &vt);
        let svd = svd_truncated(&a, 2, 30);
        // reconstruct
        let mut us = svd.u.clone();
        for i in 0..20 {
            for k in 0..2 {
                let v = us.at2(i, k) * svd.s[k];
                us.data_mut()[i * 2 + k] = v;
            }
        }
        let rec = matmul(&us, &svd.v.transpose2());
        let err = rec.sub(&a).frobenius_norm() / a.frobenius_norm();
        assert!(err < 1e-4, "relative err {err}");
    }

    #[test]
    fn svd_singular_values_descending() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[30, 10], 1.0, &mut rng);
        let svd = svd_truncated(&a, 5, 30);
        for k in 1..5 {
            assert!(svd.s[k] <= svd.s[k - 1] + 1e-5);
        }
        assert!(svd.s[0] > 0.0);
    }

    #[test]
    fn orthonormalize_gives_orthonormal_cols() {
        let mut rng = Rng::new(8);
        let a = Tensor::randn(&[30, 6], 1.0, &mut rng);
        let q = orthonormalize_cols(&a);
        let g = matmul(&q.transpose2(), &q);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g.at2(i, j) - want).abs() < 1e-4,
                        "G[{i},{j}] = {}", g.at2(i, j));
            }
        }
    }

    #[test]
    fn randomized_svd_matches_jacobi_on_low_rank() {
        let mut rng = Rng::new(9);
        let u = Tensor::randn(&[40, 3], 1.0, &mut rng);
        let vt = Tensor::randn(&[3, 25], 1.0, &mut rng);
        let a = matmul(&u, &vt);
        let svd = randomized_svd(&a, 3, 8, 2, &mut rng);
        let mut us = svd.u.clone();
        for i in 0..40 {
            for k in 0..3 {
                let v = us.at2(i, k) * svd.s[k];
                us.data_mut()[i * 3 + k] = v;
            }
        }
        let rec = matmul(&us, &svd.v.transpose2());
        let err = rec.sub(&a).frobenius_norm() / a.frobenius_norm();
        assert!(err < 1e-3, "relative err {err}");
    }

    #[test]
    fn svd_best_rank_r_beats_random_projection() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[25, 25], 1.0, &mut rng);
        let svd = svd_truncated(&a, 4, 40);
        let mut us = svd.u.clone();
        for i in 0..25 {
            for k in 0..4 {
                let v = us.at2(i, k) * svd.s[k];
                us.data_mut()[i * 4 + k] = v;
            }
        }
        let rec = matmul(&us, &svd.v.transpose2());
        let err = rec.sub(&a).frobenius_norm();
        assert!(err < a.frobenius_norm(), "rank-4 approx must reduce norm");
    }
}
