//! Small dense linear algebra for host-side math.
//!
//! Used by the GP surrogate (Cholesky posterior) and the LoftQ / PiSSA
//! adapter initializers (truncated SVD). Sizes here are tiny (GP n <= a
//! few hundred; SVD on per-layer weight matrices up to ~2k x 1k), so
//! straightforward cache-friendly implementations suffice.

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// C = A[m,k] @ B[k,n]
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dim mismatch");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    // ikj loop order: streams B rows, accumulates into C row.
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    Tensor::new(&[m, n], out)
}

/// out[m, n] = x[m, k] @ w[n, k]^T with `w` row-major `[n, k]`
/// (weights-as-rows, the projection-stack layout of `ParamStore`).
///
/// This is the serving decode hot path: it writes into a caller-owned
/// buffer (`serve/workspace.rs` holds reusable scratch) so a decode
/// step performs zero allocations. The weight-row-outer / batch-inner
/// loop order streams each weight row exactly once per call and reuses
/// it across every row of `x`, which is where the batched GEMM beats
/// per-session matvecs for batch >= 2. Each (weight row, x row) dot
/// accumulates left-to-right exactly like a per-row `matvec`, so the
/// batched and per-session decode paths agree bitwise — the invariant
/// `tests/parity_decode.rs` pins down.
pub fn matmul_nt_into(x: &[f32], m: usize, k: usize, w: &[f32],
                      n: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m * k, "x is not [m, k]");
    assert_eq!(w.len(), n * k, "w is not [n, k]");
    assert_eq!(out.len(), m * n, "out is not [m, n]");
    for r in 0..n {
        let wrow = &w[r * k..(r + 1) * k];
        for i in 0..m {
            let xrow = &x[i * k..(i + 1) * k];
            let mut s = 0.0f32;
            for (a, b) in wrow.iter().zip(xrow) {
                s += a * b;
            }
            out[i * n + r] = s;
        }
    }
}

/// out[m, n] += scale * (x[m, k] @ w[n, k]^T) — the accumulating
/// variant of [`matmul_nt_into`], used by the serving engine's
/// adjoined-LoRA side path (y += s * (x A^T) B^T on top of the base
/// GEMM). Each dot accumulates left-to-right and is scaled *before*
/// the add, exactly mirroring the per-row reference matvec
/// (`y[o] += s * dot(B[o], tmp)`), so the batched and per-session
/// adjoin paths agree bitwise like the base paths do.
pub fn matmul_nt_scaled_acc_into(x: &[f32], m: usize, k: usize,
                                 w: &[f32], n: usize, scale: f32,
                                 out: &mut [f32]) {
    assert_eq!(x.len(), m * k, "x is not [m, k]");
    assert_eq!(w.len(), n * k, "w is not [n, k]");
    assert_eq!(out.len(), m * n, "out is not [m, n]");
    for r in 0..n {
        let wrow = &w[r * k..(r + 1) * k];
        for i in 0..m {
            let xrow = &x[i * k..(i + 1) * k];
            let mut s = 0.0f32;
            for (a, b) in wrow.iter().zip(xrow) {
                s += a * b;
            }
            out[i * n + r] += scale * s;
        }
    }
}

/// y = A[m,n] @ x[n]
pub fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    assert_eq!(n, x.len());
    let mut y = vec![0.0f32; m];
    for i in 0..m {
        let row = a.row(i);
        let mut s = 0.0f32;
        for j in 0..n {
            s += row[j] * x[j];
        }
        y[i] = s;
    }
    y
}

/// In-place lower Cholesky of a symmetric positive-definite matrix
/// (f64 for GP numerical stability). Returns L with A = L L^T.
pub fn cholesky(a: &[f64], n: usize) -> Result<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    bail!("cholesky: matrix not positive definite (pivot {s} at {i})");
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Solve L y = b (lower triangular, forward substitution).
pub fn solve_lower(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    y
}

/// Solve L^T x = y (backward substitution over a lower-triangular L).
pub fn solve_lower_t(l: &[f64], n: usize, y: &[f64]) -> Vec<f64> {
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

/// Solve A x = b for SPD A via Cholesky.
pub fn solve_spd(a: &[f64], n: usize, b: &[f64]) -> Result<Vec<f64>> {
    let l = cholesky(a, n)?;
    Ok(solve_lower_t(&l, n, &solve_lower(&l, n, b)))
}

/// Truncated SVD via one-sided Jacobi on A^T A eigen-structure.
///
/// Returns (U[m,r], S[r], V[n,r]) with A ~= U diag(S) V^T, singular
/// values in descending order. Intended for r << min(m, n) (LoRA ranks).
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub v: Tensor,
}

pub fn svd_truncated(a: &Tensor, r: usize, sweeps: usize) -> Svd {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let r = r.min(m).min(n);

    // One-sided Jacobi on columns of a working copy W (m x n): rotate
    // column pairs until near-orthogonal; then column norms are the
    // singular values and W/sigma the left vectors. V accumulates the
    // rotations. O(sweeps * n^2 * m) — fine for the per-matrix sizes
    // LoftQ touches; for the largest stacks we subsample sweeps.
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let col = |w: &Vec<f64>, j: usize, i: usize| w[i * n + j];

    for _ in 0..sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in p + 1..n {
                // 2x2 Gram block
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let wp = col(&w, p, i);
                    let wq = col(&w, q, i);
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                off += apq * apq;
                if apq.abs() < 1e-12 * (app * aqq).sqrt().max(1e-30) {
                    continue;
                }
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w[i * n + p];
                    let wq = w[i * n + q];
                    w[i * n + p] = c * wp - s * wq;
                    w[i * n + q] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-18 {
            break;
        }
    }

    // singular values = column norms, sorted desc
    let mut sig: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let mut s = 0.0f64;
            for i in 0..m {
                s += w[i * n + j] * w[i * n + j];
            }
            (s.sqrt(), j)
        })
        .collect();
    sig.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u = vec![0.0f32; m * r];
    let mut s_out = vec![0.0f32; r];
    let mut v_out = vec![0.0f32; n * r];
    for (k, &(sv, j)) in sig.iter().take(r).enumerate() {
        s_out[k] = sv as f32;
        let inv = if sv > 1e-12 { 1.0 / sv } else { 0.0 };
        for i in 0..m {
            u[i * r + k] = (w[i * n + j] * inv) as f32;
        }
        for i in 0..n {
            v_out[i * r + k] = v[i * n + j] as f32;
        }
    }
    Svd {
        u: Tensor::new(&[m, r], u),
        s: s_out,
        v: Tensor::new(&[n, r], v_out),
    }
}

/// Thin QR by modified Gram-Schmidt: A[m,k] -> Q[m,k] with
/// orthonormal columns (R discarded). Rank-deficient columns are
/// replaced by zeros.
pub fn orthonormalize_cols(a: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let mut q: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    for j in 0..k {
        for p in 0..j {
            let mut dot = 0.0f64;
            for i in 0..m {
                dot += q[i * k + p] * q[i * k + j];
            }
            for i in 0..m {
                q[i * k + j] -= dot * q[i * k + p];
            }
        }
        let mut norm = 0.0f64;
        for i in 0..m {
            norm += q[i * k + j] * q[i * k + j];
        }
        let norm = norm.sqrt();
        if norm > 1e-10 {
            for i in 0..m {
                q[i * k + j] /= norm;
            }
        } else {
            for i in 0..m {
                q[i * k + j] = 0.0;
            }
        }
    }
    Tensor::new(&[m, k], q.into_iter().map(|x| x as f32).collect())
}

/// Randomized truncated SVD (Halko et al.): much cheaper than Jacobi
/// for rank r << n. Used by LoftQ/PiSSA inside the BO loop where a
/// full SVD per candidate would dominate the wall-clock.
pub fn randomized_svd(a: &Tensor, r: usize, oversample: usize,
                      power_iters: usize,
                      rng: &mut crate::rng::Rng) -> Svd {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let k = (r + oversample).min(m).min(n);
    // range finder: Y = (A A^T)^q A Omega
    let omega = Tensor::randn(&[n, k], 1.0, rng);
    let mut y = matmul(a, &omega); // [m, k]
    let at = a.transpose2();
    for _ in 0..power_iters {
        y = orthonormalize_cols(&y);
        let z = matmul(&at, &y); // [n, k]
        y = matmul(a, &orthonormalize_cols(&z));
    }
    let q = orthonormalize_cols(&y); // [m, k]
    // small projected problem: B = Q^T A  (k x n)
    let b = matmul(&q.transpose2(), a);
    // exact Jacobi SVD on the small B^T (n x k -> only k columns)
    let svd_small = svd_truncated(&b.transpose2(), r, 40);
    // B^T = Ub S Vb^T  =>  A ~ Q B = Q (Vb S Ub^T)^T = (Q Vb) S Ub^T... careful:
    // svd_small: B^T [n,k] = U_s [n,r] S V_s [k,r]
    // => B = V_s S U_s^T  => A ~ Q V_s S U_s^T
    // so U = Q V_s [m,r], V = U_s [n,r]
    let u = matmul(&q, &svd_small.v);
    Svd { u, s: svd_small.s, v: svd_small.u }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn matmul_known() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(&[2, 2], vec![5., 6., 7., 8.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matvec_known() {
        let a = Tensor::new(&[2, 3], vec![1., 0., 2., 0., 1., 0.]);
        assert_eq!(matvec(&a, &[1., 2., 3.]), vec![7., 2.]);
    }

    #[test]
    fn matmul_nt_into_matches_per_row_matvec_bitwise() {
        let mut rng = Rng::new(21);
        let (m, k, n) = (5, 48, 17);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let w = Tensor::randn(&[n, k], 1.0, &mut rng);
        let mut out = vec![0.0f32; m * n];
        matmul_nt_into(x.data(), m, k, w.data(), n, &mut out);
        for i in 0..m {
            let y = matvec(&w, x.row(i));
            assert_eq!(&out[i * n..(i + 1) * n], &y[..],
                       "row {i} diverged from matvec");
        }
    }

    #[test]
    fn matmul_nt_scaled_acc_adds_on_top() {
        // out starts non-zero; the scaled product accumulates onto it
        let x = [1.0f32, 2.0];
        let w = [3.0f32, 4.0, 5.0, 6.0];
        let mut out = [10.0f32, 20.0];
        matmul_nt_scaled_acc_into(&x, 1, 2, &w, 2, 0.5, &mut out);
        assert_eq!(out, [10.0 + 0.5 * 11.0, 20.0 + 0.5 * 17.0]);
        // scale 0 is a no-op
        let before = out;
        matmul_nt_scaled_acc_into(&x, 1, 2, &w, 2, 0.0, &mut out);
        assert_eq!(out, before);
    }

    #[test]
    fn matmul_nt_into_known_values() {
        // x [1,2] @ w [2,2]^T, w rows are the output neurons
        let x = [1.0f32, 2.0];
        let w = [3.0f32, 4.0, 5.0, 6.0];
        let mut out = [0.0f32; 2];
        matmul_nt_into(&x, 1, 2, &w, 2, &mut out);
        assert_eq!(out, [11.0, 17.0]);
    }

    #[test]
    fn cholesky_roundtrip() {
        // A = L0 L0^T with L0 = [[2,0],[1,3]]
        let a = [4.0, 2.0, 2.0, 10.0];
        let l = cholesky(&a, 2).unwrap();
        assert!((l[0] - 2.0).abs() < 1e-12);
        assert!((l[2] - 1.0).abs() < 1e-12);
        assert!((l[3] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = [1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_err());
    }

    #[test]
    fn spd_solve() {
        let a = [4.0, 2.0, 2.0, 10.0];
        let x = solve_spd(&a, 2, &[8.0, 26.0]).unwrap();
        // A x = b -> x = [1, 2.4]? check: 4*1+2*2.4=8.8 no. solve exactly:
        // [4 2; 2 10] x = [8; 26] => x = [(8*10-2*26)/(40-4), ...] = [28/36*... ]
        let r0 = 4.0 * x[0] + 2.0 * x[1];
        let r1 = 2.0 * x[0] + 10.0 * x[1];
        assert!((r0 - 8.0).abs() < 1e-10 && (r1 - 26.0).abs() < 1e-10);
    }

    #[test]
    fn svd_reconstructs_low_rank() {
        // Build an exactly rank-2 matrix and check recovery.
        let mut rng = Rng::new(1);
        let u = Tensor::randn(&[20, 2], 1.0, &mut rng);
        let vt = Tensor::randn(&[2, 15], 1.0, &mut rng);
        let a = matmul(&u, &vt);
        let svd = svd_truncated(&a, 2, 30);
        // reconstruct
        let mut us = svd.u.clone();
        for i in 0..20 {
            for k in 0..2 {
                let v = us.at2(i, k) * svd.s[k];
                us.data_mut()[i * 2 + k] = v;
            }
        }
        let rec = matmul(&us, &svd.v.transpose2());
        let err = rec.sub(&a).frobenius_norm() / a.frobenius_norm();
        assert!(err < 1e-4, "relative err {err}");
    }

    #[test]
    fn svd_singular_values_descending() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[30, 10], 1.0, &mut rng);
        let svd = svd_truncated(&a, 5, 30);
        for k in 1..5 {
            assert!(svd.s[k] <= svd.s[k - 1] + 1e-5);
        }
        assert!(svd.s[0] > 0.0);
    }

    #[test]
    fn orthonormalize_gives_orthonormal_cols() {
        let mut rng = Rng::new(8);
        let a = Tensor::randn(&[30, 6], 1.0, &mut rng);
        let q = orthonormalize_cols(&a);
        let g = matmul(&q.transpose2(), &q);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g.at2(i, j) - want).abs() < 1e-4,
                        "G[{i},{j}] = {}", g.at2(i, j));
            }
        }
    }

    #[test]
    fn randomized_svd_matches_jacobi_on_low_rank() {
        let mut rng = Rng::new(9);
        let u = Tensor::randn(&[40, 3], 1.0, &mut rng);
        let vt = Tensor::randn(&[3, 25], 1.0, &mut rng);
        let a = matmul(&u, &vt);
        let svd = randomized_svd(&a, 3, 8, 2, &mut rng);
        let mut us = svd.u.clone();
        for i in 0..40 {
            for k in 0..3 {
                let v = us.at2(i, k) * svd.s[k];
                us.data_mut()[i * 3 + k] = v;
            }
        }
        let rec = matmul(&us, &svd.v.transpose2());
        let err = rec.sub(&a).frobenius_norm() / a.frobenius_norm();
        assert!(err < 1e-3, "relative err {err}");
    }

    #[test]
    fn svd_best_rank_r_beats_random_projection() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[25, 25], 1.0, &mut rng);
        let svd = svd_truncated(&a, 4, 40);
        let mut us = svd.u.clone();
        for i in 0..25 {
            for k in 0..4 {
                let v = us.at2(i, k) * svd.s[k];
                us.data_mut()[i * 4 + k] = v;
            }
        }
        let rec = matmul(&us, &svd.v.transpose2());
        let err = rec.sub(&a).frobenius_norm();
        assert!(err < a.frobenius_norm(), "rank-4 approx must reduce norm");
    }
}
