//! Analytic peak fine-tuning memory model at paper scale.
//!
//! The paper reports peak GPU GB while LoRA/LoftQ-fine-tuning pruned
//! LLaMA-7B/13B/Vicuna-7B on an L20. That measurement is a
//! deterministic function of (architecture, pruning rate, per-layer bit
//! widths, LoRA rank, batch geometry); this module reproduces it with a
//! three-constant model calibrated on Table 1's fp16 anchor
//! (LLM-Pruner @20 % = 35.06 GB):
//!
//!   peak = WORKSPACE_FACTOR * weight_bytes        (weights + autograd
//!                                                  temporaries/dequant
//!                                                  workspace)
//!        + ACT_TENSORS * B * S * L * d_kept * 2B  (fp16 activations)
//!        + lora_optimizer_bytes                   (fp16 param+grad,
//!                                                  fp32 m/v)
//!        + OVERHEAD_GB                            (CUDA ctx, allocator)
//!
//! The same constants reproduce the quantized rows within ~5 % and the
//! 30/50 % rows within ~10 % — see EXPERIMENTS.md §Table1.

use crate::model::{ModelConfig, PROJS};
use crate::quant::{BitConfig, QuantFormat, BLOCK};

/// Multiplier on resident weight bytes covering gradients-of-activations
/// workspace, dequant buffers and fragmentation (calibrated).
pub const WORKSPACE_FACTOR: f64 = 1.8;
/// Effective number of live B*S*d fp16 activation tensors per layer.
pub const ACT_TENSORS: f64 = 33.0;
/// Fixed framework overhead in GB.
pub const OVERHEAD_GB: f64 = 1.2;

/// Weight storage bytes for one model under a bit configuration.
/// Embeddings / head / norms stay fp16 (QLoRA convention).
pub fn weight_bytes(cfg: &ModelConfig, rate_pct: u32, bits: &BitConfig)
                    -> f64 {
    assert_eq!(bits.n_layers(), cfg.n_layers);
    let ps = cfg.pruned(rate_pct);
    let mut proj_params_per_layer = 0usize;
    for p in PROJS {
        let (o, i) = cfg.proj_shape(&ps, p);
        proj_params_per_layer += o * i;
    }
    let mut bytes = 0.0f64;
    for fmt in &bits.layers {
        bytes += proj_params_per_layer as f64 * fmt.bits_per_param() / 8.0;
    }
    // embed + lm_head + norms at fp16
    let rest = 2 * cfg.vocab * cfg.d_model
        + cfg.d_model
        + 2 * cfg.n_layers * cfg.d_model;
    bytes + rest as f64 * 2.0
}

/// Host bytes the serving engine actually pins for weights at native
/// **quantized residency** — the `_at` sibling of [`weight_bytes`]
/// (which models paper-scale GPU bytes with fp16 conventions). This
/// one mirrors `serve::engine`'s slab layout byte-for-byte, so
/// `Engine::weight_host_bytes() == weight_bytes_at(cfg, rate, bits)`
/// is an exact invariant (tested from the engine side):
///
/// * nf4/fp4 layers: `o·i/2` packed-nibble codes + one f32 absmax
///   scale per `(row, BLOCK)` block;
/// * int8 layers: `o·i` code bytes + the same scale overhead;
/// * fp16-format layers and the fp stacks (embed, norms, lm_head):
///   raw f32, 4 B/elem (host-side representation).
pub fn weight_bytes_at(cfg: &ModelConfig, rate_pct: u32,
                       bits: &BitConfig) -> f64 {
    assert_eq!(bits.n_layers(), cfg.n_layers);
    let ps = cfg.pruned(rate_pct);
    let mut bytes = 0usize;
    for fmt in &bits.layers {
        for p in PROJS {
            let (o, i) = cfg.proj_shape(&ps, p);
            bytes += match fmt {
                QuantFormat::Fp16 => 4 * o * i,
                QuantFormat::Nf4 | QuantFormat::Fp4 => {
                    o * i / 2 + 4 * o * i.div_ceil(BLOCK)
                }
                QuantFormat::Int8 => {
                    o * i + 4 * o * i.div_ceil(BLOCK)
                }
            };
        }
    }
    let fp_params = 2 * cfg.vocab * cfg.d_model
        + cfg.d_model
        + 2 * cfg.n_layers * cfg.d_model;
    (bytes + 4 * fp_params) as f64
}

/// LoRA parameter + optimizer state bytes (fp16 param + fp16 grad +
/// fp32 Adam m and v).
pub fn lora_bytes(cfg: &ModelConfig, rate_pct: u32) -> f64 {
    let ps = cfg.pruned(rate_pct);
    let r = cfg.lora_rank;
    let mut params = 0usize;
    for p in PROJS {
        let (o, i) = cfg.proj_shape(&ps, p);
        params += r * i + o * r;
    }
    params *= cfg.n_layers;
    params as f64 * (2.0 + 2.0 + 4.0 + 4.0)
}

/// Activation bytes at peak (fp16), scaled by the kept width.
pub fn activation_bytes(cfg: &ModelConfig, rate_pct: u32) -> f64 {
    let keep = 1.0 - rate_pct as f64 / 100.0;
    ACT_TENSORS
        * cfg.batch as f64
        * cfg.seq as f64
        * cfg.n_layers as f64
        * cfg.d_model as f64
        * keep
        * 2.0
}

/// Peak fine-tuning memory in GB (the number every table reports).
pub fn peak_finetune_gb(cfg: &ModelConfig, rate_pct: u32, bits: &BitConfig)
                        -> f64 {
    let w = weight_bytes(cfg, rate_pct, bits) * WORKSPACE_FACTOR;
    let a = activation_bytes(cfg, rate_pct);
    let l = lora_bytes(cfg, rate_pct);
    (w + a + l) / 1e9 + OVERHEAD_GB
}

/// Inference (deployment) memory in GB: weights + single-batch
/// activations, no optimizer.
pub fn inference_gb(cfg: &ModelConfig, rate_pct: u32, bits: &BitConfig)
                    -> f64 {
    let w = weight_bytes(cfg, rate_pct, bits);
    let a = activation_bytes(cfg, rate_pct) / cfg.batch as f64;
    (w + a) / 1e9 + OVERHEAD_GB * 0.5
}

/// Map a small-model per-layer bit assignment onto another layer count
/// by proportional stretching of the layer index (used to project
/// simulator-scale configs onto the paper architectures).
pub fn stretch_bits(bits: &BitConfig, to_layers: usize) -> BitConfig {
    let from = bits.n_layers();
    assert!(from > 0);
    let layers = (0..to_layers)
        .map(|l| bits.layers[l * from / to_layers])
        .collect();
    BitConfig { layers }
}

/// KV-cache bytes one serving session pins at deployment scale, for an
/// arbitrary per-element storage cost: per layer, K and V of
/// `[max_seq, attn_dim]` at `bytes_per_elem` bytes/element, where
/// attn_dim shrinks with the pruning rate. `bytes_per_elem` comes from
/// `serve::kv_cache::KvPrecision::modeled_bytes_per_elem()` — 4.0 for
/// f32 KV, ~1.06 for int8 KV with per-block absmax scales (the scale
/// overhead is amortized exactly like `QuantFormat::bits_per_param`).
pub fn kv_bytes_per_session_at(cfg: &ModelConfig, rate_pct: u32,
                               max_seq: usize, bytes_per_elem: f64)
                               -> f64 {
    let ps = cfg.pruned(rate_pct);
    let attn_dim = ps.attn_dim(cfg);
    (cfg.n_layers * 2 * max_seq * attn_dim) as f64 * bytes_per_elem
}

/// Deployment bytes of one KV *page* (`--kv-layout paged`): per layer,
/// K and V of `[page_tokens, attn_dim]` at `bytes_per_elem` — exactly
/// [`kv_bytes_per_session_at`] with `page_tokens` in place of
/// `max_seq`, so a page is `page_tokens / max_seq` of a worst-case
/// session and the paged pool's budget math composes with the slab
/// model instead of inventing a second one.
pub fn kv_page_bytes(cfg: &ModelConfig, rate_pct: u32,
                     page_tokens: usize, bytes_per_elem: f64) -> f64 {
    kv_bytes_per_session_at(cfg, rate_pct, page_tokens, bytes_per_elem)
}

/// Deployment bytes of one KV *token* row: per layer, K and V of
/// `[1, attn_dim]` at `bytes_per_elem` — [`kv_bytes_per_session_at`]
/// with a one-token sequence. This is the unit the sub-page prefix
/// cache saves in: a sub-page hit of `m` tokens avoids recomputing
/// `m * kv_token_bytes` of prefill KV, and
/// `KvCachePool::prefix_bytes_saved_modeled` must agree with it.
pub fn kv_token_bytes(cfg: &ModelConfig, rate_pct: u32,
                      bytes_per_elem: f64) -> f64 {
    kv_bytes_per_session_at(cfg, rate_pct, 1, bytes_per_elem)
}

/// Page-granular KV bytes a session of `seq` tokens pins under the
/// paged layout: whole pages (`ceil(seq / page_tokens)`), since a
/// partially-filled tail page is still exclusively reserved. This is
/// what replaces the worst-case `max_seq` reservation in admission
/// accounting — short sessions stop paying for slack they never touch.
pub fn kv_bytes_per_session_paged(cfg: &ModelConfig, rate_pct: u32,
                                  seq: usize, page_tokens: usize,
                                  bytes_per_elem: f64) -> f64 {
    let pages = seq.div_ceil(page_tokens.max(1));
    pages as f64 * kv_page_bytes(cfg, rate_pct, page_tokens, bytes_per_elem)
}

/// KV bytes per session at the default serving representation (f32
/// host slabs, `KvPrecision::F32` — 4 bytes/element). Pass `--kv-bits
/// 8` / `KvPrecision::Int8` through [`kv_bytes_per_session_at`] for the
/// quantized cache footprint.
pub fn kv_bytes_per_session(cfg: &ModelConfig, rate_pct: u32,
                            max_seq: usize) -> f64 {
    kv_bytes_per_session_at(cfg, rate_pct, max_seq, 4.0)
}

/// KV-cache budget available to the serving layer: the device headroom
/// left after the resident inference footprint (weights + activations)
/// of the active precision config. Never negative; the serving
/// admission controller sizes its slab pool from this.
pub fn serve_kv_budget_gb(cfg: &ModelConfig, rate_pct: u32,
                          bits: &BitConfig, device_gb: f64) -> f64 {
    (device_gb - inference_gb(cfg, rate_pct, bits)).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantFormat;

    fn fp16(cfg: &ModelConfig) -> BitConfig {
        BitConfig::uniform(cfg.n_layers, QuantFormat::Fp16)
    }

    fn nf4(cfg: &ModelConfig) -> BitConfig {
        BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4)
    }

    #[test]
    fn reproduces_table1_fp16_anchor() {
        let cfg = ModelConfig::paper_7b();
        let gb = peak_finetune_gb(&cfg, 20, &fp16(&cfg));
        assert!(
            (gb - 35.06).abs() < 3.0,
            "fp16 @20% expected ~35.06 GB, got {gb:.2}"
        );
    }

    #[test]
    fn reproduces_table1_qpruner1_anchor() {
        let cfg = ModelConfig::paper_7b();
        let gb = peak_finetune_gb(&cfg, 20, &nf4(&cfg));
        assert!(
            (gb - 21.78).abs() < 2.5,
            "nf4 @20% expected ~21.78 GB, got {gb:.2}"
        );
    }

    #[test]
    fn quantization_saves_at_least_30pct() {
        // the paper's headline claim at every pruning rate
        let cfg = ModelConfig::paper_7b();
        for rate in [20, 30, 50] {
            let f = peak_finetune_gb(&cfg, rate, &fp16(&cfg));
            let q = peak_finetune_gb(&cfg, rate, &nf4(&cfg));
            assert!(q < 0.7 * f, "rate {rate}: {q:.2} !< 0.7*{f:.2}");
        }
    }

    #[test]
    fn memory_monotone_in_rate() {
        let cfg = ModelConfig::paper_7b();
        let b = nf4(&cfg);
        let g20 = peak_finetune_gb(&cfg, 20, &b);
        let g30 = peak_finetune_gb(&cfg, 30, &b);
        let g50 = peak_finetune_gb(&cfg, 50, &b);
        assert!(g20 > g30 && g30 > g50);
    }

    #[test]
    fn memory_monotone_in_bits() {
        let cfg = ModelConfig::paper_7b();
        let mut mixed = nf4(&cfg);
        for i in 0..8 {
            mixed.layers[i] = QuantFormat::Int8;
        }
        let g4 = peak_finetune_gb(&cfg, 20, &nf4(&cfg));
        let gm = peak_finetune_gb(&cfg, 20, &mixed);
        let gf = peak_finetune_gb(&cfg, 20, &fp16(&cfg));
        assert!(g4 < gm && gm < gf);
    }

    #[test]
    fn mixed_precision_overhead_is_moderate() {
        // Table 1: QPruner^2/3 cost ~1-2 GB over QPruner^1
        let cfg = ModelConfig::paper_7b();
        let mut mixed = nf4(&cfg);
        for i in 0..(cfg.n_layers / 4) {
            mixed.layers[i] = QuantFormat::Int8;
        }
        let g4 = peak_finetune_gb(&cfg, 20, &nf4(&cfg));
        let gm = peak_finetune_gb(&cfg, 20, &mixed);
        assert!(gm - g4 > 0.3 && gm - g4 < 3.0, "delta {}", gm - g4);
    }

    #[test]
    fn weight_residency_bytes_track_formats() {
        let cfg = ModelConfig::paper_7b();
        let w4 = weight_bytes_at(&cfg, 20, &nf4(&cfg));
        let mut i8b = nf4(&cfg);
        for f in i8b.layers.iter_mut() {
            *f = QuantFormat::Int8;
        }
        let w8 = weight_bytes_at(&cfg, 20, &i8b);
        let wf = weight_bytes_at(&cfg, 20, &fp16(&cfg));
        assert!(w4 < w8 && w8 < wf, "{w4} !< {w8} !< {wf}");
        // nf4 residency: codes at 0.5 B/param + 1/16 B scale overhead
        // per param — the ±scales-overhead envelope of the acceptance
        // criterion
        let ps = cfg.pruned(20);
        let mut proj_params = 0usize;
        for p in PROJS {
            let (o, i) = cfg.proj_shape(&ps, p);
            proj_params += o * i;
        }
        proj_params *= cfg.n_layers;
        let fp_params = 2 * cfg.vocab * cfg.d_model
            + cfg.d_model
            + 2 * cfg.n_layers * cfg.d_model;
        let proj_bytes = w4 - 4.0 * fp_params as f64;
        let per_param = proj_bytes / proj_params as f64;
        assert!(
            per_param >= 0.5 && per_param < 0.57,
            "nf4 residency {per_param} B/param"
        );
        // and shrinks with pruning like every other component
        assert!(weight_bytes_at(&cfg, 50, &nf4(&cfg)) < w4);
    }

    #[test]
    fn lora_bytes_tiny_fraction() {
        let cfg = ModelConfig::paper_7b();
        let l = lora_bytes(&cfg, 20);
        let w = weight_bytes(&cfg, 20, &fp16(&cfg));
        assert!(l < 0.02 * w);
    }

    #[test]
    fn inference_below_finetune() {
        let cfg = ModelConfig::paper_7b();
        let b = nf4(&cfg);
        assert!(inference_gb(&cfg, 20, &b) < peak_finetune_gb(&cfg, 20, &b));
    }

    #[test]
    fn component_bytes_monotone_in_rate() {
        // every accounting component must shrink as pruning deepens
        let cfg = ModelConfig::paper_7b();
        let b = nf4(&cfg);
        for (r_lo, r_hi) in [(0u32, 20u32), (20, 30), (30, 50)] {
            assert!(weight_bytes(&cfg, r_lo, &b)
                    > weight_bytes(&cfg, r_hi, &b));
            assert!(lora_bytes(&cfg, r_lo) > lora_bytes(&cfg, r_hi));
            assert!(activation_bytes(&cfg, r_lo)
                    > activation_bytes(&cfg, r_hi));
            assert!(inference_gb(&cfg, r_lo, &b)
                    > inference_gb(&cfg, r_hi, &b));
        }
    }

    #[test]
    fn component_bytes_monotone_in_bits() {
        // nf4 < (nf4 + some int8) < fp16, for weights and inference
        let cfg = ModelConfig::paper_7b();
        let mut mixed = nf4(&cfg);
        for i in 0..8 {
            mixed.layers[i] = QuantFormat::Int8;
        }
        for rate in [20u32, 50] {
            let w4 = weight_bytes(&cfg, rate, &nf4(&cfg));
            let wm = weight_bytes(&cfg, rate, &mixed);
            let wf = weight_bytes(&cfg, rate, &fp16(&cfg));
            assert!(w4 < wm && wm < wf, "rate {rate}");
            let i4 = inference_gb(&cfg, rate, &nf4(&cfg));
            let im = inference_gb(&cfg, rate, &mixed);
            let ifp = inference_gb(&cfg, rate, &fp16(&cfg));
            assert!(i4 < im && im < ifp, "rate {rate}");
        }
    }

    #[test]
    fn stretch_bits_preserves_prefix_structure() {
        let mut small = BitConfig::uniform(4, QuantFormat::Nf4);
        small.layers[0] = QuantFormat::Int8;
        let big = stretch_bits(&small, 32);
        assert_eq!(big.n_layers(), 32);
        // first quarter maps to the int8 layer, rest to nf4
        assert!(big.layers[..8]
            .iter()
            .all(|f| *f == QuantFormat::Int8));
        assert!(big.layers[8..]
            .iter()
            .all(|f| *f == QuantFormat::Nf4));
        // identity when layer counts match
        assert_eq!(stretch_bits(&small, 4), small);
    }

    #[test]
    fn serve_kv_budget_never_exceeds_inference_headroom() {
        let cfg = ModelConfig::paper_7b();
        let device_gb = 24.0; // L20-class card
        for rate in [0u32, 20, 30, 50] {
            for bits in [fp16(&cfg), nf4(&cfg)] {
                let budget =
                    serve_kv_budget_gb(&cfg, rate, &bits, device_gb);
                let inf = inference_gb(&cfg, rate, &bits);
                assert!(budget >= 0.0);
                assert!(
                    budget + inf <= device_gb + 1e-9,
                    "rate {rate} bits {}: {budget} + {inf} > {device_gb}",
                    bits.short()
                );
            }
        }
        // no headroom -> zero budget, never negative
        let tiny_device = 1.0;
        let b = serve_kv_budget_gb(&cfg, 20, &fp16(&cfg), tiny_device);
        assert_eq!(b, 0.0);
        // quantizing frees headroom for the KV pool
        assert!(serve_kv_budget_gb(&cfg, 20, &nf4(&cfg), device_gb)
                > serve_kv_budget_gb(&cfg, 20, &fp16(&cfg), device_gb));
    }

    #[test]
    fn kv_bytes_shrink_with_pruning_and_grow_with_seq() {
        let cfg = ModelConfig::paper_7b();
        assert!(kv_bytes_per_session(&cfg, 0, 256)
                > kv_bytes_per_session(&cfg, 50, 256));
        assert!(kv_bytes_per_session(&cfg, 0, 512)
                > kv_bytes_per_session(&cfg, 0, 256));
        // 7B @ max_seq 256: 32 layers * 2 * 256 * 4096 * 4B (f32)
        let b = kv_bytes_per_session(&cfg, 0, 256);
        assert!((b - 32.0 * 2.0 * 256.0 * 4096.0 * 4.0).abs() < 1.0);
    }

    #[test]
    fn kv_bytes_scale_linearly_with_precision() {
        let cfg = ModelConfig::paper_7b();
        let f32b = kv_bytes_per_session_at(&cfg, 20, 256, 4.0);
        // int8 KV with per-64-block f32 scales: 1 + 4/64 bytes/elem
        let i8b = kv_bytes_per_session_at(&cfg, 20, 256,
                                          1.0 + 4.0 / 64.0);
        assert!(f32b / i8b >= 3.5, "int8 KV ratio {}", f32b / i8b);
        // the default accessor is the f32 figure
        assert_eq!(kv_bytes_per_session(&cfg, 20, 256), f32b);
    }

    #[test]
    fn kv_page_bytes_compose_with_session_model() {
        let cfg = ModelConfig::paper_7b();
        // max_seq a whole number of pages: page accounting is exact
        let per_session = kv_bytes_per_session_at(&cfg, 20, 64, 4.0);
        let per_page = kv_page_bytes(&cfg, 20, 16, 4.0);
        assert!((per_session - 4.0 * per_page).abs() < 1e-6);
        // a short session pins only its pages, not the max_seq slab
        let short = kv_bytes_per_session_paged(&cfg, 20, 10, 16, 4.0);
        assert!((short - per_page).abs() < 1e-6, "10 tokens = 1 page");
        assert!(short < per_session / 2.0,
                "short paged session must undercut the slab by > 2x");
        // partial tail pages round up to whole pages
        let tail = kv_bytes_per_session_paged(&cfg, 20, 17, 16, 4.0);
        assert!((tail - 2.0 * per_page).abs() < 1e-6);
        // precision scaling carries through unchanged
        let i8p = kv_page_bytes(&cfg, 20, 16, 1.0 + 4.0 / 64.0);
        assert!(per_page / i8p >= 3.5);
        // the token unit composes both ways: page_tokens of them make
        // a page, max_seq of them make a session slab
        let tok = kv_token_bytes(&cfg, 20, 4.0);
        assert!((16.0 * tok - per_page).abs() < 1e-6);
        assert!((64.0 * tok - per_session).abs() < 1e-6);
    }

    #[test]
    fn table3_13b_scale_sanity() {
        // Table 3 parens: LLM-Pruner @50% = 41.32 GB, QPruner^3 ~ 30.5 GB
        let cfg = ModelConfig::paper_13b();
        let f = peak_finetune_gb(&cfg, 50, &fp16(&cfg));
        let q = peak_finetune_gb(&cfg, 50, &nf4(&cfg));
        assert!(f > 25.0 && f < 50.0, "13B fp16 @50% {f:.2}");
        assert!(q < f * 0.8);
    }
}
