//! The QPruner pipeline coordinator — the paper's system contribution.
//!
//! Orchestrates, entirely in rust over the AOT artifacts:
//!
//!   1. corpus **pretraining** (substrate: the in-repo stand-in for the
//!      public LLaMA/Vicuna checkpoints);
//!   2. **structured pruning** (§3.1): gradient pass -> Taylor group
//!      importance -> per-layer head/channel selection -> weight
//!      compaction to the pruned artifact shapes;
//!   3. **mixed-precision quantization** (§3.2): calibration pass ->
//!      mutual-information bit allocation (QPruner^2), optionally
//!      refined by the GP/EI **Bayesian optimization** loop
//!      (QPruner^3, Algorithm 1) where each candidate is LoftQ-prepared,
//!      proxy-fine-tuned and evaluated;
//!   4. **performance recovery** (§3.3): LoRA/LoftQ fine-tuning on the
//!      frozen (simulated-quantized) base;
//!   5. **zero-shot evaluation** over the 7-task suite + paper-scale
//!      peak-memory accounting.
//!
//! Each stage is a composable method with a *stage-scoped* option
//! struct ([`PruneOpts`], [`QuantOpts`], [`BoOpts`], [`RecoverOpts`]);
//! [`PipelineOpts`] is the bundle the full [`Coordinator::run`]
//! composition reads. The pipeline's deliverable is a deployable
//! [`ModelArtifact`] ([`Coordinator::run_with_artifact`] /
//! `qpruner export`): the frozen recovery base in its native
//! quantized encodings plus the trained LoRA deltas, which
//! `serve --artifact` boots without re-running any stage.

use crate::artifact::{LoraDelta, LoraMode, ModelArtifact, Provenance};
use crate::bo::{self, Acquisition, Observation};
use crate::data::{paper_suite, CorpusStream, Language, TaskSpec};
use crate::eval::{eval_suite, mean_accuracy, TaskResult};
use crate::finetune::{self, FinetuneOpts, FinetuneState};
use crate::lora::{self, InitMethod, LoraState};
use crate::memory;
use crate::metrics::{LossCurve, Metrics};
use crate::mi;
use crate::model::{ModelConfig, ParamStore};
use crate::pruning::{self, Aggregate, DependencyGraph, TaylorOrder};
use crate::quant::{BitConfig, QuantFormat};
use crate::rng::Rng;
use crate::runtime::{tensor_f32, Arg, Runtime};
use anyhow::{ensure, Context, Result};

/// The four method presets of Table 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// LLM-Pruner baseline: fp16 base + plain LoRA.
    LlmPruner,
    /// QPruner^1: uniform 4-bit + LoftQ.
    QPruner1,
    /// QPruner^2: MI-allocated mixed precision + LoftQ.
    QPruner2,
    /// QPruner^3: QPruner^2 refined by Bayesian optimization.
    QPruner3,
}

impl Method {
    pub fn label(self) -> &'static str {
        match self {
            Method::LlmPruner => "LLM-Pruner",
            Method::QPruner1 => "QPruner^1",
            Method::QPruner2 => "QPruner^2",
            Method::QPruner3 => "QPruner^3",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "llm-pruner" | "llmpruner" | "baseline" => Some(Method::LlmPruner),
            "qpruner1" | "q1" => Some(Method::QPruner1),
            "qpruner2" | "q2" => Some(Method::QPruner2),
            "qpruner3" | "q3" => Some(Method::QPruner3),
            _ => None,
        }
    }
}

/// Structured-pruning stage knobs (§3.1).
#[derive(Clone, Debug)]
pub struct PruneOpts {
    pub rate_pct: u32,
    /// importance estimation (Table 2: element^1 / element^2)
    pub taylor: TaylorOrder,
    pub aggregate: Aggregate,
}

/// Mixed-precision search-space knobs (§3.2) shared by the MI
/// allocator and the BO loop.
#[derive(Clone, Debug)]
pub struct QuantOpts {
    /// 4-bit data type (Table 2 ablation: NF4 vs FP4)
    pub four_bit: QuantFormat,
    /// max fraction of 8-bit layers (paper: 0.25)
    pub frac8: f64,
}

/// Bayesian-optimization stage knobs (Algorithm 1).
#[derive(Clone, Debug)]
pub struct BoOpts {
    /// acquisition function (Eq. 8's alpha)
    pub acquisition: Acquisition,
    /// BO iterations after the MI warm start (QPruner^3)
    pub iters: usize,
    /// random configs appended to the warm start (paper App. D: 10)
    pub init_random: usize,
    /// steps of the cheap proxy fine-tune inside the loop
    pub proxy_steps: usize,
    /// items/task for the proxy evaluation inside the loop
    pub proxy_items: usize,
}

/// Performance-recovery stage knobs (§3.3).
#[derive(Clone, Debug)]
pub struct RecoverOpts {
    /// adapter init (Table 2: LoftQ / Gaussian / PiSSA, LoftQ iters)
    pub init: InitMethod,
    pub finetune: FinetuneOpts,
}

/// All knobs of one pipeline run — a bundle of the stage-scoped
/// option structs plus the cross-stage method/seed/eval settings.
#[derive(Clone, Debug)]
pub struct PipelineOpts {
    pub method: Method,
    pub prune: PruneOpts,
    pub quant: QuantOpts,
    pub bo: BoOpts,
    pub recover: RecoverOpts,
    /// items/task for the final evaluation
    pub eval_items: usize,
    pub seed: u64,
    /// paper-scale architecture for the memory column ("7b" | "13b")
    pub memory_arch: String,
}

impl PipelineOpts {
    pub fn quick(rate_pct: u32, method: Method) -> PipelineOpts {
        PipelineOpts {
            method,
            prune: PruneOpts {
                rate_pct,
                taylor: TaylorOrder::First,
                aggregate: Aggregate::Sum,
            },
            quant: QuantOpts { four_bit: QuantFormat::Nf4, frac8: 0.25 },
            bo: BoOpts {
                acquisition: Acquisition::Ei,
                iters: 6,
                init_random: 3,
                proxy_steps: 16,
                proxy_items: 12,
            },
            recover: RecoverOpts {
                init: InitMethod::LoftQ { iters: 1 },
                finetune: FinetuneOpts::default(),
            },
            eval_items: 50,
            seed: 42,
            memory_arch: "7b".into(),
        }
    }

    /// Adapter init the recovery stage actually uses: the fp16
    /// baseline takes Gaussian LoRA, quantized methods the configured
    /// init (paper §4 protocol).
    pub fn effective_init(&self) -> InitMethod {
        if self.method == Method::LlmPruner {
            InitMethod::Gaussian
        } else {
            self.recover.init
        }
    }
}

/// Everything a table row needs.
#[derive(Clone, Debug)]
pub struct PipelineResult {
    pub method: Method,
    pub rate_pct: u32,
    pub bits: BitConfig,
    pub tasks: Vec<TaskResult>,
    pub mean_accuracy: f64,
    pub memory_gb: f64,
    pub observations: Vec<Observation>,
    pub curve: LossCurve,
    pub trainable_params: usize,
}

/// The coordinator owns the runtime, the language and the metrics.
pub struct Coordinator {
    pub rt: Runtime,
    pub lang: Language,
    pub metrics: Metrics,
}

impl Coordinator {
    pub fn new(rt: Runtime, lang: Language) -> Coordinator {
        Coordinator { rt, lang, metrics: Metrics::new() }
    }

    fn memory_cfg(memory_arch: &str) -> ModelConfig {
        if memory_arch == "13b" {
            ModelConfig::paper_13b()
        } else {
            ModelConfig::paper_7b()
        }
    }

    /// Paper-scale memory for a bit config at this rate.
    pub fn memory_gb(&self, memory_arch: &str, rate_pct: u32,
                     bits_small: &BitConfig) -> f64 {
        // map the small model's per-layer bits onto the paper arch by
        // proportional stretching of the layer index
        let arch = Self::memory_cfg(memory_arch);
        let stretched = memory::stretch_bits(bits_small, arch.n_layers);
        memory::peak_finetune_gb(&arch, rate_pct, &stretched)
    }

    // ------------------------------------------------------------------
    // stage 1: pretraining substrate
    // ------------------------------------------------------------------

    /// Full-parameter corpus pretraining via the `pretrain_{size}_r0`
    /// artifact. Returns the trained store and the loss curve.
    pub fn pretrain(&mut self, cfg: &ModelConfig, steps: usize, lr: f32,
                    seed: u64) -> Result<(ParamStore, LossCurve)> {
        let mut store = ParamStore::init(cfg, seed);
        let name = format!("pretrain_{}_r0", cfg.name);
        let k = cfg.scan_steps;
        let mut stream = CorpusStream::new(&self.lang, seed ^ 0x5EED);
        let mut m: Vec<_> =
            store.weights.iter().map(|w| crate::tensor::Tensor::zeros(w.shape())).collect();
        let mut v = m.clone();
        let mut t = 0.0f32;
        let mut curve = LossCurve::default();
        let shape = [k, cfg.batch, cfg.seq + 1];
        let calls = steps.div_ceil(k);
        for call in 0..calls {
            let tokens = stream.next_block(k, cfg.batch, cfg.seq + 1);
            let warm = 20.0f32;
            let lr_t = if (call * k) < warm as usize {
                lr * ((call * k) as f32 + 1.0) / warm
            } else {
                lr
            };
            let mut args: Vec<Arg> = Vec::new();
            for w in &store.weights {
                args.push(Arg::F32(w));
            }
            for x in &m {
                args.push(Arg::F32(x));
            }
            for x in &v {
                args.push(Arg::F32(x));
            }
            args.push(Arg::Scalar(t));
            args.push(Arg::I32(&tokens, &shape));
            args.push(Arg::Scalar(lr_t));
            let out = self.rt.exec(&name, &args)?;
            ensure!(out.len() == 1 + 36 + 1, "pretrain output arity");
            let losses = tensor_f32(&out[0])?;
            for (i, &l) in losses.data().iter().enumerate() {
                curve.push((call * k + i) as u64 + 1, l);
            }
            for i in 0..12 {
                store.weights[i] = tensor_f32(&out[1 + i])?;
                m[i] = tensor_f32(&out[13 + i])?;
                v[i] = tensor_f32(&out[25 + i])?;
            }
            t = tensor_f32(&out[37])?.item();
        }
        Ok((store, curve))
    }

    // ------------------------------------------------------------------
    // stage 2: structured pruning
    // ------------------------------------------------------------------

    /// Gradient pass + Taylor importance + compaction.
    pub fn prune(&mut self, store: &ParamStore, opts: &PruneOpts,
                 seed: u64) -> Result<ParamStore> {
        if opts.rate_pct == 0 {
            return Ok(store.clone());
        }
        let cfg = store.cfg.clone();
        let graph = DependencyGraph::build(&cfg);
        let zero = LoraState::zeros(store);
        let mut stream = CorpusStream::new(&self.lang, seed ^ 0xA11CE);
        // accumulate grads over a few calibration batches
        let mut acc: Option<Vec<crate::tensor::Tensor>> = None;
        let n_batches = 4;
        for _ in 0..n_batches {
            let tokens =
                stream.next_block(1, cfg.batch, cfg.seq + 1);
            let (_, grads) =
                finetune::weight_grads(&mut self.rt, store, &zero, &tokens)?;
            match &mut acc {
                None => acc = Some(grads),
                Some(a) => {
                    for (x, g) in a.iter_mut().zip(&grads) {
                        x.add_assign(g);
                    }
                }
            }
        }
        let grads = acc.unwrap();
        let imp = pruning::group_importance(
            &cfg, &graph, store, &grads, opts.taylor, opts.aggregate,
        )?;
        let plan = pruning::PruningPlan::from_importance(
            &cfg, &graph, &imp, opts.rate_pct,
        );
        pruning::apply_plan(store, &plan)
    }

    // ------------------------------------------------------------------
    // stage 3: bit allocation
    // ------------------------------------------------------------------

    /// MI-based initial allocation b0 (QPruner^2).
    pub fn allocate_bits_mi(&mut self, pruned: &ParamStore,
                            opts: &QuantOpts, seed: u64)
                            -> Result<BitConfig> {
        let cfg = &pruned.cfg;
        let zero = LoraState::zeros(pruned);
        let mut stream = CorpusStream::new(&self.lang, seed ^ 0xCA11B);
        // several calib batches -> more samples for the MI histogram
        let n_batches = 8;
        let mut pooled_all: Vec<f32> = Vec::new();
        let mut preds: Vec<usize> = Vec::new();
        let mut pooled_layers: Vec<Vec<f32>> =
            vec![Vec::new(); cfg.n_layers];
        for _ in 0..n_batches {
            let block = stream.next_block(1, cfg.batch, cfg.seq + 1);
            // calib takes [B, S]: drop the final column
            let mut toks = Vec::with_capacity(cfg.batch * cfg.seq);
            for b in 0..cfg.batch {
                let row = &block[b * (cfg.seq + 1)..(b + 1) * (cfg.seq + 1)];
                toks.extend_from_slice(&row[..cfg.seq]);
            }
            let (pooled, logits) =
                finetune::calibrate(&mut self.rt, pruned, &zero, &toks)?;
            // pooled: [L, B, d]
            let d = cfg.d_model;
            for l in 0..cfg.n_layers {
                let (_, slab) = pooled.slab(l);
                pooled_layers[l].extend_from_slice(slab);
            }
            // predictions: argmax of last-position logits
            for b in 0..cfg.batch {
                let row = logits.row(b);
                let mut best = 0usize;
                for (i, &x) in row.iter().enumerate() {
                    if x > row[best] {
                        best = i;
                    }
                }
                preds.push(best);
            }
            let _ = d;
        }
        let batch_total = preds.len();
        for l in 0..cfg.n_layers {
            pooled_all.extend_from_slice(&pooled_layers[l]);
        }
        let scores = mi::layer_mi_scores(
            &pooled_all, cfg.n_layers, batch_total, cfg.d_model, &preds,
            seed ^ 0x31,
        );
        Ok(mi::allocate_bits(&scores, opts.frac8, opts.four_bit))
    }

    // ------------------------------------------------------------------
    // stage 3b: Bayesian optimization (Algorithm 1)
    // ------------------------------------------------------------------

    /// Evaluate one candidate: LoftQ-prepare, proxy fine-tune, reduced
    /// eval. Returns (perf, paper-scale GB).
    pub fn evaluate_candidate(&mut self, pruned: &ParamStore,
                              bits: &BitConfig, opts: &PipelineOpts,
                              rng: &mut Rng) -> Result<(f64, f64)> {
        let prep = lora::prepare(pruned, bits, opts.recover.init, rng)?;
        let mut state = FinetuneState::new(prep.lora);
        let mut stream =
            CorpusStream::new(&self.lang, opts.seed ^ rng.next_u64());
        let ft = FinetuneOpts {
            steps: opts.bo.proxy_steps,
            lr: opts.recover.finetune.lr,
            warmup: 4,
            seed: opts.seed,
        };
        finetune::finetune(&mut self.rt, &prep.base, &mut state, &mut stream,
                           &ft)?;
        let tasks: Vec<TaskSpec> = paper_suite();
        let results = eval_suite(&mut self.rt, &prep.base, &state.lora,
                                 &self.lang, &tasks, opts.bo.proxy_items)?;
        let perf = mean_accuracy(&results);
        let mem = self.memory_gb(&opts.memory_arch, opts.prune.rate_pct,
                                 bits);
        Ok((perf, mem))
    }

    /// Like `evaluate_candidate` but returning the per-task breakdown
    /// (used by the Figure 3/4 Pareto harness).
    pub fn evaluate_candidate_detailed(
        &mut self, pruned: &ParamStore, bits: &BitConfig,
        opts: &PipelineOpts, rng: &mut Rng,
    ) -> Result<(Vec<TaskResult>, f64)> {
        let prep = lora::prepare(pruned, bits, opts.recover.init, rng)?;
        let mut state = FinetuneState::new(prep.lora);
        let mut stream =
            CorpusStream::new(&self.lang, opts.seed ^ rng.next_u64());
        let ft = FinetuneOpts {
            steps: opts.bo.proxy_steps,
            lr: opts.recover.finetune.lr,
            warmup: 4,
            seed: opts.seed,
        };
        finetune::finetune(&mut self.rt, &prep.base, &mut state, &mut stream,
                           &ft)?;
        let tasks = paper_suite();
        let results = eval_suite(&mut self.rt, &prep.base, &state.lora,
                                 &self.lang, &tasks, opts.bo.proxy_items)?;
        let mem = self.memory_gb(&opts.memory_arch, opts.prune.rate_pct,
                                 bits);
        Ok((results, mem))
    }

    /// Algorithm 1: warm start (b0 + random configs), then GP + EI
    /// suggestions. Returns the best config and the full dataset D.
    pub fn bo_loop(&mut self, pruned: &ParamStore, b0: BitConfig,
                   opts: &PipelineOpts)
                   -> Result<(BitConfig, Vec<Observation>)> {
        let n_layers = pruned.cfg.n_layers;
        let mut rng = Rng::new(opts.seed ^ 0xB0);
        let mut observed: Vec<Observation> = Vec::new();

        // warm start: the MI config + random budget-respecting configs
        let mut warm = vec![b0];
        let max8 = ((n_layers as f64) * opts.quant.frac8).floor() as usize;
        for _ in 0..opts.bo.init_random {
            let n8 = rng.below(max8 + 1);
            let mut c = BitConfig::uniform(n_layers, opts.quant.four_bit);
            for i in rng.choose_k(n_layers, n8) {
                c.layers[i] = QuantFormat::Int8;
            }
            if !warm.iter().any(|w: &BitConfig| w.short() == c.short()) {
                warm.push(c);
            }
        }
        for c in warm {
            let (perf, mem) =
                self.evaluate_candidate(pruned, &c, opts, &mut rng)?;
            observed.push(Observation { config: c, perf, memory_gb: mem });
        }

        for _ in 0..opts.bo.iters {
            let Some(cand) = bo::suggest(&observed, opts.bo.acquisition,
                                         opts.quant.four_bit,
                                         opts.quant.frac8,
                                         &mut rng)?
            else {
                break; // search space exhausted
            };
            let (perf, mem) =
                self.evaluate_candidate(pruned, &cand, opts, &mut rng)?;
            observed.push(Observation { config: cand, perf, memory_gb: mem });
        }

        let best = observed
            .iter()
            .max_by(|a, b| a.perf.partial_cmp(&b.perf).unwrap())
            .context("BO produced no observations")?
            .config
            .clone();
        Ok((best, observed))
    }

    // ------------------------------------------------------------------
    // stage 4: performance recovery
    // ------------------------------------------------------------------

    /// Prepare the frozen (simulated-quantized) base + adapters and
    /// run the recovery fine-tune. Returns the prepared base (the
    /// deployment weights) and the trained adapter state.
    pub fn recover(&mut self, pruned: &ParamStore, bits: &BitConfig,
                   init: InitMethod, opts: &RecoverOpts, seed: u64,
                   rng: &mut Rng)
                   -> Result<(ParamStore, FinetuneState)> {
        let prep = lora::prepare(pruned, bits, init, rng)?;
        let mut state = FinetuneState::new(prep.lora);
        let mut stream = CorpusStream::new(&self.lang, seed ^ 0xF17E);
        finetune::finetune(&mut self.rt, &prep.base, &mut state,
                           &mut stream, &opts.finetune)?;
        Ok((prep.base, state))
    }

    // ------------------------------------------------------------------
    // the full pipeline
    // ------------------------------------------------------------------

    pub fn run(&mut self, store: &ParamStore, opts: &PipelineOpts)
               -> Result<PipelineResult> {
        let (result, _, _) = self.run_stages(store, opts)?;
        Ok(result)
    }

    /// Run the full pipeline *and* keep the deployable pieces: the
    /// frozen recovery base and the trained adapters.
    fn run_stages(&mut self, store: &ParamStore, opts: &PipelineOpts)
                  -> Result<(PipelineResult, ParamStore, LoraState)> {
        let mut rng = Rng::new(opts.seed);

        // 1. prune
        let t0 = std::time::Instant::now();
        let pruned = self.prune(store, &opts.prune, opts.seed)?;
        self.metrics.add_time("pipeline.prune", t0.elapsed().as_secs_f64());

        // 2. bit allocation per method
        let (bits, observations) = match opts.method {
            Method::LlmPruner => (
                BitConfig::uniform(pruned.cfg.n_layers, QuantFormat::Fp16),
                Vec::new(),
            ),
            Method::QPruner1 => (
                BitConfig::uniform(pruned.cfg.n_layers,
                                   opts.quant.four_bit),
                Vec::new(),
            ),
            Method::QPruner2 => {
                let b = self.allocate_bits_mi(&pruned, &opts.quant,
                                              opts.seed)?;
                (b, Vec::new())
            }
            Method::QPruner3 => {
                let b0 = self.allocate_bits_mi(&pruned, &opts.quant,
                                               opts.seed)?;
                let (best, obs) = self.bo_loop(&pruned, b0, opts)?;
                (best, obs)
            }
        };

        // 3 + 4. prepare base + adapters, recovery fine-tune
        let init = opts.effective_init();
        let t1 = std::time::Instant::now();
        let (base, state) = self.recover(&pruned, &bits, init,
                                         &opts.recover, opts.seed,
                                         &mut rng)?;
        let trainable = state.lora.trainable_params();
        self.metrics
            .add_time("pipeline.finetune", t1.elapsed().as_secs_f64());

        // 5. evaluate
        let tasks = paper_suite();
        let t2 = std::time::Instant::now();
        let results = eval_suite(&mut self.rt, &base, &state.lora,
                                 &self.lang, &tasks, opts.eval_items)?;
        self.metrics.add_time("pipeline.eval", t2.elapsed().as_secs_f64());
        let mean = mean_accuracy(&results);
        let mem = self.memory_gb(&opts.memory_arch, opts.prune.rate_pct,
                                 &bits);

        let result = PipelineResult {
            method: opts.method,
            rate_pct: opts.prune.rate_pct,
            bits,
            tasks: results,
            mean_accuracy: mean,
            memory_gb: mem,
            observations,
            curve: state.curve.clone(),
            trainable_params: trainable,
        };
        Ok((result, base, state.lora))
    }

    /// Run the pipeline and package the deliverable: the result row
    /// plus a [`ModelArtifact`] holding the frozen base in its native
    /// quantized encodings and the trained LoRA deltas —
    /// `serve --artifact` boots it without re-running any stage.
    pub fn run_with_artifact(&mut self, store: &ParamStore,
                             opts: &PipelineOpts, source: &str)
                             -> Result<(PipelineResult, ModelArtifact)> {
        let (result, base, lora) = self.run_stages(store, opts)?;
        let stages = match opts.method {
            Method::LlmPruner => "prune>recover",
            Method::QPruner1 => "prune>quant>recover",
            Method::QPruner2 => "prune>mi>recover",
            Method::QPruner3 => "prune>mi>bo>recover",
        };
        let artifact = ModelArtifact::from_pipeline(
            &base,
            &result.bits,
            Some(LoraDelta::from_state(&lora)),
            LoraMode::Merge,
            Provenance {
                method: opts.method.label().to_string(),
                seed: opts.seed,
                stages: stages.to_string(),
                source: source.to_string(),
            },
        )?;
        Ok((result, artifact))
    }

    /// Evaluate a store without any tuning ("w/o tuning" rows).
    pub fn eval_untuned(&mut self, store: &ParamStore, n_items: usize)
                        -> Result<Vec<TaskResult>> {
        let zero = LoraState::zeros(store);
        let tasks = paper_suite();
        eval_suite(&mut self.rt, store, &zero, &self.lang, &tasks, n_items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_labels_parse() {
        for m in [Method::LlmPruner, Method::QPruner1, Method::QPruner2,
                  Method::QPruner3] {
            assert_eq!(
                Method::parse(&m.label().to_lowercase()
                                  .replace("llm-pruner", "llm-pruner")
                                  .replace('^', "")),
                Some(m)
            );
        }
    }

    #[test]
    fn quick_opts_sane() {
        let o = PipelineOpts::quick(20, Method::QPruner2);
        assert_eq!(o.prune.rate_pct, 20);
        assert!(o.quant.frac8 <= 0.25);
        assert_eq!(o.effective_init(), InitMethod::LoftQ { iters: 1 });
        let b = PipelineOpts::quick(20, Method::LlmPruner);
        assert_eq!(b.effective_init(), InitMethod::Gaussian);
    }
}
