//! Mutual-information bit allocation (paper §3.2, Eq. 7).
//!
//! For each layer l the calibration artifact returns the mean-pooled
//! post-block hidden state X_l and the model's final-position logits.
//! The prediction Y = argmax(logits). I(X_l; Y) is estimated by
//! discretizing a fixed random 1-D projection of X_l into quantile
//! bins and the predicted token into frequency-ranked classes, then
//! summing the plug-in estimator over the joint histogram.
//!
//! Layers with higher I(X_l; Y) get the 8-bit slots, subject to the
//! paper's budget (<= 25 % of layers at 8-bit).

use crate::quant::{BitConfig, QuantFormat};
use crate::rng::Rng;

/// Histogram-based plug-in MI estimate between a scalar-projected
/// continuous variable and a discrete label.
pub fn mutual_information(x: &[f64], y: &[usize], x_bins: usize,
                          y_classes: usize) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n == 0 {
        return 0.0;
    }
    // quantile binning of x
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| x[a].partial_cmp(&x[b]).unwrap());
    let mut xb = vec![0usize; n];
    for (rank, &i) in order.iter().enumerate() {
        xb[i] = (rank * x_bins / n).min(x_bins - 1);
    }
    // joint histogram
    let mut joint = vec![0.0f64; x_bins * y_classes];
    let mut px = vec![0.0f64; x_bins];
    let mut py = vec![0.0f64; y_classes];
    for i in 0..n {
        let yi = y[i].min(y_classes - 1);
        joint[xb[i] * y_classes + yi] += 1.0;
        px[xb[i]] += 1.0;
        py[yi] += 1.0;
    }
    let nf = n as f64;
    let mut mi = 0.0;
    for b in 0..x_bins {
        for c in 0..y_classes {
            let pxy = joint[b * y_classes + c] / nf;
            if pxy > 0.0 {
                mi += pxy * (pxy / (px[b] / nf * py[c] / nf)).ln();
            }
        }
    }
    mi.max(0.0)
}

/// Map raw predicted token ids to frequency-ranked class labels
/// (top `classes-1` tokens get their own class, the rest share one).
pub fn rank_classes(pred: &[usize], classes: usize) -> Vec<usize> {
    use std::collections::HashMap;
    let mut freq: HashMap<usize, usize> = HashMap::new();
    for &p in pred {
        *freq.entry(p).or_default() += 1;
    }
    let mut by_freq: Vec<(usize, usize)> = freq.into_iter().collect();
    by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let mut label: HashMap<usize, usize> = HashMap::new();
    for (rank, (tok, _)) in by_freq.into_iter().enumerate() {
        label.insert(tok, rank.min(classes - 1));
    }
    pred.iter().map(|p| label[p]).collect()
}

/// Per-layer MI scores from pooled hiddens [L, B, d] + predictions [B].
///
/// `pooled` is row-major; a fixed random projection (seeded) reduces
/// each layer's [B, d] block to B scalars.
pub fn layer_mi_scores(pooled: &[f32], n_layers: usize, batch: usize,
                       d_model: usize, pred: &[usize], seed: u64) -> Vec<f64> {
    assert_eq!(pooled.len(), n_layers * batch * d_model);
    assert_eq!(pred.len(), batch);
    let mut rng = Rng::new(seed);
    let proj: Vec<f64> = (0..d_model).map(|_| rng.normal()).collect();
    let x_bins = (batch / 8).clamp(4, 16);
    let y_classes = (batch / 8).clamp(4, 16);
    let y = rank_classes(pred, y_classes);
    (0..n_layers)
        .map(|l| {
            let x: Vec<f64> = (0..batch)
                .map(|b| {
                    let off = (l * batch + b) * d_model;
                    pooled[off..off + d_model]
                        .iter()
                        .zip(&proj)
                        .map(|(&h, &p)| h as f64 * p)
                        .sum()
                })
                .collect();
            mutual_information(&x, &y, x_bins, y_classes)
        })
        .collect()
}

/// Initial bit-width configuration b0 (Algorithm 1 line 2): rank layers
/// by MI, give the top `floor(frac8 * L)` layers 8-bit, the rest the
/// 4-bit format.
pub fn allocate_bits(mi: &[f64], frac8: f64, four_bit: QuantFormat)
                     -> BitConfig {
    let l = mi.len();
    let n8 = ((l as f64) * frac8).floor() as usize;
    let mut order: Vec<usize> = (0..l).collect();
    order.sort_by(|&a, &b| mi[b].partial_cmp(&mi[a]).unwrap());
    let mut layers = vec![four_bit; l];
    for &i in order.iter().take(n8) {
        layers[i] = QuantFormat::Int8;
    }
    BitConfig { layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mi_zero_for_independent() {
        let mut rng = Rng::new(1);
        let n = 4000;
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
        let mi = mutual_information(&x, &y, 8, 4);
        assert!(mi < 0.02, "independent MI {mi}");
    }

    #[test]
    fn mi_high_for_dependent() {
        let mut rng = Rng::new(2);
        let n = 4000;
        let y: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
        let x: Vec<f64> =
            y.iter().map(|&c| c as f64 + 0.05 * rng.normal()).collect();
        let mi = mutual_information(&x, &y, 8, 4);
        assert!(mi > 1.0, "dependent MI {mi}"); // H(Y) = ln 4 ~ 1.386
    }

    #[test]
    fn mi_monotone_in_noise() {
        let mut rng = Rng::new(3);
        let n = 4000;
        let y: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
        let mut last = f64::INFINITY;
        for noise in [0.05, 0.5, 3.0] {
            let x: Vec<f64> = y
                .iter()
                .map(|&c| c as f64 + noise * rng.normal())
                .collect();
            let mi = mutual_information(&x, &y, 8, 4);
            assert!(mi < last + 0.05, "noise {noise}: {mi} !< {last}");
            last = mi;
        }
    }

    #[test]
    fn mi_nonnegative_always() {
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let n = 50 + rng.below(200);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<usize> = (0..n).map(|_| rng.below(6)).collect();
            assert!(mutual_information(&x, &y, 6, 6) >= 0.0);
        }
    }

    #[test]
    fn rank_classes_compacts_labels() {
        let pred = vec![100, 100, 100, 7, 7, 3];
        let y = rank_classes(&pred, 3);
        assert_eq!(y[0], 0); // most frequent -> class 0
        assert_eq!(y[3], 1);
        assert_eq!(y[5], 2);
    }

    #[test]
    fn allocate_respects_budget_and_ranking() {
        let mi = vec![0.1, 0.9, 0.5, 0.2, 0.8, 0.3, 0.05, 0.4];
        let cfg = allocate_bits(&mi, 0.25, QuantFormat::Nf4);
        assert_eq!(cfg.layers.len(), 8);
        assert!(cfg.frac_8bit() <= 0.25 + 1e-9);
        // the two highest-MI layers (1 and 4) get 8-bit
        assert_eq!(cfg.layers[1], QuantFormat::Int8);
        assert_eq!(cfg.layers[4], QuantFormat::Int8);
        assert_eq!(cfg.layers[6], QuantFormat::Nf4);
    }

    #[test]
    fn allocate_zero_budget_is_uniform() {
        let mi = vec![0.5; 6];
        let cfg = allocate_bits(&mi, 0.0, QuantFormat::Fp4);
        assert!(cfg.layers.iter().all(|&f| f == QuantFormat::Fp4));
    }

    #[test]
    fn layer_scores_shapes() {
        let (l, b, d) = (3, 64, 8);
        let mut rng = Rng::new(5);
        let pooled: Vec<f32> =
            (0..l * b * d).map(|_| rng.normal_f32(1.0)).collect();
        let pred: Vec<usize> = (0..b).map(|_| rng.below(10)).collect();
        let s = layer_mi_scores(&pooled, l, b, d, &pred, 7);
        assert_eq!(s.len(), l);
        assert!(s.iter().all(|&x| x.is_finite() && x >= 0.0));
    }
}
