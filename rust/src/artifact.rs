//! The deployable `ModelArtifact` — the typed hand-off between the
//! QPruner pipeline and the serving layer.
//!
//! The pipeline's whole point (§3.1–3.3) is a *deployable compressed
//! model*: pruned shapes, a per-layer mixed-precision assignment, the
//! quantized base weights, and the LoRA recovery adapters trained on
//! top of the frozen base. This module makes that deliverable a
//! first-class, serialized, versioned object:
//!
//! * projection weights are stored in their **native encodings** —
//!   nf4/fp4 packed nibbles or int8 codes with per-block absmax scales
//!   (`quant::QuantizedMatrix`), fp16 layers as raw f32 — so the file
//!   is the size the paper's memory accounting promises, not an fp32
//!   checkpoint;
//! * optional **LoRA A/B deltas** ride along with a merge-or-adjoin
//!   deployment flag (`LoraMode`): fold `s·BA` into the base at engine
//!   build time, or keep the low-rank side path live in decode;
//! * **provenance** records which stages produced the artifact
//!   (method, seed, stage trail, source checkpoint);
//! * an FNV-1a **integrity checksum** and a format **version** gate
//!   loading: corrupt bytes and future formats are rejected instead of
//!   silently decoding garbage.
//!
//! Round-trip exactness: `deployed_store()` reproduces
//! `lora::quantize_base(store, bits)` bit-for-bit for nf4/fp4 (the
//! block absmax maps to the ±1.0 codebook ends, so re-quantization is
//! a fixed point) and to within one ulp for int8 — the property
//! `tests/artifact_roundtrip.rs` pins down end-to-end through
//! `serve::engine::EngineBuilder`.

use crate::lora::LoraState;
use crate::model::{proj_index, ModelConfig, ParamStore, PrunedShapes,
                   PROJS};
use crate::quant::{BitConfig, QuantFormat, QuantSlab, QuantizedMatrix};
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

/// Current on-disk format version. Bump on any layout change; loaders
/// reject other versions outright.
pub const ARTIFACT_VERSION: u32 = 1;

const MAGIC: &[u8; 8] = b"QPMARTF1";

/// The 12-stack indices stored raw (always f32): embed, attn_norm,
/// mlp_norm, final_norm, lm_head. Projections live in `projs`.
const FP_STACKS: [usize; 5] = [0, 1, 6, 10, 11];

/// How LoRA deltas deploy at engine build time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoraMode {
    /// Fold `s·BA` into the (dequantized) base weights once at build:
    /// plain GEMMs afterwards, no per-token adapter cost.
    Merge,
    /// Keep A/B as a low-rank side path evaluated every decode step —
    /// exactly the training-time numerics.
    Adjoin,
}

impl LoraMode {
    pub fn label(self) -> &'static str {
        match self {
            LoraMode::Merge => "merge",
            LoraMode::Adjoin => "adjoin",
        }
    }

    pub fn parse(s: &str) -> Option<LoraMode> {
        match s {
            "merge" | "merged" => Some(LoraMode::Merge),
            "adjoin" | "adjoined" => Some(LoraMode::Adjoin),
            _ => None,
        }
    }
}

/// Trained LoRA adapters in pipeline ABI order (A/B stacks per
/// projection, 14 tensors — the same layout as `lora::LoraState`).
#[derive(Clone, Debug)]
pub struct LoraDelta {
    pub tensors: Vec<Tensor>,
    pub rank: usize,
    pub alpha: usize,
}

impl LoraDelta {
    pub fn scaling(&self) -> f32 {
        self.alpha as f32 / self.rank as f32
    }

    pub fn from_state(state: &LoraState) -> LoraDelta {
        LoraDelta {
            tensors: state.tensors.clone(),
            rank: state.rank,
            alpha: state.alpha,
        }
    }

    /// (A, B) slabs of one layer/projection (A `[r, in]`, B `[out, r]`
    /// row-major slices into the stacked tensors).
    pub fn layer_ab(&self, proj_idx: usize, layer: usize)
                    -> (&[f32], &[f32]) {
        let (_, a) = self.tensors[2 * proj_idx].slab(layer);
        let (_, b) = self.tensors[2 * proj_idx + 1].slab(layer);
        (a, b)
    }
}

/// Where an artifact came from — recorded verbatim, surfaced by
/// `info`-style tooling and the export CLI.
#[derive(Clone, Debug, Default)]
pub struct Provenance {
    /// method label ("QPruner^3", ...)
    pub method: String,
    pub seed: u64,
    /// stage trail, e.g. "prune>mi>bo>recover"
    pub stages: String,
    /// source checkpoint or "random-init"
    pub source: String,
}

/// One projection matrix in its native deployment encoding — the
/// exact type the serving engine keeps resident ([`quant::QuantSlab`]):
/// loading an artifact moves these blobs straight into the engine with
/// no dequantization and no re-encoding.
pub use crate::quant::QuantSlab as WeightBlob;

/// The serialized, versioned deliverable of one pipeline run.
#[derive(Clone, Debug)]
pub struct ModelArtifact {
    pub cfg: ModelConfig,
    pub ps: PrunedShapes,
    /// per-layer deployment precision (the encoding of `projs`)
    pub bits: BitConfig,
    /// raw f32 stacks in `FP_STACKS` order: embed, attn_norm,
    /// mlp_norm, final_norm, lm_head
    pub fp_stacks: Vec<Tensor>,
    /// `[PROJS.len()][n_layers]` native-encoded projection matrices —
    /// the engine's residency unit, adopted as-is at build time
    pub projs: Vec<Vec<QuantSlab>>,
    pub lora: Option<LoraDelta>,
    /// default deployment mode for `lora` (builders may override)
    pub lora_mode: LoraMode,
    pub provenance: Provenance,
}

impl ModelArtifact {
    /// Encode a pipeline output. `store` is the deployment base in
    /// f32 (either the pruned full-precision weights, or — after a
    /// LoftQ/PiSSA recovery — the prepared base whose projections
    /// already sit on the quantization grid; encoding is a fixed point
    /// of the quantizer either way). `lora`, when present, must match
    /// the store's adapter shapes.
    pub fn from_pipeline(store: &ParamStore, bits: &BitConfig,
                         lora: Option<LoraDelta>, lora_mode: LoraMode,
                         provenance: Provenance)
                         -> Result<ModelArtifact> {
        ensure!(
            bits.n_layers() == store.cfg.n_layers,
            "bit config has {} layers, model has {}",
            bits.n_layers(),
            store.cfg.n_layers
        );
        if let Some(d) = &lora {
            let want = LoraState::shapes(store);
            ensure!(
                d.tensors.len() == want.len(),
                "lora delta has {} tensors, expected {}",
                d.tensors.len(),
                want.len()
            );
            for (t, w) in d.tensors.iter().zip(&want) {
                ensure!(
                    t.shape() == w.as_slice(),
                    "lora delta shape {:?} != expected {:?}",
                    t.shape(),
                    w
                );
            }
            ensure!(d.rank > 0, "lora rank must be positive");
        }
        let fp_stacks =
            FP_STACKS.iter().map(|&i| store.weights[i].clone()).collect();
        let mut projs = Vec::with_capacity(PROJS.len());
        for p in PROJS {
            let mut per_layer = Vec::with_capacity(store.cfg.n_layers);
            for l in 0..store.cfg.n_layers {
                let w = store.layer_proj(l, p);
                per_layer.push(QuantSlab::from_f32(&w, bits.layers[l]));
            }
            projs.push(per_layer);
        }
        Ok(ModelArtifact {
            cfg: store.cfg.clone(),
            ps: store.ps,
            bits: bits.clone(),
            fp_stacks,
            projs,
            lora,
            lora_mode,
            provenance,
        })
    }

    /// Check every stack and blob against the shapes the config
    /// demands — the load-time validation, without materializing any
    /// dequantized weights.
    pub fn validate_shapes(&self) -> Result<()> {
        let shapes = ParamStore::shapes(&self.cfg, &self.ps);
        ensure!(
            self.fp_stacks.len() == FP_STACKS.len()
                && self.projs.len() == PROJS.len(),
            "artifact stack counts are wrong"
        );
        for (fi, &wi) in FP_STACKS.iter().enumerate() {
            ensure!(
                self.fp_stacks[fi].shape() == shapes[wi].as_slice(),
                "artifact stack {wi} shape {:?} != expected {:?}",
                self.fp_stacks[fi].shape(),
                shapes[wi]
            );
        }
        for (pi, p) in PROJS.iter().enumerate() {
            let (o, i) = self.cfg.proj_shape(&self.ps, p);
            ensure!(
                self.projs[pi].len() == self.cfg.n_layers,
                "artifact proj {p} has {} layers, expected {}",
                self.projs[pi].len(),
                self.cfg.n_layers
            );
            for (l, blob) in self.projs[pi].iter().enumerate() {
                ensure!(
                    blob.dims() == (o, i),
                    "artifact proj {p} layer {l} is {:?}, expected \
                     ({o}, {i})",
                    blob.dims()
                );
            }
        }
        Ok(())
    }

    /// Reassemble the deployment `ParamStore`: packed blobs are
    /// dequantized to f32, exactly the numerics of
    /// `lora::quantize_base(store, bits)`.
    pub fn deployed_store(&self) -> Result<ParamStore> {
        self.validate_shapes()?;
        let shapes = ParamStore::shapes(&self.cfg, &self.ps);
        let mut weights: Vec<Tensor> =
            shapes.iter().map(|s| Tensor::zeros(s)).collect();
        for (fi, &wi) in FP_STACKS.iter().enumerate() {
            weights[wi] = self.fp_stacks[fi].clone();
        }
        for (pi, p) in PROJS.iter().enumerate() {
            let stack = &mut weights[proj_index(p)];
            for (l, blob) in self.projs[pi].iter().enumerate() {
                let t = blob.dequantized();
                stack.slab_mut(l).copy_from_slice(t.data());
            }
        }
        Ok(ParamStore { cfg: self.cfg.clone(), ps: self.ps, weights })
    }

    /// Total native storage bytes of the encoded weights (+ LoRA).
    pub fn storage_bytes(&self) -> usize {
        let mut n: usize =
            self.fp_stacks.iter().map(|t| t.len() * 4).sum();
        for per_layer in &self.projs {
            for b in per_layer {
                n += b.storage_bytes();
            }
        }
        if let Some(d) = &self.lora {
            n += d.tensors.iter().map(|t| t.len() * 4).sum::<usize>();
        }
        n
    }

    /// One-line human summary for CLI output.
    pub fn summary(&self) -> String {
        format!(
            "{} rate {}% bits {} lora {} ({}) — {:.2} MB native \
             [{} seed {} via {}]",
            self.cfg.name,
            self.ps.rate_pct,
            self.bits.short(),
            if self.lora.is_some() { "yes" } else { "no" },
            self.lora_mode.label(),
            self.storage_bytes() as f64 / 1e6,
            self.provenance.method,
            self.provenance.seed,
            if self.provenance.stages.is_empty() {
                "?"
            } else {
                self.provenance.stages.as_str()
            },
        )
    }

    // ---------------- serialization ----------------

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let payload = self.encode_payload();
        let mut out =
            Vec::with_capacity(payload.len() + MAGIC.len() + 20);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&ARTIFACT_VERSION.to_le_bytes());
        out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&payload);
        std::fs::write(path, out)
            .with_context(|| format!("write artifact {path:?}"))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ModelArtifact> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("open artifact {path:?}"))?;
        ensure!(
            bytes.len() >= MAGIC.len() + 20,
            "artifact {path:?} truncated ({} bytes)",
            bytes.len()
        );
        ensure!(
            bytes[..MAGIC.len()] == MAGIC[..],
            "bad artifact magic in {path:?} (not a qpruner model \
             artifact)"
        );
        let mut cur = Cursor { b: &bytes[..], p: MAGIC.len() };
        let version = cur.u32()?;
        ensure!(
            version == ARTIFACT_VERSION,
            "unsupported artifact version {version} (this build reads \
             version {ARTIFACT_VERSION}) — re-export the artifact"
        );
        let checksum = cur.u64()?;
        let plen = cur.u64()? as usize;
        let payload = cur.take(plen)?;
        ensure!(
            cur.p == bytes.len(),
            "artifact {path:?} has {} trailing bytes",
            bytes.len() - cur.p
        );
        ensure!(
            fnv1a64(payload) == checksum,
            "artifact checksum mismatch in {path:?} (corrupt or \
             truncated file)"
        );
        Self::decode_payload(payload)
            .with_context(|| format!("decode artifact {path:?}"))
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut out = Vec::new();
        let (rank, alpha) = self
            .lora
            .as_ref()
            .map(|d| (d.rank, d.alpha))
            .unwrap_or((0, 0));
        // free-text provenance fields go into a tab-separated header:
        // strip the separator (and newlines) so a checkpoint path
        // containing a tab can't produce an artifact that saves fine
        // but fails the field-count check on every load
        let clean = |s: &str| s.replace(['\t', '\n', '\r'], " ");
        let meta = format!(
            "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}",
            self.cfg.name,
            self.ps.rate_pct,
            self.ps.heads_kept,
            self.ps.d_ff_kept,
            self.bits.short(),
            self.lora_mode.label(),
            rank,
            alpha,
            clean(&self.provenance.method),
            self.provenance.seed,
            clean(&self.provenance.stages),
            clean(&self.provenance.source),
        );
        put_u32(&mut out, meta.len() as u32);
        out.extend_from_slice(meta.as_bytes());
        for t in &self.fp_stacks {
            put_tensor(&mut out, t);
        }
        for per_layer in &self.projs {
            for blob in per_layer {
                match blob {
                    QuantSlab::F32(t) => {
                        out.push(0u8);
                        put_tensor(&mut out, t);
                    }
                    QuantSlab::Packed(q) => {
                        out.push(1u8);
                        out.push(fmt_code(q.fmt));
                        put_u64(&mut out, q.rows as u64);
                        put_u64(&mut out, q.cols as u64);
                        put_u64(&mut out, q.codes.len() as u64);
                        out.extend_from_slice(&q.codes);
                        put_u64(&mut out, q.scales.len() as u64);
                        for &s in &q.scales {
                            out.extend_from_slice(&s.to_le_bytes());
                        }
                    }
                }
            }
        }
        match &self.lora {
            None => out.push(0u8),
            Some(d) => {
                out.push(1u8);
                put_u32(&mut out, d.tensors.len() as u32);
                for t in &d.tensors {
                    put_tensor(&mut out, t);
                }
            }
        }
        out
    }

    fn decode_payload(payload: &[u8]) -> Result<ModelArtifact> {
        let mut cur = Cursor { b: payload, p: 0 };
        let mlen = cur.u32()? as usize;
        let meta = std::str::from_utf8(cur.take(mlen)?)
            .context("artifact meta is not utf-8")?;
        let f: Vec<&str> = meta.split('\t').collect();
        ensure!(f.len() == 12, "bad artifact meta ({} fields)", f.len());
        let cfg = ModelConfig::preset(f[0])?;
        let ps = PrunedShapes {
            rate_pct: f[1].parse().context("artifact rate")?,
            heads_kept: f[2].parse().context("artifact heads")?,
            d_ff_kept: f[3].parse().context("artifact d_ff")?,
        };
        let bits = BitConfig::parse_short(f[4])
            .with_context(|| format!("bad artifact bits {:?}", f[4]))?;
        ensure!(
            bits.n_layers() == cfg.n_layers,
            "artifact bits cover {} layers, model has {}",
            bits.n_layers(),
            cfg.n_layers
        );
        let lora_mode = LoraMode::parse(f[5]).with_context(|| {
            format!("bad artifact lora mode {:?}", f[5])
        })?;
        let rank: usize = f[6].parse().context("artifact rank")?;
        let alpha: usize = f[7].parse().context("artifact alpha")?;
        let provenance = Provenance {
            method: f[8].to_string(),
            seed: f[9].parse().context("artifact seed")?,
            stages: f[10].to_string(),
            source: f[11].to_string(),
        };
        let mut fp_stacks = Vec::with_capacity(FP_STACKS.len());
        for _ in 0..FP_STACKS.len() {
            fp_stacks.push(take_tensor(&mut cur)?);
        }
        let mut projs = Vec::with_capacity(PROJS.len());
        for _ in 0..PROJS.len() {
            let mut per_layer = Vec::with_capacity(cfg.n_layers);
            for _ in 0..cfg.n_layers {
                per_layer.push(match cur.u8()? {
                    0 => QuantSlab::F32(take_tensor(&mut cur)?),
                    1 => {
                        let fmt = fmt_from_code(cur.u8()?)?;
                        let rows = cur.u64()? as usize;
                        let cols = cur.u64()? as usize;
                        let nc = cur.u64()? as usize;
                        let codes = cur.take(nc)?.to_vec();
                        let ns = cur.u64()? as usize;
                        ensure!(ns <= 1 << 31, "scales too large");
                        let raw = cur.take(ns * 4)?;
                        let scales: Vec<f32> = raw
                            .chunks_exact(4)
                            .map(|c| f32::from_le_bytes([
                                c[0], c[1], c[2], c[3],
                            ]))
                            .collect();
                        QuantSlab::Packed(QuantizedMatrix {
                            fmt,
                            rows,
                            cols,
                            codes,
                            scales,
                        })
                    }
                    t => bail!("bad weight blob tag {t}"),
                });
            }
            projs.push(per_layer);
        }
        let lora = match cur.u8()? {
            0 => None,
            1 => {
                ensure!(rank > 0, "lora present but rank is 0");
                let n = cur.u32()? as usize;
                let mut tensors = Vec::with_capacity(n);
                for _ in 0..n {
                    tensors.push(take_tensor(&mut cur)?);
                }
                Some(LoraDelta { tensors, rank, alpha })
            }
            t => bail!("bad lora tag {t}"),
        };
        ensure!(
            cur.p == payload.len(),
            "artifact payload has {} undecoded bytes",
            payload.len() - cur.p
        );
        let art = ModelArtifact {
            cfg,
            ps,
            bits,
            fp_stacks,
            projs,
            lora,
            lora_mode,
            provenance,
        };
        // shape-check everything once up front, without paying for a
        // dequantization the engine build will do anyway
        art.validate_shapes()?;
        Ok(art)
    }
}

fn fmt_code(fmt: QuantFormat) -> u8 {
    match fmt {
        QuantFormat::Nf4 => 0,
        QuantFormat::Fp4 => 1,
        QuantFormat::Int8 => 2,
        QuantFormat::Fp16 => 3,
    }
}

fn fmt_from_code(c: u8) -> Result<QuantFormat> {
    Ok(match c {
        0 => QuantFormat::Nf4,
        1 => QuantFormat::Fp4,
        2 => QuantFormat::Int8,
        3 => QuantFormat::Fp16,
        _ => bail!("bad quant format code {c}"),
    })
}

/// FNV-1a 64-bit — small, dependency-free, and plenty for integrity
/// (this guards against corruption, not adversaries).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    put_u32(out, t.ndim() as u32);
    for &d in t.shape() {
        put_u64(out, d as u64);
    }
    for &x in t.data() {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .p
            .checked_add(n)
            .filter(|&e| e <= self.b.len());
        let Some(end) = end else {
            bail!(
                "artifact truncated: need {n} bytes at offset {}",
                self.p
            );
        };
        let s = &self.b[self.p..end];
        self.p = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_le_bytes(a))
    }
}

fn take_tensor(cur: &mut Cursor) -> Result<Tensor> {
    let nd = cur.u32()? as usize;
    ensure!(nd >= 1 && nd <= 4, "bad tensor ndim {nd}");
    let mut shape = Vec::with_capacity(nd);
    for _ in 0..nd {
        let d = cur.u64()? as usize;
        ensure!(d > 0 && d <= 1 << 32, "bad tensor dim {d}");
        shape.push(d);
    }
    let count = shape
        .iter()
        .try_fold(1usize, |a, &d| a.checked_mul(d))
        .context("tensor shape overflows")?;
    ensure!(count <= 1 << 31, "tensor too large ({count} elems)");
    // one bounds-checked take for the whole payload, not one per
    // element — artifact load is dominated by these reads
    let raw = cur.take(count * 4)?;
    let data: Vec<f32> = raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Tensor::new(&shape, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lora;
    use crate::rng::Rng;

    fn setup() -> (ParamStore, BitConfig) {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 9);
        let mut bits =
            BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
        bits.layers[0] = QuantFormat::Int8;
        (store, bits)
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("qpruner_artifact_mod_t");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn deployed_store_matches_quantize_base_exactly() {
        let (store, bits) = setup();
        let art = ModelArtifact::from_pipeline(
            &store, &bits, None, LoraMode::Merge,
            Provenance::default(),
        )
        .unwrap();
        let deployed = art.deployed_store().unwrap();
        let want = lora::quantize_base(&store, &bits);
        for (a, b) in deployed.weights.iter().zip(&want.weights) {
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn save_load_roundtrip_preserves_everything() {
        let (store, bits) = setup();
        let mut rng = Rng::new(3);
        let prep =
            lora::init_gaussian(&store, &bits, &mut rng);
        let art = ModelArtifact::from_pipeline(
            &store,
            &bits,
            Some(LoraDelta::from_state(&prep.lora)),
            LoraMode::Adjoin,
            Provenance {
                method: "QPruner^2".into(),
                seed: 42,
                stages: "prune>mi>recover".into(),
                source: "unit-test".into(),
            },
        )
        .unwrap();
        let path = tmp("roundtrip.qpart");
        art.save(&path).unwrap();
        let back = ModelArtifact::load(&path).unwrap();
        assert_eq!(back.bits, art.bits);
        assert_eq!(back.ps, art.ps);
        assert_eq!(back.lora_mode, LoraMode::Adjoin);
        assert_eq!(back.provenance.method, "QPruner^2");
        assert_eq!(back.provenance.seed, 42);
        assert_eq!(back.provenance.stages, "prune>mi>recover");
        let a = art.deployed_store().unwrap();
        let b = back.deployed_store().unwrap();
        for (x, y) in a.weights.iter().zip(&b.weights) {
            assert_eq!(x.data(), y.data());
        }
        let la = art.lora.as_ref().unwrap();
        let lb = back.lora.as_ref().unwrap();
        assert_eq!(la.rank, lb.rank);
        assert_eq!(la.alpha, lb.alpha);
        for (x, y) in la.tensors.iter().zip(&lb.tensors) {
            assert_eq!(x.data(), y.data());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupted_payload_is_rejected() {
        let (store, bits) = setup();
        let art = ModelArtifact::from_pipeline(
            &store, &bits, None, LoraMode::Merge,
            Provenance::default(),
        )
        .unwrap();
        let path = tmp("corrupt.qpart");
        art.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = ModelArtifact::load(&path).unwrap_err();
        assert!(
            format!("{err:#}").contains("checksum"),
            "unexpected error: {err:#}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let (store, bits) = setup();
        let art = ModelArtifact::from_pipeline(
            &store, &bits, None, LoraMode::Merge,
            Provenance::default(),
        )
        .unwrap();
        let path = tmp("version.qpart");
        art.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // version u32 sits right after the 8-byte magic
        bytes[8..12]
            .copy_from_slice(&(ARTIFACT_VERSION + 1).to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = ModelArtifact::load(&path).unwrap_err();
        assert!(
            format!("{err:#}").contains("version"),
            "unexpected error: {err:#}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_is_rejected() {
        let path = tmp("magic.qpart");
        std::fs::write(&path, b"definitely not an artifact at all")
            .unwrap();
        assert!(ModelArtifact::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn native_encoding_is_smaller_than_f32() {
        let (store, bits) = setup();
        let art = ModelArtifact::from_pipeline(
            &store, &bits, None, LoraMode::Merge,
            Provenance::default(),
        )
        .unwrap();
        // nf4-dominated projections must store far below 4 B/param;
        // allow for the raw embed/head stacks which dominate tiny
        let f32_bytes = store.total_params() * 4;
        assert!(
            art.storage_bytes() < f32_bytes,
            "{} !< {}",
            art.storage_bytes(),
            f32_bytes
        );
    }

    #[test]
    fn lora_mode_parse_roundtrip() {
        for m in [LoraMode::Merge, LoraMode::Adjoin] {
            assert_eq!(LoraMode::parse(m.label()), Some(m));
        }
        assert!(LoraMode::parse("fold").is_none());
    }
}
