//! Bayesian optimization of the bit-width configuration (paper §3.2,
//! Eq. 8 and Algorithm 1).
//!
//! A Gaussian-Process surrogate (RBF kernel over the per-layer bit
//! features, Cholesky posterior) models P(b); an acquisition function
//! (EI by default, UCB available) proposes the next configuration from
//! a constrained discrete candidate pool ({4,8}^L with the 8-bit
//! fraction capped). Every evaluated (b, P(b), M(b)) lands in the
//! dataset D; the non-dominated subset is the Pareto front of
//! Figures 3/4.

use crate::linalg;
use crate::quant::{BitConfig, QuantFormat};
use crate::rng::Rng;
use anyhow::Result;

/// One evaluated configuration (a row of the paper's dataset D).
#[derive(Clone, Debug)]
pub struct Observation {
    pub config: BitConfig,
    /// task performance P(b) — higher is better (mean accuracy here)
    pub perf: f64,
    /// memory usage M(b) in GB at paper scale
    pub memory_gb: f64,
}

/// GP covariance kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// squared-exponential (smooth)
    Rbf,
    /// Matern 5/2 — the BO community default for rougher objectives
    Matern52,
}

impl Kernel {
    fn eval(self, a: &[f64], b: &[f64], ls: f64) -> f64 {
        let d2: f64 =
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
        match self {
            Kernel::Rbf => (-0.5 * d2 / (ls * ls)).exp(),
            Kernel::Matern52 => {
                let r = d2.sqrt() / ls;
                let s = 5.0f64.sqrt() * r;
                (1.0 + s + 5.0 * d2 / (3.0 * ls * ls)) * (-s).exp()
            }
        }
    }
}

/// Gaussian Process regression in f64 (RBF or Matern 5/2 kernel).
pub struct Gp {
    kernel: Kernel,
    lengthscale: f64,
    signal_var: f64,
    noise_var: f64,
    x: Vec<Vec<f64>>,
    /// Cholesky factor of K + noise I
    l: Vec<f64>,
    /// alpha = K^{-1} (y - mean)
    alpha: Vec<f64>,
    y_mean: f64,
}

impl Gp {
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], lengthscale: f64,
               noise_var: f64) -> Result<Gp> {
        Self::fit_kernel(xs, ys, Kernel::Rbf, lengthscale, noise_var)
    }

    pub fn fit_kernel(xs: &[Vec<f64>], ys: &[f64], kernel: Kernel,
                      lengthscale: f64, noise_var: f64) -> Result<Gp> {
        assert_eq!(xs.len(), ys.len());
        let n = xs.len();
        let y_mean = ys.iter().sum::<f64>() / n as f64;
        let yc: Vec<f64> = ys.iter().map(|y| y - y_mean).collect();
        let signal_var = {
            let v = yc.iter().map(|y| y * y).sum::<f64>() / n as f64;
            v.max(1e-6)
        };
        let mut k = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] =
                    signal_var * kernel.eval(&xs[i], &xs[j], lengthscale);
                if i == j {
                    k[i * n + j] += noise_var + 1e-9;
                }
            }
        }
        let l = linalg::cholesky(&k, n)?;
        let alpha = linalg::solve_lower_t(&l, n, &linalg::solve_lower(&l, n, &yc));
        Ok(Gp {
            kernel,
            lengthscale,
            signal_var,
            noise_var,
            x: xs.to_vec(),
            l,
            alpha,
            y_mean,
        })
    }

    /// Fit with the lengthscale chosen by log-marginal-likelihood over
    /// a geometric grid (Rasmussen & Williams Eq. 2.30) — the
    /// rust-side equivalent of Optuna's hyperparameter adaptation.
    pub fn fit_ml(xs: &[Vec<f64>], ys: &[f64], kernel: Kernel,
                  noise_var: f64) -> Result<Gp> {
        let d = xs.first().map(|x| x.len()).unwrap_or(1) as f64;
        let base = d.sqrt();
        let mut best: Option<(f64, Gp)> = None;
        for mult in [0.25, 0.5, 0.75, 1.0, 1.5, 2.5] {
            let gp = Self::fit_kernel(xs, ys, kernel, base * mult,
                                      noise_var)?;
            let nll = gp.log_marginal_likelihood(ys);
            if best.as_ref().map(|(b, _)| nll > *b).unwrap_or(true) {
                best = Some((nll, gp));
            }
        }
        Ok(best.unwrap().1)
    }

    /// log p(y | X, theta) for the fitted hyperparameters.
    pub fn log_marginal_likelihood(&self, ys: &[f64]) -> f64 {
        let n = self.x.len();
        let yc: Vec<f64> = ys.iter().map(|y| y - self.y_mean).collect();
        let data_fit: f64 =
            yc.iter().zip(&self.alpha).map(|(y, a)| y * a).sum::<f64>();
        let log_det: f64 =
            (0..n).map(|i| self.l[i * n + i].ln()).sum::<f64>() * 2.0;
        -0.5 * data_fit - 0.5 * log_det
            - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln()
    }

    pub fn lengthscale(&self) -> f64 {
        self.lengthscale
    }

    /// Posterior mean and variance at x*.
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        let n = self.x.len();
        let kstar: Vec<f64> = self
            .x
            .iter()
            .map(|xi| {
                self.signal_var * self.kernel.eval(xi, x, self.lengthscale)
            })
            .collect();
        let mean = self.y_mean
            + kstar.iter().zip(&self.alpha).map(|(k, a)| k * a).sum::<f64>();
        let v = linalg::solve_lower(&self.l, n, &kstar);
        let var = self.signal_var + self.noise_var
            - v.iter().map(|x| x * x).sum::<f64>();
        (mean, var.max(1e-12))
    }
}

/// Acquisition functions (the alpha(b) of Eq. 8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Acquisition {
    /// Expected improvement over the incumbent best.
    Ei,
    /// Upper confidence bound, mean + kappa * std.
    Ucb,
}

fn normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Abramowitz-Stegun 7.1.26 erf approximation (|err| < 1.5e-7).
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741)
            * t
            - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

pub fn acquisition_score(acq: Acquisition, mean: f64, var: f64,
                         best: f64, kappa: f64) -> f64 {
    let std = var.sqrt();
    match acq {
        Acquisition::Ei => {
            if std < 1e-12 {
                return 0.0;
            }
            let z = (mean - best) / std;
            (mean - best) * normal_cdf(z) + std * normal_pdf(z)
        }
        Acquisition::Ucb => mean + kappa * std,
    }
}

/// Candidate generator: all 1-flip neighbours of the evaluated configs
/// plus random budget-respecting samples, deduplicated, constraint
/// frac_8bit <= max_frac8, minus already-evaluated points.
pub fn candidates(observed: &[Observation], n_layers: usize,
                  four_bit: QuantFormat, max_frac8: f64, n_random: usize,
                  rng: &mut Rng) -> Vec<BitConfig> {
    use std::collections::HashSet;
    let mut seen: HashSet<String> =
        observed.iter().map(|o| o.config.short()).collect();
    let mut out = Vec::new();
    let push = |c: BitConfig, out: &mut Vec<BitConfig>,
                    seen: &mut HashSet<String>| {
        if c.frac_8bit() <= max_frac8 + 1e-9 && seen.insert(c.short()) {
            out.push(c);
        }
    };
    // 1-flip neighbourhood of every observed config
    for o in observed {
        for l in 0..n_layers {
            let mut c = o.config.clone();
            c.layers[l] = match c.layers[l] {
                QuantFormat::Int8 => four_bit,
                _ => QuantFormat::Int8,
            };
            push(c, &mut out, &mut seen);
        }
    }
    // random samples under the budget
    let max8 = ((n_layers as f64) * max_frac8).floor() as usize;
    for _ in 0..n_random {
        let n8 = rng.below(max8 + 1);
        let mut c = BitConfig::uniform(n_layers, four_bit);
        for i in rng.choose_k(n_layers, n8) {
            c.layers[i] = QuantFormat::Int8;
        }
        push(c, &mut out, &mut seen);
    }
    out
}

/// One Algorithm-1 suggestion: fit the GP on D, maximize alpha over
/// the candidate pool. Returns None when the pool is empty (search
/// space exhausted).
pub fn suggest(observed: &[Observation], acq: Acquisition,
               four_bit: QuantFormat, max_frac8: f64, rng: &mut Rng)
               -> Result<Option<BitConfig>> {
    let n_layers = observed
        .first()
        .map(|o| o.config.n_layers())
        .expect("suggest needs >= 1 observation");
    let xs: Vec<Vec<f64>> =
        observed.iter().map(|o| o.config.features()).collect();
    let ys: Vec<f64> = observed.iter().map(|o| o.perf).collect();
    let ls = (n_layers as f64).sqrt() * 0.75;
    let gp = Gp::fit(&xs, &ys, ls, 1e-4)?;
    let best = ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let pool = candidates(observed, n_layers, four_bit, max_frac8, 64, rng);
    let mut best_c: Option<(f64, BitConfig)> = None;
    for c in pool {
        let (m, v) = gp.predict(&c.features());
        let score = acquisition_score(acq, m, v, best, 2.0);
        if best_c.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
            best_c = Some((score, c));
        }
    }
    Ok(best_c.map(|(_, c)| c))
}

/// Non-dominated (maximize perf, minimize memory) subset — the red
/// points of Figures 3/4.
pub fn pareto_front(observed: &[Observation]) -> Vec<usize> {
    let mut front = Vec::new();
    'outer: for (i, a) in observed.iter().enumerate() {
        for (j, b) in observed.iter().enumerate() {
            if i != j
                && b.perf >= a.perf
                && b.memory_gb <= a.memory_gb
                && (b.perf > a.perf || b.memory_gb < a.memory_gb)
            {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(short: &str, perf: f64, mem: f64) -> Observation {
        let layers = short
            .chars()
            .map(|c| match c {
                '8' => QuantFormat::Int8,
                _ => QuantFormat::Nf4,
            })
            .collect();
        Observation { config: BitConfig { layers }, perf, memory_gb: mem }
    }

    #[test]
    fn gp_interpolates_training_points() {
        let xs = vec![vec![0.0, 0.0], vec![1.0, 0.0], vec![0.0, 1.0]];
        let ys = vec![1.0, 2.0, 3.0];
        let gp = Gp::fit(&xs, &ys, 1.0, 1e-6).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let (m, v) = gp.predict(x);
            assert!((m - y).abs() < 0.05, "mean {m} vs {y}");
            assert!(v < 0.05, "var {v}");
        }
    }

    #[test]
    fn matern_interpolates_training_points() {
        let xs = vec![vec![0.0, 0.0], vec![1.0, 0.0], vec![0.0, 1.0]];
        let ys = vec![1.0, 2.0, 3.0];
        let gp =
            Gp::fit_kernel(&xs, &ys, Kernel::Matern52, 1.0, 1e-6).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let (m, _) = gp.predict(x);
            assert!((m - y).abs() < 0.05, "matern mean {m} vs {y}");
        }
    }

    #[test]
    fn kernels_are_valid_covariances() {
        for k in [Kernel::Rbf, Kernel::Matern52] {
            // k(x,x)=1, symmetric, decaying
            let a = vec![0.5, -0.25];
            let b = vec![1.5, 0.75];
            assert!((k.eval(&a, &a, 1.0) - 1.0).abs() < 1e-12);
            assert!((k.eval(&a, &b, 1.0) - k.eval(&b, &a, 1.0)).abs()
                    < 1e-12);
            let near = k.eval(&a, &vec![0.6, -0.25], 1.0);
            let far = k.eval(&a, &vec![3.0, 3.0], 1.0);
            assert!(near > far && far > 0.0);
        }
    }

    #[test]
    fn ml_fit_picks_reasonable_lengthscale() {
        // smooth function of 1 coordinate -> ML should not pick the
        // tiniest lengthscale
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..12 {
            let x = i as f64 / 3.0;
            xs.push(vec![x, 0.0]);
            ys.push((x * 0.8).sin());
        }
        let gp = Gp::fit_ml(&xs, &ys, Kernel::Rbf, 1e-6).unwrap();
        assert!(gp.lengthscale() > 0.3, "ls {}", gp.lengthscale());
        // and it must still interpolate
        let (m, _) = gp.predict(&xs[5]);
        assert!((m - ys[5]).abs() < 0.05);
    }

    #[test]
    fn marginal_likelihood_prefers_true_model() {
        // data generated with ls=1 should score >= heavily mismatched ls
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..10 {
            let x = i as f64 / 2.0;
            xs.push(vec![x]);
            ys.push((x).sin());
        }
        let good = Gp::fit_kernel(&xs, &ys, Kernel::Rbf, 1.0, 1e-4).unwrap();
        let bad = Gp::fit_kernel(&xs, &ys, Kernel::Rbf, 0.01, 1e-4).unwrap();
        assert!(good.log_marginal_likelihood(&ys)
                > bad.log_marginal_likelihood(&ys));
    }

    #[test]
    fn gp_uncertainty_grows_away_from_data() {
        let xs = vec![vec![0.0], vec![1.0]];
        let ys = vec![0.0, 1.0];
        let gp = Gp::fit(&xs, &ys, 0.5, 1e-6).unwrap();
        let (_, v_near) = gp.predict(&[0.5]);
        let (_, v_far) = gp.predict(&[5.0]);
        assert!(v_far > v_near);
    }

    #[test]
    fn erf_reference_values() {
        assert!((erf(0.0)).abs() < 1e-9);
        assert!((erf(1.0) - 0.842_700_79).abs() < 1e-6);
        assert!((erf(-1.0) + 0.842_700_79).abs() < 1e-6);
        assert!((erf(3.0) - 0.999_977_9).abs() < 1e-5);
    }

    #[test]
    fn ei_zero_at_certainty_below_best() {
        let s = acquisition_score(Acquisition::Ei, 0.5, 1e-14, 1.0, 2.0);
        assert!(s.abs() < 1e-9);
    }

    #[test]
    fn ei_positive_with_uncertainty() {
        let s = acquisition_score(Acquisition::Ei, 0.5, 0.25, 1.0, 2.0);
        assert!(s > 0.0);
    }

    #[test]
    fn ucb_orders_by_optimism() {
        let a = acquisition_score(Acquisition::Ucb, 1.0, 0.01, 0.0, 2.0);
        let b = acquisition_score(Acquisition::Ucb, 1.0, 1.0, 0.0, 2.0);
        assert!(b > a);
    }

    #[test]
    fn candidates_respect_budget_and_dedup() {
        let mut rng = Rng::new(1);
        let o = vec![obs("44444444", 0.5, 20.0)];
        let pool = candidates(&o, 8, QuantFormat::Nf4, 0.25, 32, &mut rng);
        assert!(!pool.is_empty());
        let mut shorts: Vec<String> = pool.iter().map(|c| c.short()).collect();
        let before = shorts.len();
        shorts.sort();
        shorts.dedup();
        assert_eq!(shorts.len(), before, "duplicates in pool");
        for c in &pool {
            assert!(c.frac_8bit() <= 0.25 + 1e-9);
            assert_ne!(c.short(), "44444444", "evaluated point re-proposed");
        }
    }

    #[test]
    fn suggest_returns_valid_config() {
        let mut rng = Rng::new(2);
        let o = vec![
            obs("44444444", 0.50, 20.0),
            obs("84444444", 0.55, 21.0),
            obs("44448444", 0.52, 21.0),
        ];
        let c = suggest(&o, Acquisition::Ei, QuantFormat::Nf4, 0.25,
                        &mut rng).unwrap().unwrap();
        assert_eq!(c.n_layers(), 8);
        assert!(c.frac_8bit() <= 0.25 + 1e-9);
    }

    #[test]
    fn gp_learns_additive_bit_value() {
        // synthetic truth: perf = 0.5 + 0.1 * (#8bit in first half)
        let mut obs_v = Vec::new();
        let pats = ["44444444", "84444444", "48444444", "88444444",
                    "44444448", "44448888"];
        for p in pats {
            let n8_front = p[..4].chars().filter(|&c| c == '8').count();
            obs_v.push(obs(p, 0.5 + 0.1 * n8_front as f64, 20.0));
        }
        let xs: Vec<Vec<f64>> =
            obs_v.iter().map(|o| o.config.features()).collect();
        let ys: Vec<f64> = obs_v.iter().map(|o| o.perf).collect();
        let gp = Gp::fit(&xs, &ys, 2.0, 1e-5).unwrap();
        // front-loaded config should predict higher than back-loaded
        let hi = BitConfig {
            layers: "88844444".chars().map(|c| if c == '8' {
                QuantFormat::Int8 } else { QuantFormat::Nf4 }).collect(),
        };
        let lo = BitConfig {
            layers: "44444888".chars().map(|c| if c == '8' {
                QuantFormat::Int8 } else { QuantFormat::Nf4 }).collect(),
        };
        let (mh, _) = gp.predict(&hi.features());
        let (ml, _) = gp.predict(&lo.features());
        assert!(mh > ml, "GP failed to learn positional value: {mh} vs {ml}");
    }

    #[test]
    fn pareto_front_correct() {
        let o = vec![
            obs("4444", 0.5, 20.0), // dominated by #2
            obs("8444", 0.6, 19.0),
            obs("4844", 0.4, 25.0), // dominated
            obs("8844", 0.7, 22.0),
            obs("4484", 0.6, 19.0), // tie with #1 -> both kept
        ];
        let f = pareto_front(&o);
        assert!(f.contains(&1));
        assert!(f.contains(&3));
        assert!(f.contains(&4));
        assert!(!f.contains(&0));
        assert!(!f.contains(&2));
    }

    #[test]
    fn pareto_single_point() {
        let o = vec![obs("44", 0.1, 1.0)];
        assert_eq!(pareto_front(&o), vec![0]);
    }
}
