//! Structured pruning engine (paper §3.1, following LLM-Pruner).
//!
//! Dependency analysis on the LLaMA block yields two families of
//! coupled structures:
//!
//!  * **attention heads** — head h of layer l couples rows
//!    [h*hd, (h+1)*hd) of wq/wk/wv with the same column range of wo
//!    (Deg analysis of Eq. in §3.1: the o-projection consumes exactly
//!    the activations those rows produce);
//!  * **MLP channel groups** — `MLP_GROUP` consecutive channels couple
//!    rows of w_gate/w_up with the matching columns of w_down.
//!
//! Group importance is the Taylor expansion of the task loss (Eq. 4-6):
//! first-order `|g . w|` (element^1) or with the Fisher-diagonal
//! second-order correction `|g.w - 0.5 w^2 g^2|` (element^2, H_kk ~ g^2).
//! Element scores are aggregated to group level by sum/max/prod/last
//! (paper §3.1 last paragraph).

use crate::model::{ModelConfig, ParamStore, MLP_GROUP};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GroupKind {
    AttnHead,
    MlpChannels,
}

/// One coupled structure (prunable unit).
#[derive(Clone, Debug)]
pub struct Group {
    pub kind: GroupKind,
    pub layer: usize,
    /// head index or MLP group index
    pub index: usize,
}

/// The dependency graph: all coupled structures of the architecture.
#[derive(Clone, Debug)]
pub struct DependencyGraph {
    pub groups: Vec<Group>,
    pub heads_per_layer: usize,
    pub mlp_groups_per_layer: usize,
}

impl DependencyGraph {
    pub fn build(cfg: &ModelConfig) -> Self {
        let mut groups = Vec::new();
        let mg = cfg.d_ff / MLP_GROUP;
        for l in 0..cfg.n_layers {
            for h in 0..cfg.n_heads {
                groups.push(Group { kind: GroupKind::AttnHead, layer: l, index: h });
            }
            for g in 0..mg {
                groups.push(Group { kind: GroupKind::MlpChannels, layer: l, index: g });
            }
        }
        DependencyGraph {
            groups,
            heads_per_layer: cfg.n_heads,
            mlp_groups_per_layer: mg,
        }
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }
}

/// Taylor order for element importance (Table 2 "Importance Estimation").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaylorOrder {
    /// element^1: |g * w|
    First,
    /// element^2: |g*w - 0.5 * w^2 * g^2| (Fisher diagonal Hessian)
    Second,
}

impl TaylorOrder {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "first" | "element1" | "1" => Some(TaylorOrder::First),
            "second" | "element2" | "2" => Some(TaylorOrder::Second),
            _ => None,
        }
    }
}

/// Aggregation of element scores into a group score.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Aggregate {
    Sum,
    Max,
    /// product via mean of log scores (LLM-Pruner "prod")
    Prod,
    /// only the last projection in the group (wo / w_down)
    Last,
}

fn elem_score(w: f32, g: f32, order: TaylorOrder) -> f64 {
    match order {
        TaylorOrder::First => (g as f64 * w as f64).abs(),
        TaylorOrder::Second => {
            let gw = g as f64 * w as f64;
            (gw - 0.5 * (w as f64).powi(2) * (g as f64).powi(2)).abs()
        }
    }
}

/// Accumulate the importance of a row-range x full-width slab of a
/// stacked [L, out, in] tensor pair (weights, grads).
fn slab_scores(
    w: &Tensor,
    g: &Tensor,
    layer: usize,
    rows: std::ops::Range<usize>,
    transpose: bool, // true: interpret range as columns
    order: TaylorOrder,
    acc: &mut GroupAccum,
) {
    let (sh, wd) = w.slab(layer);
    let (_, gd) = g.slab(layer);
    let (out, inp) = (sh[0], sh[1]);
    if !transpose {
        for r in rows {
            for c in 0..inp {
                acc.push(elem_score(wd[r * inp + c], gd[r * inp + c], order));
            }
        }
    } else {
        for r in 0..out {
            for c in rows.clone() {
                acc.push(elem_score(wd[r * inp + c], gd[r * inp + c], order));
            }
        }
    }
}

struct GroupAccum {
    agg: Aggregate,
    sum: f64,
    max: f64,
    log_sum: f64,
    n: usize,
    last_start: Option<usize>,
}

impl GroupAccum {
    fn new(agg: Aggregate) -> Self {
        GroupAccum { agg, sum: 0.0, max: 0.0, log_sum: 0.0, n: 0, last_start: None }
    }

    fn mark_last(&mut self) {
        self.last_start = Some(self.n);
    }

    fn push(&mut self, s: f64) {
        self.sum += s;
        self.max = self.max.max(s);
        self.log_sum += (s + 1e-12).ln();
        self.n += 1;
    }

    fn finish(self, last_sum: f64) -> f64 {
        match self.agg {
            Aggregate::Sum => self.sum,
            Aggregate::Max => self.max,
            Aggregate::Prod => (self.log_sum / self.n.max(1) as f64).exp(),
            Aggregate::Last => last_sum,
        }
    }
}

/// Importance of every group given weights and gradients (stacked,
/// unpruned shapes).
pub fn group_importance(
    cfg: &ModelConfig,
    graph: &DependencyGraph,
    store: &ParamStore,
    grads: &[Tensor],
    order: TaylorOrder,
    agg: Aggregate,
) -> Result<Vec<f64>> {
    ensure!(grads.len() == 12, "expected 12 grad stacks, got {}", grads.len());
    for (w, g) in store.weights.iter().zip(grads) {
        ensure!(w.shape() == g.shape(), "grad shape mismatch");
    }
    let hd = cfg.head_dim();
    let mut out = Vec::with_capacity(graph.n_groups());
    for grp in &graph.groups {
        let mut acc = GroupAccum::new(agg);
        let last_sum: f64;
        match grp.kind {
            GroupKind::AttnHead => {
                let rows = grp.index * hd..(grp.index + 1) * hd;
                for name in ["wq", "wk", "wv"] {
                    let i = crate::model::proj_index(name);
                    slab_scores(
                        &store.weights[i], &grads[i], grp.layer,
                        rows.clone(), false, order, &mut acc,
                    );
                }
                // last member: wo columns
                acc.mark_last();
                let before = acc.sum;
                let i = crate::model::proj_index("wo");
                slab_scores(
                    &store.weights[i], &grads[i], grp.layer, rows, true,
                    order, &mut acc,
                );
                last_sum = acc.sum - before;
            }
            GroupKind::MlpChannels => {
                let rows = grp.index * MLP_GROUP..(grp.index + 1) * MLP_GROUP;
                for name in ["w_gate", "w_up"] {
                    let i = crate::model::proj_index(name);
                    slab_scores(
                        &store.weights[i], &grads[i], grp.layer,
                        rows.clone(), false, order, &mut acc,
                    );
                }
                acc.mark_last();
                let before = acc.sum;
                let i = crate::model::proj_index("w_down");
                slab_scores(
                    &store.weights[i], &grads[i], grp.layer, rows, true,
                    order, &mut acc,
                );
                last_sum = acc.sum - before;
            }
        }
        out.push(acc.finish(last_sum));
    }
    Ok(out)
}

/// A pruning plan: which heads / MLP groups each layer keeps
/// (sorted ascending, preserving original order).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PruningPlan {
    pub rate_pct: u32,
    pub kept_heads: Vec<Vec<usize>>,
    pub kept_mlp_groups: Vec<Vec<usize>>,
}

impl PruningPlan {
    /// Importance-driven plan: per layer, keep the top-k most important
    /// heads and MLP groups where k matches the uniform pruned shapes
    /// (which heads go is importance-driven; how many is rate-driven,
    /// as in LLM-Pruner's fixed-ratio layer pruning).
    pub fn from_importance(
        cfg: &ModelConfig,
        graph: &DependencyGraph,
        importance: &[f64],
        rate_pct: u32,
    ) -> Self {
        let ps = cfg.pruned(rate_pct);
        let keep_heads = ps.heads_kept;
        let keep_mlp = ps.d_ff_kept / MLP_GROUP;
        let mut kept_heads = Vec::new();
        let mut kept_mlp_groups = Vec::new();
        for l in 0..cfg.n_layers {
            let mut heads: Vec<(usize, f64)> = graph
                .groups
                .iter()
                .zip(importance)
                .filter(|(g, _)| g.layer == l && g.kind == GroupKind::AttnHead)
                .map(|(g, &s)| (g.index, s))
                .collect();
            heads.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let mut hk: Vec<usize> =
                heads.into_iter().take(keep_heads).map(|(i, _)| i).collect();
            hk.sort_unstable();
            kept_heads.push(hk);

            let mut mlps: Vec<(usize, f64)> = graph
                .groups
                .iter()
                .zip(importance)
                .filter(|(g, _)| g.layer == l && g.kind == GroupKind::MlpChannels)
                .map(|(g, &s)| (g.index, s))
                .collect();
            mlps.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let mut mk: Vec<usize> =
                mlps.into_iter().take(keep_mlp).map(|(i, _)| i).collect();
            mk.sort_unstable();
            kept_mlp_groups.push(mk);
        }
        PruningPlan { rate_pct, kept_heads, kept_mlp_groups }
    }

    /// Baseline plan keeping the lowest-indexed structures (ablation /
    /// no-importance control).
    pub fn first_k(cfg: &ModelConfig, rate_pct: u32) -> Self {
        let ps = cfg.pruned(rate_pct);
        let kept_heads = vec![(0..ps.heads_kept).collect(); cfg.n_layers];
        let kept_mlp_groups =
            vec![(0..ps.d_ff_kept / MLP_GROUP).collect(); cfg.n_layers];
        PruningPlan { rate_pct, kept_heads, kept_mlp_groups }
    }

    /// Random plan (another ablation control: importance vs chance).
    pub fn random(cfg: &ModelConfig, rate_pct: u32,
                  rng: &mut crate::rng::Rng) -> Self {
        let ps = cfg.pruned(rate_pct);
        let mut kept_heads = Vec::new();
        let mut kept_mlp_groups = Vec::new();
        for _ in 0..cfg.n_layers {
            let mut h = rng.choose_k(cfg.n_heads, ps.heads_kept);
            h.sort_unstable();
            kept_heads.push(h);
            let mut m =
                rng.choose_k(cfg.d_ff / MLP_GROUP, ps.d_ff_kept / MLP_GROUP);
            m.sort_unstable();
            kept_mlp_groups.push(m);
        }
        PruningPlan { rate_pct, kept_heads, kept_mlp_groups }
    }

    /// Fraction of (layer, structure) selections shared with `other`.
    pub fn overlap(&self, other: &PruningPlan) -> f64 {
        let mut shared = 0usize;
        let mut total = 0usize;
        for (a, b) in self.kept_heads.iter().zip(&other.kept_heads) {
            total += a.len();
            shared += a.iter().filter(|x| b.contains(x)).count();
        }
        for (a, b) in self.kept_mlp_groups.iter().zip(&other.kept_mlp_groups)
        {
            total += a.len();
            shared += a.iter().filter(|x| b.contains(x)).count();
        }
        shared as f64 / total.max(1) as f64
    }
}

/// Layer-protection policy: LLM-Pruner leaves the first and last
/// blocks untouched and prunes the middle range *deeper* so the global
/// parameter budget still matches the nominal rate. Our artifact
/// shapes are uniform per layer, so protection is expressed through
/// the *selection* weights: protected layers get +inf importance on
/// all their groups, which `from_importance` then keeps... however
/// uniform shapes force the same per-layer keep count, so instead we
/// expose protection as an importance transform used by the global
/// diagnostics and the `layer_pruning_profile` report below.
#[derive(Clone, Copy, Debug)]
pub struct Protection {
    pub first: usize,
    pub last: usize,
    pub boost: f64,
}

impl Default for Protection {
    fn default() -> Self {
        // LLM-Pruner's LLaMA recipe protects the first 4 / last 2
        Protection { first: 4, last: 2, boost: 1e6 }
    }
}

impl Protection {
    /// Scale group importances so protected layers rank above all
    /// prunable ones.
    pub fn apply(&self, cfg: &ModelConfig, graph: &DependencyGraph,
                 importance: &[f64]) -> Vec<f64> {
        graph
            .groups
            .iter()
            .zip(importance)
            .map(|(g, &s)| {
                if g.layer < self.first.min(cfg.n_layers)
                    || g.layer >= cfg.n_layers.saturating_sub(self.last)
                {
                    s + self.boost
                } else {
                    s
                }
            })
            .collect()
    }
}

/// Global-ranking diagnostic: if structures were pruned by one global
/// importance ordering at `rate_pct` (LLM-Pruner's other mode), how
/// many would each layer lose? Exposes the *uneven layer importance*
/// that motivates the paper's mixed-precision allocation (§1).
pub fn layer_pruning_profile(
    cfg: &ModelConfig,
    graph: &DependencyGraph,
    importance: &[f64],
    rate_pct: u32,
) -> Vec<usize> {
    let n_prune =
        (graph.n_groups() as f64 * rate_pct as f64 / 100.0).round() as usize;
    let mut order: Vec<usize> = (0..graph.n_groups()).collect();
    order.sort_by(|&a, &b| importance[a].partial_cmp(&importance[b]).unwrap());
    let mut lost = vec![0usize; cfg.n_layers];
    for &gi in order.iter().take(n_prune) {
        lost[graph.groups[gi].layer] += 1;
    }
    lost
}

/// Apply a pruning plan by *compacting* the weight stacks: kept head
/// rows / MLP channel rows are gathered, the coupled wo / w_down
/// columns gathered to match. Returns a ParamStore with the pruned
/// shapes expected by the `_r{rate}` artifacts.
pub fn apply_plan(store: &ParamStore, plan: &PruningPlan) -> Result<ParamStore> {
    let cfg = &store.cfg;
    ensure!(
        store.ps.rate_pct == 0,
        "apply_plan expects an unpruned store (rate 0), got rate {}",
        store.ps.rate_pct
    );
    let ps = cfg.pruned(plan.rate_pct);
    let hd = cfg.head_dim();
    for l in 0..cfg.n_layers {
        ensure!(plan.kept_heads[l].len() == ps.heads_kept, "head count");
        ensure!(
            plan.kept_mlp_groups[l].len() == ps.d_ff_kept / MLP_GROUP,
            "mlp group count"
        );
    }

    let mut new = Vec::with_capacity(12);
    let shapes = ParamStore::shapes(cfg, &ps);
    for (i, name) in crate::model::WEIGHT_NAMES.iter().enumerate() {
        let w = &store.weights[i];
        let t = match *name {
            "embed" | "attn_norm" | "mlp_norm" | "final_norm" | "lm_head" => {
                w.clone()
            }
            "wq" | "wk" | "wv" | "wo" | "w_gate" | "w_up" | "w_down" => {
                let mut slabs = Vec::new();
                for l in 0..cfg.n_layers {
                    let (sh, data) = w.slab(l);
                    let mat = Tensor::new(sh, data.to_vec());
                    let idx: Vec<usize> = match *name {
                        "wq" | "wk" | "wv" | "wo" => plan.kept_heads[l]
                            .iter()
                            .flat_map(|&h| h * hd..(h + 1) * hd)
                            .collect(),
                        _ => plan.kept_mlp_groups[l]
                            .iter()
                            .flat_map(|&g| {
                                g * MLP_GROUP..(g + 1) * MLP_GROUP
                            })
                            .collect(),
                    };
                    let pruned = match *name {
                        "wq" | "wk" | "wv" | "w_gate" | "w_up" => {
                            mat.gather_rows(&idx)
                        }
                        "wo" | "w_down" => mat.gather_cols(&idx),
                        _ => unreachable!(),
                    };
                    slabs.push(pruned);
                }
                stack(&slabs)
            }
            _ => unreachable!(),
        };
        ensure!(
            t.shape() == shapes[i].as_slice(),
            "{name}: pruned shape {:?} != expected {:?}",
            t.shape(),
            shapes[i]
        );
        new.push(t);
    }
    Ok(ParamStore { cfg: cfg.clone(), ps, weights: new })
}

/// Stack equal-shape matrices into [L, ...].
fn stack(mats: &[Tensor]) -> Tensor {
    let inner = mats[0].shape().to_vec();
    let mut shape = vec![mats.len()];
    shape.extend_from_slice(&inner);
    let mut data = Vec::with_capacity(mats.len() * mats[0].len());
    for m in mats {
        assert_eq!(m.shape(), inner.as_slice());
        data.extend_from_slice(m.data());
    }
    Tensor::new(&shape, data)
}

/// Per-layer total importance (used to characterize the "uneven layer
/// importance" the paper's mixed-precision motivation rests on).
pub fn layer_importance(
    cfg: &ModelConfig,
    graph: &DependencyGraph,
    importance: &[f64],
) -> Vec<f64> {
    let mut per_layer = vec![0.0; cfg.n_layers];
    for (g, &s) in graph.groups.iter().zip(importance) {
        per_layer[g.layer] += s;
    }
    per_layer
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn setup() -> (ModelConfig, ParamStore, Vec<Tensor>) {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 3);
        let mut rng = Rng::new(4);
        let grads: Vec<Tensor> = store
            .weights
            .iter()
            .map(|w| Tensor::randn(w.shape(), 0.01, &mut rng))
            .collect();
        (cfg, store, grads)
    }

    #[test]
    fn graph_enumerates_all_groups() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let g = DependencyGraph::build(&cfg);
        // 2 layers * (4 heads + 192/8=24 mlp groups)
        assert_eq!(g.n_groups(), 2 * (4 + 24));
        assert_eq!(g.heads_per_layer, 4);
        assert_eq!(g.mlp_groups_per_layer, 24);
    }

    #[test]
    fn importance_nonnegative_and_finite() {
        let (cfg, store, grads) = setup();
        let graph = DependencyGraph::build(&cfg);
        for order in [TaylorOrder::First, TaylorOrder::Second] {
            for agg in [Aggregate::Sum, Aggregate::Max, Aggregate::Prod,
                        Aggregate::Last] {
                let imp = group_importance(&cfg, &graph, &store, &grads,
                                           order, agg).unwrap();
                assert_eq!(imp.len(), graph.n_groups());
                assert!(imp.iter().all(|&s| s.is_finite() && s >= 0.0));
            }
        }
    }

    #[test]
    fn zero_grad_means_zero_first_order_importance() {
        let (cfg, store, _) = setup();
        let graph = DependencyGraph::build(&cfg);
        let zeros: Vec<Tensor> =
            store.weights.iter().map(|w| Tensor::zeros(w.shape())).collect();
        let imp = group_importance(&cfg, &graph, &store, &zeros,
                                   TaylorOrder::First, Aggregate::Sum)
            .unwrap();
        assert!(imp.iter().all(|&s| s == 0.0));
    }

    #[test]
    fn plan_keeps_most_important_heads() {
        let (cfg, store, mut grads) = setup();
        let graph = DependencyGraph::build(&cfg);
        // inflate grads of head 2 in layer 0 so it must be kept
        let hd = cfg.head_dim();
        let wq = crate::model::proj_index("wq");
        {
            let g = &mut grads[wq];
            let inp = cfg.d_model;
            let slab = g.slab_mut(0);
            for r in 2 * hd..3 * hd {
                for c in 0..inp {
                    slab[r * inp + c] = 10.0;
                }
            }
        }
        let imp = group_importance(&cfg, &graph, &store, &grads,
                                   TaylorOrder::First, Aggregate::Sum)
            .unwrap();
        let plan = PruningPlan::from_importance(&cfg, &graph, &imp, 50);
        assert!(plan.kept_heads[0].contains(&2));
        assert_eq!(plan.kept_heads[0].len(), cfg.pruned(50).heads_kept);
    }

    #[test]
    fn apply_plan_produces_expected_shapes_and_values() {
        let (cfg, store, grads) = setup();
        let graph = DependencyGraph::build(&cfg);
        let imp = group_importance(&cfg, &graph, &store, &grads,
                                   TaylorOrder::First, Aggregate::Sum)
            .unwrap();
        let plan = PruningPlan::from_importance(&cfg, &graph, &imp, 20);
        let pruned = apply_plan(&store, &plan).unwrap();
        let ps = cfg.pruned(20);
        assert_eq!(pruned.ps, ps);
        assert_eq!(
            pruned.weights[crate::model::proj_index("wq")].shape(),
            &[cfg.n_layers, ps.attn_dim(&cfg), cfg.d_model]
        );
        // spot-check value propagation: first kept head of layer 0
        let h0 = plan.kept_heads[0][0];
        let hd = cfg.head_dim();
        let orig = store.layer_proj(0, "wq");
        let got = pruned.layer_proj(0, "wq");
        for r in 0..hd {
            assert_eq!(got.row(r), orig.row(h0 * hd + r));
        }
    }

    #[test]
    fn apply_plan_rejects_pruned_store() {
        let (cfg, store, _) = setup();
        let plan = PruningPlan::first_k(&cfg, 20);
        let pruned = apply_plan(&store, &plan).unwrap();
        let plan2 = PruningPlan::first_k(&cfg, 50);
        assert!(apply_plan(&pruned, &plan2).is_err());
    }

    #[test]
    fn first_k_plan_is_prefix() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let plan = PruningPlan::first_k(&cfg, 50);
        assert_eq!(plan.kept_heads[0], vec![0, 1]);
    }

    #[test]
    fn random_plan_valid_and_differs_from_first_k() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let mut rng = crate::rng::Rng::new(21);
        let r = PruningPlan::random(&cfg, 50, &mut rng);
        let f = PruningPlan::first_k(&cfg, 50);
        let ps = cfg.pruned(50);
        for l in 0..cfg.n_layers {
            assert_eq!(r.kept_heads[l].len(), ps.heads_kept);
            assert!(r.kept_heads[l].windows(2).all(|w| w[0] < w[1]));
        }
        assert!(r.overlap(&f) < 1.0);
        assert_eq!(f.overlap(&f), 1.0);
    }

    #[test]
    fn protection_ranks_protected_layers_first() {
        let cfg = ModelConfig::preset("small").unwrap(); // 4 layers
        let graph = DependencyGraph::build(&cfg);
        let imp = vec![1.0; graph.n_groups()];
        let prot = Protection { first: 1, last: 1, boost: 100.0 };
        let boosted = prot.apply(&cfg, &graph, &imp);
        for (g, &s) in graph.groups.iter().zip(&boosted) {
            if g.layer == 0 || g.layer == cfg.n_layers - 1 {
                assert!(s > 50.0);
            } else {
                assert_eq!(s, 1.0);
            }
        }
    }

    #[test]
    fn global_profile_is_uneven_for_uneven_importance() {
        let (cfg, store, mut grads) = setup();
        let graph = DependencyGraph::build(&cfg);
        // make layer 1 uniformly more important
        for i in [2usize, 3, 4, 5, 7, 8, 9] {
            let g = &mut grads[i];
            let inner: usize = g.shape()[1..].iter().product();
            let _ = inner;
            for x in g.slab_mut(1).iter_mut() {
                *x *= 10.0;
            }
        }
        let imp = group_importance(&cfg, &graph, &store, &grads,
                                   TaylorOrder::First, Aggregate::Sum)
            .unwrap();
        let lost = layer_pruning_profile(&cfg, &graph, &imp, 50);
        assert_eq!(lost.len(), cfg.n_layers);
        let total: usize = lost.iter().sum();
        assert!(total > 0);
        // layer 0 must lose more than the boosted layer 1
        assert!(lost[0] > lost[1], "profile {lost:?}");
    }

    #[test]
    fn layer_importance_sums_groups() {
        let (cfg, store, grads) = setup();
        let graph = DependencyGraph::build(&cfg);
        let imp = group_importance(&cfg, &graph, &store, &grads,
                                   TaylorOrder::First, Aggregate::Sum)
            .unwrap();
        let li = layer_importance(&cfg, &graph, &imp);
        assert_eq!(li.len(), cfg.n_layers);
        let total: f64 = imp.iter().sum();
        assert!((li.iter().sum::<f64>() - total).abs() < 1e-9 * total.abs());
    }
}
