//! Model architecture description + parameter store + checkpoint I/O.
//!
//! Mirrors python/compile/configs.py exactly: the AOT artifact argument
//! shapes are derived from the same arithmetic on both sides.

use crate::rng::Rng;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Canonical projection order (must match configs.PROJS).
pub const PROJS: [&str; 7] =
    ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

/// Width of one MLP pruning group (configs.MLP_GROUP).
pub const MLP_GROUP: usize = 8;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub scan_steps: usize,
    pub eval_rows: usize,
    pub lora_rank: usize,
    pub lora_alpha: usize,
}

impl ModelConfig {
    pub fn preset(name: &str) -> Result<ModelConfig> {
        let (d, l, h, f, v, s, b, k, er) = match name {
            "tiny" => (64, 2, 4, 192, 256, 32, 4, 4, 16),
            "small" => (128, 4, 4, 384, 512, 64, 4, 8, 32),
            "base" => (384, 8, 8, 1024, 2048, 128, 4, 8, 32),
            "large" => (768, 12, 12, 2048, 8192, 128, 4, 4, 32),
            _ => bail!("unknown model size {name}"),
        };
        Ok(ModelConfig {
            name: name.to_string(),
            d_model: d,
            n_layers: l,
            n_heads: h,
            d_ff: f,
            vocab: v,
            seq: s,
            batch: b,
            scan_steps: k,
            eval_rows: er,
            lora_rank: 8,
            lora_alpha: 16,
        })
    }

    /// Paper-scale architectures, used only by the analytic memory
    /// model (`memory` module) to reproduce the GB columns of
    /// Tables 1/3.
    pub fn paper_7b() -> ModelConfig {
        ModelConfig {
            name: "llama-7b".into(),
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            d_ff: 11008,
            vocab: 32000,
            seq: 256,
            batch: 8,
            scan_steps: 1,
            eval_rows: 32,
            lora_rank: 8,
            lora_alpha: 16,
        }
    }

    pub fn paper_13b() -> ModelConfig {
        ModelConfig {
            name: "llama-13b".into(),
            d_model: 5120,
            n_layers: 40,
            n_heads: 40,
            d_ff: 13824,
            vocab: 32000,
            seq: 256,
            batch: 8,
            scan_steps: 1,
            eval_rows: 32,
            lora_rank: 8,
            lora_alpha: 16,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn pruned(&self, rate_pct: u32) -> PrunedShapes {
        let keep = 1.0 - rate_pct as f64 / 100.0;
        let heads = ((self.n_heads as f64 * keep).round() as usize).max(1);
        let dff = ((self.d_ff as f64 * keep) as usize / MLP_GROUP * MLP_GROUP)
            .max(MLP_GROUP);
        PrunedShapes { rate_pct, heads_kept: heads, d_ff_kept: dff }
    }

    /// [out, in] of a projection under pruned shapes.
    pub fn proj_shape(&self, ps: &PrunedShapes, proj: &str) -> (usize, usize) {
        let d = self.d_model;
        let a = ps.attn_dim(self);
        let f = ps.d_ff_kept;
        match proj {
            "wq" | "wk" | "wv" => (a, d),
            "wo" => (d, a),
            "w_gate" | "w_up" => (f, d),
            "w_down" => (d, f),
            _ => panic!("unknown proj {proj}"),
        }
    }

    pub fn param_count(&self, ps: &PrunedShapes) -> usize {
        let mut n = 2 * self.vocab * self.d_model + self.d_model;
        let mut per_layer = 2 * self.d_model;
        for p in PROJS {
            let (o, i) = self.proj_shape(ps, p);
            per_layer += o * i;
        }
        n += self.n_layers * per_layer;
        n
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrunedShapes {
    pub rate_pct: u32,
    pub heads_kept: usize,
    pub d_ff_kept: usize,
}

impl PrunedShapes {
    pub fn attn_dim(&self, cfg: &ModelConfig) -> usize {
        self.heads_kept * cfg.head_dim()
    }
}

/// The 12 weight stacks, in artifact ABI order.
pub const WEIGHT_NAMES: [&str; 12] = [
    "embed", "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate",
    "w_up", "w_down", "final_norm", "lm_head",
];

/// Index of each projection stack inside WEIGHT_NAMES.
pub fn proj_index(proj: &str) -> usize {
    match proj {
        "wq" => 2,
        "wk" => 3,
        "wv" => 4,
        "wo" => 5,
        "w_gate" => 7,
        "w_up" => 8,
        "w_down" => 9,
        _ => panic!("unknown proj {proj}"),
    }
}

/// Row of an `[vocab, d]` embedding table for a token id, with the
/// OOB-clamp policy shared by the pipeline (`ParamStore::embed_row`)
/// and the serving engine: negative / out-of-range ids map to the PAD
/// row (row 0) instead of panicking on client-supplied garbage.
pub fn embed_row_clamped(embed: &Tensor, vocab: usize, token: i32)
                         -> &[f32] {
    let idx = if token < 0 || token as usize >= vocab {
        0
    } else {
        token as usize
    };
    embed.row(idx)
}

/// Full parameter set of one model: 12 stacked tensors.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub cfg: ModelConfig,
    pub ps: PrunedShapes,
    pub weights: Vec<Tensor>, // 12, ABI order
}

impl ParamStore {
    pub fn shapes(cfg: &ModelConfig, ps: &PrunedShapes) -> Vec<Vec<usize>> {
        let (d, l, v) = (cfg.d_model, cfg.n_layers, cfg.vocab);
        let a = ps.attn_dim(cfg);
        let f = ps.d_ff_kept;
        vec![
            vec![v, d],
            vec![l, d],
            vec![l, a, d],
            vec![l, a, d],
            vec![l, a, d],
            vec![l, d, a],
            vec![l, d],
            vec![l, f, d],
            vec![l, f, d],
            vec![l, d, f],
            vec![d],
            vec![v, d],
        ]
    }

    /// Random init: N(0, 1/fan_in) matrices, unit norm gains.
    pub fn init(cfg: &ModelConfig, seed: u64) -> ParamStore {
        let ps = cfg.pruned(0);
        let mut rng = Rng::new(seed);
        let mut weights = Vec::new();
        for (i, sh) in Self::shapes(cfg, &ps).iter().enumerate() {
            if matches!(i, 1 | 6 | 10) {
                weights.push(Tensor::ones(sh));
            } else {
                let fan_in = *sh.last().unwrap() as f32;
                weights.push(Tensor::randn(sh, fan_in.powf(-0.5), &mut rng));
            }
        }
        ParamStore { cfg: cfg.clone(), ps, weights }
    }

    pub fn get(&self, name: &str) -> &Tensor {
        let i = WEIGHT_NAMES.iter().position(|n| *n == name).unwrap();
        &self.weights[i]
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        let i = WEIGHT_NAMES.iter().position(|n| *n == name).unwrap();
        &mut self.weights[i]
    }

    /// Embedding row for a token id, clamped into the vocabulary so the
    /// serving path tolerates arbitrary client-supplied token ids
    /// (reserved/OOB ids map to the PAD row rather than panicking).
    pub fn embed_row(&self, token: i32) -> &[f32] {
        embed_row_clamped(&self.weights[0], self.cfg.vocab, token)
    }

    /// Projection matrix of one layer as a fresh `[out, in]` tensor.
    pub fn layer_proj(&self, layer: usize, proj: &str) -> Tensor {
        let stack = &self.weights[proj_index(proj)];
        let (sh, data) = stack.slab(layer);
        Tensor::new(sh, data.to_vec())
    }

    pub fn set_layer_proj(&mut self, layer: usize, proj: &str, t: &Tensor) {
        let (o, i) = self.cfg.proj_shape(&self.ps, proj);
        assert_eq!(t.shape(), &[o, i]);
        let stack = &mut self.weights[proj_index(proj)];
        stack.slab_mut(layer).copy_from_slice(t.data());
    }

    pub fn total_params(&self) -> usize {
        self.weights.iter().map(|w| w.len()).sum()
    }

    // ---------------- checkpoint I/O (own binary format) -------------

    const MAGIC: &'static [u8; 8] = b"QPCKPT01";

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(Self::MAGIC)?;
        let meta = format!(
            "{}\t{}\t{}\t{}",
            self.cfg.name, self.ps.rate_pct, self.ps.heads_kept,
            self.ps.d_ff_kept
        );
        f.write_all(&(meta.len() as u32).to_le_bytes())?;
        f.write_all(meta.as_bytes())?;
        f.write_all(&(self.weights.len() as u32).to_le_bytes())?;
        for w in &self.weights {
            f.write_all(&(w.ndim() as u32).to_le_bytes())?;
            for &d in w.shape() {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            for &x in w.data() {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ParamStore> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("open checkpoint {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            bail!("bad checkpoint magic in {path:?}");
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let mlen = u32::from_le_bytes(len4) as usize;
        let mut meta = vec![0u8; mlen];
        f.read_exact(&mut meta)?;
        let meta = String::from_utf8(meta)?;
        let parts: Vec<&str> = meta.split('\t').collect();
        if parts.len() != 4 {
            bail!("bad checkpoint meta {meta}");
        }
        let cfg = ModelConfig::preset(parts[0])?;
        let ps = PrunedShapes {
            rate_pct: parts[1].parse()?,
            heads_kept: parts[2].parse()?,
            d_ff_kept: parts[3].parse()?,
        };
        f.read_exact(&mut len4)?;
        let n = u32::from_le_bytes(len4) as usize;
        let mut weights = Vec::with_capacity(n);
        for _ in 0..n {
            f.read_exact(&mut len4)?;
            let nd = u32::from_le_bytes(len4) as usize;
            let mut shape = Vec::with_capacity(nd);
            let mut d8 = [0u8; 8];
            for _ in 0..nd {
                f.read_exact(&mut d8)?;
                shape.push(u64::from_le_bytes(d8) as usize);
            }
            let count: usize = shape.iter().product();
            let mut raw = vec![0u8; count * 4];
            f.read_exact(&mut raw)?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            weights.push(Tensor::new(&shape, data));
        }
        let expect = Self::shapes(&cfg, &ps);
        for (w, e) in weights.iter().zip(&expect) {
            if w.shape() != e.as_slice() {
                bail!("checkpoint shape {:?} != expected {:?}", w.shape(), e);
            }
        }
        Ok(ParamStore { cfg, ps, weights })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_python_configs() {
        let t = ModelConfig::preset("tiny").unwrap();
        assert_eq!((t.d_model, t.n_layers, t.d_ff, t.vocab), (64, 2, 192, 256));
        let b = ModelConfig::preset("base").unwrap();
        assert_eq!((b.d_model, b.n_layers, b.n_heads), (384, 8, 8));
        assert!(ModelConfig::preset("nope").is_err());
    }

    #[test]
    fn pruned_shapes_match_python() {
        // mirrors PrunedShapes.for_rate arithmetic
        let b = ModelConfig::preset("base").unwrap();
        let p20 = b.pruned(20);
        assert_eq!(p20.heads_kept, 6); // round(8*0.8) = 6
        assert_eq!(p20.d_ff_kept, 1024 * 8 / 10 / 8 * 8); // 816
        let p50 = b.pruned(50);
        assert_eq!(p50.heads_kept, 4);
        assert_eq!(p50.d_ff_kept, 512);
        let p0 = b.pruned(0);
        assert_eq!(p0.heads_kept, 8);
        assert_eq!(p0.d_ff_kept, 1024);
    }

    #[test]
    fn param_count_consistent_with_store() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 0);
        assert_eq!(store.total_params(), cfg.param_count(&cfg.pruned(0)));
    }

    #[test]
    fn base_param_count_magnitude() {
        let cfg = ModelConfig::preset("base").unwrap();
        let n = cfg.param_count(&cfg.pruned(0));
        assert!(n > 10_000_000 && n < 25_000_000, "base params {n}");
        let large = ModelConfig::preset("large").unwrap();
        let nl = large.param_count(&large.pruned(0));
        assert!(nl > 80_000_000, "large params {nl}");
    }

    #[test]
    fn layer_proj_roundtrip() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let mut store = ParamStore::init(&cfg, 1);
        let w = store.layer_proj(1, "w_gate");
        assert_eq!(w.shape(), &[192, 64]);
        let w2 = w.scale(2.0);
        store.set_layer_proj(1, "w_gate", &w2);
        let back = store.layer_proj(1, "w_gate");
        assert_eq!(back.data(), w2.data());
        // layer 0 untouched
        let l0 = store.layer_proj(0, "w_gate");
        assert_ne!(l0.data(), back.data());
    }

    #[test]
    fn embed_row_clamps_out_of_range_tokens() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 5);
        assert_eq!(store.embed_row(7), store.weights[0].row(7));
        // OOB / negative ids fall back to the PAD row (row 0)
        assert_eq!(store.embed_row(-3), store.weights[0].row(0));
        assert_eq!(store.embed_row(cfg.vocab as i32),
                   store.weights[0].row(0));
    }

    #[test]
    fn checkpoint_roundtrip() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 7);
        let dir = std::env::temp_dir().join("qpruner_test_ckpt");
        let path = dir.join("t.qckpt");
        store.save(&path).unwrap();
        let back = ParamStore::load(&path).unwrap();
        assert_eq!(back.cfg, store.cfg);
        for (a, b) in back.weights.iter().zip(&store.weights) {
            assert_eq!(a, b);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("qpruner_test_ckpt2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.qckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(ParamStore::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn paper_7b_param_count() {
        let cfg = ModelConfig::paper_7b();
        let n = cfg.param_count(&cfg.pruned(0));
        // LLaMA-7B is ~6.7B params
        assert!(n > 6_000_000_000 && n < 7_500_000_000, "{n}");
    }
}
