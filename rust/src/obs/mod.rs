//! Serving observability: lifecycle tracing, decode-phase profiling,
//! and bounded streaming metrics.
//!
//! Three layers, all allocation-free on the hot path:
//!
//! * [`span`] — per-session lifecycle records (submit → admit →
//!   first token → finish/evict) collected by the scheduler, with
//!   TTFT / inter-token latency derivable per session.
//! * this module — [`PhaseProfiler`], sampled wall-time attribution
//!   of decode steps to phases (qkv / attn / mlp / lora / vocab) and
//!   layers. `Engine` decides once per public call whether to sample
//!   (default 1-in-4); non-sampled steps cost one relaxed atomic
//!   increment. A sampled step runs a [`StepTimer`] whose laps tile
//!   the step's wall time, so the per-phase sum reconstructs the
//!   measured wall time instead of drifting from it. Accumulators are
//!   plain atomics merged at [`PhaseProfiler::snapshot`]; timers
//!   never touch activations, so logits stay bit-identical with
//!   profiling on or off (pinned by `tests/parity_decode.rs`).
//! * [`hist`] — fixed log2-bucket histograms and the metric
//!   [`hist::Registry`] replacing unbounded `LatencyStats` buffers on
//!   the serving path.
//!
//! [`trace_export`] turns spans + phase events into a
//! Chrome/Perfetto-loadable `trace.json` and a JSONL event log;
//! [`json`] is the strict parser CI uses to validate both.

pub mod hist;
pub mod json;
pub mod span;
pub mod trace_export;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Where a decode step spends its time. `Lora` only accrues on
/// engines with adjoined adapters; `Vocab` is the final norm + lm_head
/// projection (recorded once per step under layer 0).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Qkv,
    Attn,
    Mlp,
    Lora,
    Vocab,
}

pub const PHASES: [Phase; 5] =
    [Phase::Qkv, Phase::Attn, Phase::Mlp, Phase::Lora, Phase::Vocab];

impl Phase {
    pub fn idx(&self) -> usize {
        match self {
            Phase::Qkv => 0,
            Phase::Attn => 1,
            Phase::Mlp => 2,
            Phase::Lora => 3,
            Phase::Vocab => 4,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Phase::Qkv => "qkv",
            Phase::Attn => "attn",
            Phase::Mlp => "mlp",
            Phase::Lora => "lora",
            Phase::Vocab => "vocab",
        }
    }
}

/// One timed interval from a sampled step (feeds the trace export).
#[derive(Clone, Copy, Debug)]
pub struct PhaseEvent {
    pub phase: Phase,
    pub layer: u32,
    pub step: u64,
    pub start: Instant,
    pub dur_ns: u64,
}

/// Sampled per-phase / per-layer wall-time accumulators for one
/// engine. Shared `Arc` between the engine and whoever snapshots;
/// all counters are relaxed atomics (telemetry only — no ordering
/// requirements).
#[derive(Debug)]
pub struct PhaseProfiler {
    n_layers: usize,
    /// sample every Nth instrumented call; 0 disables profiling
    every: u32,
    /// keep raw [`PhaseEvent`]s for trace export (off by default:
    /// aggregates cost nothing, events cost memory)
    events_on: bool,
    events_cap: usize,
    calls: AtomicU64,
    sampled: AtomicU64,
    wall_ns: AtomicU64,
    /// `[phase][layer]` flattened as `phase * n_layers + layer`
    phase_ns: Vec<AtomicU64>,
    events: Mutex<Vec<PhaseEvent>>,
    events_dropped: AtomicU64,
}

impl PhaseProfiler {
    pub fn new(
        n_layers: usize,
        every: u32,
        events_on: bool,
        events_cap: usize,
    ) -> PhaseProfiler {
        let n = PHASES.len() * n_layers.max(1);
        PhaseProfiler {
            n_layers: n_layers.max(1),
            every,
            events_on,
            events_cap,
            calls: AtomicU64::new(0),
            sampled: AtomicU64::new(0),
            wall_ns: AtomicU64::new(0),
            phase_ns: (0..n).map(|_| AtomicU64::new(0)).collect(),
            events: Mutex::new(Vec::new()),
            events_dropped: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.every > 0
    }

    pub fn every(&self) -> u32 {
        self.every
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Decide whether this instrumented call is sampled. Costs one
    /// relaxed fetch_add when profiling is on; returns the step index
    /// when sampled.
    pub fn sample_step(&self) -> Option<u64> {
        if self.every == 0 {
            return None;
        }
        let c = self.calls.fetch_add(1, Ordering::Relaxed);
        if c % self.every as u64 == 0 {
            Some(c)
        } else {
            None
        }
    }

    /// Fold one sampled step's accumulator (layout
    /// `phase * n_layers + layer`) and its events into the shared
    /// totals. One mutex lock per *sampled* step, never per token.
    pub fn commit(
        &self,
        acc: &[u64],
        wall_ns: u64,
        events: &[PhaseEvent],
    ) {
        self.sampled.fetch_add(1, Ordering::Relaxed);
        self.wall_ns.fetch_add(wall_ns, Ordering::Relaxed);
        for (slot, &ns) in self.phase_ns.iter().zip(acc) {
            if ns > 0 {
                slot.fetch_add(ns, Ordering::Relaxed);
            }
        }
        if self.events_on && !events.is_empty() {
            let mut buf = self.events.lock().unwrap();
            let room = self.events_cap.saturating_sub(buf.len());
            let take = room.min(events.len());
            buf.extend_from_slice(&events[..take]);
            if take < events.len() {
                self.events_dropped.fetch_add(
                    (events.len() - take) as u64,
                    Ordering::Relaxed,
                );
            }
        }
    }

    /// Drain the retained raw events (trace export calls this once at
    /// end of run).
    pub fn take_events(&self) -> Vec<PhaseEvent> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }

    pub fn snapshot(&self) -> PhaseSnapshot {
        let l = self.n_layers;
        let mut per_phase = [0.0f64; 5];
        let mut per_layer = vec![0.0f64; l];
        for (i, slot) in self.phase_ns.iter().enumerate() {
            let s = slot.load(Ordering::Relaxed) as f64 / 1e9;
            per_phase[i / l] += s;
            per_layer[i % l] += s;
        }
        PhaseSnapshot {
            per_phase_secs: per_phase,
            per_layer_secs: per_layer,
            total_steps: self.calls.load(Ordering::Relaxed),
            sampled_steps: self.sampled.load(Ordering::Relaxed),
            sampled_wall_secs: self.wall_ns.load(Ordering::Relaxed)
                as f64
                / 1e9,
            lane_busy_secs: Vec::new(),
            events_dropped: self
                .events_dropped
                .load(Ordering::Relaxed),
            every: self.every,
        }
    }
}

/// Merged view of a [`PhaseProfiler`] (plus, when the engine fills it
/// in, the thread pool's per-lane busy time over the same sampled
/// steps).
#[derive(Clone, Debug, Default)]
pub struct PhaseSnapshot {
    /// seconds per phase, indexed by [`Phase::idx`]
    pub per_phase_secs: [f64; 5],
    pub per_layer_secs: Vec<f64>,
    /// instrumented calls seen (sampled or not)
    pub total_steps: u64,
    pub sampled_steps: u64,
    /// wall time of the sampled steps only
    pub sampled_wall_secs: f64,
    /// per-lane busy seconds from `ThreadPool` profiling
    pub lane_busy_secs: Vec<f64>,
    pub events_dropped: u64,
    pub every: u32,
}

impl PhaseSnapshot {
    pub fn phase_sum_secs(&self) -> f64 {
        self.per_phase_secs.iter().sum()
    }

    /// phase-sum / sampled wall — the tiling invariant puts this in
    /// (0.9, 1.0] on any sane clock; NaN with zero sampled steps.
    pub fn coverage(&self) -> f64 {
        if self.sampled_wall_secs <= 0.0 {
            return f64::NAN;
        }
        self.phase_sum_secs() / self.sampled_wall_secs
    }

    /// Share of one phase in the sampled total (NaN when nothing was
    /// sampled).
    pub fn phase_frac(&self, p: Phase) -> f64 {
        let sum = self.phase_sum_secs();
        if sum <= 0.0 {
            return f64::NAN;
        }
        self.per_phase_secs[p.idx()] / sum
    }
}

/// Lap timer for one sampled step. Owns the scratch buffers (taken
/// from the engine workspace, returned by [`StepTimer::finish`]) so
/// the steady state allocates nothing. `lap(phase, layer)` attributes
/// everything since the previous lap to `(phase, layer)` — laps tile
/// `[start, last lap]`, which is what makes the phase sum track the
/// step wall time instead of under-counting.
pub struct StepTimer<'a> {
    prof: &'a PhaseProfiler,
    step: u64,
    t0: Instant,
    last: Instant,
    acc: Vec<u64>,
    events: Vec<PhaseEvent>,
}

impl<'a> StepTimer<'a> {
    pub fn begin(
        prof: &'a PhaseProfiler,
        step: u64,
        mut acc: Vec<u64>,
        mut events: Vec<PhaseEvent>,
    ) -> StepTimer<'a> {
        acc.clear();
        acc.resize(PHASES.len() * prof.n_layers, 0);
        events.clear();
        let now = Instant::now();
        StepTimer { prof, step, t0: now, last: now, acc, events }
    }

    pub fn lap(&mut self, phase: Phase, layer: usize) {
        let now = Instant::now();
        let dur = now.duration_since(self.last).as_nanos() as u64;
        self.acc[phase.idx() * self.prof.n_layers + layer] += dur;
        if self.prof.events_on {
            self.events.push(PhaseEvent {
                phase,
                layer: layer as u32,
                step: self.step,
                start: self.last,
                dur_ns: dur,
            });
        }
        self.last = now;
    }

    /// Commit to the profiler and hand the scratch buffers back.
    pub fn finish(self) -> (Vec<u64>, Vec<PhaseEvent>) {
        let wall =
            self.last.duration_since(self.t0).as_nanos() as u64;
        self.prof.commit(&self.acc, wall, &self.events);
        (self.acc, self.events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_rate_is_exact() {
        let p = PhaseProfiler::new(2, 4, false, 0);
        let hits = (0..16)
            .filter(|_| p.sample_step().is_some())
            .count();
        assert_eq!(hits, 4);
        let s = p.snapshot();
        assert_eq!(s.total_steps, 16);
        // sample_step does not imply commit
        assert_eq!(s.sampled_steps, 0);
        // disabled profiler never samples and never counts
        let off = PhaseProfiler::new(2, 0, false, 0);
        assert!(off.sample_step().is_none());
        assert!(!off.enabled());
    }

    #[test]
    fn laps_tile_the_step_and_attribute_by_phase() {
        let p = PhaseProfiler::new(2, 1, true, 100);
        let step = p.sample_step().unwrap();
        let mut t =
            StepTimer::begin(&p, step, Vec::new(), Vec::new());
        busy_wait_us(200);
        t.lap(Phase::Qkv, 0);
        busy_wait_us(200);
        t.lap(Phase::Attn, 0);
        busy_wait_us(200);
        t.lap(Phase::Mlp, 1);
        t.lap(Phase::Vocab, 0);
        t.finish();
        let s = p.snapshot();
        assert_eq!(s.sampled_steps, 1);
        let sum = s.phase_sum_secs();
        assert!(sum > 0.0);
        // the tiling invariant: laps cover the whole wall time
        assert!(
            s.coverage() > 0.999 && s.coverage() <= 1.001,
            "coverage {}",
            s.coverage()
        );
        assert!(s.per_phase_secs[Phase::Qkv.idx()] > 0.0);
        assert!(s.per_layer_secs[1] > 0.0);
        assert_eq!(p.take_events().len(), 4);
        assert_eq!(p.take_events().len(), 0, "drain empties");
    }

    #[test]
    fn event_cap_drops_and_counts() {
        let p = PhaseProfiler::new(1, 1, true, 2);
        let step = p.sample_step().unwrap();
        let mut t =
            StepTimer::begin(&p, step, Vec::new(), Vec::new());
        for _ in 0..5 {
            t.lap(Phase::Attn, 0);
        }
        t.finish();
        assert_eq!(p.take_events().len(), 2);
        assert_eq!(p.snapshot().events_dropped, 3);
    }

    #[test]
    fn commit_merges_across_steps() {
        let p = PhaseProfiler::new(1, 1, false, 0);
        let mut acc = vec![0u64; 5];
        acc[Phase::Attn.idx()] = 1_000;
        p.commit(&acc, 2_000, &[]);
        p.commit(&acc, 2_000, &[]);
        let s = p.snapshot();
        assert_eq!(s.sampled_steps, 2);
        assert!(
            (s.per_phase_secs[Phase::Attn.idx()] - 2e-6).abs() < 1e-12
        );
        assert!((s.sampled_wall_secs - 4e-6).abs() < 1e-12);
        assert!((s.coverage() - 0.5).abs() < 1e-9);
        assert!((s.phase_frac(Phase::Attn) - 1.0).abs() < 1e-9);
    }

    fn busy_wait_us(us: u64) {
        let t0 = Instant::now();
        while t0.elapsed().as_micros() < us as u128 {
            std::hint::spin_loop();
        }
    }
}
