//! Export spans and phase events as a Chrome/Perfetto trace and a
//! structured JSONL event log; validate both (the `trace-check` CLI
//! subcommand CI runs against every traced serve smoke).
//!
//! The trace uses the Trace Event Format's complete ("X") events with
//! microsecond timestamps relative to the tracer epoch. Each session
//! gets its own track (`tid = session id + 1`) carrying one
//! whole-lifecycle `session` event plus nested `queued` / `prefill` /
//! `decode` sub-spans; sampled decode-phase events land on the shared
//! engine track (`tid = 0`) under category `phase`. Load the file at
//! `https://ui.perfetto.dev` or `chrome://tracing` as-is.

use super::json::{escape, Json};
use super::span::Tracer;
use super::PhaseEvent;

fn x_event(
    tid: u64,
    cat: &str,
    name: &str,
    ts_us: f64,
    dur_us: f64,
    args: &str,
) -> String {
    format!(
        "{{\"ph\":\"X\",\"pid\":1,\"tid\":{tid},\"cat\":\"{cat}\",\
         \"name\":\"{name}\",\"ts\":{ts_us:.3},\"dur\":{dur_us:.3},\
         \"args\":{{{args}}}}}"
    )
}

/// Build the full Chrome trace JSON document.
pub fn chrome_trace(tracer: &Tracer, phases: &[PhaseEvent]) -> String {
    let mut ev: Vec<String> = vec![
        "{\"ph\":\"M\",\"pid\":1,\"name\":\"process_name\",\
         \"args\":{\"name\":\"qpruner-serve\"}}"
            .to_string(),
        "{\"ph\":\"M\",\"pid\":1,\"tid\":0,\"name\":\"thread_name\",\
         \"args\":{\"name\":\"decode-engine\"}}"
            .to_string(),
    ];
    for s in tracer.spans() {
        let tid = s.id + 1;
        let sub = tracer.us_since_epoch(s.submitted);
        let fin = tracer.us_since_epoch(s.finished);
        ev.push(format!(
            "{{\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
             \"name\":\"thread_name\",\
             \"args\":{{\"name\":\"session {}\"}}}}",
            s.id
        ));
        let num_or_null = |v: Option<f64>| match v {
            Some(x) if x.is_finite() => format!("{x:.3}"),
            _ => "null".to_string(),
        };
        ev.push(x_event(
            tid,
            "session",
            "session",
            sub,
            (fin - sub).max(0.0),
            &format!(
                "\"id\":{},\"client\":{},\"prompt_len\":{},\
                 \"tokens\":{},\"outcome\":\"{}\",\"ttft_ms\":{},\
                 \"mean_itl_ms\":{}",
                s.id,
                s.client,
                s.prompt_len,
                s.tokens,
                s.outcome.label(),
                num_or_null(s.ttft_ms()),
                num_or_null(s.mean_itl_ms()),
            ),
        ));
        if let Some(adm) = s.admitted {
            let adm_us = tracer.us_since_epoch(adm);
            ev.push(x_event(
                tid,
                "session",
                "queued",
                sub,
                (adm_us - sub).max(0.0),
                "",
            ));
            if let Some(ft) = s.first_token {
                let ft_us = tracer.us_since_epoch(ft);
                ev.push(x_event(
                    tid,
                    "session",
                    "prefill",
                    adm_us,
                    (ft_us - adm_us).max(0.0),
                    "",
                ));
                ev.push(x_event(
                    tid,
                    "session",
                    "decode",
                    ft_us,
                    (fin - ft_us).max(0.0),
                    &format!("\"tokens\":{}", s.tokens),
                ));
            }
        }
    }
    for p in phases {
        ev.push(x_event(
            0,
            "phase",
            p.phase.label(),
            tracer.us_since_epoch(p.start),
            p.dur_ns as f64 / 1e3,
            &format!("\"layer\":{},\"step\":{}", p.layer, p.step),
        ));
    }
    format!(
        "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n{}\n]}}",
        ev.join(",\n")
    )
}

/// Structured JSONL event log: one meta line, one line per session
/// span, one line per retained phase event. Every line is a complete
/// JSON object — stream-parseable without loading the file.
pub fn events_jsonl(tracer: &Tracer, phases: &[PhaseEvent]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\"type\":\"meta\",\
         \"schema\":\"qpruner.serve.events.v1\",\"sessions\":{},\
         \"phase_events\":{},\"spans_dropped\":{}}}\n",
        tracer.spans().len(),
        phases.len(),
        tracer.dropped()
    ));
    let num_or_null = |v: Option<f64>| match v {
        Some(x) if x.is_finite() => format!("{x:.4}"),
        _ => "null".to_string(),
    };
    for s in tracer.spans() {
        let opt_us = |t: Option<std::time::Instant>| match t {
            Some(t) => format!("{:.3}", tracer.us_since_epoch(t)),
            None => "null".to_string(),
        };
        out.push_str(&format!(
            "{{\"type\":\"session\",\"id\":{},\"client\":{},\
             \"prompt_len\":{},\"tokens\":{},\"outcome\":\"{}\",\
             \"submitted_us\":{:.3},\"admitted_us\":{},\
             \"first_token_us\":{},\"finished_us\":{:.3},\
             \"ttft_ms\":{},\"decode_ms\":{},\"mean_itl_ms\":{}}}\n",
            s.id,
            s.client,
            s.prompt_len,
            s.tokens,
            escape(s.outcome.label()),
            tracer.us_since_epoch(s.submitted),
            opt_us(s.admitted),
            opt_us(s.first_token),
            tracer.us_since_epoch(s.finished),
            num_or_null(s.ttft_ms()),
            num_or_null(s.decode_ms()),
            num_or_null(s.mean_itl_ms()),
        ));
    }
    for p in phases {
        out.push_str(&format!(
            "{{\"type\":\"phase\",\"phase\":\"{}\",\"layer\":{},\
             \"step\":{},\"start_us\":{:.3},\"dur_us\":{:.3}}}\n",
            p.phase.label(),
            p.layer,
            p.step,
            tracer.us_since_epoch(p.start),
            p.dur_ns as f64 / 1e3,
        ));
    }
    out
}

/// What `trace-check` asserts about a trace document.
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceSummary {
    /// whole-lifecycle `session` events
    pub sessions: usize,
    /// sessions whose outcome is `done`
    pub complete_sessions: usize,
    pub phase_events: usize,
    pub total_events: usize,
}

/// Strict-parse a Chrome trace document and count what matters.
/// Errors on malformed JSON or a missing/ill-typed `traceEvents`
/// array — the exact failure modes a `NaN` or truncated write would
/// produce.
pub fn validate_trace(body: &str) -> Result<TraceSummary, String> {
    let doc = Json::parse(body)?;
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or("no traceEvents array")?;
    let mut sum = TraceSummary {
        total_events: events.len(),
        ..Default::default()
    };
    for e in events {
        let ph = e.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        let cat = e.get("cat").and_then(|c| c.as_str()).unwrap_or("");
        let name =
            e.get("name").and_then(|n| n.as_str()).unwrap_or("");
        if ph != "X" {
            continue;
        }
        // complete events must carry finite ts + dur
        for k in ["ts", "dur"] {
            e.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("X event missing {k}"))?;
        }
        if cat == "session" && name == "session" {
            sum.sessions += 1;
            let done = e
                .get("args")
                .and_then(|a| a.get("outcome"))
                .and_then(|o| o.as_str())
                == Some("done");
            if done {
                sum.complete_sessions += 1;
            }
        } else if cat == "phase" {
            sum.phase_events += 1;
        }
    }
    Ok(sum)
}

/// Strict-parse a `qpruner.serve.events.v1` JSONL event log (the
/// `--events-out` file and the HTTP server's `GET /traces` body).
/// Every line must parse; the first non-empty line must be the meta
/// record carrying the schema tag, and its declared session count
/// must match the session lines actually present — the exact
/// invariant that catches a truncated export or a dropped span.
pub fn validate_events(body: &str) -> Result<TraceSummary, String> {
    let mut lines = body.lines().enumerate().filter(|(_, l)| {
        !l.trim().is_empty()
    });
    let (_, meta_line) =
        lines.next().ok_or("empty event log")?;
    let meta = Json::parse(meta_line)
        .map_err(|e| format!("meta line: {e}"))?;
    if meta.get("type").and_then(|t| t.as_str()) != Some("meta") {
        return Err("first line is not a meta record".into());
    }
    let schema = meta
        .get("schema")
        .and_then(|s| s.as_str())
        .ok_or("meta line has no schema")?;
    if schema != "qpruner.serve.events.v1" {
        return Err(format!("unknown schema {schema:?}"));
    }
    let declared = meta
        .get("sessions")
        .and_then(|s| s.as_f64())
        .ok_or("meta line has no session count")? as usize;
    let mut sum = TraceSummary { total_events: 1, ..Default::default() };
    for (no, line) in lines {
        let v = Json::parse(line)
            .map_err(|e| format!("line {}: {e}", no + 1))?;
        sum.total_events += 1;
        match v.get("type").and_then(|t| t.as_str()) {
            Some("session") => {
                sum.sessions += 1;
                // terminal sessions always carry a finish timestamp
                v.get("finished_us")
                    .and_then(|f| f.as_f64())
                    .ok_or_else(|| {
                        format!("line {}: session has no finished_us",
                                no + 1)
                    })?;
                if v.get("outcome").and_then(|o| o.as_str())
                    == Some("done")
                {
                    sum.complete_sessions += 1;
                }
            }
            Some("phase") => sum.phase_events += 1,
            Some("meta") => {
                return Err(format!("line {}: duplicate meta", no + 1))
            }
            _ => {
                return Err(format!("line {}: unknown type", no + 1))
            }
        }
    }
    if sum.sessions != declared {
        return Err(format!(
            "meta declares {declared} sessions, found {}",
            sum.sessions
        ));
    }
    Ok(sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::SpanOutcome;
    use crate::obs::Phase;
    use std::time::{Duration, Instant};

    fn tracer_with_sessions() -> Tracer {
        let mut tr = Tracer::new(64);
        let t0 = Instant::now();
        for id in 0..3u64 {
            tr.on_submit(id, id as usize, 4, t0);
            tr.on_admitted(id, t0 + Duration::from_millis(1 + id));
            tr.on_first_token(
                id,
                t0 + Duration::from_millis(2 + id),
            );
            tr.on_finish(
                id,
                t0 + Duration::from_millis(10 + id),
                5,
                if id == 2 {
                    SpanOutcome::Evicted
                } else {
                    SpanOutcome::Done
                },
            );
        }
        tr
    }

    fn phase_events(tr: &Tracer) -> Vec<PhaseEvent> {
        let t = tr.epoch() + Duration::from_millis(3);
        vec![
            PhaseEvent {
                phase: Phase::Qkv,
                layer: 0,
                step: 1,
                start: t,
                dur_ns: 5_000,
            },
            PhaseEvent {
                phase: Phase::Vocab,
                layer: 0,
                step: 1,
                start: t + Duration::from_micros(5),
                dur_ns: 7_000,
            },
        ]
    }

    #[test]
    fn chrome_trace_parses_and_counts() {
        let tr = tracer_with_sessions();
        let body = chrome_trace(&tr, &phase_events(&tr));
        let sum = validate_trace(&body).unwrap();
        assert_eq!(sum.sessions, 3);
        assert_eq!(sum.complete_sessions, 2);
        assert_eq!(sum.phase_events, 2);
        // 2 process/engine meta + 3 * (meta + session + 3 subspans)
        // + 2 phase events
        assert_eq!(sum.total_events, 2 + 3 * 5 + 2);
    }

    #[test]
    fn events_jsonl_lines_all_parse() {
        let tr = tracer_with_sessions();
        let log = events_jsonl(&tr, &phase_events(&tr));
        let mut kinds = std::collections::BTreeMap::new();
        for line in log.lines() {
            let v = Json::parse(line).unwrap();
            let t = v
                .get("type")
                .and_then(|t| t.as_str())
                .unwrap()
                .to_string();
            *kinds.entry(t).or_insert(0usize) += 1;
        }
        assert_eq!(kinds.get("meta"), Some(&1));
        assert_eq!(kinds.get("session"), Some(&3));
        assert_eq!(kinds.get("phase"), Some(&2));
    }

    #[test]
    fn validate_events_accepts_real_logs() {
        let tr = tracer_with_sessions();
        let log = events_jsonl(&tr, &phase_events(&tr));
        let sum = validate_events(&log).unwrap();
        assert_eq!(sum.sessions, 3);
        assert_eq!(sum.complete_sessions, 2);
        assert_eq!(sum.phase_events, 2);
        assert_eq!(sum.total_events, 6);
        // phase-free logs (server /traces between steps) also pass
        let bare = events_jsonl(&tr, &[]);
        assert_eq!(validate_events(&bare).unwrap().phase_events, 0);
    }

    #[test]
    fn validate_events_rejects_malformed_logs() {
        assert!(validate_events("").is_err());
        assert!(validate_events("{\"type\":\"session\"}").is_err());
        // wrong schema
        assert!(validate_events(
            "{\"type\":\"meta\",\"schema\":\"other.v9\",\
             \"sessions\":0}"
        )
        .is_err());
        // declared/found session count mismatch (truncated log)
        let tr = tracer_with_sessions();
        let log = events_jsonl(&tr, &[]);
        let truncated: Vec<&str> =
            log.lines().take(3).collect();
        assert!(validate_events(&truncated.join("\n")).is_err());
        // garbage mid-log names the line
        let err = validate_events(
            "{\"type\":\"meta\",\
             \"schema\":\"qpruner.serve.events.v1\",\"sessions\":0}\n\
             not json",
        )
        .unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn validate_rejects_malformed_documents() {
        assert!(validate_trace("not json").is_err());
        assert!(validate_trace("{\"traceEvents\":3}").is_err());
        // NaN in a ts field is a parse error, not a silent pass
        let bad = "{\"traceEvents\":[{\"ph\":\"X\",\"ts\":NaN,\
                   \"dur\":1,\"cat\":\"phase\",\"name\":\"qkv\"}]}";
        assert!(validate_trace(bad).is_err());
        let empty = validate_trace("{\"traceEvents\":[]}").unwrap();
        assert_eq!(empty.sessions, 0);
    }
}
