//! Request-lifecycle span records for the serving scheduler.
//!
//! Every session that enters the scheduler gets one [`SessionSpan`]:
//! the wall-clock instants of its lifecycle transitions
//! (submitted → admitted → first token → finished/evicted), the token
//! count, and the outcome. The scheduler drives the [`Tracer`] with
//! one call per transition; the tracer keeps live sessions in a map
//! and moves them to a bounded completed list at finish — a week-long
//! run drops spans past the cap (counted) instead of growing without
//! limit. Derived per-session latencies (TTFT, decode span, mean ITL)
//! come straight from the instants, so tests can assert the histogram
//! recordings equal the span deltas exactly.

use std::collections::HashMap;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanOutcome {
    Done,
    Evicted,
    /// Per-request deadline expired; partial tokens were delivered.
    DeadlineExceeded,
    /// An engine step failed for this one session; it was evicted and
    /// quarantined instead of poisoning the batch.
    Quarantined,
    /// The client went away mid-generation (socket drop / slow consumer).
    Disconnected,
}

impl SpanOutcome {
    pub fn label(&self) -> &'static str {
        match self {
            SpanOutcome::Done => "done",
            SpanOutcome::Evicted => "evicted",
            SpanOutcome::DeadlineExceeded => "deadline",
            SpanOutcome::Quarantined => "quarantined",
            SpanOutcome::Disconnected => "disconnect",
        }
    }

    /// Everything except `Done` ends a session before its natural
    /// completion; events/metrics consumers group on this.
    pub fn is_failure(&self) -> bool {
        !matches!(self, SpanOutcome::Done)
    }
}

/// One finished session's lifecycle record.
#[derive(Clone, Debug)]
pub struct SessionSpan {
    pub id: u64,
    pub client: usize,
    pub prompt_len: usize,
    pub submitted: Instant,
    /// left the wait queue and was prefilled (None: evicted while
    /// still queued — cannot happen today, kept for forward-compat)
    pub admitted: Option<Instant>,
    pub first_token: Option<Instant>,
    pub finished: Instant,
    pub tokens: u64,
    pub outcome: SpanOutcome,
}

impl SessionSpan {
    /// Time-to-first-token: submit → first sampled token.
    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token.map(|t| {
            t.duration_since(self.submitted).as_secs_f64() * 1e3
        })
    }

    /// Decode span: first token → finished.
    pub fn decode_ms(&self) -> Option<f64> {
        self.first_token.map(|t| {
            self.finished.duration_since(t).as_secs_f64() * 1e3
        })
    }

    /// Mean inter-token latency over the decode span (None with
    /// fewer than two tokens).
    pub fn mean_itl_ms(&self) -> Option<f64> {
        if self.tokens < 2 {
            return None;
        }
        self.decode_ms().map(|d| d / (self.tokens - 1) as f64)
    }
}

struct LiveSpan {
    client: usize,
    prompt_len: usize,
    submitted: Instant,
    admitted: Option<Instant>,
    first_token: Option<Instant>,
}

/// Collects session spans during a serve run. Not thread-safe by
/// design: the scheduler is single-threaded (parallelism lives below
/// it, inside `Engine::step_batch`).
pub struct Tracer {
    epoch: Instant,
    live: HashMap<u64, LiveSpan>,
    done: Vec<SessionSpan>,
    cap: usize,
    dropped: u64,
}

impl Tracer {
    /// `cap` bounds the completed-span list; spans finished past it
    /// are counted in [`Tracer::dropped`] and discarded.
    pub fn new(cap: usize) -> Tracer {
        Tracer {
            epoch: Instant::now(),
            live: HashMap::new(),
            done: Vec::new(),
            cap,
            dropped: 0,
        }
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Microseconds from the tracer epoch (trace timestamp base).
    pub fn us_since_epoch(&self, t: Instant) -> f64 {
        t.saturating_duration_since(self.epoch).as_secs_f64() * 1e6
    }

    pub fn on_submit(
        &mut self,
        id: u64,
        client: usize,
        prompt_len: usize,
        t: Instant,
    ) {
        self.live.insert(
            id,
            LiveSpan {
                client,
                prompt_len,
                submitted: t,
                admitted: None,
                first_token: None,
            },
        );
    }

    pub fn on_admitted(&mut self, id: u64, t: Instant) {
        if let Some(s) = self.live.get_mut(&id) {
            s.admitted = Some(t);
        }
    }

    pub fn on_first_token(&mut self, id: u64, t: Instant) {
        if let Some(s) = self.live.get_mut(&id) {
            s.first_token = Some(t);
        }
    }

    pub fn on_finish(
        &mut self,
        id: u64,
        t: Instant,
        tokens: u64,
        outcome: SpanOutcome,
    ) {
        let Some(s) = self.live.remove(&id) else { return };
        if self.done.len() >= self.cap {
            self.dropped += 1;
            return;
        }
        self.done.push(SessionSpan {
            id,
            client: s.client,
            prompt_len: s.prompt_len,
            submitted: s.submitted,
            admitted: s.admitted,
            first_token: s.first_token,
            finished: t,
            tokens,
            outcome,
        });
    }

    pub fn spans(&self) -> &[SessionSpan] {
        &self.done
    }

    pub fn live_len(&self) -> usize {
        self.live.len()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn lifecycle_produces_consistent_span() {
        let mut tr = Tracer::new(16);
        let t0 = Instant::now();
        tr.on_submit(7, 2, 5, t0);
        let t1 = t0 + Duration::from_millis(3);
        tr.on_admitted(7, t1);
        let t2 = t0 + Duration::from_millis(5);
        tr.on_first_token(7, t2);
        let t3 = t0 + Duration::from_millis(25);
        tr.on_finish(7, t3, 6, SpanOutcome::Done);
        assert_eq!(tr.live_len(), 0);
        let s = &tr.spans()[0];
        assert_eq!((s.id, s.client, s.prompt_len), (7, 2, 5));
        assert!((s.ttft_ms().unwrap() - 5.0).abs() < 1e-9);
        assert!((s.decode_ms().unwrap() - 20.0).abs() < 1e-9);
        assert!((s.mean_itl_ms().unwrap() - 4.0).abs() < 1e-9);
        assert_eq!(s.outcome, SpanOutcome::Done);
    }

    #[test]
    fn cap_drops_and_counts() {
        let mut tr = Tracer::new(1);
        let t = Instant::now();
        for id in 0..3 {
            tr.on_submit(id, 0, 1, t);
            tr.on_finish(id, t, 1, SpanOutcome::Done);
        }
        assert_eq!(tr.spans().len(), 1);
        assert_eq!(tr.dropped(), 2);
    }

    #[test]
    fn finish_of_unknown_id_is_a_noop() {
        let mut tr = Tracer::new(4);
        tr.on_finish(99, Instant::now(), 0, SpanOutcome::Evicted);
        assert!(tr.spans().is_empty());
        // single-token sessions have no ITL
        let t = Instant::now();
        tr.on_submit(1, 0, 1, t);
        tr.on_first_token(1, t);
        tr.on_finish(1, t, 1, SpanOutcome::Done);
        assert!(tr.spans()[0].mean_itl_ms().is_none());
    }
}
