//! Bounded log2-bucket latency histograms and the metric registry.
//!
//! `LatencyStats` (metrics.rs) keeps raw samples and clones-and-sorts
//! on every percentile query — fine for offline benches, wrong for a
//! serving hot path that records one latency per token. [`Hist`] is
//! the streaming replacement: a fixed array of power-of-two buckets
//! with 8 linear sub-buckets per octave (HDR-histogram style), so
//! `record` is O(1) with no allocation, two histograms merge by adding
//! counts, and memory is constant (~4 KB) regardless of run length.
//! Quantiles are nearest-rank over bucket midpoints; the relative
//! error is bounded by the sub-bucket width (≤ 1/16 of the value),
//! and exact `min`/`max`/`mean` are tracked on the side.
//!
//! [`Registry`] is the serve-side metric namespace: named counters,
//! gauges, and histograms in a `BTreeMap` so the JSON snapshot
//! (`snapshot_json`) is stable and diffable across runs.

use std::collections::BTreeMap;

/// Linear sub-buckets per octave (2^3 = 8).
const SUB_BITS: u32 = 3;
const SUB: u64 = 1 << SUB_BITS;
/// Values below 2^(SUB_BITS+1) get one exact bucket each.
const LINEAR_MAX: u64 = SUB * 2; // 16
/// 16 exact buckets + 8 per octave for msb 4..=63.
const N_BUCKETS: usize = LINEAR_MAX as usize + 60 * SUB as usize;

fn bucket_index(v: u64) -> usize {
    if v < LINEAR_MAX {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros(); // >= 4
    let sub = (v >> (msb - SUB_BITS)) & (SUB - 1);
    LINEAR_MAX as usize
        + (msb as usize - 4) * SUB as usize
        + sub as usize
}

/// Inclusive lower bound of bucket `i` in the recorded unit (ns).
fn bucket_low(i: usize) -> u64 {
    if i < LINEAR_MAX as usize {
        return i as u64;
    }
    let rel = i - LINEAR_MAX as usize;
    let msb = (rel / SUB as usize) as u32 + 4;
    let sub = (rel % SUB as usize) as u64;
    (1u64 << msb) + (sub << (msb - SUB_BITS))
}

/// Exclusive upper bound of bucket `i`.
fn bucket_high(i: usize) -> u64 {
    if i < LINEAR_MAX as usize {
        return i as u64 + 1;
    }
    let rel = i - LINEAR_MAX as usize;
    let msb = (rel / SUB as usize) as u32 + 4;
    bucket_low(i) + (1u64 << (msb - SUB_BITS))
}

/// Streaming latency histogram over nanoseconds. The public API
/// mirrors `LatencyStats` (record/percentiles in milliseconds) so the
/// scheduler and `ServeReport` swapped over without reshaping callers.
#[derive(Clone, Debug)]
pub struct Hist {
    counts: Vec<u64>,
    count: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist {
            counts: vec![0; N_BUCKETS],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    /// O(1), allocation-free record of one duration in nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        self.counts[bucket_index(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Record a duration in milliseconds (negatives clamp to zero).
    pub fn record_ms(&mut self, ms: f64) {
        let ns = if ms <= 0.0 || !ms.is_finite() {
            0
        } else {
            (ms * 1e6).round().min(u64::MAX as f64) as u64
        };
        self.record_ns(ns);
    }

    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum_ns as f64 / self.count as f64 / 1e6
    }

    pub fn min_ms(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.min_ns as f64 / 1e6
    }

    pub fn max_ms(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.max_ns as f64 / 1e6
    }

    /// Nearest-rank percentile over bucket midpoints, clamped into
    /// `[min, max]` so the tails report the exact extremes. `NaN`
    /// when empty (serialization maps it to `null`, never `NaN`).
    pub fn percentile_ms(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank =
            ((q / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let mid = (bucket_low(i) + bucket_high(i)) as f64 / 2.0;
                let mid = mid
                    .max(self.min_ns as f64)
                    .min(self.max_ns as f64);
                return mid / 1e6;
            }
        }
        self.max_ms()
    }

    pub fn percentiles_ms(&self, qs: &[f64]) -> Vec<f64> {
        qs.iter().map(|&q| self.percentile_ms(q)).collect()
    }

    /// Add another histogram's population into this one.
    pub fn merge(&mut self, other: &Hist) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// `"p50 1.2ms  p95 3.4ms  p99 5.6ms  mean 1.5ms (n=100)"`
    pub fn summary(&self) -> String {
        if self.count == 0 {
            return "n=0".to_string();
        }
        let p = self.percentiles_ms(&[50.0, 95.0, 99.0]);
        format!(
            "p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms  mean {:.2}ms (n={})",
            p[0],
            p[1],
            p[2],
            self.mean_ms(),
            self.count
        )
    }

    /// Stable JSON object: summary stats plus the sparse non-empty
    /// buckets as `[index, count]` pairs.
    pub fn to_json(&self) -> String {
        let num = |v: f64| {
            if v.is_finite() {
                format!("{v:.4}")
            } else {
                "null".to_string()
            }
        };
        let p = self.percentiles_ms(&[50.0, 90.0, 95.0, 99.0]);
        let buckets: Vec<String> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| format!("[{i},{c}]"))
            .collect();
        format!(
            "{{\"count\":{},\"mean_ms\":{},\"min_ms\":{},\
             \"max_ms\":{},\"p50_ms\":{},\"p90_ms\":{},\
             \"p95_ms\":{},\"p99_ms\":{},\"buckets\":[{}]}}",
            self.count,
            num(self.mean_ms()),
            num(self.min_ms()),
            num(self.max_ms()),
            num(p[0]),
            num(p[1]),
            num(p[2]),
            num(p[3]),
            buckets.join(",")
        )
    }
}

/// One named metric in the registry.
#[derive(Clone, Debug)]
pub enum Metric {
    Counter(u64),
    Gauge(f64),
    Hist(Hist),
}

/// Named metric namespace with a stable JSON snapshot. Names follow
/// the `serve.*` dotted convention (see the README glossary).
#[derive(Default, Debug)]
pub struct Registry {
    metrics: BTreeMap<String, Metric>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter_add(&mut self, name: &str, by: u64) {
        match self
            .metrics
            .entry(name.to_string())
            .or_insert(Metric::Counter(0))
        {
            Metric::Counter(c) => *c += by,
            other => panic!("{name} is not a counter: {other:?}"),
        }
    }

    pub fn gauge_set(&mut self, name: &str, v: f64) {
        self.metrics.insert(name.to_string(), Metric::Gauge(v));
    }

    pub fn hist_mut(&mut self, name: &str) -> &mut Hist {
        match self
            .metrics
            .entry(name.to_string())
            .or_insert_with(|| Metric::Hist(Hist::new()))
        {
            Metric::Hist(h) => h,
            other => panic!("{name} is not a histogram: {other:?}"),
        }
    }

    /// Install a pre-populated histogram under `name`.
    pub fn hist_set(&mut self, name: &str, h: Hist) {
        self.metrics.insert(name.to_string(), Metric::Hist(h));
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        match self.metrics.get(name) {
            Some(Metric::Counter(c)) => Some(*c),
            _ => None,
        }
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        match self.metrics.get(name) {
            Some(Metric::Gauge(g)) => Some(*g),
            _ => None,
        }
    }

    pub fn hist(&self, name: &str) -> Option<&Hist> {
        match self.metrics.get(name) {
            Some(Metric::Hist(h)) => Some(h),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    /// Versioned snapshot: kinds are grouped so consumers can iterate
    /// one section without sniffing value shapes. Keys inside each
    /// section are sorted (BTreeMap order) — byte-stable given the
    /// same metric values.
    pub fn snapshot_json(&self) -> String {
        let esc = super::json::escape;
        let mut counters = Vec::new();
        let mut gauges = Vec::new();
        let mut hists = Vec::new();
        for (name, m) in &self.metrics {
            match m {
                Metric::Counter(c) => {
                    counters.push(format!("\"{}\":{}", esc(name), c));
                }
                Metric::Gauge(g) => {
                    let v = if g.is_finite() {
                        format!("{g:.6}")
                    } else {
                        "null".to_string()
                    };
                    gauges.push(format!("\"{}\":{}", esc(name), v));
                }
                Metric::Hist(h) => {
                    hists.push(format!(
                        "\"{}\":{}",
                        esc(name),
                        h.to_json()
                    ));
                }
            }
        }
        format!(
            "{{\"schema\":\"qpruner.serve.metrics.v1\",\
             \"counters\":{{{}}},\"gauges\":{{{}}},\
             \"histograms\":{{{}}}}}",
            counters.join(","),
            gauges.join(","),
            hists.join(",")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_tile_the_axis() {
        // every bucket's high == next bucket's low, and index() maps
        // both endpoints into the right bucket
        for i in 0..N_BUCKETS - 1 {
            assert_eq!(bucket_high(i), bucket_low(i + 1), "gap at {i}");
            assert_eq!(bucket_index(bucket_low(i)), i);
            assert_eq!(bucket_index(bucket_high(i) - 1), i);
        }
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn empty_hist_is_nan_and_json_null() {
        let h = Hist::new();
        assert!(h.is_empty());
        assert!(h.percentile_ms(50.0).is_nan());
        assert!(h.mean_ms().is_nan());
        let j = h.to_json();
        assert!(j.contains("\"p50_ms\":null"), "{j}");
        assert!(!j.contains("NaN"), "{j}");
    }

    #[test]
    fn percentiles_are_ordered_and_bounded() {
        let mut h = Hist::new();
        // deterministic skewed population
        let mut x = 9u64;
        for _ in 0..10_000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            let ms = 0.1 + (x % 1000) as f64 / 50.0;
            h.record_ms(ms);
        }
        let p = h.percentiles_ms(&[50.0, 95.0, 99.0]);
        assert!(p[0] <= p[1] && p[1] <= p[2], "{p:?}");
        assert!(p[0] >= h.min_ms() && p[2] <= h.max_ms());
        assert_eq!(h.len(), 10_000);
    }

    #[test]
    fn relative_error_is_within_sub_bucket_width() {
        // constant population: every quantile must land within 1/16
        // (6.25% at the midpoint) of the true value
        for ms in [0.001, 0.7, 3.0, 42.0, 1234.5] {
            let mut h = Hist::new();
            for _ in 0..100 {
                h.record_ms(ms);
            }
            for q in [1.0, 50.0, 99.0] {
                let got = h.percentile_ms(q);
                // min==max clamps the midpoint to the exact value
                assert!(
                    (got - ms).abs() / ms < 1e-9,
                    "q{q} of {ms}: {got}"
                );
            }
            assert!((h.mean_ms() - ms).abs() / ms < 1e-6);
        }
    }

    #[test]
    fn merge_equals_union() {
        let mut a = Hist::new();
        let mut b = Hist::new();
        let mut u = Hist::new();
        for i in 0..500 {
            let ms = 0.5 + i as f64 * 0.01;
            if i % 2 == 0 {
                a.record_ms(ms);
            } else {
                b.record_ms(ms);
            }
            u.record_ms(ms);
        }
        a.merge(&b);
        assert_eq!(a.len(), u.len());
        for q in [10.0, 50.0, 90.0, 99.0] {
            assert_eq!(a.percentile_ms(q), u.percentile_ms(q));
        }
        assert_eq!(a.min_ms(), u.min_ms());
        assert_eq!(a.max_ms(), u.max_ms());
    }

    #[test]
    fn registry_kinds_and_snapshot_schema() {
        let mut r = Registry::new();
        r.counter_add("serve.completed", 3);
        r.counter_add("serve.completed", 2);
        r.gauge_set("serve.kv_used_frac", 0.25);
        r.hist_mut("serve.latency_ms").record_ms(1.5);
        assert_eq!(r.counter("serve.completed"), Some(5));
        assert_eq!(r.gauge("serve.kv_used_frac"), Some(0.25));
        assert_eq!(r.hist("serve.latency_ms").unwrap().len(), 1);
        let snap = r.snapshot_json();
        let v = super::super::json::Json::parse(&snap).unwrap();
        assert_eq!(
            v.get("schema").and_then(|s| s.as_str()),
            Some("qpruner.serve.metrics.v1")
        );
        let c = v.get("counters").unwrap();
        assert_eq!(
            c.get("serve.completed").and_then(|x| x.as_f64()),
            Some(5.0)
        );
        assert!(v.get("histograms")
            .and_then(|h| h.get("serve.latency_ms"))
            .is_some());
    }
}
