//! Minimal JSON value parser and string escaping.
//!
//! The crate hand-rolls all JSON it *writes* (no serde in the image),
//! which historically left nothing that could *read* JSON back — so
//! bugs like `ServeReport::to_json` emitting a literal `NaN` shipped
//! undetected because no test ever parsed the output. This is a small
//! strict recursive-descent parser used by those regression tests, by
//! `trace-check` (CI validation of `trace.json`), and by the metrics
//! snapshot tests. Strictness is the point: `NaN`/`Infinity` are
//! rejected exactly like any other JSON parser would reject them.

/// Escape a string for embedding in a JSON document (no quotes).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// A parsed JSON value. Object keys keep insertion order (a `Vec` of
/// pairs, not a map) — duplicate keys resolve to the first match in
/// [`Json::get`].
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document; trailing non-whitespace is an
    /// error.
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(format!(
                "trailing garbage at byte {} of {}",
                p.i,
                b.len()
            ));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => {
                kv.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}",
                c as char, self.i
            ))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("short \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(
                                char::from_u32(code)
                                    .unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+'
                           || c == b'-')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| "bad number")?;
        let n: f64 = txt
            .parse()
            .map_err(|_| format!("bad number '{txt}'"))?;
        if !n.is_finite() {
            return Err(format!("non-finite number '{txt}'"));
        }
        Ok(Json::Num(n))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("bad array at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("bad object at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_documents() {
        let v = Json::parse(
            r#"{"a":[1,2.5,-3e2],"b":{"c":null,"d":true},"s":"x\ny"}"#,
        )
        .unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].as_f64(), Some(-300.0));
        assert!(v.get("b").unwrap().get("c").unwrap().is_null());
        assert_eq!(v.get("s").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn as_bool_is_strict() {
        let v = Json::parse(r#"{"t":true,"f":false,"n":1}"#).unwrap();
        assert_eq!(v.get("t").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("f").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("n").unwrap().as_bool(), None);
    }

    #[test]
    fn rejects_nan_and_garbage() {
        assert!(Json::parse("{\"x\":NaN}").is_err());
        assert!(Json::parse("{\"x\":1} trailing").is_err());
        assert!(Json::parse("{\"x\":}").is_err());
        assert!(Json::parse("[1,2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn escape_round_trips_through_parse() {
        let raw = "he said \"hi\"\n\tand \\ left\u{1}";
        let doc = format!("{{\"k\":\"{}\"}}", escape(raw));
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some(raw));
    }
}
