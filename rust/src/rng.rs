//! Deterministic pseudo-random generation (no `rand` crate offline).
//!
//! xoshiro256++ core with Box-Muller normals. Every stochastic component
//! in the pipeline (init, data synthesis, BO candidate sampling) takes an
//! explicit `Rng` so experiments are reproducible from a single seed.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-component seeding).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Fill a slice with N(0, std^2) samples.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for x in buf.iter_mut() {
            *x = self.normal_f32(std);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(11);
        let k = r.choose_k(20, 8);
        assert_eq!(k.len(), 8);
        let mut s = k.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let w = [0.0, 0.0, 1.0];
        for _ in 0..100 {
            assert_eq!(r.categorical(&w), 2);
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
