//! QPruner — probabilistic decision quantization for structured pruning in LLMs.
//!
//! Rust + JAX + Pallas reproduction of "QPruner: Probabilistic Decision
//! Quantization for Structured Pruning in Large Language Models"
//! (NAACL 2025 Findings).
//!
//! Layer 3 (this crate) owns the full pipeline: structured pruning,
//! mixed-precision quantization, mutual-information bit allocation,
//! Bayesian-optimization refinement, LoRA/LoftQ fine-tuning and
//! zero-shot evaluation. Layers 2 (JAX model) and 1 (Pallas kernels)
//! are compiled once to HLO-text artifacts by `python/compile/aot.py`
//! and executed from Rust through PJRT (`runtime` module). Python is
//! never on the runtime path.

// Host-side stand-in for the PJRT `xla` crate (not vendored offline);
// see xla_stub.rs and runtime.rs for the swap instructions.
mod xla_stub;

pub mod rng;
pub mod artifact;
pub mod tensor;
pub mod parallel;
pub mod linalg;
pub mod quant;
pub mod model;
pub mod pruning;
pub mod mi;
pub mod bo;
pub mod lora;
pub mod data;
pub mod memory;
pub mod config;
pub mod report;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod finetune;
pub mod eval;
pub mod coordinator;
pub mod experiments;
pub mod serve;
pub mod server;
