//! Std-only persistent thread pool for the serving decode hot path.
//!
//! The offline build bans external crates (no rayon), so this module
//! provides the minimal fork/join primitive the fused decode kernels
//! need: a pool of persistent workers plus [`ThreadPool::run`], which
//! hands every worker one *lane* index and blocks until all lanes
//! finish. Work is partitioned **statically** via [`chunk_range`] —
//! each output element is computed by exactly one lane with a fixed
//! inner accumulation order, so results are bit-identical across
//! thread counts (the determinism invariant `tests/parity_decode.rs`
//! pins down: 1 vs 2 vs 8 workers produce the same logits).
//!
//! Design notes:
//!
//! * workers park on a condvar between jobs — no spinning, and a pool
//!   constructed once per engine costs nothing while idle;
//! * `run` borrows its closure for the duration of the call only (the
//!   lifetime is erased to hand it to the workers, and the submitter
//!   does not return until every worker has finished — the standard
//!   scoped-pool argument);
//! * submissions are serialized by a submitter lock, so a pool shared
//!   by several engines (or several tests) is safe, just not
//!   concurrent;
//! * `threads == 1` short-circuits to an inline call: a single-lane
//!   pool spawns no threads at all and is exactly the serial kernel.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Instant;

/// Lifetime-erased reference to the job closure. Safety: only called
/// by workers between job publication and the final `active == 0`
/// handshake, a window during which `ThreadPool::run` keeps the real
/// closure alive on the submitter's stack (the `'static` is a lie the
/// handshake makes honest — the standard scoped-pool argument).
#[derive(Clone, Copy)]
struct Job(&'static (dyn Fn(usize) + Sync));

struct PoolState {
    /// bumped once per published job; workers run each epoch once
    epoch: u64,
    job: Option<Job>,
    /// workers still executing the current epoch
    active: usize,
    /// a worker lane's job panicked (caught; re-raised by `run`)
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// workers wait here for a new epoch
    work_cv: Condvar,
    /// the submitter waits here for `active == 0`
    done_cv: Condvar,
    /// when set, lanes accumulate per-job busy time into `busy_ns` —
    /// telemetry for the serving profiler (`obs::PhaseProfiler`),
    /// toggled only around *sampled* decode steps so the default cost
    /// is one relaxed load per job per lane
    profile: AtomicBool,
    /// per-lane cumulative busy nanoseconds (index = lane)
    busy_ns: Vec<AtomicU64>,
}

impl Shared {
    /// Run one lane's job, timing it when profiling is on. Relaxed
    /// atomics throughout: the counters are telemetry, never part of
    /// the fork/join handshake, and never read by the kernels — so
    /// profiling cannot perturb results (logits stay bit-identical
    /// with it on).
    fn run_lane(&self, lane: usize, f: &(dyn Fn(usize) + Sync)) {
        if self.profile.load(Ordering::Relaxed) {
            let t0 = Instant::now();
            f(lane);
            self.busy_ns[lane].fetch_add(
                t0.elapsed().as_nanos() as u64,
                Ordering::Relaxed,
            );
        } else {
            f(lane);
        }
    }
}

/// Persistent fork/join pool; see the module docs.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// lane submissions are serialized through this (a pool is shared,
    /// not concurrent)
    submit: Mutex<()>,
    threads: usize,
}

impl ThreadPool {
    /// Pool with `threads` total lanes: the calling thread runs lane 0
    /// and `threads - 1` spawned workers run lanes `1..threads`.
    /// `threads` is clamped to at least 1.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            profile: AtomicBool::new(false),
            busy_ns: (0..threads).map(|_| AtomicU64::new(0)).collect(),
        });
        let handles = (1..threads)
            .map(|lane| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("qpruner-pool-{lane}"))
                    .spawn(move || worker_loop(&shared, lane))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, handles, submit: Mutex::new(()), threads }
    }

    /// Total lanes (including the caller's lane 0).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(lane)` once for every lane in `0..threads()`,
    /// returning after all lanes finish. The caller runs lane 0; the
    /// workers run the rest concurrently. `f` must partition its work
    /// by lane (see [`chunk_range`]) — the pool does no splitting
    /// itself.
    ///
    /// Panic behavior: a panic on any lane is contained — worker
    /// panics are caught and re-raised here after the join; a panic on
    /// the caller's lane unwinds only after every worker has finished
    /// (the drop guard below), so the lifetime-erased closure and the
    /// buffers it writes are never freed while a lane still runs.
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.threads == 1 {
            self.shared.run_lane(0, f);
            return;
        }
        let _serial = self.submit.lock().unwrap();
        // SAFETY: the 'static is fiction — see `Job`. Every worker
        // finishes (active == 0, enforced by `JoinGuard` even on
        // unwind) before this frame returns, so the closure is alive
        // whenever a worker calls it.
        let job = Job(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync),
                                  &'static (dyn Fn(usize) + Sync)>(f)
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none() && st.active == 0);
            st.job = Some(job);
            st.epoch += 1;
            st.active = self.threads - 1;
            // a stale flag can survive a run whose caller lane also
            // panicked (the check below is skipped by the unwind);
            // clear it so this job can't inherit a prior job's panic
            st.panicked = false;
            self.shared.work_cv.notify_all();
        }
        // joins (and unpublishes the job) on both the normal path and
        // the unwind path of f(0)
        let guard = JoinGuard { shared: &self.shared };
        self.shared.run_lane(0, f);
        drop(guard);
        let mut st = self.shared.state.lock().unwrap();
        if std::mem::take(&mut st.panicked) {
            drop(st);
            panic!("qpruner thread pool: a worker lane panicked");
        }
    }

    /// Toggle per-lane busy-time accounting. The serving profiler
    /// turns this on only for sampled decode steps; on a pool shared
    /// between engines the counters aggregate across them (documented
    /// telemetry semantics — lane *utilization*, not attribution).
    pub fn set_profiling(&self, on: bool) {
        self.shared.profile.store(on, Ordering::Relaxed);
    }

    pub fn profiling(&self) -> bool {
        self.shared.profile.load(Ordering::Relaxed)
    }

    /// Cumulative busy nanoseconds per lane while profiling was on.
    pub fn lane_busy_ns(&self) -> Vec<u64> {
        self.shared
            .busy_ns
            .iter()
            .map(|a| a.load(Ordering::Relaxed))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Blocks until the current epoch's workers all report done, then
/// unpublishes the job — in `Drop` so the join happens even when the
/// submitter's own lane unwinds (no lane may outlive the closure).
struct JoinGuard<'a> {
    shared: &'a Shared,
}

impl Drop for JoinGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
    }
}

fn worker_loop(shared: &Shared, lane: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    if let Some(job) = st.job {
                        seen = st.epoch;
                        break job;
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // the submitter blocks until we report done — see `Job`. A
        // panic is caught so `active` always reaches 0 (no deadlocked
        // submitter, no poisoned lock); `run` re-raises it.
        let poisoned = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                shared.run_lane(lane, job.0)
            }),
        )
        .is_err();
        let mut st = shared.state.lock().unwrap();
        if poisoned {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_one();
        }
    }
}

/// Contiguous slice of `0..n` owned by `lane` out of `lanes` — the
/// static partition every parallel kernel uses. Balanced to within one
/// item; empty for lanes beyond `n`. Deterministic: the mapping
/// depends only on `(n, lane, lanes)`, and because each item is
/// processed by exactly one lane with an order fixed by the kernel,
/// *results* do not depend on `lanes` at all.
pub fn chunk_range(n: usize, lane: usize, lanes: usize)
                   -> std::ops::Range<usize> {
    debug_assert!(lane < lanes);
    let base = n / lanes;
    let extra = n % lanes;
    let lo = lane * base + lane.min(extra);
    let hi = lo + base + usize::from(lane < extra);
    lo..hi.min(n)
}

/// Shareable raw pointer into an `f32` buffer, for parallel kernels
/// whose lanes write *disjoint* index sets of one output slice (e.g.
/// interleaved columns of a row-major `[m, n]` matrix, or per-session
/// regions of a workspace buffer).
///
/// Safety contract for [`SyncPtr::slice_mut`]: callers must guarantee
/// (1) the pointed-to buffer outlives the parallel region, and (2) no
/// two lanes touch overlapping ranges. Both are enforced structurally
/// by the kernels in `linalg.rs` / `serve/engine.rs` (partitions come
/// from [`chunk_range`] or per-session offsets).
#[derive(Clone, Copy)]
pub struct SyncPtr(*mut f32);

unsafe impl Send for SyncPtr {}
unsafe impl Sync for SyncPtr {}

impl SyncPtr {
    pub fn new(buf: &mut [f32]) -> SyncPtr {
        SyncPtr(buf.as_mut_ptr())
    }

    /// `&mut buf[off..off + len]` without a borrow — see the struct
    /// docs for the aliasing contract.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, off: usize, len: usize)
                            -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.0.add(off), len)
    }

    /// Write one element; same contract as [`SyncPtr::slice_mut`].
    pub unsafe fn write(&self, idx: usize, v: f32) {
        *self.0.add(idx) = v;
    }
}

/// Lane count for auto-configured pools: `available_parallelism`,
/// falling back to 1 when the host refuses to say.
pub fn auto_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Process-wide shared pool, sized by [`auto_threads`] on first use.
/// Engines built without an explicit `--threads` override share it;
/// tests that need a specific lane count construct their own pools.
pub fn shared() -> Arc<ThreadPool> {
    static POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
    Arc::clone(
        POOL.get_or_init(|| Arc::new(ThreadPool::new(auto_threads()))),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn every_lane_runs_exactly_once() {
        for threads in [1usize, 2, 5] {
            let pool = ThreadPool::new(threads);
            let hits: Vec<AtomicUsize> =
                (0..threads).map(|_| AtomicUsize::new(0)).collect();
            pool.run(&|lane| {
                hits[lane].fetch_add(1, Ordering::SeqCst);
            });
            for (lane, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1,
                           "lane {lane} at {threads} threads");
            }
        }
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let pool = ThreadPool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(&|_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 150);
    }

    #[test]
    fn chunk_ranges_tile_exactly() {
        for n in [0usize, 1, 5, 7, 64, 100] {
            for lanes in [1usize, 2, 3, 8, 13] {
                let mut seen = vec![0u8; n];
                for lane in 0..lanes {
                    for i in chunk_range(n, lane, lanes) {
                        seen[i] += 1;
                    }
                }
                assert!(seen.iter().all(|&c| c == 1),
                        "n={n} lanes={lanes}: {seen:?}");
            }
        }
    }

    #[test]
    fn chunked_parallel_sum_matches_serial() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        let mut out = vec![0.0f32; xs.len()];
        let pool = ThreadPool::new(4);
        let lanes = pool.threads();
        let ptr = SyncPtr::new(&mut out);
        pool.run(&|lane| {
            for i in chunk_range(xs.len(), lane, lanes) {
                unsafe { ptr.write(i, xs[i] * 2.0) };
            }
        });
        for (i, (&x, &o)) in xs.iter().zip(&out).enumerate() {
            assert_eq!(o, x * 2.0, "index {i}");
        }
    }

    #[test]
    fn worker_panic_is_contained_and_reraised() {
        let pool = ThreadPool::new(3);
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.run(&|lane| {
                    if lane == 1 {
                        panic!("boom on a worker lane");
                    }
                });
            }),
        );
        assert!(r.is_err(), "worker panic was swallowed");
        // the pool joins cleanly and stays usable afterwards
        let total = AtomicUsize::new(0);
        pool.run(&|_| {
            total.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn submitter_panic_still_joins_workers() {
        let pool = ThreadPool::new(3);
        let r = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.run(&|lane| {
                    if lane == 0 {
                        panic!("boom on the caller lane");
                    }
                });
            }),
        );
        assert!(r.is_err());
        // JoinGuard waited out the workers during the unwind: a new
        // job runs every lane exactly once
        let total = AtomicUsize::new(0);
        pool.run(&|_| {
            total.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.threads(), 1);
        let ran = AtomicUsize::new(0);
        pool.run(&|lane| {
            assert_eq!(lane, 0);
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn lane_profiling_accumulates_only_when_on() {
        for threads in [1usize, 3] {
            let pool = ThreadPool::new(threads);
            let spin = |_lane: usize| {
                let t0 = std::time::Instant::now();
                while t0.elapsed().as_micros() < 200 {
                    std::hint::spin_loop();
                }
            };
            assert!(!pool.profiling());
            pool.run(&spin);
            assert!(
                pool.lane_busy_ns().iter().all(|&n| n == 0),
                "accounted while profiling was off"
            );
            pool.set_profiling(true);
            pool.run(&spin);
            pool.set_profiling(false);
            let busy = pool.lane_busy_ns();
            assert_eq!(busy.len(), threads);
            assert!(
                busy.iter().all(|&n| n >= 100_000),
                "lane busy time missing: {busy:?}"
            );
            // toggling off freezes the counters
            pool.run(&spin);
            assert_eq!(pool.lane_busy_ns(), busy);
        }
    }

    #[test]
    fn shared_pool_is_a_singleton() {
        let a = shared();
        let b = shared();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.threads() >= 1);
    }
}
