//! Recovery fine-tuning driver (paper §3.3).
//!
//! Runs the AOT `train_{size}_r{rate}` artifact: K AdamW steps on the
//! LoRA adapters are fused into one scanned XLA call (the frozen base
//! weights cross the PJRT boundary once per call, the optimizer state
//! round-trips as literals). The base stays frozen — and, when
//! quantized, *stays quantized*: what crosses the boundary is the
//! simulated-dequantized matrix, exactly the QLoRA compute model.

use crate::data::CorpusStream;
use crate::lora::LoraState;
use crate::metrics::LossCurve;
use crate::model::ParamStore;
use crate::runtime::{tensor_f32, Arg, Runtime};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Optimizer + adapter state threaded through train calls.
pub struct FinetuneState {
    pub lora: LoraState,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub t: f32,
    pub steps_done: u64,
    pub curve: LossCurve,
}

impl FinetuneState {
    pub fn new(lora: LoraState) -> FinetuneState {
        let m = lora.tensors.iter().map(|t| Tensor::zeros(t.shape())).collect();
        let v = lora.tensors.iter().map(|t| Tensor::zeros(t.shape())).collect();
        FinetuneState { lora, m, v, t: 0.0, steps_done: 0, curve: LossCurve::default() }
    }
}

/// Hyper-parameters of one recovery run.
#[derive(Clone, Debug)]
pub struct FinetuneOpts {
    pub steps: usize,
    pub lr: f32,
    /// linear warmup steps (paper uses a short warmup)
    pub warmup: usize,
    pub seed: u64,
}

impl Default for FinetuneOpts {
    fn default() -> Self {
        FinetuneOpts { steps: 64, lr: 3e-4, warmup: 8, seed: 1234 }
    }
}

/// Artifact tag for a (size, rate) pair, e.g. "train_base_r20".
pub fn train_artifact(size: &str, rate_pct: u32) -> String {
    format!("train_{size}_r{rate_pct}")
}

/// Fine-tune `state` on `stream` for `opts.steps` steps (rounded up to
/// whole scan calls). Returns per-step losses in `state.curve`.
pub fn finetune(
    rt: &mut Runtime,
    base: &ParamStore,
    state: &mut FinetuneState,
    stream: &mut CorpusStream,
    opts: &FinetuneOpts,
) -> Result<()> {
    let cfg = &base.cfg;
    let name = train_artifact(&cfg.name, base.ps.rate_pct);
    let k = cfg.scan_steps;
    let calls = opts.steps.div_ceil(k);
    let token_shape = [k, cfg.batch, cfg.seq + 1];

    // NOTE(§Perf): a device-resident-buffer prefix via execute_b was
    // tried and reverted — the PJRT CPU client consumes input buffers
    // on execute, so reuse across scan windows is unsound (see
    // EXPERIMENTS.md §Perf entry 3). Literals are copied per call.
    for _ in 0..calls {
        let tokens = stream.next_block(k, cfg.batch, cfg.seq + 1);
        // lr schedule: linear warmup then constant (evaluated at the
        // first step of the scan window; fine at our K)
        let step = state.steps_done as f32;
        let lr = if (state.steps_done as usize) < opts.warmup {
            opts.lr * (step + 1.0) / opts.warmup as f32
        } else {
            opts.lr
        };

        let mut args: Vec<Arg> = Vec::with_capacity(12 + 3 * 14 + 3);
        for w in &base.weights {
            args.push(Arg::F32(w));
        }
        for t in &state.lora.tensors {
            args.push(Arg::F32(t));
        }
        for t in &state.m {
            args.push(Arg::F32(t));
        }
        for t in &state.v {
            args.push(Arg::F32(t));
        }
        args.push(Arg::Scalar(state.t));
        args.push(Arg::I32(&tokens, &token_shape));
        args.push(Arg::Scalar(lr));

        let out = rt.exec(&name, &args)?;
        ensure!(out.len() == 1 + 3 * 14 + 1, "train output arity {}", out.len());
        let losses = tensor_f32(&out[0])?;
        for (i, &l) in losses.data().iter().enumerate() {
            state.curve.push(state.steps_done + i as u64 + 1, l);
        }
        for i in 0..14 {
            state.lora.tensors[i] = tensor_f32(&out[1 + i])?;
            state.m[i] = tensor_f32(&out[1 + 14 + i])?;
            state.v[i] = tensor_f32(&out[1 + 28 + i])?;
        }
        state.t = tensor_f32(&out[1 + 42])?.item();
        state.steps_done += k as u64;
    }
    Ok(())
}

/// Held-out LM loss via the evalloss artifact.
pub fn eval_loss(
    rt: &mut Runtime,
    base: &ParamStore,
    lora: &LoraState,
    tokens: &[i32],
) -> Result<f32> {
    let cfg = &base.cfg;
    let name = format!("evalloss_{}_r{}", cfg.name, base.ps.rate_pct);
    let shape = [cfg.batch, cfg.seq + 1];
    ensure!(tokens.len() == shape[0] * shape[1], "evalloss token len");
    let mut args: Vec<Arg> = Vec::new();
    for w in &base.weights {
        args.push(Arg::F32(w));
    }
    for t in &lora.tensors {
        args.push(Arg::F32(t));
    }
    args.push(Arg::I32(tokens, &shape));
    let out = rt.exec_f32(&name, &args)?;
    Ok(out[0].item())
}

/// Loss + weight gradients for Taylor importance (grads artifact).
pub fn weight_grads(
    rt: &mut Runtime,
    base: &ParamStore,
    lora: &LoraState,
    tokens: &[i32],
) -> Result<(f32, Vec<Tensor>)> {
    let cfg = &base.cfg;
    let name = format!("grads_{}_r{}", cfg.name, base.ps.rate_pct);
    let shape = [cfg.batch, cfg.seq + 1];
    ensure!(tokens.len() == shape[0] * shape[1], "grads token len");
    let mut args: Vec<Arg> = Vec::new();
    for w in &base.weights {
        args.push(Arg::F32(w));
    }
    for t in &lora.tensors {
        args.push(Arg::F32(t));
    }
    args.push(Arg::I32(tokens, &shape));
    let out = rt.exec_f32(&name, &args)?;
    ensure!(out.len() == 13, "grads output arity {}", out.len());
    let loss = out[0].item();
    Ok((loss, out[1..].to_vec()))
}

/// Calibration pass: per-layer pooled hiddens + last-position logits
/// (feeds the MI allocator).
pub fn calibrate(
    rt: &mut Runtime,
    base: &ParamStore,
    lora: &LoraState,
    tokens: &[i32],
) -> Result<(Tensor, Tensor)> {
    let cfg = &base.cfg;
    let name = format!("calib_{}_r{}", cfg.name, base.ps.rate_pct);
    let shape = [cfg.batch, cfg.seq];
    ensure!(tokens.len() == shape[0] * shape[1], "calib token len");
    let mut args: Vec<Arg> = Vec::new();
    for w in &base.weights {
        args.push(Arg::F32(w));
    }
    for t in &lora.tensors {
        args.push(Arg::F32(t));
    }
    args.push(Arg::I32(tokens, &shape));
    let mut out = rt.exec_f32(&name, &args)?;
    ensure!(out.len() == 2, "calib output arity {}", out.len());
    let logits = out.pop().unwrap();
    let pooled = out.pop().unwrap();
    Ok((pooled, logits))
}
