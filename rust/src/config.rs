//! Experiment configuration + CLI parsing (serde/clap are not vendored
//! offline; this is a deliberately small key=value system).
//!
//! Configs load from TOML-subset files (`key = value` lines, `#`
//! comments, [section] headers flattened to `section.key`) and/or
//! `--key value` CLI overrides, in that order.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Flat string-map configuration with typed getters.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Config {
        Config::default()
    }

    /// Parse a TOML-subset string.
    pub fn from_str_content(content: &str) -> Result<Config> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in content.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("config line {}: expected key = value, got {raw:?}",
                      ln + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = v.trim().trim_matches('"').to_string();
            map.insert(key, val);
        }
        Ok(Config { map })
    }

    pub fn from_file(path: &Path) -> Result<Config> {
        let content = std::fs::read_to_string(path)
            .with_context(|| format!("read config {path:?}"))?;
        Self::from_str_content(&content)
    }

    /// Apply `--key value` (or `--key=value`) CLI overrides. Returns
    /// positional (non-flag) arguments.
    pub fn apply_cli(&mut self, args: &[String]) -> Result<Vec<String>> {
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    self.map.insert(k.to_string(), v.to_string());
                } else {
                    if i + 1 >= args.len() {
                        bail!("flag --{stripped} expects a value");
                    }
                    self.map.insert(stripped.to_string(),
                                    args[i + 1].clone());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(positional)
    }

    pub fn set(&mut self, key: &str, val: &str) {
        self.map.insert(key.to_string(), val.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}={v}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}={v}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{key}={v}")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("{key}={v}: expected bool"),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    /// Resolve the shared `--scale smoke|paper` fidelity choice into a
    /// preset pair — the one helper behind every subcommand arm
    /// (`Scale::{smoke,paper}`, `ServeOpts::{smoke,paper}`, ...)
    /// instead of a copy-pasted match per arm.
    pub fn scale_preset<T>(&self, smoke: impl FnOnce() -> T,
                           paper: impl FnOnce() -> T) -> T {
        match self.str_or("scale", "paper").as_str() {
            "smoke" => smoke(),
            _ => paper(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_toml_subset() {
        let c = Config::from_str_content(
            "# comment\nsize = base\n[bo]\niters = 40 # inline\nfrac8 = 0.25\n",
        )
        .unwrap();
        assert_eq!(c.get("size"), Some("base"));
        assert_eq!(c.usize_or("bo.iters", 0).unwrap(), 40);
        assert!((c.f64_or("bo.frac8", 0.0).unwrap() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::from_str_content("just words\n").is_err());
    }

    #[test]
    fn cli_overrides_and_positional() {
        let mut c = Config::from_str_content("size = tiny\n").unwrap();
        let pos = c
            .apply_cli(&[
                "run".into(),
                "--size".into(),
                "base".into(),
                "--bo.iters=12".into(),
            ])
            .unwrap();
        assert_eq!(pos, vec!["run"]);
        assert_eq!(c.get("size"), Some("base"));
        assert_eq!(c.usize_or("bo.iters", 0).unwrap(), 12);
    }

    #[test]
    fn missing_flag_value_errors() {
        let mut c = Config::new();
        assert!(c.apply_cli(&["--oops".into()]).is_err());
    }

    #[test]
    fn typed_getters_validate() {
        let c = Config::from_str_content("n = abc\n").unwrap();
        assert!(c.usize_or("n", 1).is_err());
        assert_eq!(c.usize_or("missing", 7).unwrap(), 7);
        let b = Config::from_str_content("flag = yes\n").unwrap();
        assert!(b.bool_or("flag", false).unwrap());
    }

    #[test]
    fn scale_preset_picks_smoke_or_paper() {
        let c = Config::from_str_content("scale = smoke\n").unwrap();
        assert_eq!(c.scale_preset(|| 1, || 2), 1);
        let c = Config::from_str_content("scale = paper\n").unwrap();
        assert_eq!(c.scale_preset(|| 1, || 2), 2);
        // default (unset) is paper fidelity
        assert_eq!(Config::new().scale_preset(|| 1, || 2), 2);
    }
}
