//! qpruner CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   pretrain   pretrain a corpus checkpoint (the LLaMA/Vicuna stand-in)
//!   run        one QPruner pipeline run (prune -> quantize -> BO ->
//!              fine-tune -> eval) with a table-style summary
//!   export     run the pipeline and write the deployable ModelArtifact
//!              (native-encoded quantized base + trained LoRA deltas)
//!   table1 | table2 | table3 | fig1 | fig3
//!              regenerate a paper table/figure (writes results/)
//!   serve      synthetic multi-client serving run over a pruned +
//!              quantized checkpoint or an exported --artifact
//!              (continuous batching, KV pool)
//!   serve-http std-only HTTP front-end over the same serving stack:
//!              POST /v1/generate (SSE streaming), GET /metrics,
//!              GET /traces, GET /healthz, POST /admin/reload
//!              (artifact hot-swap); SIGTERM drains gracefully
//!   bench-serve
//!              closed-loop load generator: p50/p95/p99 latency,
//!              tokens/sec, batch occupancy, rejection rate
//!              (writes results/bench_serve.md + BENCH_serve.json)
//!   quantize   per-format round-trip error analysis on a checkpoint
//!   info       artifact + runtime environment report

use anyhow::{bail, Context, Result};
use qpruner::config::Config;
use qpruner::coordinator::{Method, PipelineOpts};
use qpruner::experiments::{self, Scale};
use qpruner::lora::InitMethod;
use qpruner::model::ModelConfig;
use qpruner::pruning::TaylorOrder;
use qpruner::quant::QuantFormat;
use qpruner::report::scatter_csv;
use std::path::PathBuf;

fn usage() -> ! {
    eprintln!(
        "usage: qpruner <cmd> [--key value ...]\n\
         cmds: pretrain | run | export | table1 | table2 | table3 |\n\
               fig1 | fig3 | serve | serve-http | bench-serve |\n\
               trace-check | quantize | info\n\
         common flags:\n\
           --size tiny|small|base       model preset   (default small)\n\
           --style llama|vicuna         corpus dialect (default llama)\n\
           --ckpt-dir DIR               checkpoints    (default checkpoints)\n\
           --out-dir DIR                results        (default results)\n\
           --scale smoke|paper          harness fidelity (default paper)\n\
         run / export flags:\n\
           --rate 20 --method q3 --four-bit nf4|fp4 --init loftq1|gaussian|pissa\n\
           --taylor first|second --steps N --bo-iters N --seed N\n\
           --out PATH                   (export) artifact path, default\n\
                                        CKPT_DIR/SIZE_STYLE_METHOD_rRATE.qpart\n\
           --deploy-only true           (export) skip the AOT pipeline:\n\
                                        quantize the checkpoint per\n\
                                        --quant/--bits + LoftQ adapters\n\
         serve / bench-serve flags:\n\
           --artifact PATH              boot an exported ModelArtifact\n\
                                        (pruned+quantized+LoRA) instead\n\
                                        of a raw checkpoint\n\
           --lora merge|adjoin          LoRA deployment override: fold\n\
                                        s*BA into the base at build, or\n\
                                        keep a low-rank decode side path\n\
           --clients N                  concurrent closed-loop clients\n\
           --requests N                 total requests to issue\n\
           --max-batch N                continuous-batching cap per step\n\
           --kv-budget-gb G             modeled KV-cache budget (default:\n\
                                        device headroom after weights)\n\
           --seed N                     workload + sampling seed\n\
           --quant fp16|nf4|fp4|int8    uniform deployment precision\n\
           --bits STR                   per-layer precision, e.g. 8444\n\
           --kv-bits 32|8               KV-cache precision (int8 KV\n\
                                        admits ~3.8x the sessions)\n\
           --kv-layout slab|paged       KV pool layout: whole-slab\n\
                                        reservations, or fixed-size\n\
                                        pages with copy-on-write\n\
                                        prompt-prefix sharing\n\
           --page-tokens N              page capacity in tokens\n\
                                        (paged layout, default 64)\n\
           --shared-prefix N            prepend N shared tokens to\n\
                                        every prompt (synthetic system\n\
                                        prompt; exercises the prefix\n\
                                        cache, 0 = off)\n\
           --compact off|starve|thresh=P  page compaction policy: run\n\
                                        on admit-time page starvation,\n\
                                        or whenever the fragmentation\n\
                                        fraction reaches P; any mode\n\
                                        also enables sub-page prefix\n\
                                        matching (default off)\n\
           --threads N                  decode thread-pool lanes\n\
                                        (default: all cores; results\n\
                                        are identical at any count)\n\
           --device-gb G --max-seq N --max-queue N --ttl-steps N\n\
           --prompt-len LO:HI --max-new LO:HI (request length ranges)\n\
           --stall-prob P --temperature T --memory-arch 7b|13b\n\
         serve observability flags:\n\
           --trace-out PATH             write a Chrome/Perfetto trace\n\
                                        (chrome://tracing or ui.perfetto.dev)\n\
           --events-out PATH            structured JSONL event log\n\
           --metrics-out PATH           metrics-registry JSON snapshot\n\
           --stats-every N              progress line every N scheduler\n\
                                        steps (0 = off)\n\
           --profile-every N            sample every Nth decode step for\n\
                                        the phase profiler (0 = off)\n\
         serve robustness flags:\n\
           --fault-plan SPEC            seeded fault injection, e.g.\n\
                                        seed=42,decode_err=0.01,\n\
                                        page_starve=0.05,client_drop=0.02,\n\
                                        stall_ms=50@0.01,reload_corrupt\n\
                                        (unset = zero overhead)\n\
           --deadline-ms N              default per-request deadline\n\
                                        from admission; expired sessions\n\
                                        are cancelled with partial output\n\
           --brownout true              enable brownout load shedding\n\
                                        with default thresholds\n\
           --brownout-queue-frac F --brownout-occ-frac F\n\
           --brownout-clamp N --brownout-enter-steps N\n\
           --brownout-exit-steps N      (any of these also enables it)\n\
         serve-http flags (plus all serve flags above):\n\
           --addr HOST:PORT             bind address (default\n\
                                        127.0.0.1:8080; port 0 picks\n\
                                        an ephemeral port, printed to\n\
                                        stderr as 'listening on ...')\n\
           --max-conns N                concurrent-connection cap\n\
                                        (default 64; excess gets 503)\n\
           --io-timeout-secs N          socket read/write timeout\n\
                                        (default 10; 0 disables)\n\
           --watchdog-ms N              core-loop heartbeat watchdog;\n\
                                        a missed beat fails /healthz\n\
                                        until beats resume (default\n\
                                        1000; 0 disables)\n\
           endpoints: POST /v1/generate (SSE streaming when\n\
           \"stream\":true), GET /metrics, GET /traces, GET /healthz,\n\
           POST /admin/reload; SIGTERM drains gracefully\n\
         trace-check flags:\n\
           --trace PATH|-               document to validate ('-'\n\
                                        reads stdin)\n\
           --format trace|events|auto   Chrome trace vs JSONL event\n\
                                        log (default auto-detect)\n\
           --min-sessions N             require >= N complete session\n\
                                        spans (default 1)\n\
           --require-phases true|false  require >= 1 phase event\n\
                                        (default true)"
    );
    std::process::exit(2);
}

/// Shared `run` / `export` pipeline-option plumbing: preset from
/// `--rate`/`--method`, fidelity from `--scale`, then per-stage flag
/// overrides mapped onto the stage-scoped option structs.
fn pipeline_opts_from(cfg: &Config, scale: &Scale)
                      -> Result<PipelineOpts> {
    let method = Method::parse(&cfg.str_or("method", "q3"))
        .context("bad --method")?;
    let mut opts =
        PipelineOpts::quick(cfg.usize_or("rate", 20)? as u32, method);
    scale.apply(&mut opts);
    if let Some(fb) = cfg.get("four-bit") {
        opts.quant.four_bit =
            QuantFormat::parse(fb).context("bad --four-bit")?;
    }
    if let Some(init) = cfg.get("init") {
        opts.recover.init =
            InitMethod::parse(init).context("bad --init")?;
    }
    if let Some(t) = cfg.get("taylor") {
        opts.prune.taylor =
            TaylorOrder::parse(t).context("bad --taylor")?;
    }
    opts.recover.finetune.steps =
        cfg.usize_or("steps", opts.recover.finetune.steps)?;
    opts.bo.iters = cfg.usize_or("bo-iters", opts.bo.iters)?;
    opts.seed = cfg.u64_or("seed", opts.seed)?;
    Ok(opts)
}

/// Parse "LO:HI" (or a single "N" meaning N..=N) into an inclusive
/// range pair for the serve workload length flags.
fn parse_range(s: &str) -> Result<(usize, usize)> {
    let (lo, hi) = match s.split_once(':') {
        Some((a, b)) => (a.trim().parse()?, b.trim().parse()?),
        None => {
            let v: usize = s.trim().parse()?;
            (v, v)
        }
    };
    if lo == 0 || lo > hi {
        bail!("bad range {s:?} (expected LO:HI with 1 <= LO <= HI)");
    }
    Ok((lo, hi))
}

/// Everything the serving subcommands (`serve`, `bench-serve`,
/// `serve-http`) share: parsed workload/pool options, the deployment
/// source folded into a pre-configured [`EngineBuilder`], and the
/// engine template the HTTP server re-applies on `/admin/reload`.
struct ServeSetup {
    sopts: qpruner::serve::ServeOpts,
    builder: qpruner::serve::engine::EngineBuilder,
    template: qpruner::server::EngineTemplate,
    model_name: String,
    vocab: usize,
    rate: u32,
    bits: qpruner::quant::BitConfig,
    kv_precision: qpruner::serve::kv_cache::KvPrecision,
}

fn serve_setup(cfg: &Config, ckpt_dir: &std::path::Path, size: &str,
               style: &str, model_cfg: &ModelConfig)
               -> Result<ServeSetup> {
    use qpruner::artifact::{LoraMode, ModelArtifact};
    use qpruner::model::ParamStore;
    use qpruner::quant::BitConfig;
    use qpruner::serve::engine::EngineBuilder;
    use qpruner::serve::kv_cache::KvPrecision;
    use qpruner::serve::{self, ServeOpts};
    use qpruner::server::EngineTemplate;

    let mut sopts =
        cfg.scale_preset(ServeOpts::smoke, ServeOpts::paper);
    sopts.clients = cfg.usize_or("clients", sopts.clients)?;
    sopts.requests = cfg.usize_or("requests", sopts.requests)?;
    sopts.max_batch = cfg.usize_or("max-batch", sopts.max_batch)?;
    if let Some(v) = cfg.get("kv-budget-gb") {
        sopts.kv_budget_gb =
            Some(v.parse().context("bad --kv-budget-gb")?);
    }
    sopts.device_gb = cfg.f64_or("device-gb", sopts.device_gb)?;
    sopts.memory_arch = cfg.str_or("memory-arch", &sopts.memory_arch);
    serve::check_memory_arch(&sopts.memory_arch)
        .context("bad --memory-arch")?;
    sopts.max_seq = cfg.usize_or("max-seq", sopts.max_seq)?;
    if let Some(v) = cfg.get("kv-layout") {
        sopts.kv_layout = qpruner::serve::kv_cache::KvLayout::parse(v)
            .with_context(|| format!(
                "bad --kv-layout {v:?} (expected slab|paged)"
            ))?;
    }
    sopts.page_tokens =
        cfg.usize_or("page-tokens", sopts.page_tokens)?;
    sopts.shared_prefix =
        cfg.usize_or("shared-prefix", sopts.shared_prefix)?;
    if let Some(v) = cfg.get("compact") {
        sopts.compact =
            qpruner::serve::kv_cache::CompactMode::parse(v)
                .with_context(|| format!(
                    "bad --compact {v:?} (expected off|starve|thresh=P)"
                ))?;
    }
    let kv_precision = match cfg.get("kv-bits") {
        None => KvPrecision::F32,
        Some(v) => {
            let bits: u32 =
                v.parse().context("bad --kv-bits (expected 32|8)")?;
            KvPrecision::from_bits(bits).with_context(|| {
                format!("bad --kv-bits {bits} (expected 32|8)")
            })?
        }
    };
    if let Some(v) = cfg.get("prompt-len") {
        sopts.prompt_len =
            parse_range(v).context("bad --prompt-len")?;
    }
    if let Some(v) = cfg.get("max-new") {
        sopts.max_new = parse_range(v).context("bad --max-new")?;
    }
    sopts.max_queue = cfg.usize_or("max-queue", sopts.max_queue)?;
    sopts.ttl_steps = cfg.u64_or("ttl-steps", sopts.ttl_steps)?;
    sopts.stall_prob = cfg.f64_or("stall-prob", sopts.stall_prob)?;
    sopts.temperature =
        cfg.f64_or("temperature", sopts.temperature as f64)? as f32;
    sopts.seed = cfg.u64_or("seed", sopts.seed)?;
    sopts.stats_every =
        cfg.u64_or("stats-every", sopts.stats_every)?;
    sopts.trace_out = cfg.get("trace-out").map(PathBuf::from);
    sopts.events_out = cfg.get("events-out").map(PathBuf::from);
    sopts.metrics_out = cfg.get("metrics-out").map(PathBuf::from);

    // robustness knobs shared by serve / bench-serve / serve-http
    sopts.fault_plan = cfg.get("fault-plan").map(str::to_string);
    if let Some(v) = cfg.get("deadline-ms") {
        let ms: u64 = v.parse().context("bad --deadline-ms")?;
        if ms == 0 {
            bail!("--deadline-ms must be >= 1");
        }
        sopts.deadline_ms = Some(ms);
    }
    // any brownout flag enables brownout with defaults for the rest
    {
        use qpruner::serve::admission::BrownoutConfig;
        let enabled = cfg.bool_or("brownout", false)?
            || [
                "brownout-queue-frac",
                "brownout-occ-frac",
                "brownout-clamp",
                "brownout-enter-steps",
                "brownout-exit-steps",
            ]
            .iter()
            .any(|k| cfg.get(k).is_some());
        if enabled {
            let mut b = BrownoutConfig::default();
            b.queue_frac =
                cfg.f64_or("brownout-queue-frac", b.queue_frac)?;
            b.occ_frac =
                cfg.f64_or("brownout-occ-frac", b.occ_frac)?;
            b.clamp_max_new =
                cfg.usize_or("brownout-clamp", b.clamp_max_new)?;
            b.enter_steps =
                cfg.u64_or("brownout-enter-steps", b.enter_steps)?;
            b.exit_steps =
                cfg.u64_or("brownout-exit-steps", b.exit_steps)?;
            if !(0.0..=1.0).contains(&b.queue_frac)
                || !(0.0..=1.0).contains(&b.occ_frac)
            {
                bail!("brownout fractions must be in [0, 1]");
            }
            sopts.brownout = Some(b);
        }
    }

    // deployment source: an exported artifact boots the pipeline's
    // own pruned+quantized+LoRA deliverable; the checkpoint path
    // quantizes a raw store per --bits/--quant
    let mut template = EngineTemplate::default();
    template.kv_precision = kv_precision;
    let mut builder = EngineBuilder::new().kv_precision(kv_precision);
    if let Some(v) = cfg.get("profile-every") {
        let n: u32 =
            v.parse().context("bad --profile-every (expected N)")?;
        builder = builder.profile_every(n);
        template.profile_every = Some(n);
    }
    if let Some(t) = cfg.get("threads") {
        let n: usize =
            t.parse().context("bad --threads (expected N)")?;
        builder = builder.threads(n);
        template.threads = Some(n);
    }
    if let Some(m) = cfg.get("lora") {
        let mode = LoraMode::parse(m)
            .context("bad --lora (expected merge|adjoin)")?;
        builder = builder.lora(mode);
        template.lora = Some(mode);
    }
    let (model_name, vocab, rate, bits);
    if let Some(p) = cfg.get("artifact") {
        let art = ModelArtifact::load(std::path::Path::new(p))?;
        eprintln!("artifact : {}", art.summary());
        model_name = art.cfg.name.clone();
        vocab = art.cfg.vocab;
        rate = art.ps.rate_pct;
        bits = art.bits.clone();
        builder = builder.artifact(art);
    } else {
        let path =
            experiments::checkpoint_path(ckpt_dir, size, style);
        let store = if path.exists() {
            ParamStore::load(&path)?
        } else {
            eprintln!(
                "no checkpoint at {path:?}; serving a random init \
                 (run `qpruner pretrain` first for a trained model)"
            );
            ParamStore::init(model_cfg, sopts.seed)
        };
        let n_layers = store.cfg.n_layers;
        bits = if let Some(s) = cfg.get("bits") {
            let b = BitConfig::parse_short(s)
                .context("bad --bits (expected e.g. 8444)")?;
            if b.n_layers() != n_layers {
                bail!("--bits has {} layers, model has {n_layers}",
                      b.n_layers());
            }
            b
        } else {
            let fmt = QuantFormat::parse(&cfg.str_or("quant", "nf4"))
                .context("bad --quant")?;
            BitConfig::uniform(n_layers, fmt)
        };
        model_name = store.cfg.name.clone();
        vocab = store.cfg.vocab;
        rate = store.ps.rate_pct;
        builder = builder.store(&store, &bits);
    }
    Ok(ServeSetup {
        sopts,
        builder,
        template,
        model_name,
        vocab,
        rate,
        bits,
        kv_precision,
    })
}

/// The serving banner all three serving subcommands print to stderr —
/// stdout stays clean for the report table / piped payloads.
fn serve_banner(s: &ServeSetup) {
    use qpruner::serve;
    let budget =
        serve::resolve_kv_budget_gb(&s.sopts, s.rate, &s.bits);
    eprintln!(
        "serving {} (rate {}%, bits {}, kv {}-bit, {} layout) — \
         kv budget {:.2} GB on a {:.0} GB {} device",
        s.model_name, s.rate, s.bits.short(),
        s.kv_precision.bits(), s.sopts.kv_layout.label(), budget,
        s.sopts.device_gb, s.sopts.memory_arch
    );
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let mut cfg = Config::new();
    if let Some(path) = args.iter().position(|a| a == "--config") {
        let p = args.get(path + 1).context("--config expects a path")?;
        cfg = Config::from_file(std::path::Path::new(p))?;
    }
    let positional = cfg.apply_cli(&args)?;
    let cmd = positional.first().map(|s| s.as_str()).unwrap_or("");

    let size = cfg.str_or("size", "small");
    let style = cfg.str_or("style", "llama");
    let ckpt_dir = PathBuf::from(cfg.str_or("ckpt-dir", "checkpoints"));
    let out_dir = PathBuf::from(cfg.str_or("out-dir", "results"));
    let model_cfg = ModelConfig::preset(&size)?;
    let scale = cfg.scale_preset(Scale::smoke, Scale::paper);

    match cmd {
        "info" => {
            let coord = experiments::open_coordinator(model_cfg.vocab, &style)?;
            println!("platform : {}", coord.rt.platform());
            println!("artifacts: {:?}", qpruner::runtime::Runtime::default_dir());
            println!("model    : {} ({} params)", model_cfg.name,
                     model_cfg.param_count(&model_cfg.pruned(0)));
            for rate in [0u32, 20, 30, 50] {
                let name = format!("train_{}_r{rate}", model_cfg.name);
                println!("  {} -> {}", name,
                         if coord.rt.has_artifact(&name) { "ok" }
                         else { "MISSING" });
            }
        }
        "pretrain" => {
            let steps = cfg.usize_or("steps", scale.pretrain_steps)?;
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, &style)?;
            let store = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, &style, steps)?;
            println!(
                "checkpoint ready: {:?} ({} params)",
                experiments::checkpoint_path(&ckpt_dir, &size, &style),
                store.total_params()
            );
        }
        "run" => {
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, &style)?;
            let store = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, &style,
                cfg.usize_or("pretrain-steps", scale.pretrain_steps)?)?;
            let opts = pipeline_opts_from(&cfg, &scale)?;
            let res = coord.run(&store, &opts)?;
            println!("method      : {}", res.method.label());
            println!("rate        : {}%", res.rate_pct);
            println!("bits        : {}", res.bits.short());
            println!("trainable   : {}", res.trainable_params);
            for t in &res.tasks {
                println!("  {:<12} {:.2}%", t.name, 100.0 * t.accuracy);
            }
            println!("mean acc    : {:.2}%", 100.0 * res.mean_accuracy);
            println!("memory (GB) : {:.2}", res.memory_gb);
            println!("final loss  : {:.4}", res.curve.tail_mean(8));
            println!("-- stage timings --\n{}", coord.metrics.report());
        }
        "export" => {
            // write the deployable ModelArtifact (native-encoded
            // quantized base + LoRA deltas). Two modes:
            //  * full pipeline (default): prune -> allocate -> BO ->
            //    recovery fine-tune, then export the frozen base +
            //    trained adapters (needs the AOT artifacts);
            //  * --deploy-only: skip the runtime-backed stages —
            //    quantize a checkpoint per --quant/--bits and attach
            //    LoftQ/PiSSA-initialized correction adapters (pure
            //    host math; what CI smokes).
            use qpruner::artifact::{LoraDelta, LoraMode,
                                    ModelArtifact, Provenance};
            use qpruner::model::ParamStore;
            use qpruner::quant::BitConfig;

            let ckpt =
                experiments::checkpoint_path(&ckpt_dir, &size, &style);
            let opts = pipeline_opts_from(&cfg, &scale)?;
            let deploy_only = cfg.bool_or("deploy-only", false)?;
            let (artifact, label) = if deploy_only {
                let store = if ckpt.exists() {
                    ParamStore::load(&ckpt)?
                } else {
                    eprintln!(
                        "no checkpoint at {ckpt:?}; exporting a \
                         random init (run `qpruner pretrain` first)"
                    );
                    ParamStore::init(&model_cfg, opts.seed)
                };
                let bits = if let Some(s) = cfg.get("bits") {
                    let b = BitConfig::parse_short(s)
                        .context("bad --bits (expected e.g. 8444)")?;
                    if b.n_layers() != store.cfg.n_layers {
                        bail!("--bits has {} layers, model has {}",
                              b.n_layers(), store.cfg.n_layers);
                    }
                    b
                } else {
                    let fmt = QuantFormat::parse(
                        &cfg.str_or("quant", "nf4"))
                        .context("bad --quant")?;
                    BitConfig::uniform(store.cfg.n_layers, fmt)
                };
                let mut rng = qpruner::rng::Rng::new(opts.seed);
                let prep = qpruner::lora::prepare(
                    &store, &bits, opts.recover.init, &mut rng)?;
                let art = ModelArtifact::from_pipeline(
                    &prep.base,
                    &bits,
                    Some(LoraDelta::from_state(&prep.lora)),
                    LoraMode::Merge,
                    Provenance {
                        method: format!(
                            "deploy-only:{}",
                            opts.recover.init.label()
                        ),
                        seed: opts.seed,
                        stages: "quantize>adapter-init".into(),
                        source: format!("{}", ckpt.display()),
                    },
                )?;
                (art, format!("deploy-only bits {}", bits.short()))
            } else {
                let mut coord = experiments::open_coordinator(
                    model_cfg.vocab, &style)?;
                let store = experiments::load_or_pretrain(
                    &mut coord, &model_cfg, &ckpt_dir, &style,
                    cfg.usize_or("pretrain-steps",
                                 scale.pretrain_steps)?)?;
                let source = format!("{}", ckpt.display());
                let (res, art) =
                    coord.run_with_artifact(&store, &opts, &source)?;
                println!("mean acc    : {:.2}%",
                         100.0 * res.mean_accuracy);
                (art, format!("{} bits {}", res.method.label(),
                              res.bits.short()))
            };
            let out = match cfg.get("out") {
                Some(p) => PathBuf::from(p),
                None => ckpt_dir.join(format!(
                    "{size}_{style}_{}_r{}.qpart",
                    cfg.str_or("method", "q3"),
                    artifact.ps.rate_pct
                )),
            };
            artifact.save(&out)?;
            println!("export      : {label}");
            println!("artifact    : {}", artifact.summary());
            println!("wrote {out:?}");
            println!("serve it: qpruner serve --artifact {}",
                     out.display());
        }
        "table1" => {
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, "llama")?;
            let llama = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, "llama",
                scale.pretrain_steps)?;
            // the Vicuna stand-in shares the architecture but is trained
            // on the chat-dialect corpus
            let mut coord_v =
                experiments::open_coordinator(model_cfg.vocab, "vicuna")?;
            let vicuna = experiments::load_or_pretrain(
                &mut coord_v, &model_cfg, &ckpt_dir, "vicuna",
                scale.pretrain_steps)?;
            let t = experiments::table1(
                &mut coord, &[("7B-sim", &llama)], &[20, 30, 50], &scale)?;
            let tv = experiments::table1(
                &mut coord_v, &[("7B-chat-sim", &vicuna)], &[20, 30, 50],
                &scale)?;
            let mut combined = t;
            combined.rows.extend(tv.rows);
            combined.save(&out_dir, "table1")?;
            println!("{}", combined.to_markdown());
        }
        "table2" => {
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, &style)?;
            let store = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, &style,
                scale.pretrain_steps)?;
            let t = experiments::table2_ablation(&mut coord, &store, &scale)?;
            t.save(&out_dir, "table2")?;
            println!("{}", t.to_markdown());
        }
        "table3" => {
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, &style)?;
            let store = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, &style,
                scale.pretrain_steps)?;
            let t = experiments::table3_13b(&mut coord, &store, &scale)?;
            t.save(&out_dir, "table3")?;
            println!("{}", t.to_markdown());
        }
        "fig1" => {
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, &style)?;
            let store = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, &style,
                scale.pretrain_steps)?;
            let t = experiments::fig1_motivating(&mut coord, &store, &scale)?;
            t.save(&out_dir, "fig1")?;
            println!("{}", t.to_markdown());
        }
        "fig3" => {
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, &style)?;
            let store = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, &style,
                scale.pretrain_steps)?;
            let n_points = cfg.usize_or("points", 50)?;
            let n_init = cfg.usize_or("init-points", 10)?;
            let rate = cfg.usize_or("rate", 50)? as u32;
            let data = experiments::fig3_pareto(
                &mut coord, &store, rate, n_points, n_init, &scale)?;
            std::fs::create_dir_all(&out_dir)?;
            for (task, rows) in &data.per_task {
                let pts: Vec<(f64, f64, String)> = rows
                    .iter()
                    .map(|(m, p, c, front)| {
                        (*m, *p,
                         format!("{c}{}", if *front { ":front" } else { "" }))
                    })
                    .collect();
                std::fs::write(
                    out_dir.join(format!("fig3_{}.csv",
                                         task.to_lowercase())),
                    scatter_csv(&pts),
                )?;
                let front_n = rows.iter().filter(|r| r.3).count();
                println!("{task}: {} points, {front_n} on the Pareto front",
                         rows.len());
            }
            println!("wrote scatter CSVs to {out_dir:?} ({} evals)",
                     data.n_evals);
        }
        "serve" | "bench-serve" => {
            use qpruner::data::Language;
            use qpruner::metrics::Metrics;
            use qpruner::serve;

            let setup =
                serve_setup(&cfg, &ckpt_dir, &size, &style, &model_cfg)?;
            serve_banner(&setup);
            let ServeSetup { sopts, builder, model_name, vocab, .. } =
                setup;
            let lang =
                Language::new(vocab, experiments::style_seed(&style));
            let mut rt = qpruner::runtime::Runtime::open_default()?;
            let mut metrics = Metrics::new();
            let report = serve::run_workload(&mut rt, builder, &lang,
                                             &sopts, &mut metrics)?;
            let title = format!(
                "{} ({}, {} requests, {} clients, max-batch {})",
                cmd, model_name, sopts.requests, sopts.clients,
                sopts.max_batch
            );
            let t = report.to_table(&title);
            println!("{}", t.to_markdown());
            if cmd == "bench-serve" {
                t.save(&out_dir, "bench_serve")?;
                let lat =
                    report.latency.percentiles_ms(&[50.0, 95.0, 99.0]);
                println!(
                    "BENCH serve tokens_per_sec={:.1} p50={:.3}ms \
                     p95={:.3}ms p99={:.3}ms occupancy={:.2} \
                     reject_rate={:.4}",
                    report.tokens_per_sec(),
                    lat[0],
                    lat[1],
                    lat[2],
                    report.mean_occupancy,
                    report.rejection_rate()
                );
                let cfg_name = format!(
                    "c{}_b{}_kv{}_{}{}",
                    sopts.clients, sopts.max_batch, report.kv_bits,
                    report.lora,
                    if report.kv_layout == "paged" { "_paged" }
                    else { "" }
                );
                std::fs::create_dir_all(&out_dir)?;
                let json_path = out_dir.join("BENCH_serve.json");
                let prev = std::fs::read_to_string(&json_path).ok();
                std::fs::write(
                    &json_path,
                    serve::bench_json_append(prev.as_deref(),
                                             &cfg_name, &report),
                )?;
                println!("wrote {:?}", out_dir.join("bench_serve.md"));
                println!("wrote {json_path:?}");
            }
            // diagnostics go to stderr: piping serve stdout must
            // yield only the report payload
            for (what, path) in [
                ("trace", &sopts.trace_out),
                ("event log", &sopts.events_out),
                ("metrics snapshot", &sopts.metrics_out),
            ] {
                if let Some(p) = path {
                    eprintln!("wrote {what} {p:?}");
                }
            }
            eprintln!("-- stage timings --\n{}", metrics.report());
        }
        "serve-http" => {
            use qpruner::server::{drain, Server, ServerOpts};
            use std::sync::atomic::AtomicBool;
            use std::sync::Arc;

            let setup =
                serve_setup(&cfg, &ckpt_dir, &size, &style, &model_cfg)?;
            serve_banner(&setup);
            let mut srv = ServerOpts::new(setup.sopts.clone());
            srv.addr = cfg.str_or("addr", &srv.addr);
            srv.max_conns =
                cfg.usize_or("max-conns", srv.max_conns)?;
            srv.io_timeout_secs =
                cfg.u64_or("io-timeout-secs", srv.io_timeout_secs)?;
            srv.watchdog_ms =
                cfg.u64_or("watchdog-ms", srv.watchdog_ms)?;
            srv.template = setup.template;
            let mut rt = qpruner::runtime::Runtime::open_default()?;
            let server = Server::bind(&srv.addr)?;
            // scripted clients (CI smoke) poll stderr for this line
            // to learn the resolved ephemeral port
            eprintln!("listening on http://{}", server.local_addr());
            drain::install_signal_handlers();
            let shutdown = Arc::new(AtomicBool::new(false));
            let report =
                server.run(&mut rt, setup.builder, &srv, shutdown)?;
            eprintln!("drained: {}", report.summary());
            for (what, path) in [
                ("trace", &srv.serve.trace_out),
                ("event log", &srv.serve.events_out),
                ("metrics snapshot", &srv.serve.metrics_out),
            ] {
                if let Some(p) = path {
                    eprintln!("wrote {what} {p:?}");
                }
            }
            if !report.clean() {
                bail!("unclean drain: {}", report.summary());
            }
        }
        "trace-check" => {
            // CI gate: the trace a `serve --trace-out` run produced
            // (or the event log `serve-http`'s GET /traces streams)
            // must strict-parse and contain real lifecycle + phase
            // content, not just metadata
            use qpruner::obs::trace_export::{validate_events,
                                             validate_trace};
            let arg = cfg
                .get("trace")
                .context("trace-check needs --trace PATH|-")?;
            let (path, body) = if arg == "-" {
                let mut s = String::new();
                std::io::Read::read_to_string(
                    &mut std::io::stdin(),
                    &mut s,
                )
                .context("reading stdin")?;
                ("<stdin>".to_string(), s)
            } else {
                let b = std::fs::read_to_string(arg)
                    .with_context(|| format!("reading {arg}"))?;
                (arg.to_string(), b)
            };
            let format = cfg.str_or("format", "auto");
            let is_events = match format.as_str() {
                "events" => true,
                "trace" => false,
                // an events log is JSONL whose first record is the
                // meta line; a Chrome trace is one JSON object
                "auto" => body
                    .trim_start()
                    .starts_with("{\"type\":\"meta\""),
                other => bail!(
                    "bad --format {other:?} (expected \
                     trace|events|auto)"
                ),
            };
            let summary = if is_events {
                validate_events(&body)
            } else {
                validate_trace(&body)
            }
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            let min_sessions = cfg.usize_or("min-sessions", 1)?;
            let require_phases = cfg.bool_or("require-phases", true)?;
            println!(
                "{path}: {} events, {} session spans \
                 ({} complete), {} phase events",
                summary.total_events, summary.sessions,
                summary.complete_sessions, summary.phase_events
            );
            if summary.complete_sessions < min_sessions {
                bail!(
                    "{path}: {} complete session span(s), \
                     need >= {min_sessions}",
                    summary.complete_sessions
                );
            }
            if require_phases && summary.phase_events == 0 {
                bail!("{path}: no decode phase events in trace");
            }
            println!("trace OK");
        }
        "quantize" => {
            // per-format round-trip error analysis on a checkpoint:
            // the quantitative backdrop for the paper's {4,8}-bit
            // search space (2/3-bit error explodes; NF4 beats uniform
            // INT4; INT8 is near-lossless).
            use qpruner::model::{proj_index, ParamStore, PROJS};
            use qpruner::quant::{self, QuantFormat};
            use qpruner::report::Table;
            let path = experiments::checkpoint_path(&ckpt_dir, &size, &style);
            let store = if path.exists() {
                ParamStore::load(&path)?
            } else {
                eprintln!("no checkpoint at {path:?}; analyzing random init");
                ParamStore::init(&model_cfg, 0)
            };
            let mut t = Table::new(
                "Quantization error analysis (all projection stacks)",
                &["Format", "bits/param", "RMS err", "Max err",
                  "RMS vs fp16 weight RMS"],
            );
            let mut weight_sq = 0.0f64;
            let mut weight_n = 0usize;
            for p in PROJS {
                let s = &store.weights[proj_index(p)];
                weight_sq +=
                    s.data().iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
                weight_n += s.len();
            }
            let w_rms = (weight_sq / weight_n as f64).sqrt();
            let mut eval_fmt = |label: String, bits: f64,
                                f: &dyn Fn(&qpruner::tensor::Tensor)
                                    -> qpruner::tensor::Tensor,
                                t: &mut Table| {
                let (mut sq, mut mx, mut n) = (0.0f64, 0.0f64, 0usize);
                for p in PROJS {
                    for l in 0..store.cfg.n_layers {
                        let w = store.layer_proj(l, p);
                        let back = f(&w);
                        let (rms, m) = quant::error_stats(&w, &back);
                        sq += rms * rms * w.len() as f64;
                        mx = mx.max(m);
                        n += w.len();
                    }
                }
                let rms = (sq / n as f64).sqrt();
                t.push_row(vec![
                    label,
                    format!("{bits:.2}"),
                    format!("{rms:.5}"),
                    format!("{mx:.5}"),
                    format!("{:.3}", rms / w_rms),
                ]);
            };
            for fmt in [QuantFormat::Int8, QuantFormat::Nf4,
                        QuantFormat::Fp4] {
                eval_fmt(fmt.label().to_string(), fmt.bits_per_param(),
                         &|w| quant::simulate(w, fmt), &mut t);
            }
            for k in [4u32, 3, 2] {
                eval_fmt(
                    format!("uniform-int{k}"),
                    k as f64 + 32.0 / 64.0,
                    &move |w| {
                        quant::dequantize_uniform_k(
                            &quant::quantize_uniform_k(w, k))
                    },
                    &mut t,
                );
            }
            println!("{}", t.to_markdown());
        }
        _ => {
            bail!("unknown command {cmd:?} — run with no args for usage");
        }
    }
    Ok(())
}
