//! qpruner CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   pretrain   pretrain a corpus checkpoint (the LLaMA/Vicuna stand-in)
//!   run        one QPruner pipeline run (prune -> quantize -> BO ->
//!              fine-tune -> eval) with a table-style summary
//!   table1 | table2 | table3 | fig1 | fig3
//!              regenerate a paper table/figure (writes results/)
//!   serve      synthetic multi-client serving run over a pruned +
//!              quantized checkpoint (continuous batching, KV pool)
//!   bench-serve
//!              closed-loop load generator: p50/p95/p99 latency,
//!              tokens/sec, batch occupancy, rejection rate
//!   quantize   per-format round-trip error analysis on a checkpoint
//!   info       artifact + runtime environment report

use anyhow::{bail, Context, Result};
use qpruner::config::Config;
use qpruner::coordinator::{Method, PipelineOpts};
use qpruner::experiments::{self, Scale};
use qpruner::lora::InitMethod;
use qpruner::model::ModelConfig;
use qpruner::pruning::TaylorOrder;
use qpruner::quant::QuantFormat;
use qpruner::report::scatter_csv;
use std::path::PathBuf;

fn usage() -> ! {
    eprintln!(
        "usage: qpruner <cmd> [--key value ...]\n\
         cmds: pretrain | run | table1 | table2 | table3 | fig1 | fig3 |\n\
               serve | bench-serve | quantize | info\n\
         common flags:\n\
           --size tiny|small|base       model preset   (default small)\n\
           --style llama|vicuna         corpus dialect (default llama)\n\
           --ckpt-dir DIR               checkpoints    (default checkpoints)\n\
           --out-dir DIR                results        (default results)\n\
           --scale smoke|paper          harness fidelity (default paper)\n\
         run flags:\n\
           --rate 20 --method q3 --four-bit nf4|fp4 --init loftq1|gaussian|pissa\n\
           --taylor first|second --steps N --bo-iters N --seed N\n\
         serve / bench-serve flags:\n\
           --clients N                  concurrent closed-loop clients\n\
           --requests N                 total requests to issue\n\
           --max-batch N                continuous-batching cap per step\n\
           --kv-budget-gb G             modeled KV-cache budget (default:\n\
                                        device headroom after weights)\n\
           --seed N                     workload + sampling seed\n\
           --quant fp16|nf4|fp4|int8    uniform deployment precision\n\
           --bits STR                   per-layer precision, e.g. 8444\n\
           --kv-bits 32|8               KV-cache precision (int8 KV\n\
                                        admits ~3.8x the sessions)\n\
           --device-gb G --max-seq N --max-queue N --ttl-steps N\n\
           --prompt-len LO:HI --max-new LO:HI (request length ranges)\n\
           --stall-prob P --temperature T --memory-arch 7b|13b"
    );
    std::process::exit(2);
}

/// Parse "LO:HI" (or a single "N" meaning N..=N) into an inclusive
/// range pair for the serve workload length flags.
fn parse_range(s: &str) -> Result<(usize, usize)> {
    let (lo, hi) = match s.split_once(':') {
        Some((a, b)) => (a.trim().parse()?, b.trim().parse()?),
        None => {
            let v: usize = s.trim().parse()?;
            (v, v)
        }
    };
    if lo == 0 || lo > hi {
        bail!("bad range {s:?} (expected LO:HI with 1 <= LO <= HI)");
    }
    Ok((lo, hi))
}

fn scale_of(cfg: &Config) -> Scale {
    match cfg.str_or("scale", "paper").as_str() {
        "smoke" => Scale::smoke(),
        _ => Scale::paper(),
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let mut cfg = Config::new();
    if let Some(path) = args.iter().position(|a| a == "--config") {
        let p = args.get(path + 1).context("--config expects a path")?;
        cfg = Config::from_file(std::path::Path::new(p))?;
    }
    let positional = cfg.apply_cli(&args)?;
    let cmd = positional.first().map(|s| s.as_str()).unwrap_or("");

    let size = cfg.str_or("size", "small");
    let style = cfg.str_or("style", "llama");
    let ckpt_dir = PathBuf::from(cfg.str_or("ckpt-dir", "checkpoints"));
    let out_dir = PathBuf::from(cfg.str_or("out-dir", "results"));
    let model_cfg = ModelConfig::preset(&size)?;
    let scale = scale_of(&cfg);

    match cmd {
        "info" => {
            let coord = experiments::open_coordinator(model_cfg.vocab, &style)?;
            println!("platform : {}", coord.rt.platform());
            println!("artifacts: {:?}", qpruner::runtime::Runtime::default_dir());
            println!("model    : {} ({} params)", model_cfg.name,
                     model_cfg.param_count(&model_cfg.pruned(0)));
            for rate in [0u32, 20, 30, 50] {
                let name = format!("train_{}_r{rate}", model_cfg.name);
                println!("  {} -> {}", name,
                         if coord.rt.has_artifact(&name) { "ok" }
                         else { "MISSING" });
            }
        }
        "pretrain" => {
            let steps = cfg.usize_or("steps", scale.pretrain_steps)?;
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, &style)?;
            let store = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, &style, steps)?;
            println!(
                "checkpoint ready: {:?} ({} params)",
                experiments::checkpoint_path(&ckpt_dir, &size, &style),
                store.total_params()
            );
        }
        "run" => {
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, &style)?;
            let store = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, &style,
                cfg.usize_or("pretrain-steps", scale.pretrain_steps)?)?;
            let method = Method::parse(&cfg.str_or("method", "q3"))
                .context("bad --method")?;
            let mut opts =
                PipelineOpts::quick(cfg.usize_or("rate", 20)? as u32, method);
            scale.apply(&mut opts);
            if let Some(fb) = cfg.get("four-bit") {
                opts.four_bit =
                    QuantFormat::parse(fb).context("bad --four-bit")?;
            }
            if let Some(init) = cfg.get("init") {
                opts.init = InitMethod::parse(init).context("bad --init")?;
            }
            if let Some(t) = cfg.get("taylor") {
                opts.taylor = TaylorOrder::parse(t).context("bad --taylor")?;
            }
            opts.finetune.steps = cfg.usize_or("steps", opts.finetune.steps)?;
            opts.bo_iters = cfg.usize_or("bo-iters", opts.bo_iters)?;
            opts.seed = cfg.u64_or("seed", opts.seed)?;
            let res = coord.run(&store, &opts)?;
            println!("method      : {}", res.method.label());
            println!("rate        : {}%", res.rate_pct);
            println!("bits        : {}", res.bits.short());
            println!("trainable   : {}", res.trainable_params);
            for t in &res.tasks {
                println!("  {:<12} {:.2}%", t.name, 100.0 * t.accuracy);
            }
            println!("mean acc    : {:.2}%", 100.0 * res.mean_accuracy);
            println!("memory (GB) : {:.2}", res.memory_gb);
            println!("final loss  : {:.4}", res.curve.tail_mean(8));
            println!("-- stage timings --\n{}", coord.metrics.report());
        }
        "table1" => {
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, "llama")?;
            let llama = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, "llama",
                scale.pretrain_steps)?;
            // the Vicuna stand-in shares the architecture but is trained
            // on the chat-dialect corpus
            let mut coord_v =
                experiments::open_coordinator(model_cfg.vocab, "vicuna")?;
            let vicuna = experiments::load_or_pretrain(
                &mut coord_v, &model_cfg, &ckpt_dir, "vicuna",
                scale.pretrain_steps)?;
            let t = experiments::table1(
                &mut coord, &[("7B-sim", &llama)], &[20, 30, 50], &scale)?;
            let tv = experiments::table1(
                &mut coord_v, &[("7B-chat-sim", &vicuna)], &[20, 30, 50],
                &scale)?;
            let mut combined = t;
            combined.rows.extend(tv.rows);
            combined.save(&out_dir, "table1")?;
            println!("{}", combined.to_markdown());
        }
        "table2" => {
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, &style)?;
            let store = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, &style,
                scale.pretrain_steps)?;
            let t = experiments::table2_ablation(&mut coord, &store, &scale)?;
            t.save(&out_dir, "table2")?;
            println!("{}", t.to_markdown());
        }
        "table3" => {
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, &style)?;
            let store = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, &style,
                scale.pretrain_steps)?;
            let t = experiments::table3_13b(&mut coord, &store, &scale)?;
            t.save(&out_dir, "table3")?;
            println!("{}", t.to_markdown());
        }
        "fig1" => {
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, &style)?;
            let store = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, &style,
                scale.pretrain_steps)?;
            let t = experiments::fig1_motivating(&mut coord, &store, &scale)?;
            t.save(&out_dir, "fig1")?;
            println!("{}", t.to_markdown());
        }
        "fig3" => {
            let mut coord =
                experiments::open_coordinator(model_cfg.vocab, &style)?;
            let store = experiments::load_or_pretrain(
                &mut coord, &model_cfg, &ckpt_dir, &style,
                scale.pretrain_steps)?;
            let n_points = cfg.usize_or("points", 50)?;
            let n_init = cfg.usize_or("init-points", 10)?;
            let rate = cfg.usize_or("rate", 50)? as u32;
            let data = experiments::fig3_pareto(
                &mut coord, &store, rate, n_points, n_init, &scale)?;
            std::fs::create_dir_all(&out_dir)?;
            for (task, rows) in &data.per_task {
                let pts: Vec<(f64, f64, String)> = rows
                    .iter()
                    .map(|(m, p, c, front)| {
                        (*m, *p,
                         format!("{c}{}", if *front { ":front" } else { "" }))
                    })
                    .collect();
                std::fs::write(
                    out_dir.join(format!("fig3_{}.csv",
                                         task.to_lowercase())),
                    scatter_csv(&pts),
                )?;
                let front_n = rows.iter().filter(|r| r.3).count();
                println!("{task}: {} points, {front_n} on the Pareto front",
                         rows.len());
            }
            println!("wrote scatter CSVs to {out_dir:?} ({} evals)",
                     data.n_evals);
        }
        "serve" | "bench-serve" => {
            use qpruner::data::Language;
            use qpruner::metrics::Metrics;
            use qpruner::model::ParamStore;
            use qpruner::quant::BitConfig;
            use qpruner::serve::{self, ServeOpts};

            let mut sopts = match cfg.str_or("scale", "paper").as_str() {
                "smoke" => ServeOpts::smoke(),
                _ => ServeOpts::paper(),
            };
            sopts.clients = cfg.usize_or("clients", sopts.clients)?;
            sopts.requests = cfg.usize_or("requests", sopts.requests)?;
            sopts.max_batch =
                cfg.usize_or("max-batch", sopts.max_batch)?;
            if let Some(v) = cfg.get("kv-budget-gb") {
                sopts.kv_budget_gb = Some(
                    v.parse().context("bad --kv-budget-gb")?,
                );
            }
            sopts.device_gb = cfg.f64_or("device-gb", sopts.device_gb)?;
            sopts.memory_arch =
                cfg.str_or("memory-arch", &sopts.memory_arch);
            serve::check_memory_arch(&sopts.memory_arch)
                .context("bad --memory-arch")?;
            sopts.max_seq = cfg.usize_or("max-seq", sopts.max_seq)?;
            if let Some(v) = cfg.get("kv-bits") {
                let bits: u32 =
                    v.parse().context("bad --kv-bits (expected 32|8)")?;
                sopts.kv_precision =
                    qpruner::serve::kv_cache::KvPrecision::from_bits(
                        bits,
                    )
                    .with_context(|| {
                        format!("bad --kv-bits {bits} (expected 32|8)")
                    })?;
            }
            if let Some(v) = cfg.get("prompt-len") {
                sopts.prompt_len =
                    parse_range(v).context("bad --prompt-len")?;
            }
            if let Some(v) = cfg.get("max-new") {
                sopts.max_new =
                    parse_range(v).context("bad --max-new")?;
            }
            sopts.max_queue =
                cfg.usize_or("max-queue", sopts.max_queue)?;
            sopts.ttl_steps = cfg.u64_or("ttl-steps", sopts.ttl_steps)?;
            sopts.stall_prob =
                cfg.f64_or("stall-prob", sopts.stall_prob)?;
            sopts.temperature =
                cfg.f64_or("temperature", sopts.temperature as f64)?
                    as f32;
            sopts.seed = cfg.u64_or("seed", sopts.seed)?;

            let path =
                experiments::checkpoint_path(&ckpt_dir, &size, &style);
            let store = if path.exists() {
                ParamStore::load(&path)?
            } else {
                eprintln!(
                    "no checkpoint at {path:?}; serving a random init \
                     (run `qpruner pretrain` first for a trained model)"
                );
                ParamStore::init(&model_cfg, sopts.seed)
            };
            let n_layers = store.cfg.n_layers;
            let bits = if let Some(s) = cfg.get("bits") {
                let b = BitConfig::parse_short(s)
                    .context("bad --bits (expected e.g. 8444)")?;
                if b.n_layers() != n_layers {
                    bail!("--bits has {} layers, model has {n_layers}",
                          b.n_layers());
                }
                b
            } else {
                let fmt = QuantFormat::parse(&cfg.str_or("quant", "nf4"))
                    .context("bad --quant")?;
                BitConfig::uniform(n_layers, fmt)
            };
            let lang = Language::new(store.cfg.vocab,
                                     experiments::style_seed(&style));
            let mut rt = qpruner::runtime::Runtime::open_default()?;
            let mut metrics = Metrics::new();
            let budget =
                serve::resolve_kv_budget_gb(&sopts, store.ps.rate_pct,
                                            &bits);
            println!(
                "serving {} (rate {}%, bits {}, kv {}-bit) — kv \
                 budget {:.2} GB on a {:.0} GB {} device",
                store.cfg.name, store.ps.rate_pct, bits.short(),
                sopts.kv_precision.bits(), budget,
                sopts.device_gb, sopts.memory_arch
            );
            let report = serve::run_workload(&mut rt, &store, &bits,
                                             &lang, &sopts,
                                             &mut metrics)?;
            let title = format!(
                "{} ({}, {} requests, {} clients, max-batch {})",
                cmd, store.cfg.name, sopts.requests, sopts.clients,
                sopts.max_batch
            );
            let t = report.to_table(&title);
            println!("{}", t.to_markdown());
            if cmd == "bench-serve" {
                t.save(&out_dir, "bench_serve")?;
                let lat =
                    report.latency.percentiles_ms(&[50.0, 95.0, 99.0]);
                println!(
                    "BENCH serve tokens_per_sec={:.1} p50={:.3}ms \
                     p95={:.3}ms p99={:.3}ms occupancy={:.2} \
                     reject_rate={:.4}",
                    report.tokens_per_sec(),
                    lat[0],
                    lat[1],
                    lat[2],
                    report.mean_occupancy,
                    report.rejection_rate()
                );
                println!("wrote {:?}", out_dir.join("bench_serve.md"));
            }
            println!("-- stage timings --\n{}", metrics.report());
        }
        "quantize" => {
            // per-format round-trip error analysis on a checkpoint:
            // the quantitative backdrop for the paper's {4,8}-bit
            // search space (2/3-bit error explodes; NF4 beats uniform
            // INT4; INT8 is near-lossless).
            use qpruner::model::{proj_index, ParamStore, PROJS};
            use qpruner::quant::{self, QuantFormat};
            use qpruner::report::Table;
            let path = experiments::checkpoint_path(&ckpt_dir, &size, &style);
            let store = if path.exists() {
                ParamStore::load(&path)?
            } else {
                eprintln!("no checkpoint at {path:?}; analyzing random init");
                ParamStore::init(&model_cfg, 0)
            };
            let mut t = Table::new(
                "Quantization error analysis (all projection stacks)",
                &["Format", "bits/param", "RMS err", "Max err",
                  "RMS vs fp16 weight RMS"],
            );
            let mut weight_sq = 0.0f64;
            let mut weight_n = 0usize;
            for p in PROJS {
                let s = &store.weights[proj_index(p)];
                weight_sq +=
                    s.data().iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
                weight_n += s.len();
            }
            let w_rms = (weight_sq / weight_n as f64).sqrt();
            let mut eval_fmt = |label: String, bits: f64,
                                f: &dyn Fn(&qpruner::tensor::Tensor)
                                    -> qpruner::tensor::Tensor,
                                t: &mut Table| {
                let (mut sq, mut mx, mut n) = (0.0f64, 0.0f64, 0usize);
                for p in PROJS {
                    for l in 0..store.cfg.n_layers {
                        let w = store.layer_proj(l, p);
                        let back = f(&w);
                        let (rms, m) = quant::error_stats(&w, &back);
                        sq += rms * rms * w.len() as f64;
                        mx = mx.max(m);
                        n += w.len();
                    }
                }
                let rms = (sq / n as f64).sqrt();
                t.push_row(vec![
                    label,
                    format!("{bits:.2}"),
                    format!("{rms:.5}"),
                    format!("{mx:.5}"),
                    format!("{:.3}", rms / w_rms),
                ]);
            };
            for fmt in [QuantFormat::Int8, QuantFormat::Nf4,
                        QuantFormat::Fp4] {
                eval_fmt(fmt.label().to_string(), fmt.bits_per_param(),
                         &|w| quant::simulate(w, fmt), &mut t);
            }
            for k in [4u32, 3, 2] {
                eval_fmt(
                    format!("uniform-int{k}"),
                    k as f64 + 32.0 / 64.0,
                    &move |w| {
                        quant::dequantize_uniform_k(
                            &quant::quantize_uniform_k(w, k))
                    },
                    &mut t,
                );
            }
            println!("{}", t.to_markdown());
        }
        _ => {
            bail!("unknown command {cmd:?} — run with no args for usage");
        }
    }
    Ok(())
}
