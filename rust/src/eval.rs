//! Zero-shot multiple-choice evaluation harness.
//!
//! Mirrors the lm-eval-harness contract the paper uses (Gao et al.,
//! 2023): each choice is scored by the length-normalized sum of token
//! log-probabilities given the shared context; the prediction is the
//! argmax choice; the metric is accuracy.

use crate::data::{gen_items, pack_rows, EvalItem, Language, TaskSpec};
use crate::lora::LoraState;
use crate::model::ParamStore;
use crate::runtime::{Arg, Runtime};
use anyhow::{ensure, Result};

#[derive(Clone, Debug)]
pub struct TaskResult {
    pub name: String,
    pub accuracy: f64,
    pub n_items: usize,
}

/// Mean accuracy across task results (the P(b) objective for BO).
pub fn mean_accuracy(results: &[TaskResult]) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    results.iter().map(|r| r.accuracy).sum::<f64>() / results.len() as f64
}

/// Score all items of one task; returns (accuracy, n).
pub fn eval_task(
    rt: &mut Runtime,
    base: &ParamStore,
    lora: &LoraState,
    lang: &Language,
    spec: &TaskSpec,
    n_items: usize,
) -> Result<TaskResult> {
    let items = gen_items(lang, spec, n_items);
    let scores = score_items(rt, base, lora, &items)?;
    let mut correct = 0usize;
    for (item, s) in items.iter().zip(&scores) {
        let pred = argmax(s);
        if pred == item.correct {
            correct += 1;
        }
    }
    Ok(TaskResult {
        name: spec.name.to_string(),
        accuracy: correct as f64 / items.len() as f64,
        n_items: items.len(),
    })
}

/// Length-normalized per-choice scores for a batch of items.
pub fn score_items(
    rt: &mut Runtime,
    base: &ParamStore,
    lora: &LoraState,
    items: &[EvalItem],
) -> Result<Vec<Vec<f64>>> {
    let cfg = &base.cfg;
    let name = format!("evalchoices_{}_r{}", cfg.name, base.ps.rate_pct);
    let r_cap = cfg.eval_rows;
    let seq = cfg.seq;

    // flatten all rows, then process in r_cap chunks (padding the tail)
    let (toks, mask, n_rows) = pack_rows(items, seq);
    let mut row_scores = vec![0.0f64; n_rows];
    let mut row = 0usize;
    while row < n_rows {
        let take = (n_rows - row).min(r_cap);
        let mut t_chunk = vec![0i32; r_cap * seq];
        let mut m_chunk = vec![0.0f32; r_cap * seq];
        t_chunk[..take * seq]
            .copy_from_slice(&toks[row * seq..(row + take) * seq]);
        m_chunk[..take * seq]
            .copy_from_slice(&mask[row * seq..(row + take) * seq]);
        // pad rows must still have a nonzero mask count downstream; we
        // simply ignore their scores.
        let m_t = crate::tensor::Tensor::new(&[r_cap, seq], m_chunk);
        let t_shape = [r_cap, seq];
        let mut args: Vec<Arg> = Vec::new();
        for w in &base.weights {
            args.push(Arg::F32(w));
        }
        for t in &lora.tensors {
            args.push(Arg::F32(t));
        }
        args.push(Arg::I32(&t_chunk, &t_shape));
        args.push(Arg::F32(&m_t));
        let out = rt.exec_f32(&name, &args)?;
        ensure!(out.len() == 2, "evalchoices output arity");
        let sums = &out[0];
        let counts = &out[1];
        for i in 0..take {
            let c = counts.data()[i].max(1.0);
            row_scores[row + i] = (sums.data()[i] / c) as f64;
        }
        row += take;
    }

    // group rows back into per-item choice vectors
    let mut out = Vec::with_capacity(items.len());
    let mut r = 0usize;
    for item in items {
        let nc = item.choices.len();
        out.push(row_scores[r..r + nc].to_vec());
        r += nc;
    }
    Ok(out)
}

/// Bootstrap 95 % confidence interval on a per-item correctness vector
/// (the paper reports point accuracies; CIs quantify the simulator's
/// item-count noise in our tables).
pub fn bootstrap_ci(correct: &[bool], resamples: usize, seed: u64)
                    -> (f64, f64) {
    if correct.is_empty() {
        return (0.0, 0.0);
    }
    let mut rng = crate::rng::Rng::new(seed);
    let n = correct.len();
    let mut means: Vec<f64> = (0..resamples)
        .map(|_| {
            let hits = (0..n).filter(|_| correct[rng.below(n)]).count();
            hits as f64 / n as f64
        })
        .collect();
    means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lo = means[(resamples as f64 * 0.025) as usize];
    let hi = means[((resamples as f64 * 0.975) as usize).min(resamples - 1)];
    (lo, hi)
}

/// Per-item correctness vector for one task (feeds bootstrap_ci).
pub fn task_correctness(
    rt: &mut Runtime,
    base: &ParamStore,
    lora: &LoraState,
    lang: &Language,
    spec: &TaskSpec,
    n_items: usize,
) -> Result<Vec<bool>> {
    let items = gen_items(lang, spec, n_items);
    let scores = score_items(rt, base, lora, &items)?;
    Ok(items
        .iter()
        .zip(&scores)
        .map(|(item, s)| argmax(s) == item.correct)
        .collect())
}

/// Perplexity on a held-out stream: exp(mean NLL) via the evalloss
/// artifact over `n_batches` fresh batches.
pub fn perplexity(
    rt: &mut Runtime,
    base: &ParamStore,
    lora: &LoraState,
    lang: &Language,
    seed: u64,
    n_batches: usize,
) -> Result<f64> {
    let cfg = &base.cfg;
    let mut stream = crate::data::CorpusStream::new(lang, seed);
    let mut total = 0.0f64;
    for _ in 0..n_batches {
        let toks = stream.next_block(1, cfg.batch, cfg.seq + 1);
        let loss =
            crate::finetune::eval_loss(rt, base, lora, &toks)? as f64;
        total += loss;
    }
    Ok((total / n_batches as f64).exp())
}

fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Evaluate the full suite.
pub fn eval_suite(
    rt: &mut Runtime,
    base: &ParamStore,
    lora: &LoraState,
    lang: &Language,
    tasks: &[TaskSpec],
    n_items: usize,
) -> Result<Vec<TaskResult>> {
    tasks
        .iter()
        .map(|spec| eval_task(rt, base, lora, lang, spec, n_items))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[-5.0, -1.0]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn bootstrap_ci_brackets_point_estimate() {
        let correct: Vec<bool> = (0..100).map(|i| i % 3 != 0).collect();
        let p = correct.iter().filter(|&&c| c).count() as f64 / 100.0;
        let (lo, hi) = bootstrap_ci(&correct, 500, 7);
        assert!(lo <= p && p <= hi, "[{lo}, {hi}] vs {p}");
        assert!(hi - lo < 0.25, "CI too wide: [{lo}, {hi}]");
        assert!(hi - lo > 0.0);
    }

    #[test]
    fn bootstrap_ci_degenerate_cases() {
        assert_eq!(bootstrap_ci(&[], 100, 1), (0.0, 0.0));
        let all = vec![true; 50];
        let (lo, hi) = bootstrap_ci(&all, 200, 2);
        assert_eq!((lo, hi), (1.0, 1.0));
    }

    #[test]
    fn mean_accuracy_averages() {
        let rs = vec![
            TaskResult { name: "a".into(), accuracy: 0.5, n_items: 10 },
            TaskResult { name: "b".into(), accuracy: 0.7, n_items: 10 },
        ];
        assert!((mean_accuracy(&rs) - 0.6).abs() < 1e-12);
        assert_eq!(mean_accuracy(&[]), 0.0);
    }
}
