//! Table/figure harnesses — one function per paper artifact.
//!
//! Each harness regenerates the corresponding table or figure at
//! simulator scale and returns a `report::Table` (plus raw data where a
//! figure needs scatter points). `Scale` controls the fidelity so that
//! integration tests can run in seconds while the recorded
//! EXPERIMENTS.md runs use the full budget.

use crate::bo::{self, Acquisition, Observation};
use crate::coordinator::{Coordinator, Method, PipelineOpts, PipelineResult};
use crate::data::Language;
use crate::lora::InitMethod;
use crate::model::{ModelConfig, ParamStore};
use crate::pruning::TaylorOrder;
use crate::quant::{BitConfig, QuantFormat};
use crate::report::{gb, pct, Table};
use crate::rng::Rng;
use crate::runtime::Runtime;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

pub const TASK_NAMES: [&str; 7] =
    ["BoolQ", "PIQA", "HellS", "WinoG", "ARC-e", "ARC-c", "OBQA"];

/// Fidelity knobs for harness runs.
#[derive(Clone, Debug)]
pub struct Scale {
    pub pretrain_steps: usize,
    pub finetune_steps: usize,
    pub eval_items: usize,
    pub bo_iters: usize,
    pub bo_init_random: usize,
    pub proxy_steps: usize,
    pub proxy_items: usize,
}

impl Scale {
    /// Seconds-scale runs for integration tests (tiny model).
    pub fn smoke() -> Scale {
        Scale {
            pretrain_steps: 24,
            finetune_steps: 8,
            eval_items: 12,
            bo_iters: 2,
            bo_init_random: 1,
            proxy_steps: 4,
            proxy_items: 6,
        }
    }

    /// The recorded-run fidelity (small/base models). Sized for the
    /// single-core CPU testbed — the paper's own budget (10 init + 40
    /// BO iterations, 25 min/candidate on an L20) is reachable by
    /// raising bo_iters/proxy_* when more hardware is available.
    pub fn paper() -> Scale {
        Scale {
            pretrain_steps: 1200,
            finetune_steps: 96,
            eval_items: 60,
            bo_iters: 10,
            bo_init_random: 4,
            proxy_steps: 16,
            proxy_items: 15,
        }
    }

    pub fn apply(&self, opts: &mut PipelineOpts) {
        opts.recover.finetune.steps = self.finetune_steps;
        opts.eval_items = self.eval_items;
        opts.bo.iters = self.bo_iters;
        opts.bo.init_random = self.bo_init_random;
        opts.bo.proxy_steps = self.proxy_steps;
        opts.bo.proxy_items = self.proxy_items;
    }
}

/// Style seeds for the two corpus dialects (LLaMA / Vicuna stand-ins).
pub fn style_seed(style: &str) -> u64 {
    match style {
        "vicuna" => 2,
        _ => 1,
    }
}

pub fn checkpoint_path(dir: &Path, size: &str, style: &str) -> PathBuf {
    dir.join(format!("{size}_{style}.qckpt"))
}

/// Load a pretrained checkpoint, or pretrain + save it if absent.
pub fn load_or_pretrain(
    coord: &mut Coordinator,
    cfg: &ModelConfig,
    ckpt_dir: &Path,
    style: &str,
    steps: usize,
) -> Result<ParamStore> {
    let path = checkpoint_path(ckpt_dir, &cfg.name, style);
    if path.exists() {
        let store = ParamStore::load(&path)?;
        if store.cfg == *cfg {
            return Ok(store);
        }
    }
    let seed = 0x9000 + style_seed(style);
    let (store, curve) = coord.pretrain(cfg, steps, 3e-3, seed)?;
    eprintln!(
        "[pretrain {} {}] steps={} loss {:.3} -> {:.3}",
        cfg.name, style, steps,
        curve.losses.first().copied().unwrap_or(f32::NAN),
        curve.tail_mean(8)
    );
    store.save(&path)?;
    Ok(store)
}

fn result_row(model: &str, rate: &str, r: &PipelineResult) -> Vec<String> {
    let mut row = vec![model.to_string(), rate.to_string(),
                       r.method.label().to_string()];
    for t in &r.tasks {
        row.push(pct(t.accuracy));
    }
    row.push(pct(r.mean_accuracy));
    row.push(gb(r.memory_gb));
    row.push(r.bits.short());
    row
}

fn untuned_row(model: &str, coord: &mut Coordinator, store: &ParamStore,
               n_items: usize) -> Result<Vec<String>> {
    let tasks = coord.eval_untuned(store, n_items)?;
    let mean =
        tasks.iter().map(|t| t.accuracy).sum::<f64>() / tasks.len() as f64;
    let mut row = vec![model.to_string(), "0%".into(), "w/o tuning".into()];
    for t in &tasks {
        row.push(pct(t.accuracy));
    }
    row.push(pct(mean));
    row.push("-".into());
    row.push("-".into());
    Ok(row)
}

fn table_headers() -> Vec<&'static str> {
    let mut h = vec!["Model", "Rate", "Method"];
    h.extend(TASK_NAMES);
    h.extend(["Mean", "Mem(GB)", "Bits"]);
    h
}

/// Table 1: main results over two models, three rates, four methods.
pub fn table1(
    coord: &mut Coordinator,
    stores: &[(&str, &ParamStore)],
    rates: &[u32],
    scale: &Scale,
) -> Result<Table> {
    let mut table = Table::new(
        "Table 1: zero-shot accuracy (%) and paper-scale peak memory (GB)",
        &table_headers(),
    );
    for (model, store) in stores {
        table.push_row(untuned_row(model, coord, store, scale.eval_items)?);
        for &rate in rates {
            for method in [Method::LlmPruner, Method::QPruner1,
                           Method::QPruner2, Method::QPruner3] {
                let mut opts = PipelineOpts::quick(rate, method);
                scale.apply(&mut opts);
                let res = coord.run(store, &opts)?;
                table.push_row(result_row(model, &format!("{rate}%"), &res));
            }
        }
    }
    Ok(table)
}

/// Table 2: ablations at 20 % pruning — 4-bit dtype, adapter init,
/// LoftQ iterations, importance estimation order.
pub fn table2_ablation(
    coord: &mut Coordinator,
    store: &ParamStore,
    scale: &Scale,
) -> Result<Table> {
    let mut h = vec!["Ablation", "Setting"];
    h.extend(TASK_NAMES);
    h.push("Mean");
    let mut table =
        Table::new("Table 2: ablations at 20% pruning (accuracy %)", &h);

    let variants: Vec<(&str, String, PipelineOpts)> = {
        let mut v = Vec::new();
        let base = |m: Method| {
            let mut o = PipelineOpts::quick(20, m);
            scale.apply(&mut o);
            o
        };
        // 4-bit dtype
        for fmt in [QuantFormat::Nf4, QuantFormat::Fp4] {
            let mut o = base(Method::QPruner2);
            o.quant.four_bit = fmt;
            v.push(("Dtype of 4-bit", fmt.label().to_string(), o));
        }
        // adapter init
        for init in [InitMethod::LoftQ { iters: 1 }, InitMethod::Gaussian,
                     InitMethod::Pissa] {
            let mut o = base(Method::QPruner2);
            o.recover.init = init;
            v.push(("Adapter init", init.label(), o));
        }
        // LoftQ iterations
        for iters in [1usize, 2, 4] {
            let mut o = base(Method::QPruner2);
            o.recover.init = InitMethod::LoftQ { iters };
            v.push(("LoftQ iters", format!("iter={iters}"), o));
        }
        // importance estimation
        for (label, ord) in [("element^1", TaylorOrder::First),
                             ("element^2", TaylorOrder::Second)] {
            let mut o = base(Method::QPruner2);
            o.prune.taylor = ord;
            v.push(("Importance", label.to_string(), o));
        }
        v
    };

    for (group, setting, opts) in variants {
        let res = coord.run(store, &opts)?;
        let mut row = vec![group.to_string(), setting];
        for t in &res.tasks {
            row.push(pct(t.accuracy));
        }
        row.push(pct(res.mean_accuracy));
        table.push_row(row);
    }
    Ok(table)
}

/// Table 3: the 13B-scale memory column at 50 % pruning.
pub fn table3_13b(
    coord: &mut Coordinator,
    store: &ParamStore,
    scale: &Scale,
) -> Result<Table> {
    let mut table = Table::new(
        "Table 3: 13B-scale — zero-shot accuracy (%) and memory (GB)",
        &table_headers(),
    );
    table.push_row(untuned_row("13B-sim", coord, store, scale.eval_items)?);
    for method in [Method::LlmPruner, Method::QPruner1, Method::QPruner3] {
        let mut opts = PipelineOpts::quick(50, method);
        opts.memory_arch = "13b".into();
        scale.apply(&mut opts);
        let res = coord.run(store, &opts)?;
        table.push_row(result_row("13B-sim", "50%", &res));
    }
    Ok(table)
}

/// Figure 1 (motivating example): LoRA-fp16 vs LoftQ-4bit vs LoftQ*
/// mixed-precision at 20 % pruning — accuracy bars + memory markers.
pub fn fig1_motivating(
    coord: &mut Coordinator,
    store: &ParamStore,
    scale: &Scale,
) -> Result<Table> {
    let mut h = vec!["Config"];
    h.extend(TASK_NAMES);
    h.extend(["Mean", "Mem(GB)"]);
    let mut table = Table::new(
        "Figure 1: accuracy and memory across fine-tuning configurations",
        &h,
    );
    for (label, method) in [("LoRA (fp16)", Method::LlmPruner),
                            ("LoftQ (4-bit)", Method::QPruner1),
                            ("LoftQ* (mixed 4/8)", Method::QPruner2)] {
        let mut opts = PipelineOpts::quick(20, method);
        scale.apply(&mut opts);
        let res = coord.run(store, &opts)?;
        let mut row = vec![label.to_string()];
        for t in &res.tasks {
            row.push(pct(t.accuracy));
        }
        row.push(pct(res.mean_accuracy));
        row.push(gb(res.memory_gb));
        table.push_row(row);
    }
    Ok(table)
}

/// Figures 3/4: BO Pareto scatter. Runs the warm start + BO loop while
/// recording *per-task* performance, then marks non-dominated points.
/// Returns (scatter rows per task, iterations log table).
pub struct ParetoData {
    /// task -> points (memory_gb, accuracy, config, on_front)
    pub per_task: Vec<(String, Vec<(f64, f64, String, bool)>)>,
    pub n_evals: usize,
}

pub fn fig3_pareto(
    coord: &mut Coordinator,
    store: &ParamStore,
    rate: u32,
    n_points: usize,
    n_init: usize,
    scale: &Scale,
) -> Result<ParetoData> {
    let mut opts = PipelineOpts::quick(rate, Method::QPruner3);
    scale.apply(&mut opts);
    // Figures 3/4 explore the space more broadly than the table budget
    opts.quant.frac8 = 0.5;
    let pruned = coord.prune(store, &opts.prune, opts.seed)?;
    let n_layers = pruned.cfg.n_layers;
    let mut rng = Rng::new(opts.seed ^ 0xFA3);

    let b0 = coord.allocate_bits_mi(&pruned, &opts.quant, opts.seed)?;
    let mut configs: Vec<BitConfig> = vec![b0];
    let max8 = ((n_layers as f64) * opts.quant.frac8).floor() as usize;
    while configs.len() < n_init {
        let n8 = rng.below(max8 + 1);
        let mut c = BitConfig::uniform(n_layers, opts.quant.four_bit);
        for i in rng.choose_k(n_layers, n8) {
            c.layers[i] = QuantFormat::Int8;
        }
        if !configs.iter().any(|x| x.short() == c.short()) {
            configs.push(c);
        }
    }

    let mut detailed: Vec<(BitConfig, Vec<f64>, f64)> = Vec::new();
    let mut observed: Vec<Observation> = Vec::new();
    let eval_one = |coord: &mut Coordinator, c: BitConfig,
                        observed: &mut Vec<Observation>,
                        detailed: &mut Vec<(BitConfig, Vec<f64>, f64)>,
                        rng: &mut Rng|
     -> Result<()> {
        let (tasks, mem) =
            coord.evaluate_candidate_detailed(&pruned, &c, &opts, rng)?;
        let per_task: Vec<f64> = tasks.iter().map(|t| t.accuracy).collect();
        let mean = per_task.iter().sum::<f64>() / per_task.len() as f64;
        observed.push(Observation {
            config: c.clone(),
            perf: mean,
            memory_gb: mem,
        });
        detailed.push((c, per_task, mem));
        Ok(())
    };

    for c in configs {
        eval_one(coord, c, &mut observed, &mut detailed, &mut rng)?;
    }
    while detailed.len() < n_points {
        let Some(cand) = bo::suggest(&observed, Acquisition::Ei,
                                     opts.quant.four_bit, opts.quant.frac8, &mut rng)?
        else {
            break;
        };
        eval_one(coord, cand, &mut observed, &mut detailed, &mut rng)?;
    }

    // per-task Pareto fronts
    let mut per_task = Vec::new();
    for (ti, name) in TASK_NAMES.iter().enumerate() {
        let pts: Vec<Observation> = detailed
            .iter()
            .map(|(c, accs, mem)| Observation {
                config: c.clone(),
                perf: accs[ti],
                memory_gb: *mem,
            })
            .collect();
        let front: std::collections::HashSet<usize> =
            bo::pareto_front(&pts).into_iter().collect();
        let rows: Vec<(f64, f64, String, bool)> = pts
            .iter()
            .enumerate()
            .map(|(i, o)| {
                (o.memory_gb, o.perf, o.config.short(), front.contains(&i))
            })
            .collect();
        per_task.push((name.to_string(), rows));
    }
    Ok(ParetoData { per_task, n_evals: detailed.len() })
}

/// Convenience: open the default runtime + a language and build a
/// coordinator for a style.
pub fn open_coordinator(vocab: usize, style: &str) -> Result<Coordinator> {
    let rt = Runtime::open_default().context("open PJRT runtime")?;
    let lang = Language::new(vocab, style_seed(style));
    Ok(Coordinator::new(rt, lang))
}
