//! Reusable activation scratch for the native decode engine.
//!
//! Every buffer a batched decode step touches lives here, sized once
//! for the largest batch seen and reused for every subsequent token —
//! the fix for the ROADMAP item about the per-token q/k/v/ctx `Vec`s
//! churning the allocator. [`DecodeWorkspace::ensure_batch`] is the
//! only place capacity can change; it counts growths vs. reuses so
//! tests (and `Metrics` via `serve.scratch_grows` /
//! `serve.scratch_reuses`) can assert the steady-state decode path
//! performs no per-token activation allocations, even at batch = 1.
//!
//! Since the decode engine went multi-threaded (`parallel.rs`), the
//! attention scratch (`scores`, `kv_row`) is laid out **per session**
//! — `[B, heads * max_seq]` and `[B, attn_dim]` — so the per-session
//! attention loop can run one session per pool lane with each lane
//! writing a disjoint region. The reference (oracle) logits path also
//! borrows `normed`/`logits` here instead of allocating two fresh
//! `Vec`s per sampled token.
//!
//! The buffers are KV-layout agnostic: attention gathers history
//! through `KvSlot::{k_row,v_row}` into `kv_row`, so slab and paged
//! slots feed the identical scratch and the identical GEMMs.

/// Scratch buffers for one engine. All matrices are row-major with the
/// batch as the leading axis; capacities are `batch_cap * dim`.
#[derive(Debug)]
pub struct DecodeWorkspace {
    d_model: usize,
    attn_dim: usize,
    d_ff: usize,
    vocab: usize,
    heads: usize,
    max_seq: usize,
    /// adapter rank of the engine's adjoined LoRA (0 = no side path)
    lora_rank: usize,
    /// largest batch the buffers currently hold
    batch_cap: usize,
    /// residual stream `[B, d_model]`
    pub hidden: Vec<f32>,
    /// RMSNorm output `[B, d_model]` (also reused for the final norm)
    pub normed: Vec<f32>,
    /// attention projections `[B, attn_dim]`
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// attention context `[B, attn_dim]`
    pub ctx: Vec<f32>,
    /// wo / w_down output `[B, d_model]`
    pub proj_d: Vec<f32>,
    /// SwiGLU intermediates `[B, d_ff]`
    pub gate: Vec<f32>,
    pub up: Vec<f32>,
    /// per-session attention scores `[B, heads * max_seq]` — one
    /// disjoint region per session so pool lanes never share
    pub scores: Vec<f32>,
    /// per-session KV dequantization scratch `[B, attn_dim]`
    pub kv_row: Vec<f32>,
    /// next-token logits `[B, vocab]`
    pub logits: Vec<f32>,
    /// adjoined-LoRA intermediate `x A^T` `[B, lora_rank]` (empty when
    /// the engine carries no adjoined adapters)
    pub lora_tmp: Vec<f32>,
    /// reusable slot-id staging for `Engine::step_batch` (grows to the
    /// largest batch once, then reused — not counted in `grows`, which
    /// tracks the activation buffers)
    pub slot_ids: Vec<usize>,
    /// profiler scratch for *sampled* decode steps: `obs::StepTimer`
    /// takes both by value at step start and hands them back at
    /// finish, so sampled-step accounting allocates once and is then
    /// reused like every other buffer here (not counted in `grows`)
    pub phase_acc: Vec<u64>,
    pub phase_events: Vec<crate::obs::PhaseEvent>,
    grows: u64,
    reuses: u64,
}

impl DecodeWorkspace {
    /// Buffers start empty (`batch_cap == 0`); the first
    /// [`DecodeWorkspace::ensure_batch`] sizes everything, including
    /// the per-session attention scratch.
    #[allow(clippy::too_many_arguments)]
    pub fn new(d_model: usize, attn_dim: usize, d_ff: usize,
               vocab: usize, heads: usize, max_seq: usize,
               lora_rank: usize)
               -> DecodeWorkspace {
        DecodeWorkspace {
            d_model,
            attn_dim,
            d_ff,
            vocab,
            heads,
            max_seq,
            lora_rank,
            batch_cap: 0,
            hidden: Vec::new(),
            normed: Vec::new(),
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            ctx: Vec::new(),
            proj_d: Vec::new(),
            gate: Vec::new(),
            up: Vec::new(),
            scores: Vec::new(),
            kv_row: Vec::new(),
            logits: Vec::new(),
            lora_tmp: Vec::new(),
            slot_ids: Vec::new(),
            phase_acc: Vec::new(),
            phase_events: Vec::new(),
            grows: 0,
            reuses: 0,
        }
    }

    /// Per-session stride of the `scores` buffer.
    pub fn scores_stride(&self) -> usize {
        self.heads * self.max_seq
    }

    /// Make every batch-sized buffer hold at least `batch` rows.
    /// Growth (an allocation) only happens when `batch` exceeds the
    /// high-water mark; every other call is a pure reuse. The decode
    /// hot path must see `grows` stay flat while `reuses` tracks the
    /// token count — `engine::tests::steady_state_decode_reuses_scratch`
    /// pins this down.
    pub fn ensure_batch(&mut self, batch: usize) {
        if batch <= self.batch_cap {
            self.reuses += 1;
            return;
        }
        self.grows += 1;
        self.batch_cap = batch;
        self.hidden.resize(batch * self.d_model, 0.0);
        self.normed.resize(batch * self.d_model, 0.0);
        self.q.resize(batch * self.attn_dim, 0.0);
        self.k.resize(batch * self.attn_dim, 0.0);
        self.v.resize(batch * self.attn_dim, 0.0);
        self.ctx.resize(batch * self.attn_dim, 0.0);
        self.proj_d.resize(batch * self.d_model, 0.0);
        self.gate.resize(batch * self.d_ff, 0.0);
        self.up.resize(batch * self.d_ff, 0.0);
        self.scores.resize(batch * self.heads * self.max_seq, 0.0);
        self.kv_row.resize(batch * self.attn_dim, 0.0);
        self.logits.resize(batch * self.vocab, 0.0);
        self.lora_tmp.resize(batch * self.lora_rank, 0.0);
    }

    pub fn batch_cap(&self) -> usize {
        self.batch_cap
    }

    /// (growth count, reuse count) since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.grows, self.reuses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_monotonically_and_counts_reuse() {
        let mut ws = DecodeWorkspace::new(8, 4, 16, 32, 2, 10, 0);
        assert_eq!(ws.stats(), (0, 0));
        ws.ensure_batch(2);
        assert_eq!(ws.batch_cap(), 2);
        assert_eq!(ws.hidden.len(), 16);
        assert_eq!(ws.logits.len(), 64);
        // smaller or equal batches never reallocate
        ws.ensure_batch(1);
        ws.ensure_batch(2);
        assert_eq!(ws.stats(), (1, 2));
        assert_eq!(ws.batch_cap(), 2);
        // growth bumps the high-water mark once
        ws.ensure_batch(5);
        assert_eq!(ws.stats(), (2, 2));
        assert_eq!(ws.gate.len(), 5 * 16);
        ws.ensure_batch(5);
        assert_eq!(ws.stats(), (2, 3));
    }

    #[test]
    fn attention_scratch_is_per_session() {
        let mut ws = DecodeWorkspace::new(8, 4, 16, 32, 3, 12, 0);
        assert_eq!(ws.scores_stride(), 36);
        assert!(ws.scores.is_empty() && ws.kv_row.is_empty());
        ws.ensure_batch(2);
        // one disjoint region per session: pool lanes never overlap
        assert_eq!(ws.scores.len(), 2 * 36);
        assert_eq!(ws.kv_row.len(), 2 * 4);
        ws.ensure_batch(5);
        assert_eq!(ws.scores.len(), 5 * 36);
        assert_eq!(ws.kv_row.len(), 5 * 4);
    }

    #[test]
    fn lora_scratch_tracks_batch_and_rank() {
        let mut ws = DecodeWorkspace::new(8, 4, 16, 32, 2, 10, 4);
        ws.ensure_batch(3);
        assert_eq!(ws.lora_tmp.len(), 12);
        // rank 0 engines keep the buffer empty at any batch
        let mut ws0 = DecodeWorkspace::new(8, 4, 16, 32, 2, 10, 0);
        ws0.ensure_batch(5);
        assert!(ws0.lora_tmp.is_empty());
    }
}
