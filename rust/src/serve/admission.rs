//! Admission control: decide at submit time whether a request may wait
//! for KV-cache capacity, must be rejected outright, or can never be
//! served.
//!
//! Capacity itself is the KV slab pool (`kv_cache.rs`); admission only
//! bounds the *wait queue* and screens requests whose token footprint
//! could never fit a slot — so an overloaded server sheds load at the
//! door with a cheap O(1) check instead of timing out deep in the
//! pipeline.

/// Why a request was turned away.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// wait queue at capacity — the memory budget has been exhausted
    /// long enough for backlog to accumulate
    QueueFull,
    /// prompt + generation budget exceeds a KV slot (`max_seq`)
    TooLong,
    /// degenerate request (empty prompt or zero generation budget)
    Malformed,
}

impl RejectReason {
    pub fn label(self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue-full",
            RejectReason::TooLong => "too-long",
            RejectReason::Malformed => "malformed",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    Admit,
    Reject(RejectReason),
}

/// Static admission policy for one serving process.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// max requests waiting for a KV slot before load shedding
    pub max_queue: usize,
    /// KV slot capacity in tokens (prompt + generated)
    pub max_seq: usize,
    /// longest session the KV pool can physically hold — `max_seq` on
    /// the slab layout, additionally clamped by the page budget on the
    /// paged layout (`KvCachePool::session_token_capacity`), so a
    /// request that could never be paged in is shed at the door rather
    /// than admitted and preempted forever
    pub token_capacity: usize,
}

impl AdmissionPolicy {
    pub fn new(max_queue: usize, max_seq: usize) -> AdmissionPolicy {
        Self::with_token_capacity(max_queue, max_seq, max_seq)
    }

    /// Policy with an explicit pool token capacity (paged layouts pass
    /// `KvCachePool::session_token_capacity`).
    pub fn with_token_capacity(max_queue: usize, max_seq: usize,
                               token_capacity: usize)
                               -> AdmissionPolicy {
        AdmissionPolicy {
            max_queue,
            max_seq,
            token_capacity: token_capacity.min(max_seq),
        }
    }

    /// Deterministic `Retry-After` hint (seconds) for a shed request:
    /// scales with queue occupancy — an empty queue means capacity is
    /// about to free (retry in 1 s), a full one means real backlog
    /// (up to 5 s). Pure arithmetic so the HTTP layer's 429/503
    /// responses are reproducible in tests.
    pub fn retry_after_secs(&self, queue_len: usize) -> u64 {
        let cap = self.max_queue.max(1);
        (1 + (4 * queue_len.min(cap)) / cap) as u64
    }

    pub fn decide(&self, prompt_len: usize, max_new: usize,
                  queue_len: usize) -> Decision {
        if prompt_len == 0 || max_new == 0 {
            return Decision::Reject(RejectReason::Malformed);
        }
        // the final sampled token is returned but never fed back, so a
        // session touches prompt_len + max_new - 1 cache positions
        if prompt_len + max_new - 1 > self.token_capacity {
            return Decision::Reject(RejectReason::TooLong);
        }
        if queue_len >= self.max_queue {
            return Decision::Reject(RejectReason::QueueFull);
        }
        Decision::Admit
    }
}

/// Brownout thresholds and hysteresis. All decisions are made in
/// scheduler *step space* (never wall clock), so two runs with the same
/// seed and workload enter and exit brownout at the same steps — the
/// degradation is deterministic, which is what lets the chaos suite
/// compare traces across runs.
#[derive(Clone, Copy, Debug)]
pub struct BrownoutConfig {
    /// enter pressure when `queue_len >= queue_frac * max_queue`
    pub queue_frac: f64,
    /// ... or when slot/page occupancy reaches this fraction
    pub occ_frac: f64,
    /// consecutive over-threshold steps before brownout engages
    pub enter_steps: u64,
    /// consecutive under-threshold steps (at the *recovery* thresholds,
    /// half the enter thresholds) before brownout releases
    pub exit_steps: u64,
    /// while active, admission clamps each request's `max_new` to this
    pub clamp_max_new: usize,
    /// while active, added to every `Retry-After` hint
    pub retry_after_bump: u64,
}

impl Default for BrownoutConfig {
    fn default() -> BrownoutConfig {
        BrownoutConfig {
            queue_frac: 0.75,
            occ_frac: 0.95,
            enter_steps: 3,
            exit_steps: 8,
            clamp_max_new: 8,
            retry_after_bump: 2,
        }
    }
}

/// Brownout state machine. Disabled (`cfg: None`) it is a single
/// always-false branch per step; enabled it tracks sustained pressure
/// with enter/exit hysteresis so admission doesn't flap.
#[derive(Clone, Debug, Default)]
pub struct Brownout {
    cfg: Option<BrownoutConfig>,
    active: bool,
    above: u64,
    below: u64,
    entries: u64,
}

impl Brownout {
    pub fn new(cfg: Option<BrownoutConfig>) -> Brownout {
        Brownout { cfg, ..Brownout::default() }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.is_some()
    }

    pub fn active(&self) -> bool {
        self.active
    }

    /// Times brownout has engaged over the process lifetime.
    pub fn entries(&self) -> u64 {
        self.entries
    }

    /// Feed one scheduler step's pressure signals. `occ_frac` is the
    /// KV pool's occupancy fraction (pages on paged, slots on slab).
    pub fn observe(&mut self, queue_len: usize, max_queue: usize,
                   occ_frac: f64) {
        let Some(cfg) = self.cfg else { return };
        let qcap = max_queue.max(1) as f64;
        let qfrac = queue_len as f64 / qcap;
        if !self.active {
            let pressure = qfrac >= cfg.queue_frac
                || occ_frac >= cfg.occ_frac;
            self.above = if pressure { self.above + 1 } else { 0 };
            if self.above >= cfg.enter_steps {
                self.active = true;
                self.entries += 1;
                self.above = 0;
                self.below = 0;
            }
        } else {
            // recover only once pressure falls well clear of the enter
            // thresholds (half), sustained — hysteresis against flap
            let calm = qfrac < cfg.queue_frac * 0.5
                && occ_frac < cfg.occ_frac * 0.5;
            self.below = if calm { self.below + 1 } else { 0 };
            if self.below >= cfg.exit_steps {
                self.active = false;
                self.above = 0;
                self.below = 0;
            }
        }
    }

    /// Degraded generation budget while active (identity otherwise).
    pub fn clamp_max_new(&self, max_new: usize) -> usize {
        match self.cfg {
            Some(cfg) if self.active => max_new.min(cfg.clamp_max_new.max(1)),
            _ => max_new,
        }
    }

    /// Extra seconds added to `Retry-After` hints while active.
    pub fn retry_after_bump(&self) -> u64 {
        match self.cfg {
            Some(cfg) if self.active => cfg.retry_after_bump,
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_within_limits() {
        let p = AdmissionPolicy::new(4, 32);
        assert_eq!(p.decide(8, 8, 0), Decision::Admit);
        assert_eq!(p.decide(8, 8, 3), Decision::Admit);
    }

    #[test]
    fn sheds_load_when_queue_full() {
        let p = AdmissionPolicy::new(4, 32);
        assert_eq!(p.decide(8, 8, 4),
                   Decision::Reject(RejectReason::QueueFull));
        assert_eq!(p.decide(8, 8, 9),
                   Decision::Reject(RejectReason::QueueFull));
    }

    #[test]
    fn screens_oversized_requests() {
        let p = AdmissionPolicy::new(4, 32);
        // 20 + 14 - 1 = 33 > 32
        assert_eq!(p.decide(20, 14, 0),
                   Decision::Reject(RejectReason::TooLong));
        // exactly at capacity is fine: 20 + 13 - 1 = 32
        assert_eq!(p.decide(20, 13, 0), Decision::Admit);
    }

    #[test]
    fn screens_malformed() {
        let p = AdmissionPolicy::new(4, 32);
        assert_eq!(p.decide(0, 8, 0),
                   Decision::Reject(RejectReason::Malformed));
        assert_eq!(p.decide(8, 0, 0),
                   Decision::Reject(RejectReason::Malformed));
    }

    #[test]
    fn token_capacity_tightens_too_long() {
        // a paged pool with fewer total page-tokens than max_seq must
        // shed sessions that could never be faulted in
        let p = AdmissionPolicy::with_token_capacity(4, 32, 16);
        assert_eq!(p.decide(10, 7, 0), Decision::Admit); // 16 positions
        assert_eq!(p.decide(10, 8, 0),
                   Decision::Reject(RejectReason::TooLong));
        // capacity never exceeds max_seq (engine buffers bound it)
        let q = AdmissionPolicy::with_token_capacity(4, 32, 1000);
        assert_eq!(q.token_capacity, 32);
        // the plain constructor keeps the old slab behavior
        assert_eq!(AdmissionPolicy::new(4, 32).token_capacity, 32);
    }

    #[test]
    fn retry_after_scales_with_queue_occupancy() {
        let p = AdmissionPolicy::new(8, 32);
        assert_eq!(p.retry_after_secs(0), 1);
        assert_eq!(p.retry_after_secs(4), 3);
        assert_eq!(p.retry_after_secs(8), 5);
        // beyond-capacity occupancy clamps instead of overflowing
        assert_eq!(p.retry_after_secs(1000), 5);
        // degenerate zero-length queue still yields a sane hint
        let z = AdmissionPolicy::new(0, 32);
        assert_eq!(z.retry_after_secs(0), 1);
    }

    #[test]
    fn brownout_disabled_is_inert() {
        let mut b = Brownout::new(None);
        for _ in 0..100 {
            b.observe(1000, 1, 1.0);
        }
        assert!(!b.active());
        assert_eq!(b.clamp_max_new(64), 64);
        assert_eq!(b.retry_after_bump(), 0);
        assert_eq!(b.entries(), 0);
    }

    #[test]
    fn brownout_enters_after_sustained_pressure_only() {
        let cfg = BrownoutConfig { enter_steps: 3, ..Default::default() };
        let mut b = Brownout::new(Some(cfg));
        // two hot steps then one calm step: the streak resets
        b.observe(8, 8, 0.0);
        b.observe(8, 8, 0.0);
        b.observe(0, 8, 0.0);
        assert!(!b.active());
        for _ in 0..3 {
            b.observe(8, 8, 0.0);
        }
        assert!(b.active());
        assert_eq!(b.entries(), 1);
        assert_eq!(b.clamp_max_new(64), cfg.clamp_max_new);
        assert_eq!(b.clamp_max_new(2), 2, "clamp never raises");
        assert_eq!(b.retry_after_bump(), cfg.retry_after_bump);
    }

    #[test]
    fn brownout_occupancy_alone_triggers() {
        let cfg = BrownoutConfig { enter_steps: 2, ..Default::default() };
        let mut b = Brownout::new(Some(cfg));
        b.observe(0, 8, 0.99);
        b.observe(0, 8, 0.99);
        assert!(b.active(), "page pressure with an empty queue counts");
    }

    #[test]
    fn brownout_exit_has_hysteresis() {
        let cfg = BrownoutConfig {
            enter_steps: 1,
            exit_steps: 4,
            ..Default::default()
        };
        let mut b = Brownout::new(Some(cfg));
        b.observe(8, 8, 0.0);
        assert!(b.active());
        // just-below-enter pressure is NOT calm enough to recover
        for _ in 0..20 {
            b.observe(5, 8, 0.0); // 0.625 >= 0.75*0.5
        }
        assert!(b.active(), "must recover at half thresholds, not enter");
        for _ in 0..3 {
            b.observe(0, 8, 0.0);
        }
        assert!(b.active(), "exit needs exit_steps consecutive calm");
        b.observe(0, 8, 0.0);
        assert!(!b.active());
        // re-entry counts again
        b.observe(8, 8, 0.0);
        assert!(b.active());
        assert_eq!(b.entries(), 2);
    }

    #[test]
    fn reject_labels_stable() {
        assert_eq!(RejectReason::QueueFull.label(), "queue-full");
        assert_eq!(RejectReason::TooLong.label(), "too-long");
        assert_eq!(RejectReason::Malformed.label(), "malformed");
    }
}
