//! Forward engine for serving: turns (pruned, quantized) deployment
//! weights into next-token logits against a session's KV cache.
//!
//! Two backends, chosen at construction:
//!
//! * **Artifact** — when the `fwd_{size}_r{rate}` AOT artifact is
//!   present and compiles, steps run through `runtime::Runtime` (PJRT).
//!   The AOT artifacts are fixed-shape full-sequence programs, so this
//!   path re-forwards the padded prefix each step — correct, but
//!   O(S^2) per token. (The PJRT ABI consumes raw f32 stacks, so this
//!   backend — and only this backend — materializes them.)
//! * **Native** — incremental decode against the slab KV cache,
//!   numerically mirroring `python/compile/model.py` (RMSNorm eps
//!   1e-6, RoPE theta 10000 with half-split rotation, SwiGLU, pre-norm
//!   residuals). This is the default whenever artifacts are absent
//!   (e.g. CI) and the only incremental path.
//!
//! **Quantized residency.** The native path keeps every projection in
//! its artifact encoding — a per-(projection, layer)
//! [`quant::QuantSlab`]: nf4/fp4 packed nibbles or int8 codes with
//! per-block absmax scales, raw f32 only for fp16-format layers and
//! the fp stacks (embed/norms/lm_head, QLoRA convention). Decode GEMMs
//! consume the codes directly through the fused kernels in `linalg`
//! (`matmul_nt_slab_into` and friends), dequantizing block-wise in
//! registers — weight traffic per token is the artifact's native
//! 0.5–1 byte/param, never a 4 byte/param f32 materialization.
//! `Engine::weight_host_bytes` reports the actual residency and
//! matches the `memory::weight_bytes_at` model.
//!
//! **Parallel decode.** All heavy per-step work — the per-projection
//! GEMMs, the per-session attention loops, and the vocab projection —
//! runs on the std-only thread pool in `parallel.rs` (static
//! deterministic partitioning: results are bit-identical across
//! thread counts). `EngineBuilder::threads` pins the lane count
//! (`--threads` on the CLI); the default shares an
//! `available_parallelism`-sized process pool.
//!
//! The native path is *batched*: [`Engine::step_batch`] stacks every
//! active session's hidden state into a `[batch, hidden]` matrix and
//! runs one fused GEMM per projection per layer, with all activation
//! scratch held in a reusable `workspace::DecodeWorkspace` (no
//! per-token activation allocations; a fused step's only allocation is
//! the batch's slot-borrow `Vec` from `slots_mut_many`). The original
//! per-session matvec implementation survives as
//! [`Engine::prefill_reference`] / [`Engine::decode_reference`] — the
//! f32-numerics oracle `tests/parity_decode.rs` diffs the fused path
//! against (|Δlogit| < 1e-4 in practice; < 1e-3 required), and the
//! `bench_serve` baseline. For an explicit PR-3-style f32-GEMM
//! baseline, [`EngineBuilder::f32_residency`] forces every slab to
//! dequantized f32 — oracle/bench use only, never the serving default.
//!
//! Weights are "deployed" once at engine construction, through the
//! [`EngineBuilder`] — the one typed entry from pipeline output to
//! serving input. Two sources:
//!
//! * `.store(&ParamStore, &BitConfig)` — projections are quantized
//!   straight into their residency slabs per the layer `BitConfig`
//!   (decoded values identical to the paper's simulated-quantization
//!   deployment numerics, `lora::quantize_base`);
//! * `.artifact(ModelArtifact)` / `.artifact_path(..)` — a pipeline
//!   `export` hands its native blobs to the engine **as-is** (no
//!   decode, no re-encode), and any trained LoRA deltas deploy per
//!   [`LoraMode`]: **merged** (fold `s·BA` into the base at build —
//!   the folded matrix is *re-quantized* into the layer's format, so
//!   residency stays native) or **adjoined** (a low-rank side path
//!   `y += s·(xAᵀ)Bᵀ` evaluated in both the batched and the reference
//!   decode paths, sharing the same accumulation order so parity
//!   testing covers it too).

use crate::artifact::{LoraDelta, LoraMode, ModelArtifact};
use crate::linalg::{self, matmul_nt_into, matmul_nt_scaled_acc_into,
                    matmul_nt_slab_into, matmul_nt_slabs_into,
                    par_matmul_nt_into};
use crate::lora;
use crate::model::{proj_index, ModelConfig, ParamStore, PrunedShapes,
                   PROJS};
use crate::obs::{Phase, PhaseProfiler, PhaseSnapshot, StepTimer};
use crate::parallel::{self, chunk_range, SyncPtr, ThreadPool};
use crate::quant::{self, BitConfig, QuantSlab};
use crate::rng::Rng;
use crate::runtime::{Arg, Runtime};
use crate::serve::kv_cache::{KvCachePool, KvPrecision, KvSlot};
use crate::serve::workspace::DecodeWorkspace;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};
use std::cell::RefCell;
use std::path::PathBuf;
use std::sync::Arc;

enum Backend {
    Native,
    /// PJRT path: the fixed ABI takes the 12 f32 stacks as arguments,
    /// so they are materialized here — and only here.
    Artifact {
        name: String,
        weights: Vec<Tensor>,
        lora_args: Vec<Tensor>,
    },
}

/// One session's slice of a batched decode step: feed `token` at
/// position `pos` into the KV cache at pool slot `slot`. The newest
/// generated token is the one not yet cached, so
/// `pos == prompt_len + generated_len - 1` and `pos == slot.len`.
#[derive(Clone, Copy, Debug)]
pub struct BatchReq {
    pub slot: usize,
    pub pos: usize,
    pub token: i32,
}

/// Default phase-profiler sampling rate: every 4th instrumented call
/// runs under lap timers (`EngineBuilder::profile_every`, 0 = off).
pub const DEFAULT_PROFILE_EVERY: u32 = 4;

/// Bound on retained raw phase events (~40 B each); aggregates keep
/// accumulating past it, only the trace-export detail is capped.
const PHASE_EVENTS_CAP: usize = 100_000;

/// Forward a lap to the step's timer when this step is sampled.
fn lap(timer: &mut Option<StepTimer<'_>>, phase: Phase, layer: usize) {
    if let Some(t) = timer {
        t.lap(phase, layer);
    }
}

/// Frozen deployment weights in serving residency: raw f32 fp stacks
/// plus one [`QuantSlab`] per (projection, layer).
struct Deployed {
    cfg: ModelConfig,
    ps: PrunedShapes,
    /// `[vocab, d_model]`
    embed: Tensor,
    /// `[n_layers, d_model]`
    attn_norm: Tensor,
    /// `[n_layers, d_model]`
    mlp_norm: Tensor,
    /// `[d_model]`
    final_norm: Tensor,
    /// `[vocab, d_model]`
    lm_head: Tensor,
    /// `[PROJS.len()][n_layers]`, PROJS order
    projs: Vec<Vec<QuantSlab>>,
}

impl Deployed {
    /// Quantize a pipeline `ParamStore` straight into residency slabs
    /// per the layer `BitConfig` (no intermediate f32 simulation).
    fn from_store(store: &ParamStore, bits: &BitConfig) -> Deployed {
        let w = &store.weights;
        let mut projs = Vec::with_capacity(PROJS.len());
        for p in PROJS {
            let mut per = Vec::with_capacity(store.cfg.n_layers);
            for l in 0..store.cfg.n_layers {
                per.push(QuantSlab::from_f32(&store.layer_proj(l, p),
                                             bits.layers[l]));
            }
            projs.push(per);
        }
        Deployed {
            cfg: store.cfg.clone(),
            ps: store.ps,
            embed: w[0].clone(),
            attn_norm: w[1].clone(),
            mlp_norm: w[6].clone(),
            final_norm: w[10].clone(),
            lm_head: w[11].clone(),
            projs,
        }
    }

    /// Adopt an artifact's native blobs as-is — the zero-copy,
    /// zero-recode load path. Returns the deployment plus the
    /// artifact's bit config, LoRA deltas and default LoRA mode.
    fn from_artifact(art: ModelArtifact)
                     -> Result<(Deployed, BitConfig,
                                Option<LoraDelta>, LoraMode)> {
        art.validate_shapes()?;
        let ModelArtifact {
            cfg, ps, bits, mut fp_stacks, projs, lora, lora_mode, ..
        } = art;
        // FP_STACKS order: embed, attn_norm, mlp_norm, final_norm,
        // lm_head (validate_shapes checked the count)
        let lm_head = fp_stacks.pop().expect("fp stacks");
        let final_norm = fp_stacks.pop().expect("fp stacks");
        let mlp_norm = fp_stacks.pop().expect("fp stacks");
        let attn_norm = fp_stacks.pop().expect("fp stacks");
        let embed = fp_stacks.pop().expect("fp stacks");
        Ok((
            Deployed {
                cfg,
                ps,
                embed,
                attn_norm,
                mlp_norm,
                final_norm,
                lm_head,
                projs,
            },
            bits,
            lora,
            lora_mode,
        ))
    }

    /// Force every packed slab to dequantized f32 — the PR-3-style
    /// f32-GEMM parity oracle / bench baseline. Never the serving
    /// default.
    fn to_f32_residency(&mut self) {
        for per in &mut self.projs {
            for slab in per.iter_mut() {
                if matches!(slab, QuantSlab::Packed(_)) {
                    let t = slab.dequantized();
                    *slab = QuantSlab::F32(t);
                }
            }
        }
    }

    /// Rebuild the 12 f32 stacks in ABI order — only the PJRT
    /// artifact backend calls this (its fixed ABI takes f32 tensors).
    fn materialize_param_store(&self) -> ParamStore {
        let shapes = ParamStore::shapes(&self.cfg, &self.ps);
        let mut weights: Vec<Tensor> =
            shapes.iter().map(|s| Tensor::zeros(s)).collect();
        weights[0] = self.embed.clone();
        weights[1] = self.attn_norm.clone();
        weights[6] = self.mlp_norm.clone();
        weights[10] = self.final_norm.clone();
        weights[11] = self.lm_head.clone();
        for (pi, p) in PROJS.iter().enumerate() {
            let stack = &mut weights[proj_index(p)];
            for (l, slab) in self.projs[pi].iter().enumerate() {
                let t = slab.dequantized();
                stack.slab_mut(l).copy_from_slice(t.data());
            }
        }
        ParamStore { cfg: self.cfg.clone(), ps: self.ps, weights }
    }
}

pub struct Engine {
    cfg: ModelConfig,
    bits: BitConfig,
    ps: PrunedShapes,
    /// raw f32 stacks (fp16 convention: never quantized)
    embed: Tensor,
    attn_norm: Tensor,
    mlp_norm: Tensor,
    final_norm: Tensor,
    lm_head: Tensor,
    /// native-residency projection weights, `[PROJS.len()][n_layers]`
    projs: Vec<Vec<QuantSlab>>,
    /// "quantized" (default) | "f32" (oracle/bench builds)
    residency: &'static str,
    backend: Backend,
    /// adjoined LoRA adapters (low-rank side path in every decode
    /// step); `None` for merged or adapter-free deployments
    adjoin: Option<LoraDelta>,
    /// "none" | "merged" | "adjoined" — reporting only
    lora_label: &'static str,
    /// KV-cache storage precision the deployment was built for; the
    /// serving layer sizes its pool from this
    kv_precision: KvPrecision,
    /// decode thread pool (deterministic static partitioning; see
    /// `parallel.rs`)
    pool: Arc<ThreadPool>,
    /// sampled decode-phase wall-time accumulators (`obs`); shared so
    /// snapshots can be taken while the engine serves
    profiler: Arc<PhaseProfiler>,
    /// RoPE tables `[max_seq, head_dim/2]`
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    half: usize,
    max_seq: usize,
    /// reusable activation scratch for the native batched path.
    /// Interior mutability keeps the public decode API `&self` (the
    /// engine is logically immutable — scratch is not observable
    /// state); the engine itself is driven single-threaded (the pool
    /// workers only ever touch disjoint workspace regions handed to
    /// them inside one call), so `RefCell` suffices.
    ws: RefCell<DecodeWorkspace>,
}

/// Weight source of an [`EngineBuilder`].
enum Source {
    /// pipeline in-memory output: quantize per `bits` at build
    Store { store: ParamStore, bits: BitConfig },
    /// exported deployable artifact (already in deployment numerics)
    Artifact(Box<ModelArtifact>),
    /// path to a serialized artifact, loaded at build
    Path(PathBuf),
}

/// Typed constructor for [`Engine`] — the single API from pipeline
/// output (in-memory store + bits, or an exported `ModelArtifact`) to
/// serving input.
///
/// ```ignore
/// let engine = EngineBuilder::new()
///     .artifact_path("checkpoints/tiny_llama_q3_r20.qpart")
///     .max_seq(64)
///     .kv_precision(KvPrecision::Int8)
///     .lora(LoraMode::Adjoin)
///     .threads(4)
///     .build(&mut rt)?;
/// ```
pub struct EngineBuilder {
    source: Option<Source>,
    max_seq: usize,
    kv_precision: KvPrecision,
    lora_mode: Option<LoraMode>,
    threads: Option<usize>,
    f32_residency: bool,
    profile_every: u32,
    profile_events: bool,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder {
            source: None,
            max_seq: 256,
            kv_precision: KvPrecision::F32,
            lora_mode: None,
            threads: None,
            f32_residency: false,
            profile_every: DEFAULT_PROFILE_EVERY,
            profile_events: false,
        }
    }
}

impl EngineBuilder {
    pub fn new() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// Serve a pipeline `ParamStore`: projections are quantized into
    /// their residency slabs per `bits` at build time.
    pub fn store(mut self, store: &ParamStore, bits: &BitConfig)
                 -> Self {
        self.source = Some(Source::Store {
            store: store.clone(),
            bits: bits.clone(),
        });
        self
    }

    /// Serve an exported [`ModelArtifact`] (weights already in
    /// deployment numerics; the native blobs are adopted as-is).
    pub fn artifact(mut self, art: ModelArtifact) -> Self {
        self.source = Some(Source::Artifact(Box::new(art)));
        self
    }

    /// Like [`EngineBuilder::artifact`], loading (and
    /// checksum/version-validating) the file at build time.
    pub fn artifact_path(mut self, path: impl Into<PathBuf>) -> Self {
        self.source = Some(Source::Path(path.into()));
        self
    }

    /// KV slot capacity in tokens (prompt + generated). Default 256.
    pub fn max_seq(mut self, n: usize) -> Self {
        self.max_seq = n;
        self
    }

    /// KV-cache storage precision the deployment targets (default
    /// f32); the serving layer reads it back via
    /// [`Engine::kv_precision`] when sizing the pool.
    pub fn kv_precision(mut self, p: KvPrecision) -> Self {
        self.kv_precision = p;
        self
    }

    /// Override the artifact's LoRA deployment mode (merge the deltas
    /// into the base at build, or adjoin them as a decode-time
    /// side path). No effect on artifacts without adapters or on
    /// store sources.
    pub fn lora(mut self, mode: LoraMode) -> Self {
        self.lora_mode = Some(mode);
        self
    }

    /// Pin the decode pool's lane count (`--threads N` on the CLI;
    /// clamped to >= 1). Default: a process-shared pool sized from
    /// `available_parallelism`. Results are identical at any count —
    /// the partitioning is static and order-preserving.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// Force dequantized-f32 weight residency — the PR-3-style
    /// f32-GEMM engine kept as parity oracle and bench baseline.
    /// Never the serving default: it materializes every projection at
    /// 4 B/param.
    pub fn f32_residency(mut self) -> Self {
        self.f32_residency = true;
        self
    }

    /// Sample every Nth instrumented forward call (`step_batch`,
    /// native `prefill`/`decode`) into the decode-phase profiler —
    /// `--profile-every N` on the CLI; 0 disables profiling entirely.
    /// Default [`DEFAULT_PROFILE_EVERY`]. Unsampled calls cost one
    /// relaxed atomic increment; sampled calls add lap timers that
    /// never touch activations, so logits are unchanged either way.
    pub fn profile_every(mut self, n: u32) -> Self {
        self.profile_every = n;
        self
    }

    /// Also retain raw per-lap [`crate::obs::PhaseEvent`]s (bounded)
    /// for Chrome-trace export. Aggregate phase totals are always
    /// kept; the raw events cost memory, so serving enables this only
    /// when `--trace-out`/`--events-out` asks for a trace.
    pub fn profile_events(mut self, on: bool) -> Self {
        self.profile_events = on;
        self
    }

    pub fn build(self, rt: &mut Runtime) -> Result<Engine> {
        let Some(source) = self.source else {
            bail!(
                "EngineBuilder needs a weight source: call .store(..) \
                 or .artifact(..) / .artifact_path(..)"
            );
        };
        let source = match source {
            Source::Path(p) => {
                Source::Artifact(Box::new(ModelArtifact::load(&p)?))
            }
            s => s,
        };
        let pool = match self.threads {
            Some(n) => Arc::new(ThreadPool::new(n)),
            None => parallel::shared(),
        };
        let residency =
            if self.f32_residency { "f32" } else { "quantized" };
        match source {
            Source::Store { store, bits } => {
                let mut dep = Deployed::from_store(&store, &bits);
                if self.f32_residency {
                    dep.to_f32_residency();
                }
                Engine::assemble(rt, dep, bits, self.max_seq,
                                 self.kv_precision, None, "none",
                                 pool, residency,
                                 self.profile_every,
                                 self.profile_events)
            }
            Source::Artifact(art) => {
                let (mut dep, bits, lora, default_mode) =
                    Deployed::from_artifact(*art)?;
                let mode = self.lora_mode.unwrap_or(default_mode);
                let (adjoin, label) = match (lora, mode) {
                    (None, _) => (None, "none"),
                    (Some(delta), LoraMode::Merge) => {
                        merge_lora_into(&mut dep.projs, &delta);
                        (None, "merged")
                    }
                    (Some(delta), LoraMode::Adjoin) => {
                        (Some(delta), "adjoined")
                    }
                };
                if self.f32_residency {
                    dep.to_f32_residency();
                }
                Engine::assemble(rt, dep, bits, self.max_seq,
                                 self.kv_precision, adjoin, label,
                                 pool, residency,
                                 self.profile_every,
                                 self.profile_events)
            }
            Source::Path(_) => unreachable!("path resolved above"),
        }
    }
}

/// Fold `W += s · B A` into every projection slab — merged-LoRA
/// deployment: one-time cost at build, zero per-token adapter cost.
/// Packed slabs are **re-quantized** into their original format, so
/// weight residency stays native (the delta lands on the quantization
/// grid — deployment semantics are `quantize(W_deq + s·BA)`).
fn merge_lora_into(projs: &mut [Vec<QuantSlab>], delta: &LoraDelta) {
    let s = delta.scaling();
    for (pi, per_layer) in projs.iter_mut().enumerate() {
        for (l, slab) in per_layer.iter_mut().enumerate() {
            let (ash, ad) = delta.tensors[2 * pi].slab(l);
            let (bsh, bd) = delta.tensors[2 * pi + 1].slab(l);
            let a_t = Tensor::new(ash, ad.to_vec());
            let b_t = Tensor::new(bsh, bd.to_vec());
            let ba = linalg::matmul(&b_t, &a_t).scale(s);
            let mut w = slab.dequantized();
            w.add_assign(&ba);
            let folded = match slab {
                QuantSlab::F32(_) => QuantSlab::F32(w),
                QuantSlab::Packed(q) => {
                    QuantSlab::Packed(quant::quantize(&w, q.fmt))
                }
            };
            *slab = folded;
        }
    }
}

/// `y[.., out] += s · (x A_lᵀ) B_lᵀ` for one layer's adjoined
/// adapter. Shared by the batched path (any `b`) and the per-session
/// reference path (`b == 1`), so both accumulate identically — the
/// parity suite covers adjoined decode for free. Adapters are tiny
/// (rank 8), so this stays on the serial f32 kernels.
fn adjoin_into(delta: &LoraDelta, proj_idx: usize, layer: usize,
               x: &[f32], b: usize, in_dim: usize, out_dim: usize,
               tmp: &mut [f32], y: &mut [f32]) {
    let (a, bw) = delta.layer_ab(proj_idx, layer);
    let r = delta.rank;
    let s = delta.scaling();
    let tmp = &mut tmp[..b * r];
    matmul_nt_into(x, b, in_dim, a, r, tmp);
    matmul_nt_scaled_acc_into(tmp, b, r, bw, out_dim, s,
                              &mut y[..b * out_dim]);
}

impl Engine {
    /// Pick a backend and precompute decode state over an
    /// already-deployed residency. Probes the runtime for the matching
    /// forward artifact; falls back to the native decode path when it
    /// is absent or the PJRT backend is not linked.
    #[allow(clippy::too_many_arguments)]
    fn assemble(rt: &mut Runtime, dep: Deployed, bits: BitConfig,
                max_seq: usize, kv_precision: KvPrecision,
                adjoin: Option<LoraDelta>, lora_label: &'static str,
                pool: Arc<ThreadPool>, residency: &'static str,
                profile_every: u32, profile_events: bool)
                -> Result<Engine> {
        ensure!(max_seq >= 2, "max_seq {max_seq} too small to serve");
        let cfg = dep.cfg.clone();
        let ps = dep.ps;
        let profiler = Arc::new(PhaseProfiler::new(
            cfg.n_layers,
            profile_every,
            profile_events,
            PHASE_EVENTS_CAP,
        ));

        let art = format!("fwd_{}_r{}", cfg.name, ps.rate_pct);
        let backend = if rt.has_artifact(&art) && max_seq <= cfg.seq {
            // the PJRT ABI takes the 12 f32 stacks as arguments:
            // materialize them for this backend only (native decode
            // stays quantized-resident)
            let store = dep.materialize_param_store();
            match rt.load(&art) {
                Ok(()) => {
                    // the AOT program takes LoRA args: pass the
                    // adjoined deltas when their shapes match the
                    // ABI, zeros otherwise (merged deltas are already
                    // folded into the base weights)
                    let abi = lora::LoraState::shapes(&store);
                    let lora_args: Vec<Tensor> = match &adjoin {
                        Some(d)
                            if d.tensors.len() == abi.len()
                                && d.tensors
                                    .iter()
                                    .zip(&abi)
                                    .all(|(t, s)| {
                                        t.shape() == s.as_slice()
                                    }) =>
                        {
                            d.tensors.clone()
                        }
                        _ => abi
                            .iter()
                            .map(|s| Tensor::zeros(s))
                            .collect(),
                    };
                    Backend::Artifact {
                        name: art,
                        weights: store.weights,
                        lora_args,
                    }
                }
                Err(e) => {
                    eprintln!(
                        "[serve] artifact {art} unusable ({e}); using \
                         native decode"
                    );
                    Backend::Native
                }
            }
        } else {
            Backend::Native
        };

        let head_dim = cfg.head_dim();
        ensure!(head_dim % 2 == 0, "RoPE needs even head_dim");
        let half = head_dim / 2;
        let mut rope_cos = vec![0.0f32; max_seq * half];
        let mut rope_sin = vec![0.0f32; max_seq * half];
        for p in 0..max_seq {
            for i in 0..half {
                let freq =
                    (10000.0f64).powf(-(i as f64) / half as f64);
                let ang = p as f64 * freq;
                rope_cos[p * half + i] = ang.cos() as f32;
                rope_sin[p * half + i] = ang.sin() as f32;
            }
        }
        let ws = DecodeWorkspace::new(
            cfg.d_model,
            ps.attn_dim(&cfg),
            ps.d_ff_kept,
            cfg.vocab,
            ps.heads_kept,
            max_seq,
            adjoin.as_ref().map(|d| d.rank).unwrap_or(0),
        );
        Ok(Engine {
            cfg,
            bits,
            ps,
            embed: dep.embed,
            attn_norm: dep.attn_norm,
            mlp_norm: dep.mlp_norm,
            final_norm: dep.final_norm,
            lm_head: dep.lm_head,
            projs: dep.projs,
            residency,
            backend,
            adjoin,
            lora_label,
            kv_precision,
            pool,
            profiler,
            rope_cos,
            rope_sin,
            half,
            max_seq,
            ws: RefCell::new(ws),
        })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn bits(&self) -> &BitConfig {
        &self.bits
    }

    pub fn pruned_shapes(&self) -> &PrunedShapes {
        &self.ps
    }

    /// KV-cache storage precision this deployment was built for.
    pub fn kv_precision(&self) -> KvPrecision {
        self.kv_precision
    }

    /// LoRA deployment: "none" | "merged" | "adjoined".
    pub fn lora_label(&self) -> &'static str {
        self.lora_label
    }

    /// Weight residency: "quantized" (native encodings, the default),
    /// "f32" (the forced oracle/bench materialization), or "f32-pjrt"
    /// when the PJRT artifact backend is active — its fixed ABI pins
    /// full f32 stacks regardless of how the slabs are encoded.
    pub fn residency_label(&self) -> &'static str {
        match self.backend {
            Backend::Artifact { .. } => "f32-pjrt",
            Backend::Native => self.residency,
        }
    }

    /// Decode pool lane count.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Host bytes the deployment weights actually pin: packed codes +
    /// f32 scales for quantized layers, 4 B/elem for fp16-format
    /// layers and the fp stacks — plus, when the PJRT artifact backend
    /// is active, the full f32 stacks its fixed ABI forces resident.
    /// On the native backend at the default quantized residency this
    /// equals `memory::weight_bytes_at(cfg, rate, bits)` and the
    /// artifact's native blob sizes — the acceptance invariant that no
    /// f32 weight materialization hides in the serving engine.
    pub fn weight_host_bytes(&self) -> usize {
        let fp = (self.embed.len()
            + self.attn_norm.len()
            + self.mlp_norm.len()
            + self.final_norm.len()
            + self.lm_head.len())
            * 4;
        let slabs = self
            .projs
            .iter()
            .flat_map(|per| per.iter())
            .map(|s| s.storage_bytes())
            .sum::<usize>();
        // the PJRT backend's materialized ABI args are real pinned
        // bytes: count them so the residency telemetry cannot
        // under-report exactly the case it exists to expose
        let backend = match &self.backend {
            Backend::Native => 0,
            Backend::Artifact { weights, lora_args, .. } => {
                weights.iter().map(|t| t.len() * 4).sum::<usize>()
                    + lora_args
                        .iter()
                        .map(|t| t.len() * 4)
                        .sum::<usize>()
            }
        };
        fp + slabs + backend
    }

    pub fn attn_dim(&self) -> usize {
        self.ps.attn_dim(&self.cfg)
    }

    /// The shape contract between this engine and a live KV pool:
    /// (layers, attn head dim, KV precision bits, vocab). Hot-swapping
    /// an engine under a pool that outlives it (`POST /admin/reload`)
    /// is only sound when the replacement's key matches — in-flight
    /// sessions keep their cached KV pages and the new weights decode
    /// against them.
    pub fn kv_shape_key(&self) -> (usize, usize, u32, usize) {
        (
            self.cfg.n_layers,
            self.attn_dim(),
            self.kv_precision.bits(),
            self.cfg.vocab,
        )
    }

    pub fn backend_label(&self) -> &'static str {
        match self.backend {
            Backend::Native => "native-kv",
            Backend::Artifact { .. } => "pjrt-artifact",
        }
    }

    /// True when decode runs through the native batched path
    /// ([`Engine::step_batch`]); the scheduler falls back to
    /// per-session [`Engine::decode`] calls for the artifact backend,
    /// which must re-forward full padded sequences anyway.
    pub fn is_native(&self) -> bool {
        matches!(self.backend, Backend::Native)
    }

    /// (growths, reuses) of the decode scratch since construction —
    /// the allocator-churn telemetry surfaced as
    /// `serve.scratch_grows` / `serve.scratch_reuses` in `Metrics`.
    /// Growths happen only when a step's batch exceeds every earlier
    /// batch; steady-state decode must be all reuses.
    pub fn scratch_stats(&self) -> (u64, u64) {
        self.ws.borrow().stats()
    }

    /// The engine's decode-phase profiler (aggregate accumulators +
    /// retained raw events for trace export).
    pub fn profiler(&self) -> &PhaseProfiler {
        &self.profiler
    }

    /// Profiler snapshot with the thread pool's per-lane busy time
    /// attached. On a shared (default) pool the lane counters
    /// aggregate every engine's sampled steps — utilization telemetry,
    /// not per-engine attribution; pin `--threads` for an exclusive
    /// pool.
    pub fn phase_snapshot(&self) -> PhaseSnapshot {
        let mut s = self.profiler.snapshot();
        s.lane_busy_secs = self
            .pool
            .lane_busy_ns()
            .iter()
            .map(|&n| n as f64 / 1e9)
            .collect();
        s
    }

    /// Start lap timing if the profiler samples this call; takes the
    /// workspace's reusable profiler scratch (returned by
    /// [`Engine::end_step_timer`]) and switches the pool's lane
    /// accounting on for the duration of the step.
    fn begin_step_timer(&self, ws: &mut DecodeWorkspace)
                        -> Option<StepTimer<'_>> {
        let step = self.profiler.sample_step()?;
        self.pool.set_profiling(true);
        Some(StepTimer::begin(
            &self.profiler,
            step,
            std::mem::take(&mut ws.phase_acc),
            std::mem::take(&mut ws.phase_events),
        ))
    }

    /// Commit a sampled step (no-op when this call was unsampled) and
    /// hand the scratch buffers back to the workspace.
    fn end_step_timer(&self, ws: &mut DecodeWorkspace,
                      timer: &mut Option<StepTimer<'_>>) {
        if let Some(t) = timer.take() {
            let (acc, events) = t.finish();
            self.pool.set_profiling(false);
            ws.phase_acc = acc;
            ws.phase_events = events;
        }
    }

    /// Embedding row for a token id — the shared OOB-clamp policy of
    /// `model::embed_row_clamped` (client-supplied garbage maps to the
    /// PAD row).
    fn embed_row(&self, token: i32) -> &[f32] {
        crate::model::embed_row_clamped(&self.embed, self.cfg.vocab,
                                        token)
    }

    /// Feed the prompt into a slot; returns the logits after its last
    /// token (from which the first new token samples). Resumable: a
    /// slot whose first `len` positions already hold the prompt's KV
    /// (prefix pages mapped by `KvCachePool::admit`) only computes the
    /// tail `len..prompt.len()` — values written for the tail are the
    /// same either way, so resumed prefill stays bit-identical to a
    /// cold one (pinned by `tests/parity_decode.rs`).
    pub fn prefill(&self, rt: &mut Runtime, mut slot: &mut KvSlot,
                   prompt: &[i32]) -> Result<Vec<f32>> {
        ensure!(!prompt.is_empty(), "prefill with empty prompt");
        ensure!(slot.len < prompt.len(),
                "prefill into a dirty slot ({} cached >= {} prompt \
                 tokens — at least the last position must be computed \
                 to produce logits)", slot.len, prompt.len());
        match &self.backend {
            Backend::Native => {
                // only the last position's logits are consumed, so the
                // [V, d] lm_head projection runs once, not per token
                let mut ws = self.ws.borrow_mut();
                // one sampling decision per prefill call: a sampled
                // prefill laps every (token, layer), accumulating the
                // whole prompt's phase profile
                let mut timer = self.begin_step_timer(&mut ws);
                let mut res = Ok(());
                let skip = slot.len;
                for (pos, &tok) in
                    prompt.iter().enumerate().skip(skip)
                {
                    // slot id is a placeholder: advance_batch pairs
                    // positionally and we pass the borrow directly
                    let req = [BatchReq { slot: 0, pos, token: tok }];
                    res = self.advance_batch(
                        &req,
                        std::slice::from_mut(&mut slot),
                        &mut ws,
                        &mut timer,
                    );
                    if res.is_err() {
                        break;
                    }
                }
                if res.is_ok() {
                    self.logits_batch(1, &mut ws, &mut timer);
                }
                self.end_step_timer(&mut ws, &mut timer);
                res?;
                Ok(ws.logits[..self.cfg.vocab].to_vec())
            }
            Backend::Artifact { name, weights, lora_args } => {
                let out = self.forward_artifact(rt, name, weights,
                                                lora_args, prompt)?;
                slot.advance_to(prompt.len());
                Ok(out)
            }
        }
    }

    /// One decode step for a session whose tokens so far are `prompt`
    /// then `generated`. The newest element of `generated` is the one
    /// token not yet in the KV cache: it is fed at position
    /// `prompt.len() + generated.len() - 1` and next-token logits come
    /// back. Taking the two slices (rather than a concatenated
    /// history) keeps the native hot path allocation-free; only the
    /// artifact backend materializes the full sequence, which it must
    /// pad into a fixed-shape buffer anyway.
    pub fn decode(&self, rt: &mut Runtime, mut slot: &mut KvSlot,
                  prompt: &[i32], generated: &[i32])
                  -> Result<Vec<f32>> {
        ensure!(!prompt.is_empty(), "decode with empty prompt");
        let len = prompt.len() + generated.len();
        let pos = len - 1;
        let token = *generated.last().unwrap_or_else(|| {
            prompt.last().expect("prompt checked non-empty")
        });
        match &self.backend {
            Backend::Native => {
                let mut ws = self.ws.borrow_mut();
                let mut timer = self.begin_step_timer(&mut ws);
                let req = [BatchReq { slot: 0, pos, token }];
                let res = self.advance_batch(
                    &req,
                    std::slice::from_mut(&mut slot),
                    &mut ws,
                    &mut timer,
                );
                if res.is_ok() {
                    self.logits_batch(1, &mut ws, &mut timer);
                }
                self.end_step_timer(&mut ws, &mut timer);
                res?;
                Ok(ws.logits[..self.cfg.vocab].to_vec())
            }
            Backend::Artifact { name, weights, lora_args } => {
                let history: Vec<i32> = prompt
                    .iter()
                    .chain(generated)
                    .copied()
                    .collect();
                let out = self.forward_artifact(rt, name, weights,
                                                lora_args, &history)?;
                slot.advance_to(len);
                Ok(out)
            }
        }
    }

    // ------------------------------------------------------------------
    // native batched path
    // ------------------------------------------------------------------

    /// One fused decode step over the whole active batch: per layer,
    /// one fused quantized GEMM per projection over the stacked
    /// `[batch, hidden]` activations (weights consumed in their native
    /// encodings, output rows split across the thread pool), then
    /// per-session attention against each KV slot with one session per
    /// pool lane (lengths may be ragged — each request carries its own
    /// `pos`). `on_logits(i, row)` is invoked once per request, in
    /// order, with that session's next-token logits — a callback
    /// rather than a return value so the logits never leave the
    /// reusable workspace. The callback runs while the engine's
    /// internal scratch is borrowed: it must not re-enter this engine
    /// (`decode`, `prefill`, `step_batch`, `scratch_stats` — nor the
    /// reference path, `prefill_reference`/`decode_reference`, whose
    /// final logits projection now shares the same workspace), or the
    /// `RefCell` will panic at runtime. Sample/record and return.
    ///
    /// All requests are validated before any cache *value* mutation,
    /// so an error leaves every slot's contents untouched (on the
    /// paged layout, pages may have been faulted in or privatized for
    /// the failed step — pure allocation, no KV values change, and the
    /// mapping is reused when the step retries). Native backend only.
    pub fn step_batch(
        &self,
        pool: &mut KvCachePool,
        reqs: &[BatchReq],
        mut on_logits: impl FnMut(usize, &[f32]),
    ) -> Result<()> {
        if reqs.is_empty() {
            return Ok(());
        }
        ensure!(
            self.is_native(),
            "step_batch requires the native backend; drive the \
             artifact backend through Engine::decode per session"
        );
        // paged layout: fault/privatize each session's write page
        // before borrowing the batch (a no-op on slab). The scheduler
        // pre-faults with preemption; this covers direct callers.
        for r in reqs {
            pool.ensure_capacity(r.slot, r.pos + 1)?;
        }
        let mut ws = self.ws.borrow_mut();
        ws.slot_ids.clear();
        ws.slot_ids.extend(reqs.iter().map(|r| r.slot));
        let mut slots = pool.slots_mut_many(&ws.slot_ids)?;
        let mut timer = self.begin_step_timer(&mut ws);
        let res =
            self.advance_batch(reqs, &mut slots, &mut ws, &mut timer);
        if res.is_ok() {
            self.logits_batch(reqs.len(), &mut ws, &mut timer);
        }
        self.end_step_timer(&mut ws, &mut timer);
        res?;
        let v = self.cfg.vocab;
        for i in 0..reqs.len() {
            on_logits(i, &ws.logits[i * v..(i + 1) * v]);
        }
        Ok(())
    }

    /// Run one token per session through all transformer blocks,
    /// updating each KV cache; leaves the final hidden states
    /// (pre final-norm) in `ws.hidden`. The lm_head projection lives
    /// in `logits_batch` so prefill can skip it for all but the last
    /// position.
    ///
    /// Pairing is positional: `reqs[i]` drives `slots[i]`, and
    /// `BatchReq::slot` is *not* read here — only the public
    /// `step_batch` resolves slot ids (via the pool); internal batch-1
    /// callers pass a placeholder id with the slot borrow itself.
    /// When `timer` is `Some` (a profiler-sampled step), lap
    /// boundaries tile the whole call: qkv GEMMs → `Qkv`, adjoined
    /// side paths → `Lora`, rope + KV write + attention + wo → `Attn`,
    /// norms/SwiGLU GEMMs/residuals → `Mlp` (the lm_head lap lives in
    /// `logits_batch` as `Vocab`). Timing never touches activations,
    /// so logits are bit-identical with profiling on or off.
    fn advance_batch(&self, reqs: &[BatchReq],
                     slots: &mut [&mut KvSlot],
                     ws: &mut DecodeWorkspace,
                     timer: &mut Option<StepTimer<'_>>) -> Result<()> {
        debug_assert_eq!(reqs.len(), slots.len());
        let b = reqs.len();
        // validate everything up front: no slot is written until every
        // request is known to be in range and in sync
        for (r, slot) in reqs.iter().zip(slots.iter()) {
            ensure!(
                r.pos < self.max_seq,
                "position {} exceeds KV capacity {}",
                r.pos,
                self.max_seq
            );
            ensure!(
                r.pos == slot.len,
                "KV desync: pos {} vs cached {}",
                r.pos,
                slot.len
            );
        }
        ws.ensure_batch(b);
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let a = self.attn_dim();
        let f = self.ps.d_ff_kept;
        let heads = self.ps.heads_kept;
        let hd = cfg.head_dim();
        let ms = self.max_seq;
        let pool = &*self.pool;

        for (i, r) in reqs.iter().enumerate() {
            ws.hidden[i * d..(i + 1) * d]
                .copy_from_slice(self.embed_row(r.token));
        }
        for l in 0..cfg.n_layers {
            // ---- attention block ----
            let gain = self.attn_norm.slab(l).1;
            for i in 0..b {
                rmsnorm(&ws.hidden[i * d..(i + 1) * d], gain,
                        &mut ws.normed[i * d..(i + 1) * d]);
            }
            // q/k/v in one pool dispatch: each lane walks its row
            // chunk of all three slabs
            matmul_nt_slabs_into(
                pool,
                &ws.normed[..b * d],
                b,
                d,
                &mut [
                    (&self.projs[0][l], &mut ws.q[..b * a]),
                    (&self.projs[1][l], &mut ws.k[..b * a]),
                    (&self.projs[2][l], &mut ws.v[..b * a]),
                ],
            );
            lap(timer, Phase::Qkv, l);
            if let Some(delta) = &self.adjoin {
                adjoin_into(delta, 0, l, &ws.normed[..b * d], b, d, a,
                            &mut ws.lora_tmp, &mut ws.q);
                adjoin_into(delta, 1, l, &ws.normed[..b * d], b, d, a,
                            &mut ws.lora_tmp, &mut ws.k);
                adjoin_into(delta, 2, l, &ws.normed[..b * d], b, d, a,
                            &mut ws.lora_tmp, &mut ws.v);
                lap(timer, Phase::Lora, l);
            }
            for (i, r) in reqs.iter().enumerate() {
                self.rope_inplace(&mut ws.q[i * a..(i + 1) * a],
                                  r.pos, heads, hd);
                self.rope_inplace(&mut ws.k[i * a..(i + 1) * a],
                                  r.pos, heads, hd);
                slots[i].write(l, r.pos, &ws.k[i * a..(i + 1) * a],
                               &ws.v[i * a..(i + 1) * a]);
            }

            // causal attention: one session per pool lane, each lane
            // confined to its sessions' disjoint workspace regions
            // (scores/kv_row/ctx are laid out per session)
            let inv = 1.0 / (hd as f32).sqrt();
            let stride = ws.scores_stride();
            {
                let q_all = &ws.q[..b * a];
                let scores = SyncPtr::new(&mut ws.scores);
                let kv_scratch = SyncPtr::new(&mut ws.kv_row);
                let ctx = SyncPtr::new(&mut ws.ctx);
                let slots_ro: &[&mut KvSlot] = &*slots;
                let lanes = pool.threads();
                pool.run(&|lane| {
                    for i in chunk_range(b, lane, lanes) {
                        // SAFETY: session i's regions are touched by
                        // exactly one lane (chunk_range partitions
                        // 0..b disjointly).
                        let sc = unsafe {
                            scores.slice_mut(i * stride, stride)
                        };
                        let kr = unsafe {
                            kv_scratch.slice_mut(i * a, a)
                        };
                        let cx =
                            unsafe { ctx.slice_mut(i * a, a) };
                        let slot: &KvSlot = &*slots_ro[i];
                        let q = &q_all[i * a..(i + 1) * a];
                        let n_t = reqs[i].pos + 1;
                        for t in 0..n_t {
                            let krow = slot.k_row(l, t, &mut *kr);
                            for h in 0..heads {
                                let o = h * hd;
                                let mut dot = 0.0f32;
                                for (qi, ki) in q[o..o + hd]
                                    .iter()
                                    .zip(&krow[o..o + hd])
                                {
                                    dot += qi * ki;
                                }
                                sc[h * ms + t] = dot * inv;
                            }
                        }
                        for h in 0..heads {
                            softmax_inplace(
                                &mut sc[h * ms..h * ms + n_t]);
                        }
                        cx.fill(0.0);
                        for t in 0..n_t {
                            let vrow = slot.v_row(l, t, &mut *kr);
                            for h in 0..heads {
                                let p = sc[h * ms + t];
                                let o = h * hd;
                                for (c, &vi) in cx[o..o + hd]
                                    .iter_mut()
                                    .zip(&vrow[o..o + hd])
                                {
                                    *c += p * vi;
                                }
                            }
                        }
                    }
                });
            }
            matmul_nt_slab_into(pool, &ws.ctx[..b * a], b, a,
                                &self.projs[3][l],
                                &mut ws.proj_d[..b * d]);
            lap(timer, Phase::Attn, l);
            if let Some(delta) = &self.adjoin {
                adjoin_into(delta, 3, l, &ws.ctx[..b * a], b, a, d,
                            &mut ws.lora_tmp, &mut ws.proj_d);
                lap(timer, Phase::Lora, l);
            }
            for (hi, &oi) in ws.hidden[..b * d]
                .iter_mut()
                .zip(&ws.proj_d[..b * d])
            {
                *hi += oi;
            }

            // ---- SwiGLU MLP block ----
            let gain2 = self.mlp_norm.slab(l).1;
            for i in 0..b {
                rmsnorm(&ws.hidden[i * d..(i + 1) * d], gain2,
                        &mut ws.normed[i * d..(i + 1) * d]);
            }
            matmul_nt_slabs_into(
                pool,
                &ws.normed[..b * d],
                b,
                d,
                &mut [
                    (&self.projs[4][l], &mut ws.gate[..b * f]),
                    (&self.projs[5][l], &mut ws.up[..b * f]),
                ],
            );
            lap(timer, Phase::Mlp, l);
            if let Some(delta) = &self.adjoin {
                adjoin_into(delta, 4, l, &ws.normed[..b * d], b, d, f,
                            &mut ws.lora_tmp, &mut ws.gate);
                adjoin_into(delta, 5, l, &ws.normed[..b * d], b, d, f,
                            &mut ws.lora_tmp, &mut ws.up);
                lap(timer, Phase::Lora, l);
            }
            for (g, &u) in ws.gate[..b * f]
                .iter_mut()
                .zip(&ws.up[..b * f])
            {
                let s = 1.0 / (1.0 + (-*g).exp()); // silu
                *g = *g * s * u;
            }
            matmul_nt_slab_into(pool, &ws.gate[..b * f], b, f,
                                &self.projs[6][l],
                                &mut ws.proj_d[..b * d]);
            lap(timer, Phase::Mlp, l);
            if let Some(delta) = &self.adjoin {
                adjoin_into(delta, 6, l, &ws.gate[..b * f], b, f, d,
                            &mut ws.lora_tmp, &mut ws.proj_d);
                lap(timer, Phase::Lora, l);
            }
            for (hi, &di) in ws.hidden[..b * d]
                .iter_mut()
                .zip(&ws.proj_d[..b * d])
            {
                *hi += di;
            }
            lap(timer, Phase::Mlp, l);
        }
        for (r, slot) in reqs.iter().zip(slots.iter_mut()) {
            slot.advance_to(r.pos + 1);
        }
        Ok(())
    }

    /// Final RMSNorm + one `[batch, vocab]` lm_head GEMM over
    /// `ws.hidden`, into `ws.logits` — vocab rows split across the
    /// pool (the lm_head stack is always f32-resident).
    fn logits_batch(&self, b: usize, ws: &mut DecodeWorkspace,
                    timer: &mut Option<StepTimer<'_>>) {
        let d = self.cfg.d_model;
        let v = self.cfg.vocab;
        let gain = self.final_norm.data();
        for i in 0..b {
            rmsnorm(&ws.hidden[i * d..(i + 1) * d], gain,
                    &mut ws.normed[i * d..(i + 1) * d]);
        }
        par_matmul_nt_into(&self.pool, &ws.normed[..b * d], b, d,
                           self.lm_head.data(), v,
                           &mut ws.logits[..b * v]);
        lap(timer, Phase::Vocab, 0);
    }

    // ------------------------------------------------------------------
    // per-session reference path (parity oracle + bench baseline)
    // ------------------------------------------------------------------

    /// Per-session matvec prefill — the pre-GEMM implementation, kept
    /// as the differential-testing oracle (`tests/parity_decode.rs`)
    /// and the `bench_serve` baseline. Allocates per token; never on
    /// the production path. (On quantized-residency engines the
    /// matvecs decode the slabs on the fly with the shared
    /// accumulation order, so its numerics equal the old
    /// f32-materialized reference exactly.)
    pub fn prefill_reference(&self, slot: &mut KvSlot,
                             prompt: &[i32]) -> Result<Vec<f32>> {
        ensure!(!prompt.is_empty(), "prefill with empty prompt");
        ensure!(slot.len < prompt.len(),
                "prefill into a dirty slot ({} cached >= {} prompt \
                 tokens)", slot.len, prompt.len());
        let mut hidden = Vec::new();
        for (pos, &tok) in prompt.iter().enumerate().skip(slot.len) {
            hidden = self.advance_hidden_ref(slot, pos, tok)?;
        }
        Ok(self.logits_from_hidden(&hidden))
    }

    /// Per-session matvec decode of one token; see
    /// [`Engine::prefill_reference`].
    pub fn decode_reference(&self, slot: &mut KvSlot, pos: usize,
                            token: i32) -> Result<Vec<f32>> {
        ensure!(
            pos == slot.len,
            "KV desync: pos {pos} vs cached {}",
            slot.len
        );
        let h = self.advance_hidden_ref(slot, pos, token)?;
        Ok(self.logits_from_hidden(&h))
    }

    /// Run one token through all transformer blocks with per-row
    /// matvecs, updating the KV cache; returns the final hidden state
    /// (pre final-norm).
    fn advance_hidden_ref(&self, slot: &mut KvSlot, pos: usize,
                          token: i32) -> Result<Vec<f32>> {
        ensure!(
            pos < self.max_seq,
            "position {pos} exceeds KV capacity {}",
            self.max_seq
        );
        ensure!(
            pos == slot.len,
            "KV desync: pos {pos} vs cached {}",
            slot.len
        );
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let a = self.attn_dim();
        let f = self.ps.d_ff_kept;
        let heads = self.ps.heads_kept;
        let hd = cfg.head_dim();
        let mut scratch = vec![0.0f32; a];
        let mut lora_tmp = vec![
            0.0f32;
            self.adjoin.as_ref().map(|x| x.rank).unwrap_or(0)
        ];

        let mut h = self.embed_row(token).to_vec();
        let mut hn = vec![0.0f32; d];
        for l in 0..cfg.n_layers {
            // attention block
            rmsnorm(&h, self.attn_norm.slab(l).1, &mut hn);
            let mut q = linalg::matvec_slab(&self.projs[0][l], &hn);
            let mut k = linalg::matvec_slab(&self.projs[1][l], &hn);
            let mut v = linalg::matvec_slab(&self.projs[2][l], &hn);
            if let Some(delta) = &self.adjoin {
                adjoin_into(delta, 0, l, &hn, 1, d, a,
                            &mut lora_tmp, &mut q);
                adjoin_into(delta, 1, l, &hn, 1, d, a,
                            &mut lora_tmp, &mut k);
                adjoin_into(delta, 2, l, &hn, 1, d, a,
                            &mut lora_tmp, &mut v);
            }
            self.rope_inplace(&mut q, pos, heads, hd);
            self.rope_inplace(&mut k, pos, heads, hd);
            slot.write(l, pos, &k, &v);

            let mut ctx = vec![0.0f32; a];
            let inv = 1.0 / (hd as f32).sqrt();
            let mut scores = vec![0.0f32; pos + 1];
            for head in 0..heads {
                let o = head * hd;
                for (t, s) in scores.iter_mut().enumerate() {
                    let kt =
                        &slot.k_row(l, t, &mut scratch)[o..o + hd];
                    let mut dot = 0.0f32;
                    for (qi, ki) in q[o..o + hd].iter().zip(kt) {
                        dot += qi * ki;
                    }
                    *s = dot * inv;
                }
                softmax_inplace(&mut scores);
                for (t, &p) in scores.iter().enumerate() {
                    let vt =
                        &slot.v_row(l, t, &mut scratch)[o..o + hd];
                    for (c, &vi) in ctx[o..o + hd].iter_mut().zip(vt) {
                        *c += p * vi;
                    }
                }
            }
            let mut attn_out =
                linalg::matvec_slab(&self.projs[3][l], &ctx);
            if let Some(delta) = &self.adjoin {
                adjoin_into(delta, 3, l, &ctx, 1, a, d,
                            &mut lora_tmp, &mut attn_out);
            }
            for (hi, &oi) in h.iter_mut().zip(&attn_out) {
                *hi += oi;
            }

            // SwiGLU MLP block
            rmsnorm(&h, self.mlp_norm.slab(l).1, &mut hn);
            let mut gate =
                linalg::matvec_slab(&self.projs[4][l], &hn);
            let mut up = linalg::matvec_slab(&self.projs[5][l], &hn);
            if let Some(delta) = &self.adjoin {
                adjoin_into(delta, 4, l, &hn, 1, d, f,
                            &mut lora_tmp, &mut gate);
                adjoin_into(delta, 5, l, &hn, 1, d, f,
                            &mut lora_tmp, &mut up);
            }
            for (g, &u) in gate.iter_mut().zip(&up) {
                let s = 1.0 / (1.0 + (-*g).exp()); // silu
                *g = *g * s * u;
            }
            let mut down =
                linalg::matvec_slab(&self.projs[6][l], &gate);
            if let Some(delta) = &self.adjoin {
                adjoin_into(delta, 6, l, &gate, 1, f, d,
                            &mut lora_tmp, &mut down);
            }
            for (hi, &di) in h.iter_mut().zip(&down) {
                *hi += di;
            }
        }
        slot.advance_to(pos + 1);
        Ok(h)
    }

    /// Final RMSNorm + lm_head `[V, d]` projection (reference path).
    /// Scratch comes from the decode workspace — counted by the
    /// `serve.scratch_*` telemetry like every other decode buffer —
    /// instead of two fresh `Vec`s per sampled token, and the vocab
    /// rows run on the pool like the batched path's.
    fn logits_from_hidden(&self, h: &[f32]) -> Vec<f32> {
        let d = self.cfg.d_model;
        let v = self.cfg.vocab;
        let mut ws = self.ws.borrow_mut();
        ws.ensure_batch(1);
        let ws = &mut *ws;
        rmsnorm(h, self.final_norm.data(), &mut ws.normed[..d]);
        par_matmul_nt_into(&self.pool, &ws.normed[..d], 1, d,
                           self.lm_head.data(), v,
                           &mut ws.logits[..v]);
        ws.logits[..v].to_vec()
    }

    /// Rotate q/k `[heads, head_dim]` (flattened) at position `pos`.
    fn rope_inplace(&self, x: &mut [f32], pos: usize, heads: usize,
                    hd: usize) {
        let half = self.half;
        let cos = &self.rope_cos[pos * half..(pos + 1) * half];
        let sin = &self.rope_sin[pos * half..(pos + 1) * half];
        for head in 0..heads {
            let o = head * hd;
            for i in 0..half {
                let x1 = x[o + i];
                let x2 = x[o + half + i];
                x[o + i] = x1 * cos[i] - x2 * sin[i];
                x[o + half + i] = x2 * cos[i] + x1 * sin[i];
            }
        }
    }

    // ------------------------------------------------------------------
    // artifact (PJRT) path
    // ------------------------------------------------------------------

    fn forward_artifact(&self, rt: &mut Runtime, name: &str,
                        weights: &[Tensor], lora_args: &[Tensor],
                        history: &[i32]) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        ensure!(
            history.len() <= cfg.seq,
            "history {} exceeds artifact seq {}",
            history.len(),
            cfg.seq
        );
        // fixed-shape [batch, seq] program: row 0 carries the session,
        // the rest is PAD
        let mut tokens = vec![0i32; cfg.batch * cfg.seq];
        tokens[..history.len()].copy_from_slice(history);
        let shape = [cfg.batch, cfg.seq];
        let mut args: Vec<Arg> = Vec::with_capacity(12 + 14 + 1);
        for w in weights {
            args.push(Arg::F32(w));
        }
        for t in lora_args {
            args.push(Arg::F32(t));
        }
        args.push(Arg::I32(&tokens, &shape));
        let out = rt.exec_f32(name, &args)?;
        // out[0]: [B, S, V]; session in row 0, logits at its last token
        let v = cfg.vocab;
        let at = (history.len() - 1) * v;
        Ok(out[0].data()[at..at + v].to_vec())
    }
}

/// RMSNorm matching `model.py` (`eps = 1e-6`).
fn rmsnorm(x: &[f32], gain: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), gain.len());
    let ms: f32 =
        x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    for ((o, &xi), &g) in out.iter_mut().zip(x).zip(gain) {
        *o = xi * inv * g;
    }
}

fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

/// Sample a token id from logits: greedy at `temperature <= 0`, else
/// temperature-scaled categorical.
pub fn sample_token(logits: &[f32], temperature: f32, rng: &mut Rng)
                    -> i32 {
    if temperature <= 0.0 {
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        return best as i32;
    }
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let weights: Vec<f64> = logits
        .iter()
        .map(|&x| (((x - m) / temperature) as f64).exp())
        .collect();
    rng.categorical(&weights) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::{ModelArtifact, Provenance};
    use crate::memory;
    use crate::quant::QuantFormat;
    use crate::serve::kv_cache::{KvCachePool, KvPrecision};

    fn setup_p(fmt: QuantFormat, n_slots: usize,
               precision: KvPrecision)
               -> (Runtime, Engine, KvCachePool) {
        let dir = std::env::temp_dir().join("qpruner_serve_engine_t");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 11);
        let bits = BitConfig::uniform(cfg.n_layers, fmt);
        let eng = EngineBuilder::new()
            .store(&store, &bits)
            .max_seq(24)
            .build(&mut rt)
            .unwrap();
        let a = eng.attn_dim();
        let pool = KvCachePool::with_slots(&cfg, a, n_slots, 24,
                                           precision, 1.0,
                                           n_slots as f64);
        (rt, eng, pool)
    }

    fn setup(fmt: QuantFormat) -> (Runtime, Engine, KvCachePool) {
        setup_p(fmt, 2, KvPrecision::F32)
    }

    #[test]
    fn native_backend_without_artifacts() {
        let (_rt, eng, _pool) = setup(QuantFormat::Nf4);
        assert_eq!(eng.backend_label(), "native-kv");
        assert!(eng.is_native());
        assert_eq!(eng.lora_label(), "none");
        assert_eq!(eng.kv_precision(), KvPrecision::F32);
        assert_eq!(eng.residency_label(), "quantized");
        assert!(eng.threads() >= 1);
    }

    #[test]
    fn builder_without_source_is_an_error() {
        let dir = std::env::temp_dir().join("qpruner_serve_engine_t");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        assert!(EngineBuilder::new().build(&mut rt).is_err());
    }

    #[test]
    fn builder_records_kv_precision() {
        let dir = std::env::temp_dir().join("qpruner_serve_engine_t");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 11);
        let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
        let eng = EngineBuilder::new()
            .store(&store, &bits)
            .max_seq(16)
            .kv_precision(KvPrecision::Int8)
            .build(&mut rt)
            .unwrap();
        assert_eq!(eng.kv_precision(), KvPrecision::Int8);
    }

    /// The no-f32-materialization acceptance invariant: the engine's
    /// resident weight bytes equal the analytic model *and* the
    /// artifact's native blob sizes, and sit far below an f32
    /// materialization of the projections.
    #[test]
    fn quantized_residency_matches_memory_model_and_artifact() {
        let (_rt, eng, _pool) = setup(QuantFormat::Nf4);
        let cfg = eng.cfg().clone();
        let rate = eng.pruned_shapes().rate_pct;
        let got = eng.weight_host_bytes() as f64;
        let want = memory::weight_bytes_at(&cfg, rate, eng.bits());
        assert_eq!(got, want, "engine residency != analytic model");
        // identical to the artifact's native storage (no LoRA)
        let store = ParamStore::init(&cfg, 11);
        let art = ModelArtifact::from_pipeline(
            &store, eng.bits(), None, LoraMode::Merge,
            Provenance::default(),
        )
        .unwrap();
        assert_eq!(eng.weight_host_bytes(), art.storage_bytes());
        // nf4 projections resident at ~0.56 B/param, not 4 B/param
        let ps = *eng.pruned_shapes();
        let mut proj_params = 0usize;
        for p in PROJS {
            let (o, i) = cfg.proj_shape(&ps, p);
            proj_params += o * i;
        }
        proj_params *= cfg.n_layers;
        let fp_params = 2 * cfg.vocab * cfg.d_model
            + cfg.d_model
            + 2 * cfg.n_layers * cfg.d_model;
        let proj_bytes = eng.weight_host_bytes() - 4 * fp_params;
        assert!(
            (proj_bytes as f64) < 0.6 * proj_params as f64,
            "nf4 projections pin {proj_bytes} B for {proj_params} \
             params — f32 materialization is hiding somewhere"
        );
    }

    /// The fused quantized kernels share the accumulation order of the
    /// f32 GEMM on dequantized weights, so a forced-f32-residency
    /// engine (the PR-3 bench baseline) must produce bit-identical
    /// logits to the native quantized-residency engine.
    #[test]
    fn f32_residency_oracle_is_bit_identical_to_native() {
        let dir = std::env::temp_dir().join("qpruner_serve_engine_t");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 11);
        let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
        let native = EngineBuilder::new()
            .store(&store, &bits)
            .max_seq(24)
            .build(&mut rt)
            .unwrap();
        let oracle = EngineBuilder::new()
            .store(&store, &bits)
            .max_seq(24)
            .f32_residency()
            .build(&mut rt)
            .unwrap();
        assert_eq!(native.residency_label(), "quantized");
        assert_eq!(oracle.residency_label(), "f32");
        assert!(
            oracle.weight_host_bytes() > native.weight_host_bytes()
        );
        let prompt = [3i32, 9, 14, 5];
        let mut pn = KvCachePool::with_slots(
            &cfg, native.attn_dim(), 1, 24, KvPrecision::F32, 1.0,
            1.0,
        );
        let mut po = KvCachePool::with_slots(
            &cfg, oracle.attn_dim(), 1, 24, KvPrecision::F32, 1.0,
            1.0,
        );
        let a = pn.alloc().unwrap();
        let b = po.alloc().unwrap();
        let ln =
            native.prefill(&mut rt, pn.slot_mut(a), &prompt).unwrap();
        let lo =
            oracle.prefill(&mut rt, po.slot_mut(b), &prompt).unwrap();
        assert_eq!(ln, lo, "residencies diverged");
        let reqs =
            [BatchReq { slot: a, pos: prompt.len(), token: 17 }];
        let mut gn = Vec::new();
        native
            .step_batch(&mut pn, &reqs, |_, l| gn = l.to_vec())
            .unwrap();
        let reqs =
            [BatchReq { slot: b, pos: prompt.len(), token: 17 }];
        let mut go = Vec::new();
        oracle
            .step_batch(&mut po, &reqs, |_, l| go = l.to_vec())
            .unwrap();
        assert_eq!(gn, go, "step_batch residencies diverged");
    }

    /// Random LoRA deltas on a quantized base: the artifact-built
    /// engine must decode identically between its batched and
    /// reference paths in both deployment modes. Merged deployment
    /// now *re-quantizes* the folded base (residency stays native),
    /// so merged vs adjoined agree only up to that quantization of
    /// the delta — checked as strong directional alignment.
    #[test]
    fn merged_and_adjoined_lora_decode_agree() {
        let dir = std::env::temp_dir().join("qpruner_serve_engine_t");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 11);
        let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
        let mut rng = Rng::new(4);
        let prep = lora::init_loftq(&store, &bits, 1, &mut rng)
            .unwrap();
        let art = ModelArtifact::from_pipeline(
            &prep.base,
            &bits,
            Some(crate::artifact::LoraDelta::from_state(&prep.lora)),
            LoraMode::Adjoin,
            Provenance::default(),
        )
        .unwrap();
        let prompt = [3i32, 9, 14, 5];
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for mode in [LoraMode::Merge, LoraMode::Adjoin] {
            let eng = EngineBuilder::new()
                .artifact(art.clone())
                .lora(mode)
                .max_seq(24)
                .build(&mut rt)
                .unwrap();
            assert_eq!(
                eng.lora_label(),
                if mode == LoraMode::Merge { "merged" }
                else { "adjoined" }
            );
            // batched path
            let mut pool = KvCachePool::with_slots(
                &cfg, eng.attn_dim(), 2, 24, KvPrecision::F32, 1.0,
                2.0,
            );
            let id = pool.alloc().unwrap();
            eng.prefill(&mut rt, pool.slot_mut(id), &prompt).unwrap();
            let reqs =
                [BatchReq { slot: id, pos: prompt.len(), token: 17 }];
            let mut got = Vec::new();
            eng.step_batch(&mut pool, &reqs, |_, l| got = l.to_vec())
                .unwrap();
            // reference path of the same engine: must match batched
            let rid = pool.alloc().unwrap();
            eng.prefill_reference(pool.slot_mut(rid), &prompt)
                .unwrap();
            let want = eng
                .decode_reference(pool.slot_mut(rid), prompt.len(), 17)
                .unwrap();
            for (x, y) in got.iter().zip(&want) {
                assert!(
                    (x - y).abs() < 1e-4,
                    "{mode:?}: batched {x} vs reference {y}"
                );
            }
            outs.push(got);
        }
        // merged vs adjoined differ by the re-quantization of the
        // folded delta: require strong directional alignment
        let dot: f64 = outs[0]
            .iter()
            .zip(&outs[1])
            .map(|(x, y)| (*x as f64) * (*y as f64))
            .sum();
        let n0: f64 =
            outs[0].iter().map(|x| (*x as f64).powi(2)).sum();
        let n1: f64 =
            outs[1].iter().map(|x| (*x as f64).powi(2)).sum();
        let cos = dot / (n0.sqrt() * n1.sqrt()).max(1e-12);
        assert!(cos > 0.95, "merge vs adjoin drifted: cos {cos}");
    }

    /// With all-zero adapters the adjoined side path must be an exact
    /// no-op: same logits as the adapter-free engine.
    #[test]
    fn zero_adjoined_lora_is_identity() {
        let dir = std::env::temp_dir().join("qpruner_serve_engine_t");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 11);
        let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
        let zeros = lora::LoraState::zeros(&store);
        let art = ModelArtifact::from_pipeline(
            &store,
            &bits,
            Some(crate::artifact::LoraDelta::from_state(&zeros)),
            LoraMode::Adjoin,
            Provenance::default(),
        )
        .unwrap();
        let eng_lora = EngineBuilder::new()
            .artifact(art)
            .max_seq(24)
            .build(&mut rt)
            .unwrap();
        let (mut rt2, eng_plain, mut pool_plain) =
            setup(QuantFormat::Nf4);
        let mut pool = KvCachePool::with_slots(
            &cfg, eng_lora.attn_dim(), 1, 24, KvPrecision::F32, 1.0,
            1.0,
        );
        let prompt = [3i32, 9, 14, 5];
        let a = pool.alloc().unwrap();
        let b = pool_plain.alloc().unwrap();
        let la = eng_lora
            .prefill(&mut rt, pool.slot_mut(a), &prompt)
            .unwrap();
        let lb = eng_plain
            .prefill(&mut rt2, pool_plain.slot_mut(b), &prompt)
            .unwrap();
        assert_eq!(la, lb, "zero adapters changed the logits");
    }

    #[test]
    fn prefill_then_decode_produces_finite_logits() {
        let (mut rt, eng, mut pool) = setup(QuantFormat::Nf4);
        let id = pool.alloc().unwrap();
        let prompt = [3i32, 9, 14, 5];
        let logits =
            eng.prefill(&mut rt, pool.slot_mut(id), &prompt).unwrap();
        assert_eq!(logits.len(), eng.cfg().vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(pool.slot(id).len, prompt.len());
        // one decode step
        let tok = sample_token(&logits, 0.0, &mut Rng::new(1));
        let l2 = eng
            .decode(&mut rt, pool.slot_mut(id), &prompt, &[tok])
            .unwrap();
        assert!(l2.iter().all(|x| x.is_finite()));
        assert_eq!(pool.slot(id).len, prompt.len() + 1);
    }

    #[test]
    fn incremental_decode_matches_fresh_prefill() {
        // KV-cache decode must equal recomputing the whole prefix
        let (mut rt, eng, mut pool) = setup(QuantFormat::Nf4);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let prompt = [3i32, 9, 14, 5, 7];
        // path A: prefill 4, then decode token 5
        let _ = eng
            .prefill(&mut rt, pool.slot_mut(a), &prompt[..4])
            .unwrap();
        let la = eng
            .decode(&mut rt, pool.slot_mut(a), &prompt[..4],
                    &prompt[4..])
            .unwrap();
        // path B: prefill all 5 at once
        let lb = eng.prefill(&mut rt, pool.slot_mut(b), &prompt).unwrap();
        for (x, y) in la.iter().zip(&lb) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn batched_step_matches_reference_decode() {
        // two staggered sessions decoded in one fused step must equal
        // the per-session matvec oracle
        let (mut rt, eng, mut pool) = setup(QuantFormat::Nf4);
        let s0 = pool.alloc().unwrap();
        let s1 = pool.alloc().unwrap();
        let p0 = [3i32, 9, 14];
        let p1 = [5i32, 7, 11, 2, 30];
        eng.prefill(&mut rt, pool.slot_mut(s0), &p0).unwrap();
        eng.prefill(&mut rt, pool.slot_mut(s1), &p1).unwrap();
        // oracle sessions with identical state
        let (_, _, mut ref_pool) =
            setup_p(QuantFormat::Nf4, 2, KvPrecision::F32);
        let r0 = ref_pool.alloc().unwrap();
        let r1 = ref_pool.alloc().unwrap();
        eng.prefill_reference(ref_pool.slot_mut(r0), &p0).unwrap();
        eng.prefill_reference(ref_pool.slot_mut(r1), &p1).unwrap();
        let want0 = eng
            .decode_reference(ref_pool.slot_mut(r0), p0.len(), 17)
            .unwrap();
        let want1 = eng
            .decode_reference(ref_pool.slot_mut(r1), p1.len(), 19)
            .unwrap();
        let reqs = [
            BatchReq { slot: s0, pos: p0.len(), token: 17 },
            BatchReq { slot: s1, pos: p1.len(), token: 19 },
        ];
        let mut got: Vec<Vec<f32>> = vec![Vec::new(); 2];
        eng.step_batch(&mut pool, &reqs, |i, l| {
            got[i] = l.to_vec();
        })
        .unwrap();
        for (x, y) in got[0].iter().zip(&want0) {
            assert!((x - y).abs() < 1e-4, "s0 {x} vs {y}");
        }
        for (x, y) in got[1].iter().zip(&want1) {
            assert!((x - y).abs() < 1e-4, "s1 {x} vs {y}");
        }
        assert_eq!(pool.slot(s0).len, p0.len() + 1);
        assert_eq!(pool.slot(s1).len, p1.len() + 1);
    }

    #[test]
    fn step_batch_validates_before_mutating() {
        let (mut rt, eng, mut pool) = setup(QuantFormat::Nf4);
        let s0 = pool.alloc().unwrap();
        let s1 = pool.alloc().unwrap();
        eng.prefill(&mut rt, pool.slot_mut(s0), &[3, 4]).unwrap();
        eng.prefill(&mut rt, pool.slot_mut(s1), &[5, 6, 7]).unwrap();
        // second request desynced (pos != len): nothing may advance
        let reqs = [
            BatchReq { slot: s0, pos: 2, token: 9 },
            BatchReq { slot: s1, pos: 9, token: 9 },
        ];
        assert!(eng
            .step_batch(&mut pool, &reqs, |_, _| {})
            .is_err());
        assert_eq!(pool.slot(s0).len, 2, "slot mutated before validation");
        assert_eq!(pool.slot(s1).len, 3);
        // aliased slots are refused too
        let dup = [
            BatchReq { slot: s0, pos: 2, token: 9 },
            BatchReq { slot: s0, pos: 2, token: 9 },
        ];
        assert!(eng.step_batch(&mut pool, &dup, |_, _| {}).is_err());
    }

    #[test]
    fn int8_kv_decode_tracks_f32_kv() {
        // quantized KV perturbs logits only within the blockwise-int8
        // error budget: the two paths must stay strongly aligned
        let (mut rt, eng, mut pf) =
            setup_p(QuantFormat::Fp16, 1, KvPrecision::F32);
        let (_, _, mut pi) =
            setup_p(QuantFormat::Fp16, 1, KvPrecision::Int8);
        let prompt = [3i32, 9, 14, 5, 7, 21];
        let a = pf.alloc().unwrap();
        let b = pi.alloc().unwrap();
        let lf = eng.prefill(&mut rt, pf.slot_mut(a), &prompt).unwrap();
        let li = eng.prefill(&mut rt, pi.slot_mut(b), &prompt).unwrap();
        assert!(li.iter().all(|x| x.is_finite()));
        let dot: f64 = lf
            .iter()
            .zip(&li)
            .map(|(x, y)| (*x as f64) * (*y as f64))
            .sum();
        let nf: f64 = lf.iter().map(|x| (*x as f64).powi(2)).sum();
        let ni: f64 = li.iter().map(|x| (*x as f64).powi(2)).sum();
        let cos = dot / (nf.sqrt() * ni.sqrt()).max(1e-12);
        assert!(cos > 0.95, "int8 KV drifted: cos {cos}");
    }

    #[test]
    fn steady_state_decode_reuses_scratch() {
        // the allocator-churn fix: after the first token sizes the
        // workspace, every subsequent token at batch <= cap is a pure
        // reuse — no per-token allocation even at batch = 1
        let (mut rt, eng, mut pool) = setup(QuantFormat::Nf4);
        let id = pool.alloc().unwrap();
        let prompt = [3i32, 9, 14, 5];
        eng.prefill(&mut rt, pool.slot_mut(id), &prompt).unwrap();
        let (grows_after_prefill, _) = eng.scratch_stats();
        assert_eq!(grows_after_prefill, 1,
                   "prefill should size the batch-1 scratch once");
        let mut pos = prompt.len();
        for step in 0..10 {
            let reqs =
                [BatchReq { slot: id, pos, token: (step % 7) as i32 }];
            eng.step_batch(&mut pool, &reqs, |_, _| {}).unwrap();
            pos += 1;
        }
        let (grows, reuses) = eng.scratch_stats();
        assert_eq!(grows, 1, "decode grew the scratch per token");
        // prompt tokens after the first + 10 decode steps all reused
        assert_eq!(reuses, (prompt.len() - 1 + 10) as u64);
    }

    #[test]
    fn position_matters_through_rope() {
        // same token at different positions must produce different
        // logits (RoPE encodes absolute position)
        let (mut rt, eng, mut pool) = setup(QuantFormat::Fp16);
        let id = pool.alloc().unwrap();
        let l1 =
            eng.prefill(&mut rt, pool.slot_mut(id), &[7, 7]).unwrap();
        let l2 = eng
            .decode(&mut rt, pool.slot_mut(id), &[7, 7], &[7])
            .unwrap();
        let diff: f32 = l1
            .iter()
            .zip(&l2)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff > 1e-6, "position had no effect: {diff}");
    }

    #[test]
    fn quantized_and_fp16_engines_agree_roughly() {
        let (mut rt, e16, mut p16) = setup(QuantFormat::Fp16);
        let (mut rt4, e4, mut p4) = setup(QuantFormat::Nf4);
        let prompt = [3i32, 10, 20, 30];
        let a = p16.alloc().unwrap();
        let b = p4.alloc().unwrap();
        let l16 =
            e16.prefill(&mut rt, p16.slot_mut(a), &prompt).unwrap();
        let l4 =
            e4.prefill(&mut rt4, p4.slot_mut(b), &prompt).unwrap();
        // matching argmax is too strong for random weights; require
        // the logit vectors to stay strongly aligned
        let dot: f64 = l16
            .iter()
            .zip(&l4)
            .map(|(x, y)| (*x as f64) * (*y as f64))
            .sum();
        let n16: f64 =
            l16.iter().map(|x| (*x as f64) * (*x as f64)).sum();
        let n4: f64 = l4.iter().map(|x| (*x as f64) * (*x as f64)).sum();
        let cos = dot / (n16.sqrt() * n4.sqrt()).max(1e-12);
        assert!(cos > 0.7, "nf4 deployment drifted: cos {cos}");
    }

    #[test]
    fn sampling_greedy_and_stochastic() {
        let logits = vec![0.0f32, 3.0, -1.0, 2.9];
        let mut rng = Rng::new(5);
        assert_eq!(sample_token(&logits, 0.0, &mut rng), 1);
        // stochastic sampling stays in range and hits >1 distinct token
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            let t = sample_token(&logits, 1.0, &mut rng);
            assert!((0..4).contains(&t));
            seen.insert(t);
        }
        assert!(seen.len() > 1);
    }

    #[test]
    fn kv_overflow_is_an_error() {
        let (mut rt, eng, mut pool) = setup(QuantFormat::Nf4);
        let id = pool.alloc().unwrap();
        let long: Vec<i32> = (0..25).map(|i| 3 + i).collect();
        // max_seq is 24 -> position 24 must refuse
        assert!(eng
            .prefill(&mut rt, pool.slot_mut(id), &long)
            .is_err());
    }
}
