//! Forward engine for serving: turns (pruned, quantized) `ParamStore`
//! weights into next-token logits against a session's KV cache.
//!
//! Two backends, chosen at construction:
//!
//! * **Artifact** — when the `fwd_{size}_r{rate}` AOT artifact is
//!   present and compiles, steps run through `runtime::Runtime` (PJRT).
//!   The AOT artifacts are fixed-shape full-sequence programs, so this
//!   path re-forwards the padded prefix each step — correct, but
//!   O(S^2) per token.
//! * **Native** — incremental single-token decode against the slab KV
//!   cache, numerically mirroring `python/compile/model.py` (RMSNorm
//!   eps 1e-6, RoPE theta 10000 with half-split rotation, SwiGLU,
//!   pre-norm residuals). This is the default whenever artifacts are
//!   absent (e.g. CI) and the only incremental path.
//!
//! Weights are "deployed" once at engine construction: projections are
//! simulated-quantized per the layer `BitConfig`
//! (`lora::quantize_base`), exactly the paper's deployment numerics.

use crate::lora;
use crate::model::{proj_index, ModelConfig, ParamStore, PrunedShapes};
use crate::quant::BitConfig;
use crate::rng::Rng;
use crate::runtime::{Arg, Runtime};
use crate::serve::kv_cache::KvSlot;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

enum Backend {
    Native,
    Artifact { name: String, lora_zeros: Vec<Tensor> },
}

pub struct Engine {
    /// frozen deployment weights (simulated-quantized projections)
    base: ParamStore,
    bits: BitConfig,
    cfg: ModelConfig,
    ps: PrunedShapes,
    backend: Backend,
    /// RoPE tables `[max_seq, head_dim/2]`
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    half: usize,
    max_seq: usize,
}

impl Engine {
    /// Quantize the store per `bits` and pick a backend. Probes the
    /// runtime for the matching forward artifact; falls back to the
    /// native decode path when it is absent or the PJRT backend is not
    /// linked.
    pub fn new(rt: &mut Runtime, store: &ParamStore, bits: &BitConfig,
               max_seq: usize) -> Result<Engine> {
        ensure!(max_seq >= 2, "max_seq {max_seq} too small to serve");
        let cfg = store.cfg.clone();
        let ps = store.ps;
        let base = lora::quantize_base(store, bits);

        let art = format!("fwd_{}_r{}", cfg.name, ps.rate_pct);
        let backend = if rt.has_artifact(&art) && max_seq <= cfg.seq {
            match rt.load(&art) {
                Ok(()) => {
                    let lora_zeros: Vec<Tensor> =
                        lora::LoraState::shapes(store)
                            .iter()
                            .map(|s| Tensor::zeros(s))
                            .collect();
                    Backend::Artifact { name: art, lora_zeros }
                }
                Err(e) => {
                    eprintln!(
                        "[serve] artifact {art} unusable ({e}); using \
                         native decode"
                    );
                    Backend::Native
                }
            }
        } else {
            Backend::Native
        };

        let head_dim = cfg.head_dim();
        ensure!(head_dim % 2 == 0, "RoPE needs even head_dim");
        let half = head_dim / 2;
        let mut rope_cos = vec![0.0f32; max_seq * half];
        let mut rope_sin = vec![0.0f32; max_seq * half];
        for p in 0..max_seq {
            for i in 0..half {
                let freq =
                    (10000.0f64).powf(-(i as f64) / half as f64);
                let ang = p as f64 * freq;
                rope_cos[p * half + i] = ang.cos() as f32;
                rope_sin[p * half + i] = ang.sin() as f32;
            }
        }
        Ok(Engine {
            base,
            bits: bits.clone(),
            cfg,
            ps,
            backend,
            rope_cos,
            rope_sin,
            half,
            max_seq,
        })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn bits(&self) -> &BitConfig {
        &self.bits
    }

    pub fn pruned_shapes(&self) -> &PrunedShapes {
        &self.ps
    }

    pub fn attn_dim(&self) -> usize {
        self.ps.attn_dim(&self.cfg)
    }

    pub fn backend_label(&self) -> &'static str {
        match self.backend {
            Backend::Native => "native-kv",
            Backend::Artifact { .. } => "pjrt-artifact",
        }
    }

    /// Feed the whole prompt into a fresh slot; returns the logits
    /// after its last token (from which the first new token samples).
    pub fn prefill(&self, rt: &mut Runtime, slot: &mut KvSlot,
                   prompt: &[i32]) -> Result<Vec<f32>> {
        ensure!(!prompt.is_empty(), "prefill with empty prompt");
        ensure!(slot.len == 0, "prefill into a dirty slot");
        match &self.backend {
            Backend::Native => {
                // only the last position's logits are consumed, so the
                // [V, d] lm_head projection runs once, not per token
                let mut hidden = Vec::new();
                for (pos, &tok) in prompt.iter().enumerate() {
                    hidden = self.advance_hidden(slot, pos, tok)?;
                }
                Ok(self.logits_from_hidden(&hidden))
            }
            Backend::Artifact { name, lora_zeros } => {
                let out = self.forward_artifact(rt, name, lora_zeros,
                                                prompt)?;
                slot.advance_to(prompt.len());
                Ok(out)
            }
        }
    }

    /// One decode step for a session whose tokens so far are `prompt`
    /// then `generated`. The newest element of `generated` is the one
    /// token not yet in the KV cache: it is fed at position
    /// `prompt.len() + generated.len() - 1` and next-token logits come
    /// back. Taking the two slices (rather than a concatenated
    /// history) keeps the native hot path allocation-free; only the
    /// artifact backend materializes the full sequence, which it must
    /// pad into a fixed-shape buffer anyway.
    pub fn decode(&self, rt: &mut Runtime, slot: &mut KvSlot,
                  prompt: &[i32], generated: &[i32])
                  -> Result<Vec<f32>> {
        ensure!(!prompt.is_empty(), "decode with empty prompt");
        let len = prompt.len() + generated.len();
        let pos = len - 1;
        let token = *generated.last().unwrap_or_else(|| {
            prompt.last().expect("prompt checked non-empty")
        });
        match &self.backend {
            Backend::Native => {
                ensure!(
                    pos == slot.len,
                    "KV desync: pos {pos} vs cached {}",
                    slot.len
                );
                self.decode_native(slot, pos, token)
            }
            Backend::Artifact { name, lora_zeros } => {
                let history: Vec<i32> = prompt
                    .iter()
                    .chain(generated)
                    .copied()
                    .collect();
                let out = self.forward_artifact(rt, name, lora_zeros,
                                                &history)?;
                slot.advance_to(len);
                Ok(out)
            }
        }
    }

    // ------------------------------------------------------------------
    // native incremental path
    // ------------------------------------------------------------------

    fn decode_native(&self, slot: &mut KvSlot, pos: usize, token: i32)
                     -> Result<Vec<f32>> {
        let h = self.advance_hidden(slot, pos, token)?;
        Ok(self.logits_from_hidden(&h))
    }

    /// Run one token through all transformer blocks, updating the KV
    /// cache; returns the final hidden state (pre final-norm). The
    /// lm_head projection lives in `logits_from_hidden` so prefill can
    /// skip it for all but the last position.
    fn advance_hidden(&self, slot: &mut KvSlot, pos: usize, token: i32)
                      -> Result<Vec<f32>> {
        ensure!(
            pos < self.max_seq,
            "position {pos} exceeds KV capacity {}",
            self.max_seq
        );
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let a = self.attn_dim();
        let heads = self.ps.heads_kept;
        let hd = cfg.head_dim();
        let w = &self.base.weights;

        let mut h = self.base.embed_row(token).to_vec();
        let mut hn = vec![0.0f32; d];
        for l in 0..cfg.n_layers {
            // attention block
            rmsnorm(&h, w[1].slab(l).1, &mut hn);
            let mut q = matvec_slab(&w[proj_index("wq")], l, &hn);
            let mut k = matvec_slab(&w[proj_index("wk")], l, &hn);
            let v = matvec_slab(&w[proj_index("wv")], l, &hn);
            self.rope_inplace(&mut q, pos, heads, hd);
            self.rope_inplace(&mut k, pos, heads, hd);
            slot.write(l, pos, &k, &v);

            let mut ctx = vec![0.0f32; a];
            let inv = 1.0 / (hd as f32).sqrt();
            let mut scores = vec![0.0f32; pos + 1];
            for head in 0..heads {
                let o = head * hd;
                for (t, s) in scores.iter_mut().enumerate() {
                    let kt = &slot.k_at(l, t)[o..o + hd];
                    let mut dot = 0.0f32;
                    for (qi, ki) in q[o..o + hd].iter().zip(kt) {
                        dot += qi * ki;
                    }
                    *s = dot * inv;
                }
                softmax_inplace(&mut scores);
                for (t, &p) in scores.iter().enumerate() {
                    let vt = &slot.v_at(l, t)[o..o + hd];
                    for (c, &vi) in ctx[o..o + hd].iter_mut().zip(vt) {
                        *c += p * vi;
                    }
                }
            }
            let attn_out = matvec_slab(&w[proj_index("wo")], l, &ctx);
            for (hi, &oi) in h.iter_mut().zip(&attn_out) {
                *hi += oi;
            }

            // SwiGLU MLP block
            rmsnorm(&h, w[6].slab(l).1, &mut hn);
            let mut gate = matvec_slab(&w[proj_index("w_gate")], l, &hn);
            let up = matvec_slab(&w[proj_index("w_up")], l, &hn);
            for (g, &u) in gate.iter_mut().zip(&up) {
                let s = 1.0 / (1.0 + (-*g).exp()); // silu
                *g = *g * s * u;
            }
            let down = matvec_slab(&w[proj_index("w_down")], l, &gate);
            for (hi, &di) in h.iter_mut().zip(&down) {
                *hi += di;
            }
        }
        slot.advance_to(pos + 1);
        Ok(h)
    }

    /// Final RMSNorm + lm_head `[V, d]` projection.
    fn logits_from_hidden(&self, h: &[f32]) -> Vec<f32> {
        let d = self.cfg.d_model;
        let w = &self.base.weights;
        let mut hf = vec![0.0f32; d];
        rmsnorm(h, w[10].data(), &mut hf);
        let hw = w[11].data();
        let mut logits = vec![0.0f32; self.cfg.vocab];
        for (r, lo) in logits.iter_mut().enumerate() {
            let row = &hw[r * d..(r + 1) * d];
            let mut s = 0.0f32;
            for (a_, b_) in row.iter().zip(&hf) {
                s += a_ * b_;
            }
            *lo = s;
        }
        logits
    }

    /// Rotate q/k `[heads, head_dim]` (flattened) at position `pos`.
    fn rope_inplace(&self, x: &mut [f32], pos: usize, heads: usize,
                    hd: usize) {
        let half = self.half;
        let cos = &self.rope_cos[pos * half..(pos + 1) * half];
        let sin = &self.rope_sin[pos * half..(pos + 1) * half];
        for head in 0..heads {
            let o = head * hd;
            for i in 0..half {
                let x1 = x[o + i];
                let x2 = x[o + half + i];
                x[o + i] = x1 * cos[i] - x2 * sin[i];
                x[o + half + i] = x2 * cos[i] + x1 * sin[i];
            }
        }
    }

    // ------------------------------------------------------------------
    // artifact (PJRT) path
    // ------------------------------------------------------------------

    fn forward_artifact(&self, rt: &mut Runtime, name: &str,
                        lora_zeros: &[Tensor], history: &[i32])
                        -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        ensure!(
            history.len() <= cfg.seq,
            "history {} exceeds artifact seq {}",
            history.len(),
            cfg.seq
        );
        // fixed-shape [batch, seq] program: row 0 carries the session,
        // the rest is PAD
        let mut tokens = vec![0i32; cfg.batch * cfg.seq];
        tokens[..history.len()].copy_from_slice(history);
        let shape = [cfg.batch, cfg.seq];
        let mut args: Vec<Arg> = Vec::with_capacity(12 + 14 + 1);
        for w in &self.base.weights {
            args.push(Arg::F32(w));
        }
        for t in lora_zeros {
            args.push(Arg::F32(t));
        }
        args.push(Arg::I32(&tokens, &shape));
        let out = rt.exec_f32(name, &args)?;
        // out[0]: [B, S, V]; session in row 0, logits at its last token
        let v = cfg.vocab;
        let at = (history.len() - 1) * v;
        Ok(out[0].data()[at..at + v].to_vec())
    }
}

/// RMSNorm matching `model.py` (`eps = 1e-6`).
fn rmsnorm(x: &[f32], gain: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), gain.len());
    let ms: f32 =
        x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    for ((o, &xi), &g) in out.iter_mut().zip(x).zip(gain) {
        *o = xi * inv * g;
    }
}

/// `stack[layer] [out, in] @ x [in] -> [out]`.
fn matvec_slab(stack: &Tensor, layer: usize, x: &[f32]) -> Vec<f32> {
    let (sh, data) = stack.slab(layer);
    let (o, i) = (sh[0], sh[1]);
    debug_assert_eq!(i, x.len());
    let mut y = vec![0.0f32; o];
    for (r, yo) in y.iter_mut().enumerate() {
        let row = &data[r * i..(r + 1) * i];
        let mut s = 0.0f32;
        for (a, b) in row.iter().zip(x) {
            s += a * b;
        }
        *yo = s;
    }
    y
}

fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

/// Sample a token id from logits: greedy at `temperature <= 0`, else
/// temperature-scaled categorical.
pub fn sample_token(logits: &[f32], temperature: f32, rng: &mut Rng)
                    -> i32 {
    if temperature <= 0.0 {
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        return best as i32;
    }
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let weights: Vec<f64> = logits
        .iter()
        .map(|&x| (((x - m) / temperature) as f64).exp())
        .collect();
    rng.categorical(&weights) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantFormat;
    use crate::serve::kv_cache::KvCachePool;

    fn setup(fmt: QuantFormat)
             -> (Runtime, Engine, KvCachePool) {
        let dir = std::env::temp_dir().join("qpruner_serve_engine_t");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        let cfg = ModelConfig::preset("tiny").unwrap();
        let store = ParamStore::init(&cfg, 11);
        let bits = BitConfig::uniform(cfg.n_layers, fmt);
        let eng = Engine::new(&mut rt, &store, &bits, 24).unwrap();
        let a = eng.attn_dim();
        let pool = KvCachePool::with_slots(&cfg, a, 2, 24, 1.0, 2.0);
        (rt, eng, pool)
    }

    #[test]
    fn native_backend_without_artifacts() {
        let (_rt, eng, _pool) = setup(QuantFormat::Nf4);
        assert_eq!(eng.backend_label(), "native-kv");
    }

    #[test]
    fn prefill_then_decode_produces_finite_logits() {
        let (mut rt, eng, mut pool) = setup(QuantFormat::Nf4);
        let id = pool.alloc().unwrap();
        let prompt = [3i32, 9, 14, 5];
        let logits =
            eng.prefill(&mut rt, pool.slot_mut(id), &prompt).unwrap();
        assert_eq!(logits.len(), eng.cfg().vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(pool.slot(id).len, prompt.len());
        // one decode step
        let tok = sample_token(&logits, 0.0, &mut Rng::new(1));
        let l2 = eng
            .decode(&mut rt, pool.slot_mut(id), &prompt, &[tok])
            .unwrap();
        assert!(l2.iter().all(|x| x.is_finite()));
        assert_eq!(pool.slot(id).len, prompt.len() + 1);
    }

    #[test]
    fn incremental_decode_matches_fresh_prefill() {
        // KV-cache decode must equal recomputing the whole prefix
        let (mut rt, eng, mut pool) = setup(QuantFormat::Nf4);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let prompt = [3i32, 9, 14, 5, 7];
        // path A: prefill 4, then decode token 5
        let _ = eng
            .prefill(&mut rt, pool.slot_mut(a), &prompt[..4])
            .unwrap();
        let la = eng
            .decode(&mut rt, pool.slot_mut(a), &prompt[..4],
                    &prompt[4..])
            .unwrap();
        // path B: prefill all 5 at once
        let lb = eng.prefill(&mut rt, pool.slot_mut(b), &prompt).unwrap();
        for (x, y) in la.iter().zip(&lb) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn position_matters_through_rope() {
        // same token at different positions must produce different
        // logits (RoPE encodes absolute position)
        let (mut rt, eng, mut pool) = setup(QuantFormat::Fp16);
        let id = pool.alloc().unwrap();
        let l1 =
            eng.prefill(&mut rt, pool.slot_mut(id), &[7, 7]).unwrap();
        let l2 = eng
            .decode(&mut rt, pool.slot_mut(id), &[7, 7], &[7])
            .unwrap();
        let diff: f32 = l1
            .iter()
            .zip(&l2)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff > 1e-6, "position had no effect: {diff}");
    }

    #[test]
    fn quantized_and_fp16_engines_agree_roughly() {
        let (mut rt, e16, mut p16) = setup(QuantFormat::Fp16);
        let (mut rt4, e4, mut p4) = setup(QuantFormat::Nf4);
        let prompt = [3i32, 10, 20, 30];
        let a = p16.alloc().unwrap();
        let b = p4.alloc().unwrap();
        let l16 =
            e16.prefill(&mut rt, p16.slot_mut(a), &prompt).unwrap();
        let l4 =
            e4.prefill(&mut rt4, p4.slot_mut(b), &prompt).unwrap();
        // matching argmax is too strong for random weights; require
        // the logit vectors to stay strongly aligned
        let dot: f64 = l16
            .iter()
            .zip(&l4)
            .map(|(x, y)| (*x as f64) * (*y as f64))
            .sum();
        let n16: f64 =
            l16.iter().map(|x| (*x as f64) * (*x as f64)).sum();
        let n4: f64 = l4.iter().map(|x| (*x as f64) * (*x as f64)).sum();
        let cos = dot / (n16.sqrt() * n4.sqrt()).max(1e-12);
        assert!(cos > 0.7, "nf4 deployment drifted: cos {cos}");
    }

    #[test]
    fn sampling_greedy_and_stochastic() {
        let logits = vec![0.0f32, 3.0, -1.0, 2.9];
        let mut rng = Rng::new(5);
        assert_eq!(sample_token(&logits, 0.0, &mut rng), 1);
        // stochastic sampling stays in range and hits >1 distinct token
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            let t = sample_token(&logits, 1.0, &mut rng);
            assert!((0..4).contains(&t));
            seen.insert(t);
        }
        assert!(seen.len() > 1);
    }

    #[test]
    fn kv_overflow_is_an_error() {
        let (mut rt, eng, mut pool) = setup(QuantFormat::Nf4);
        let id = pool.alloc().unwrap();
        let long: Vec<i32> = (0..25).map(|i| 3 + i).collect();
        // max_seq is 24 -> position 24 must refuse
        assert!(eng
            .prefill(&mut rt, pool.slot_mut(id), &long)
            .is_err());
    }
}
