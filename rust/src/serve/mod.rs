//! Batched inference serving over pruned + quantized artifacts — the
//! deployment layer the paper's memory wins pay for.
//!
//! A deployment — a `ParamStore` plus `BitConfig`, or an exported
//! `artifact::ModelArtifact` with its LoRA deltas, fed through
//! `engine::EngineBuilder` — becomes a serving process:
//! continuous-batching scheduler
//! (`scheduler.rs`), a KV-cache pool sized from the precision-aware
//! accounting in `memory.rs` with selectable f32/int8 KV storage and
//! selectable slab or paged layout — the paged layout allocates
//! fixed-size token pages from a free list and shares ref-counted
//! prompt-prefix pages across sessions (`kv_cache.rs`), per-session
//! state with TTL eviction
//! (`session.rs`), admission control (`admission.rs`), a forward
//! engine that prefers the PJRT AOT artifacts and otherwise decodes
//! the whole active batch through fused per-layer GEMMs (`engine.rs`),
//! and the engine's reusable activation scratch (`workspace.rs`).
//!
//! This module adds the closed-loop synthetic workload driver used by
//! the `serve` / `bench-serve` subcommands, the benches, and the
//! integration tests: `clients` logical clients each keep at most one
//! request in flight until `requests` total have been issued, and the
//! run reports p50/p95/p99 latency, TTFT, tokens/sec, batch occupancy,
//! and rejection rate.

pub mod admission;
pub mod engine;
pub mod faults;
pub mod kv_cache;
pub mod scheduler;
pub mod session;
pub mod workspace;

use crate::data::Language;
use crate::memory;
use crate::metrics::Metrics;
use crate::model::ModelConfig;
use crate::obs::hist::{Hist, Registry};
use crate::obs::span::Tracer;
use crate::obs::trace_export;
use crate::obs::{PhaseSnapshot, PHASES};
use crate::quant::BitConfig;
use crate::report::Table;
use crate::rng::Rng;
use crate::runtime::Runtime;
use admission::{AdmissionPolicy, BrownoutConfig};
use anyhow::{bail, ensure, Context, Result};
use engine::EngineBuilder;
use faults::{FaultPlan, FaultPoint};
use kv_cache::{CompactMode, KvCachePool, KvLayout};
use scheduler::Scheduler;
use std::path::PathBuf;
use std::time::Instant;

/// Completed-span cap for the lifecycle tracer: bounds trace memory on
/// long runs (dropped spans are counted in the export, not lost
/// silently). Shared with the HTTP server (`crate::server`), which
/// keeps a tracer installed for its whole lifetime.
pub const TRACE_SPAN_CAP: usize = 65_536;

/// Workload + server knobs for one serving run.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// concurrent logical clients (each <= 1 request in flight)
    pub clients: usize,
    /// total requests issued across all clients
    pub requests: usize,
    /// continuous-batching cap per decode step
    pub max_batch: usize,
    /// modeled deployment KV budget in GB; `None` derives it from the
    /// device headroom left by the active BitConfig (memory.rs)
    pub kv_budget_gb: Option<f64>,
    /// modeled deployment device memory (used when kv_budget_gb is
    /// derived), L20-class by default
    pub device_gb: f64,
    /// paper-scale architecture the memory accounting maps onto
    pub memory_arch: String,
    /// KV slot capacity in tokens (prompt + generated)
    pub max_seq: usize,
    /// KV pool layout: whole-slab reservations or fixed-size pages
    /// with prefix sharing
    pub kv_layout: KvLayout,
    /// page capacity in tokens (paged layout only)
    pub page_tokens: usize,
    /// every request's prompt starts with this many shared tokens (a
    /// synthetic "system prompt"; 0 disables) — the workload knob that
    /// exercises the paged layout's prefix cache
    pub shared_prefix: usize,
    /// page compaction + sub-page prefix matching trigger
    /// (`--compact {off,starve,thresh=P}`; paged layout only)
    pub compact: CompactMode,
    /// sampled prompt length range [lo, hi]; with `shared_prefix` the
    /// effective prompt is `shared_prefix + sampled` tokens
    pub prompt_len: (usize, usize),
    /// sampled generation budget range [lo, hi]
    pub max_new: (usize, usize),
    pub temperature: f32,
    pub seed: u64,
    /// wait-queue bound before load shedding
    pub max_queue: usize,
    /// scheduler steps a stalled session may hold its slot
    pub ttl_steps: u64,
    /// per-step probability an active session stalls (client
    /// disconnect injection; 0 disables)
    pub stall_prob: f64,
    /// emit a progress line to stderr every N scheduler steps
    /// (0 disables)
    pub stats_every: u64,
    /// write a Chrome/Perfetto trace of the run here (installs the
    /// lifecycle tracer and turns on raw phase-event capture)
    pub trace_out: Option<PathBuf>,
    /// write the structured JSONL event log here
    pub events_out: Option<PathBuf>,
    /// write the metrics-registry JSON snapshot here
    pub metrics_out: Option<PathBuf>,
    /// seeded fault-injection spec (`faults::FaultPlan::parse`);
    /// `None` keeps every injection site a dead branch
    pub fault_plan: Option<String>,
    /// default per-request deadline in ms (`None` = no deadline);
    /// requests may override it individually
    pub deadline_ms: Option<u64>,
    /// brownout load-shedding thresholds (`None` disables)
    pub brownout: Option<BrownoutConfig>,
}

impl ServeOpts {
    /// Seconds-scale defaults (integration tests, --scale smoke).
    pub fn smoke() -> ServeOpts {
        ServeOpts {
            clients: 8,
            requests: 240,
            max_batch: 4,
            kv_budget_gb: None,
            device_gb: 24.0,
            memory_arch: "7b".into(),
            max_seq: 28,
            kv_layout: KvLayout::Slab,
            page_tokens: 64,
            shared_prefix: 0,
            compact: CompactMode::Off,
            prompt_len: (4, 10),
            max_new: (3, 12),
            temperature: 0.8,
            seed: 42,
            max_queue: 64,
            ttl_steps: 16,
            stall_prob: 0.0,
            stats_every: 0,
            trace_out: None,
            events_out: None,
            metrics_out: None,
            fault_plan: None,
            deadline_ms: None,
            brownout: None,
        }
    }

    /// Recorded-run fidelity (--scale paper).
    pub fn paper() -> ServeOpts {
        ServeOpts {
            clients: 32,
            requests: 2000,
            max_batch: 16,
            ..ServeOpts::smoke()
        }
    }
}

/// Everything a serving run reports — a deliberately *flattened*
/// snapshot merging `SchedStats`, pool accounting, and latency
/// recorders, assembled in exactly one place (the tail of
/// `run_workload`) so consumers never hold live scheduler state.
#[derive(Debug)]
pub struct ServeReport {
    pub backend: &'static str,
    pub bits_short: String,
    /// LoRA deployment of the engine: "none" | "merged" | "adjoined"
    pub lora: &'static str,
    /// KV-cache storage precision in bits (32 = f32, 8 = int8)
    pub kv_bits: u32,
    /// KV pool layout: "slab" | "paged"
    pub kv_layout: &'static str,
    /// page capacity in tokens (0 on the slab layout)
    pub page_tokens: usize,
    /// page pool size / high-water mark (0 on the slab layout)
    pub kv_pages_total: usize,
    pub kv_pages_peak: usize,
    /// prefix-cache traffic (paged layout; all 0 on slab)
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    /// prompt tokens whose prefill was skipped via shared pages
    pub prefix_tokens_reused: u64,
    /// shared pages privatized before a write
    pub kv_cow_copies: u64,
    /// modeled bytes of prefill KV the prefix cache avoided recomputing
    pub kv_prefix_bytes_saved: f64,
    /// prefix-index entries published but never re-hit (GC candidates)
    pub prefix_idle_entries: usize,
    /// host bytes those idle entries pin
    pub prefix_idle_bytes: usize,
    /// admissions that mapped a verified token span below page
    /// granularity (sub-page prefix matching; 0 with `--compact off`)
    pub prefix_subpage_hits: u64,
    /// prompt tokens whose prefill was skipped via sub-page spans
    pub prefix_subpage_tokens: u64,
    /// compaction trigger policy label ("off" | "starve" | "thresh=P")
    pub compact_mode: String,
    /// compaction passes run / pages they returned to the free list
    pub kv_compactions: u64,
    pub kv_pages_reclaimed: u64,
    /// end-of-run fragmentation gauges: stranded tail token slots in
    /// partial private pages, and dead pages (rewind leftovers +
    /// index-only holds)
    pub kv_frag_slots: usize,
    pub kv_frag_pages: usize,
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    /// rejection breakdown: (queue-full, too-long, malformed)
    pub rejected_by: (usize, usize, usize),
    pub evicted: usize,
    /// total scheduler steps, including idle ones (e.g. waiting out a
    /// stalled session's TTL)
    pub steps: u64,
    /// steps that decoded at least one token — the denominator of
    /// `mean_occupancy`
    pub busy_steps: u64,
    pub prefill_tokens: u64,
    pub generated_tokens: u64,
    pub wall_secs: f64,
    /// end-to-end latency (submit → last token), log2-bucket histogram
    pub latency: Hist,
    /// time-to-first-token
    pub ttft: Hist,
    /// inter-token latency (one sample per decoded token after a
    /// session's first)
    pub itl: Hist,
    /// sampled decode-phase breakdown (`Engine::phase_snapshot`)
    pub phases: PhaseSnapshot,
    pub mean_occupancy: f64,
    pub max_occupancy: usize,
    pub kv_capacity_sessions: usize,
    pub kv_peak_sessions: usize,
    /// modeled deployment bytes at peak / budget (paper arch, at the
    /// pool's KV precision)
    pub kv_modeled_peak_bytes: f64,
    pub kv_modeled_budget_bytes: f64,
    /// host bytes actually pinned by the slab
    pub kv_host_slab_bytes: usize,
    /// weight residency of the engine: "quantized" (native encodings
    /// on the decode path, the default) or "f32" (oracle/bench builds)
    pub weight_residency: &'static str,
    /// host bytes the deployment weights actually pin
    /// (`Engine::weight_host_bytes` — codes + scales, no f32
    /// materialization at the default residency)
    pub weight_resident_bytes: usize,
    /// modeled native weight residency at the paper arch
    /// (`memory::weight_bytes_at`), the weights-side sibling of the
    /// modeled KV lines
    pub weight_modeled_native_bytes: f64,
    /// decode pool lane count (`--threads`)
    pub threads: usize,
    /// decode-workspace allocation telemetry: buffer growths (only
    /// when a step's batch exceeds the high-water mark) vs. pure
    /// reuses — the steady-state decode path must be all reuses
    pub scratch_grows: u64,
    pub scratch_reuses: u64,
}

impl ServeReport {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.wall_secs
    }

    pub fn rejection_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.rejected as f64 / self.submitted as f64
    }

    /// Render as a paper-style metric table (report.rs).
    pub fn to_table(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["metric", "value"]);
        let mut push = |k: &str, v: String| {
            t.push_row(vec![k.to_string(), v]);
        };
        push("backend", self.backend.to_string());
        push("bits", self.bits_short.clone());
        push("lora", self.lora.to_string());
        push("kv bits", format!("{}", self.kv_bits));
        push("requests submitted", format!("{}", self.submitted));
        push("requests completed", format!("{}", self.completed));
        push("requests rejected", format!("{}", self.rejected));
        push(
            "rejected by reason",
            format!(
                "{}={} {}={} {}={}",
                admission::RejectReason::QueueFull.label(),
                self.rejected_by.0,
                admission::RejectReason::TooLong.label(),
                self.rejected_by.1,
                admission::RejectReason::Malformed.label(),
                self.rejected_by.2,
            ),
        );
        push("sessions evicted (TTL)", format!("{}", self.evicted));
        push("rejection rate",
             format!("{:.2}%", 100.0 * self.rejection_rate()));
        push("scheduler steps", format!("{}", self.steps));
        push("decode steps (busy)", format!("{}", self.busy_steps));
        push("prefill tokens", format!("{}", self.prefill_tokens));
        push("generated tokens", format!("{}", self.generated_tokens));
        push("tokens/sec", format!("{:.1}", self.tokens_per_sec()));
        let lat = self.latency.percentiles_ms(&[50.0, 95.0, 99.0]);
        push("latency p50", format!("{:.3} ms", lat[0]));
        push("latency p95", format!("{:.3} ms", lat[1]));
        push("latency p99", format!("{:.3} ms", lat[2]));
        push("ttft p50", format!("{:.3} ms",
                                 self.ttft.percentile_ms(50.0)));
        let itl = self.itl.percentiles_ms(&[50.0, 95.0, 99.0]);
        push("itl p50", format!("{:.3} ms", itl[0]));
        push("itl p95", format!("{:.3} ms", itl[1]));
        push("itl p99", format!("{:.3} ms", itl[2]));
        // sampled decode-phase breakdown (absent when profiling is
        // off or no step was sampled)
        let ph = &self.phases;
        if ph.sampled_steps > 0 {
            push(
                "profiled steps",
                format!("{}/{} (every {})",
                        ph.sampled_steps, ph.total_steps, ph.every),
            );
            push("phase coverage",
                 format!("{:.1}%", 100.0 * ph.coverage()));
            for p in PHASES {
                push(
                    &format!("phase {}", p.label()),
                    format!(
                        "{:.4} s ({:.1}%)",
                        ph.per_phase_secs[p.idx()],
                        100.0 * ph.phase_frac(p)
                    ),
                );
            }
            if !ph.lane_busy_secs.is_empty() {
                let busy: f64 = ph.lane_busy_secs.iter().sum();
                push("pool lane busy (sampled)",
                     format!("{busy:.4} s across {} lanes",
                             ph.lane_busy_secs.len()));
            }
        }
        push("mean batch occupancy",
             format!("{:.2}", self.mean_occupancy));
        push("max batch occupancy", format!("{}", self.max_occupancy));
        push("kv sessions (peak/capacity)",
             format!("{}/{}", self.kv_peak_sessions,
                     self.kv_capacity_sessions));
        push("kv layout", self.kv_layout.to_string());
        if self.kv_layout == "paged" {
            push("kv page tokens", format!("{}", self.page_tokens));
            push("kv pages (peak/total)",
                 format!("{}/{}", self.kv_pages_peak,
                         self.kv_pages_total));
            push("prefix hits/misses",
                 format!("{}/{}", self.prefix_hits,
                         self.prefix_misses));
            push("prefix tokens reused",
                 format!("{}", self.prefix_tokens_reused));
            push("kv cow copies", format!("{}", self.kv_cow_copies));
            push("kv prefix bytes saved (modeled)",
                 format!("{:.2} MB",
                         self.kv_prefix_bytes_saved / 1e6));
            push("prefix idle entries (never re-hit)",
                 format!("{}", self.prefix_idle_entries));
            push("prefix idle bytes pinned",
                 format!("{:.2} MB",
                         self.prefix_idle_bytes as f64 / 1e6));
            push("compact mode", self.compact_mode.clone());
            push("prefix subpage hits",
                 format!("{}", self.prefix_subpage_hits));
            push("prefix subpage tokens",
                 format!("{}", self.prefix_subpage_tokens));
            push("kv compactions", format!("{}", self.kv_compactions));
            push("kv pages reclaimed",
                 format!("{}", self.kv_pages_reclaimed));
            push("kv frag (slots/pages)",
                 format!("{}/{}", self.kv_frag_slots,
                         self.kv_frag_pages));
        }
        push("kv modeled peak",
             format!("{:.3} GB", self.kv_modeled_peak_bytes / 1e9));
        push("kv modeled budget",
             format!("{:.3} GB", self.kv_modeled_budget_bytes / 1e9));
        push("kv host slab",
             format!("{:.2} MB", self.kv_host_slab_bytes as f64 / 1e6));
        push("weight residency", self.weight_residency.to_string());
        push("weight host bytes",
             format!("{:.2} MB",
                     self.weight_resident_bytes as f64 / 1e6));
        push("weight modeled native",
             format!("{:.3} GB",
                     self.weight_modeled_native_bytes / 1e9));
        push("decode threads", format!("{}", self.threads));
        push("scratch grows/reuses",
             format!("{}/{}", self.scratch_grows, self.scratch_reuses));
        t
    }

    /// One machine-readable JSON object for `BENCH_serve.json` — the
    /// perf-trajectory record tracked across PRs (tokens/sec,
    /// latency percentiles, footprint). `name` labels the config
    /// (e.g. "c8_b8_kv8"). Hand-rolled: no JSON dependency in-tree.
    ///
    /// Percentiles over an empty recorder are `NaN`, which is not
    /// valid JSON — every float that can be non-finite goes through
    /// [`json_num`] and lands as `null`
    /// (`tests::empty_report_json_is_parseable` pins this down).
    pub fn to_json(&self, name: &str) -> String {
        let lat = self.latency.percentiles_ms(&[50.0, 95.0, 99.0]);
        let itl = self.itl.percentiles_ms(&[50.0, 95.0, 99.0]);
        let ph = &self.phases;
        format!(
            "{{\"name\":{},\"backend\":{},\"bits\":{},\"lora\":{},\
             \"kv_bits\":{},\"kv_layout\":{},\"page_tokens\":{},\
             \"kv_pages_total\":{},\"kv_pages_peak\":{},\
             \"prefix_hits\":{},\"prefix_misses\":{},\
             \"prefix_tokens_reused\":{},\"kv_cow_copies\":{},\
             \"kv_prefix_bytes_saved\":{:.0},\
             \"prefix_idle_entries\":{},\"prefix_idle_bytes\":{},\
             \"prefix_subpage_hits\":{},\"prefix_subpage_tokens\":{},\
             \"compact_mode\":{},\"kv_compactions\":{},\
             \"kv_pages_reclaimed\":{},\"kv_frag_slots\":{},\
             \"kv_frag_pages\":{},\
             \"requests_submitted\":{},\
             \"requests_completed\":{},\"requests_rejected\":{},\
             \"tokens_per_sec\":{:.3},\"p50_ms\":{},\
             \"p95_ms\":{},\"p99_ms\":{},\"ttft_p50_ms\":{},\
             \"itl_p50_ms\":{},\"itl_p95_ms\":{},\"itl_p99_ms\":{},\
             \"itl_mean_ms\":{},\
             \"mean_occupancy\":{:.4},\"generated_tokens\":{},\
             \"wall_secs\":{:.4},\"kv_sessions_capacity\":{},\
             \"kv_sessions_peak\":{},\"kv_host_slab_bytes\":{},\
             \"kv_modeled_budget_bytes\":{:.0},\
             \"weight_residency\":{},\"weight_resident_bytes\":{},\
             \"weight_modeled_native_bytes\":{:.0},\"threads\":{},\
             \"scratch_grows\":{},\"scratch_reuses\":{},\
             \"profiled_steps\":{},\"phase_coverage\":{},\
             \"phase_qkv_secs\":{},\"phase_attn_secs\":{},\
             \"phase_mlp_secs\":{},\"phase_lora_secs\":{},\
             \"phase_vocab_secs\":{}}}",
            json_str(name),
            json_str(self.backend),
            json_str(&self.bits_short),
            json_str(self.lora),
            self.kv_bits,
            json_str(self.kv_layout),
            self.page_tokens,
            self.kv_pages_total,
            self.kv_pages_peak,
            self.prefix_hits,
            self.prefix_misses,
            self.prefix_tokens_reused,
            self.kv_cow_copies,
            self.kv_prefix_bytes_saved,
            self.prefix_idle_entries,
            self.prefix_idle_bytes,
            self.prefix_subpage_hits,
            self.prefix_subpage_tokens,
            json_str(&self.compact_mode),
            self.kv_compactions,
            self.kv_pages_reclaimed,
            self.kv_frag_slots,
            self.kv_frag_pages,
            self.submitted,
            self.completed,
            self.rejected,
            self.tokens_per_sec(),
            json_num(lat[0]),
            json_num(lat[1]),
            json_num(lat[2]),
            json_num(self.ttft.percentile_ms(50.0)),
            json_num(itl[0]),
            json_num(itl[1]),
            json_num(itl[2]),
            json_num(self.itl.mean_ms()),
            self.mean_occupancy,
            self.generated_tokens,
            self.wall_secs,
            self.kv_capacity_sessions,
            self.kv_peak_sessions,
            self.kv_host_slab_bytes,
            self.kv_modeled_budget_bytes,
            json_str(self.weight_residency),
            self.weight_resident_bytes,
            self.weight_modeled_native_bytes,
            self.threads,
            self.scratch_grows,
            self.scratch_reuses,
            ph.sampled_steps,
            json_num(ph.coverage()),
            json_num(ph.per_phase_secs[0]),
            json_num(ph.per_phase_secs[1]),
            json_num(ph.per_phase_secs[2]),
            json_num(ph.per_phase_secs[3]),
            json_num(ph.per_phase_secs[4]),
        )
    }
}

/// Render a float as JSON: `null` when non-finite (an empty latency
/// recorder's percentiles are `NaN` — a literal `NaN` is not JSON).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.4}")
    } else {
        "null".to_string()
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Assemble `BENCH_serve.json` from named reports.
pub fn bench_json(entries: &[(String, &ServeReport)]) -> String {
    let mut out = String::from("[\n");
    for (i, (name, r)) in entries.iter().enumerate() {
        out.push_str("  ");
        out.push_str(&r.to_json(name));
        if i + 1 < entries.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]\n");
    out
}

/// Append one report to an existing `BENCH_serve.json` body instead of
/// clobbering it — consecutive `bench-serve` runs (and a prior
/// `cargo bench`) accumulate configs in one trajectory file. Anything
/// that doesn't look like a JSON array is replaced wholesale.
pub fn bench_json_append(prev: Option<&str>, name: &str,
                         r: &ServeReport) -> String {
    bench_json_append_obj(prev, &r.to_json(name))
}

/// [`bench_json_append`] for a pre-rendered JSON object — lets the
/// bench binary record non-`ServeReport` entries (the `decode_b{N}`
/// fused-vs-baseline kernel lines) in the same trajectory file.
pub fn bench_json_append_obj(prev: Option<&str>, entry: &str)
                             -> String {
    let fresh = || format!("[\n  {entry}\n]\n");
    let Some(prev) = prev else { return fresh() };
    let trimmed = prev.trim_end();
    let Some(head) = trimmed.strip_suffix(']') else {
        return fresh();
    };
    let head = head.trim_end();
    if !head.starts_with('[') {
        return fresh();
    }
    if head == "[" {
        format!("[\n  {entry}\n]\n")
    } else {
        format!("{head},\n  {entry}\n]\n")
    }
}

fn paper_arch(name: &str) -> ModelConfig {
    // callers validate via `check_memory_arch`; default keeps the pure
    // accounting helpers infallible
    if name == "13b" {
        ModelConfig::paper_13b()
    } else {
        ModelConfig::paper_7b()
    }
}

/// Reject unknown `--memory-arch` values instead of silently
/// accounting against the wrong architecture.
pub fn check_memory_arch(name: &str) -> Result<()> {
    ensure!(
        name == "7b" || name == "13b",
        "bad memory arch {name:?} (expected 7b|13b)"
    );
    Ok(())
}

/// (inference footprint GB, KV headroom GB) on the modeled device for
/// this precision config — the single source of the headroom rule used
/// by both the budget resolver and `run_workload`'s diagnostics.
pub fn modeled_memory_gb(opts: &ServeOpts, rate_pct: u32,
                         bits: &BitConfig) -> (f64, f64) {
    let arch = paper_arch(&opts.memory_arch);
    let stretched = memory::stretch_bits(bits, arch.n_layers);
    let inference = memory::inference_gb(&arch, rate_pct, &stretched);
    let headroom = memory::serve_kv_budget_gb(&arch, rate_pct,
                                              &stretched,
                                              opts.device_gb);
    (inference, headroom)
}

/// Resolve the modeled KV budget: explicit flag, clamped to the device
/// headroom the precision config leaves; or the full headroom.
pub fn resolve_kv_budget_gb(opts: &ServeOpts, rate_pct: u32,
                            bits: &BitConfig) -> f64 {
    let (_, headroom) = modeled_memory_gb(opts, rate_pct, bits);
    match opts.kv_budget_gb {
        Some(gb) => gb.min(headroom),
        None => headroom,
    }
}

/// Build the full serving stack from a pre-configured
/// [`EngineBuilder`] and the pool/scheduler knobs in `opts`: stamp
/// `max_seq` onto the builder, build the engine, size the KV pool
/// from the engine's own bit config and KV precision against the
/// modeled device budget, wire admission to the pool's real token
/// capacity, and (when `want_trace`) install a lifecycle tracer.
/// Shared by the synthetic workload driver and the HTTP server —
/// both front-ends serve through the identical stack, which is what
/// makes their token streams bit-comparable.
pub fn build_stack(rt: &mut Runtime, builder: EngineBuilder,
                   opts: &ServeOpts, want_trace: bool)
                   -> Result<(engine::Engine, Scheduler)> {
    let mut builder = builder.max_seq(opts.max_seq);
    if want_trace {
        builder = builder.profile_events(true);
    }
    let engine = builder.build(rt)?;
    let rate = engine.pruned_shapes().rate_pct;
    let bits = engine.bits().clone();
    let host_cfg = engine.cfg().clone();
    check_memory_arch(&opts.memory_arch)?;
    let arch = paper_arch(&opts.memory_arch);
    // diagnose the no-headroom case before budget resolution clamps an
    // explicit --kv-budget-gb to zero with a misleading error
    let (inference, headroom) = modeled_memory_gb(opts, rate, &bits);
    if headroom <= 0.0 {
        bail!(
            "no KV headroom: inference footprint {inference:.2} GB \
             (bits {}, rate {rate}%) does not fit the {:.0} GB {} \
             device — raise --device-gb, prune deeper, or quantize \
             more layers",
            bits.short(),
            opts.device_gb,
            opts.memory_arch
        );
    }
    let budget_gb = resolve_kv_budget_gb(opts, rate, &bits);
    // the scheduler can keep at most max_batch sessions decoding plus
    // the stalled ones TTL has not yet reclaimed — host slots beyond
    // that are unreachable slab
    let stall_allowance = if opts.stall_prob > 0.0 {
        opts.max_batch
            .saturating_mul(opts.ttl_steps as usize + 2)
    } else {
        0
    };
    let mut pool = KvCachePool::for_budget_layout(
        &host_cfg,
        engine.attn_dim(),
        &arch,
        rate,
        opts.max_seq,
        engine.kv_precision(),
        budget_gb,
        opts.max_batch + stall_allowance,
        opts.kv_layout,
        opts.page_tokens,
    )?;
    // page compaction + sub-page prefix matching (`--compact`): a
    // no-op knob on the slab layout
    pool.set_compact_mode(opts.compact);
    // the paged pool may hold fewer total page-tokens than max_seq;
    // shed sessions that could never be faulted in at the door
    let admission = AdmissionPolicy::with_token_capacity(
        opts.max_queue,
        opts.max_seq,
        pool.session_token_capacity(),
    );
    let mut sched =
        Scheduler::new(pool, admission, opts.max_batch, opts.ttl_steps);
    if want_trace {
        sched.set_tracer(Tracer::new(TRACE_SPAN_CAP));
    }
    // robustness wiring, shared by both front-ends: faults, deadlines,
    // brownout all live scheduler-side so the offline driver and the
    // HTTP server exercise identical containment paths
    if let Some(spec) = &opts.fault_plan {
        sched.set_faults(
            FaultPlan::parse(spec).context("--fault-plan")?,
        );
    }
    sched.set_default_deadline_ms(opts.deadline_ms);
    sched.set_brownout(opts.brownout);
    Ok((engine, sched))
}

/// Assemble the live metrics-registry snapshot
/// (`qpruner.serve.metrics.v1`) from the scheduler's current state —
/// the single source for both the `--metrics-out` file and the HTTP
/// server's `GET /metrics`, so the two never drift schema.
pub fn metrics_registry(sched: &Scheduler, scratch_grows: u64,
                        scratch_reuses: u64, wall: f64) -> Registry {
    let mut reg = Registry::new();
    reg.counter_add("serve.requests_submitted",
                    sched.stats.submitted as u64);
    reg.counter_add("serve.requests_completed",
                    sched.stats.completed as u64);
    reg.counter_add("serve.requests_rejected",
                    sched.stats.rejected as u64);
    reg.counter_add("serve.sessions_evicted",
                    sched.stats.evicted as u64);
    reg.counter_add("serve.deadline_exceeded",
                    sched.stats.deadline_exceeded as u64);
    reg.counter_add("serve.sessions_quarantined",
                    sched.stats.quarantined as u64);
    reg.counter_add("serve.client_disconnects",
                    sched.stats.disconnects as u64);
    reg.counter_add("serve.prefill_tokens",
                    sched.stats.prefill_tokens);
    reg.counter_add("serve.generated_tokens",
                    sched.stats.generated_tokens);
    reg.counter_add("serve.scratch_grows", scratch_grows);
    reg.counter_add("serve.scratch_reuses", scratch_reuses);
    let pstats = sched.pool.paged_stats();
    reg.counter_add("serve.prefix_hits", pstats.prefix_hits);
    reg.counter_add("serve.prefix_misses", pstats.prefix_misses);
    reg.counter_add("serve.prefix_tokens_reused",
                    pstats.prefix_tokens_reused);
    reg.counter_add("serve.kv_cow_copies", pstats.cow_copies);
    // sub-page prefix matching + compaction (all zero with
    // `--compact off` / on slab)
    reg.counter_add("kv.prefix_subpage_hits",
                    pstats.prefix_subpage_hits);
    reg.counter_add("kv.prefix_subpage_tokens",
                    pstats.prefix_subpage_tokens);
    reg.counter_add("kv.compactions", pstats.compactions);
    reg.counter_add("kv.pages_reclaimed", pstats.pages_reclaimed);
    reg.gauge_set("kv.frag_slots", sched.pool.frag_slots() as f64);
    reg.gauge_set("kv.frag_pages", sched.pool.frag_pages() as f64);
    reg.gauge_set("serve.kv_pages_total",
                  sched.pool.pages_total() as f64);
    reg.gauge_set("serve.kv_pages_peak",
                  sched.pool.pages_peak() as f64);
    // idle-prefix GC stats: published entries never re-hit and the
    // host bytes they pin (reclaimable without losing any reuse)
    reg.gauge_set("kv.prefix_idle_entries",
                  sched.pool.prefix_idle_entries() as f64);
    reg.gauge_set("kv.prefix_idle_bytes",
                  sched.pool.prefix_idle_bytes() as f64);
    reg.gauge_set(
        "serve.tokens_per_sec",
        if wall > 0.0 {
            sched.stats.generated_tokens as f64 / wall
        } else {
            0.0
        },
    );
    reg.gauge_set("serve.mean_occupancy",
                  sched.stats.mean_occupancy());
    reg.gauge_set("serve.wall_secs", wall);
    reg.hist_set("serve.latency_ms", sched.latency.clone());
    reg.hist_set("serve.ttft_ms", sched.ttft.clone());
    reg.hist_set("serve.itl_ms", sched.itl.clone());
    // robustness: brownout state and fault-injection counters (the
    // faults.* keys only appear when a plan is configured, so
    // fault-free snapshots keep their exact historical shape)
    reg.gauge_set("serve.brownout",
                  if sched.brownout.active() { 1.0 } else { 0.0 });
    reg.counter_add("serve.brownout_entries",
                    sched.brownout.entries());
    if let Some(fp) = sched.faults() {
        reg.counter_add("faults.injected_total", fp.total_fired());
        for p in FaultPoint::ALL {
            reg.counter_add(&format!("faults.{}", p.label()),
                            fp.fired(p));
        }
    }
    reg
}

/// Run a closed-loop synthetic multi-client workload to completion.
///
/// The deployment comes in as a *pre-configured* [`EngineBuilder`]
/// (weight source + KV precision + LoRA mode); this function stamps
/// the workload's `max_seq` onto it, builds the engine, sizes the KV
/// pool from the engine's own bit config and KV precision, and drives
/// the scheduler until the workload drains.
pub fn run_workload(rt: &mut Runtime, builder: EngineBuilder,
                    lang: &Language, opts: &ServeOpts,
                    metrics: &mut Metrics) -> Result<ServeReport> {
    ensure!(opts.clients > 0 && opts.requests > 0, "empty workload");
    ensure!(opts.prompt_len.0 >= 1
            && opts.prompt_len.0 <= opts.prompt_len.1,
            "bad prompt_len range");
    ensure!(opts.max_new.0 >= 1 && opts.max_new.0 <= opts.max_new.1,
            "bad max_new range");
    // only bail when *every* request would be oversized; workloads
    // whose larger length combinations exceed max_seq are legitimate —
    // those requests exercise the RejectReason::TooLong shedding path
    ensure!(
        opts.shared_prefix + opts.prompt_len.0 + opts.max_new.0 - 1
            <= opts.max_seq,
        "even the smallest request (shared prefix {} + prompt {} + new \
         {} tokens) exceeds max_seq {} — every request would be \
         rejected",
        opts.shared_prefix,
        opts.prompt_len.0,
        opts.max_new.0,
        opts.max_seq
    );

    let t_build = Instant::now();
    // a trace request implies raw phase-event capture (the aggregate
    // profiler runs regardless; events are the expensive part)
    let want_trace =
        opts.trace_out.is_some() || opts.events_out.is_some();
    let (engine, mut sched) = build_stack(rt, builder, opts,
                                          want_trace)?;
    metrics.add_time("serve.build_engine",
                     t_build.elapsed().as_secs_f64());
    ensure!(
        engine.cfg().vocab == lang.vocab,
        "language vocab {} != model vocab {}",
        lang.vocab,
        engine.cfg().vocab
    );
    let rate = engine.pruned_shapes().rate_pct;
    let bits = engine.bits().clone();
    let arch = paper_arch(&opts.memory_arch);

    // closed-loop clients: one outstanding request each
    struct Client {
        remaining: usize,
        outstanding: Option<u64>,
        rng: Rng,
    }
    let base = opts.requests / opts.clients;
    let extra = opts.requests % opts.clients;
    let mut clients: Vec<Client> = (0..opts.clients)
        .map(|i| Client {
            remaining: base + usize::from(i < extra),
            outstanding: None,
            rng: Rng::new(opts.seed ^ (0xC11E_47 + i as u64 * 7919)),
        })
        .collect();
    let mut workload_rng = Rng::new(opts.seed ^ 0x5E47E);
    // one fixed "system prompt" every request starts with — the
    // workload signal the paged layout's prefix cache keys on
    let shared: Vec<i32> = if opts.shared_prefix > 0 {
        let mut rng = Rng::new(opts.seed ^ 0x5F1_E0);
        lang.sample(opts.shared_prefix, &mut rng)
    } else {
        Vec::new()
    };

    let t0 = Instant::now();
    let max_steps: u64 = 50_000 + 200 * opts.requests as u64;
    loop {
        // submissions
        for (ci, c) in clients.iter_mut().enumerate() {
            if c.remaining == 0 || c.outstanding.is_some() {
                continue;
            }
            let plen = opts.prompt_len.0
                + c.rng.below(opts.prompt_len.1 - opts.prompt_len.0 + 1);
            let mnew = opts.max_new.0
                + c.rng.below(opts.max_new.1 - opts.max_new.0 + 1);
            let mut prompt = shared.clone();
            prompt.extend(lang.sample(plen, &mut c.rng));
            c.remaining -= 1;
            c.outstanding = sched.submit(ci, prompt, mnew,
                                         opts.seed, opts.temperature);
            // a rejected request is spent (the client moves on)
        }

        if sched.idle()
            && clients.iter().all(|c| c.remaining == 0
                                  && c.outstanding.is_none())
        {
            break;
        }

        sched.step(&engine, rt, &mut workload_rng, opts.stall_prob)?;

        if opts.stats_every > 0
            && sched.step_no() % opts.stats_every == 0
        {
            eprintln!(
                "[serve] step {:>6}  done {:>5}/{}  active {:>3}  \
                 queue {:>3}  itl {}",
                sched.step_no(),
                sched.stats.completed,
                opts.requests,
                sched.active_len(),
                sched.queue_len(),
                sched.itl.summary(),
            );
        }

        // reap terminal sessions so clients can issue their next
        // request, and drop them from the table so a long run's memory
        // stays bounded by the live session count
        for c in clients.iter_mut() {
            if let Some(id) = c.outstanding {
                if sched.table.get(id).is_terminal() {
                    sched.table.remove(id);
                    c.outstanding = None;
                }
            }
        }

        if sched.step_no() > max_steps {
            bail!("workload failed to drain in {max_steps} steps \
                   (completed {}, queue {}, active {})",
                  sched.stats.completed, sched.queue_len(),
                  sched.active_len());
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    metrics.add_time("serve.workload", wall);
    metrics.incr("serve.requests", sched.stats.submitted as u64);
    metrics.incr("serve.tokens", sched.stats.generated_tokens);
    // allocator-churn telemetry: the decode workspace grows only on a
    // new batch high-water mark; everything else must be a reuse
    let (scratch_grows, scratch_reuses) = engine.scratch_stats();
    metrics.set_counter("serve.scratch_grows", scratch_grows);
    metrics.set_counter("serve.scratch_reuses", scratch_reuses);

    // phase breakdown from the sampled decode-step profiler, plus the
    // pool's per-lane busy time over the same sampled steps
    let phases = engine.phase_snapshot();

    // trace exports: lifecycle spans + raw phase events
    if want_trace {
        let tracer = sched.take_tracer().expect("tracer installed");
        let phase_events = engine.profiler().take_events();
        if let Some(path) = &opts.trace_out {
            let body =
                trace_export::chrome_trace(&tracer, &phase_events);
            std::fs::write(path, body).with_context(|| {
                format!("writing trace to {}", path.display())
            })?;
        }
        if let Some(path) = &opts.events_out {
            let body =
                trace_export::events_jsonl(&tracer, &phase_events);
            std::fs::write(path, body).with_context(|| {
                format!("writing event log to {}", path.display())
            })?;
        }
    }

    // bounded streaming-metrics snapshot (stable schema,
    // `qpruner.serve.metrics.v1` — same assembly `GET /metrics`
    // serves live)
    if let Some(path) = &opts.metrics_out {
        let reg = metrics_registry(&sched, scratch_grows,
                                   scratch_reuses, wall);
        std::fs::write(path, reg.snapshot_json()).with_context(|| {
            format!("writing metrics snapshot to {}", path.display())
        })?;
    }

    // weights-side residency accounting, next to the KV footprint:
    // actual host bytes pinned by the engine's slabs, and the modeled
    // native residency at the paper arch
    let stretched = memory::stretch_bits(&bits, arch.n_layers);
    let weight_modeled_native_bytes =
        memory::weight_bytes_at(&arch, rate, &stretched);

    let st = &sched.stats;
    let pstats = sched.pool.paged_stats();
    Ok(ServeReport {
        backend: engine.backend_label(),
        bits_short: bits.short(),
        lora: engine.lora_label(),
        kv_bits: sched.pool.precision().bits(),
        kv_layout: sched.pool.layout().label(),
        page_tokens: sched.pool.page_tokens(),
        kv_pages_total: sched.pool.pages_total(),
        kv_pages_peak: sched.pool.pages_peak(),
        prefix_hits: pstats.prefix_hits,
        prefix_misses: pstats.prefix_misses,
        prefix_tokens_reused: pstats.prefix_tokens_reused,
        kv_cow_copies: pstats.cow_copies,
        kv_prefix_bytes_saved: sched.pool.prefix_bytes_saved_modeled(),
        prefix_idle_entries: sched.pool.prefix_idle_entries(),
        prefix_idle_bytes: sched.pool.prefix_idle_bytes(),
        prefix_subpage_hits: pstats.prefix_subpage_hits,
        prefix_subpage_tokens: pstats.prefix_subpage_tokens,
        compact_mode: opts.compact.label(),
        kv_compactions: pstats.compactions,
        kv_pages_reclaimed: pstats.pages_reclaimed,
        kv_frag_slots: sched.pool.frag_slots(),
        kv_frag_pages: sched.pool.frag_pages(),
        submitted: st.submitted,
        completed: st.completed,
        rejected: st.rejected,
        rejected_by: (st.rejected_queue_full, st.rejected_too_long,
                      st.rejected_malformed),
        evicted: st.evicted,
        steps: sched.step_no(),
        busy_steps: st.busy_steps,
        prefill_tokens: st.prefill_tokens,
        generated_tokens: st.generated_tokens,
        wall_secs: wall,
        latency: sched.latency.clone(),
        ttft: sched.ttft.clone(),
        itl: sched.itl.clone(),
        phases,
        mean_occupancy: st.mean_occupancy(),
        max_occupancy: st.max_occupancy,
        kv_capacity_sessions: sched.pool.capacity(),
        kv_peak_sessions: sched.pool.peak_in_use(),
        kv_modeled_peak_bytes: sched.pool.modeled_peak_bytes(),
        kv_modeled_budget_bytes: sched.pool.modeled_budget_bytes(),
        kv_host_slab_bytes: sched.pool.host_slab_bytes(),
        weight_residency: engine.residency_label(),
        weight_resident_bytes: engine.weight_host_bytes(),
        weight_modeled_native_bytes,
        threads: engine.threads(),
        scratch_grows,
        scratch_reuses,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::quant::QuantFormat;

    #[test]
    fn smoke_paper_opts_are_sane() {
        let s = ServeOpts::smoke();
        assert!(s.prompt_len.1 + s.max_new.1 - 1 <= s.max_seq);
        let p = ServeOpts::paper();
        assert!(p.requests > s.requests);
        assert!(p.max_batch >= s.max_batch);
    }

    #[test]
    fn kv_budget_clamps_to_headroom() {
        let cfg = ModelConfig::preset("tiny").unwrap();
        let bits = BitConfig::uniform(cfg.n_layers, QuantFormat::Nf4);
        let opts = ServeOpts { kv_budget_gb: Some(1e9),
                               ..ServeOpts::smoke() };
        let b = resolve_kv_budget_gb(&opts, 0, &bits);
        let arch = ModelConfig::paper_7b();
        let stretched = memory::stretch_bits(&bits, arch.n_layers);
        let headroom = memory::serve_kv_budget_gb(
            &arch, 0, &stretched, opts.device_gb);
        assert!(b <= headroom + 1e-9, "budget {b} > headroom {headroom}");
        // derived budget equals the headroom exactly
        let derived = ServeOpts { kv_budget_gb: None,
                                  ..ServeOpts::smoke() };
        assert!((resolve_kv_budget_gb(&derived, 0, &bits) - headroom)
            .abs() < 1e-12);
    }

    #[test]
    fn memory_arch_is_validated() {
        assert!(check_memory_arch("7b").is_ok());
        assert!(check_memory_arch("13b").is_ok());
        assert!(check_memory_arch("13B").is_err());
        assert!(check_memory_arch("70b").is_err());
        assert!(check_memory_arch("").is_err());
    }

    #[test]
    fn report_table_renders() {
        let r = ServeReport {
            backend: "native-kv",
            bits_short: "44".into(),
            lora: "merged",
            kv_bits: 8,
            kv_layout: "paged",
            page_tokens: 16,
            kv_pages_total: 24,
            kv_pages_peak: 20,
            prefix_hits: 5,
            prefix_misses: 3,
            prefix_tokens_reused: 80,
            kv_cow_copies: 2,
            kv_prefix_bytes_saved: 3.2e7,
            prefix_idle_entries: 3,
            prefix_idle_bytes: 1_500_000,
            prefix_subpage_hits: 2,
            prefix_subpage_tokens: 5,
            compact_mode: "thresh=0.25".into(),
            kv_compactions: 4,
            kv_pages_reclaimed: 6,
            kv_frag_slots: 7,
            kv_frag_pages: 1,
            submitted: 10,
            completed: 8,
            rejected: 2,
            rejected_by: (2, 0, 0),
            evicted: 0,
            steps: 40,
            busy_steps: 28,
            prefill_tokens: 60,
            generated_tokens: 70,
            wall_secs: 0.5,
            latency: Hist::new(),
            ttft: Hist::new(),
            itl: Hist::new(),
            phases: PhaseSnapshot::default(),
            mean_occupancy: 2.5,
            max_occupancy: 4,
            kv_capacity_sessions: 4,
            kv_peak_sessions: 4,
            kv_modeled_peak_bytes: 2e8,
            kv_modeled_budget_bytes: 4e8,
            kv_host_slab_bytes: 1_000_000,
            weight_residency: "quantized",
            weight_resident_bytes: 2_500_000,
            weight_modeled_native_bytes: 3.5e9,
            threads: 4,
            scratch_grows: 2,
            scratch_reuses: 68,
        };
        assert!((r.tokens_per_sec() - 140.0).abs() < 1e-9);
        assert!((r.rejection_rate() - 0.2).abs() < 1e-12);
        let md = r.to_table("serve smoke").to_markdown();
        assert!(md.contains("rejection rate"));
        assert!(md.contains("20.00%"));
        assert!(md.contains("tokens/sec"));
        assert!(md.contains("queue-full=2"));
        assert!(md.contains("decode steps (busy)"));
        assert!(md.contains("kv bits"));
        assert!(md.contains("lora"));
        assert!(md.contains("merged"));
        assert!(md.contains("2/68"));
        assert!(md.contains("weight residency"));
        assert!(md.contains("quantized"));
        assert!(md.contains("decode threads"));
        // paged-layout lines render alongside the slab accounting
        assert!(md.contains("kv layout"));
        assert!(md.contains("paged"));
        assert!(md.contains("20/24"));
        assert!(md.contains("prefix hits/misses"));
        assert!(md.contains("5/3"));
        // machine-readable twin of the table
        let j = r.to_json("smoke_cfg");
        assert!(j.contains("\"name\":\"smoke_cfg\""));
        assert!(j.contains("\"tokens_per_sec\":140.000"));
        assert!(j.contains("\"lora\":\"merged\""));
        assert!(j.contains("\"kv_bits\":8"));
        assert!(j.contains("\"kv_layout\":\"paged\""));
        assert!(j.contains("\"prefix_hits\":5"));
        assert!(j.contains("\"prefix_tokens_reused\":80"));
        assert!(j.contains("\"prefix_idle_entries\":3"));
        assert!(j.contains("\"prefix_idle_bytes\":1500000"));
        assert!(md.contains("prefix idle entries"));
        // compaction + sub-page prefix accounting
        assert!(j.contains("\"prefix_subpage_hits\":2"));
        assert!(j.contains("\"prefix_subpage_tokens\":5"));
        assert!(j.contains("\"compact_mode\":\"thresh=0.25\""));
        assert!(j.contains("\"kv_compactions\":4"));
        assert!(j.contains("\"kv_pages_reclaimed\":6"));
        assert!(j.contains("\"kv_frag_slots\":7"));
        assert!(j.contains("\"kv_frag_pages\":1"));
        assert!(md.contains("compact mode"));
        assert!(md.contains("thresh=0.25"));
        assert!(md.contains("kv compactions"));
        assert!(md.contains("kv pages reclaimed"));
        assert!(md.contains("7/1"));
        assert!(j.contains("\"kv_pages_peak\":20"));
        assert!(j.contains("\"weight_residency\":\"quantized\""));
        assert!(j.contains("\"weight_resident_bytes\":2500000"));
        assert!(j.contains("\"threads\":4"));
        // raw-object append used by the decode-kernel bench lines
        let with_obj = bench_json_append_obj(
            Some("[\n]"),
            "{\"name\":\"decode_b8\",\"fused_tokens_per_sec\":1.0}",
        );
        assert!(with_obj.contains("\"name\":\"decode_b8\""));
        assert!(with_obj.trim_end().ends_with(']'));
        let arr = bench_json(&[("a".into(), &r), ("b".into(), &r)]);
        assert!(arr.starts_with("[\n"));
        assert!(arr.trim_end().ends_with(']'));
        assert_eq!(arr.matches("\"backend\"").count(), 2);
        // appending accumulates configs instead of clobbering
        let appended = bench_json_append(Some(&arr), "c", &r);
        assert_eq!(appended.matches("\"backend\"").count(), 3);
        assert!(appended.trim_end().ends_with(']'));
        assert!(appended.contains("\"name\":\"c\""));
        // garbage (or absent) files are replaced wholesale
        let replaced = bench_json_append(Some("not json"), "d", &r);
        assert_eq!(replaced.matches("\"backend\"").count(), 1);
        assert_eq!(bench_json_append(None, "e", &r)
                       .matches("\"backend\"")
                       .count(),
                   1);
    }

    #[test]
    fn json_str_escapes() {
        assert_eq!(json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_str("x\ny"), "\"x\\ny\"");
    }

    /// Regression: a report whose latency recorders are empty (e.g.
    /// every request rejected) used to serialize percentiles as the
    /// literal `NaN`, which no JSON parser accepts. Empty recorders
    /// must land as `null` and the whole object must parse.
    #[test]
    fn empty_report_json_is_parseable() {
        use crate::obs::json::Json;
        let r = ServeReport {
            backend: "native-kv",
            bits_short: "44".into(),
            lora: "none",
            kv_bits: 32,
            kv_layout: "slab",
            page_tokens: 0,
            kv_pages_total: 0,
            kv_pages_peak: 0,
            prefix_hits: 0,
            prefix_misses: 0,
            prefix_tokens_reused: 0,
            kv_cow_copies: 0,
            kv_prefix_bytes_saved: 0.0,
            prefix_idle_entries: 0,
            prefix_idle_bytes: 0,
            prefix_subpage_hits: 0,
            prefix_subpage_tokens: 0,
            compact_mode: "off".into(),
            kv_compactions: 0,
            kv_pages_reclaimed: 0,
            kv_frag_slots: 0,
            kv_frag_pages: 0,
            submitted: 3,
            completed: 0,
            rejected: 3,
            rejected_by: (3, 0, 0),
            evicted: 0,
            steps: 1,
            busy_steps: 0,
            prefill_tokens: 0,
            generated_tokens: 0,
            wall_secs: 0.01,
            latency: Hist::new(),
            ttft: Hist::new(),
            itl: Hist::new(),
            phases: PhaseSnapshot::default(),
            mean_occupancy: 0.0,
            max_occupancy: 0,
            kv_capacity_sessions: 4,
            kv_peak_sessions: 0,
            kv_modeled_peak_bytes: 0.0,
            kv_modeled_budget_bytes: 4e8,
            kv_host_slab_bytes: 1_000_000,
            weight_residency: "quantized",
            weight_resident_bytes: 2_500_000,
            weight_modeled_native_bytes: 3.5e9,
            threads: 1,
            scratch_grows: 0,
            scratch_reuses: 0,
        };
        let j = r.to_json("all_rejected");
        assert!(!j.contains("NaN"), "literal NaN leaked into: {j}");
        assert!(j.contains("\"p50_ms\":null"));
        assert!(j.contains("\"itl_p99_ms\":null"));
        let doc = Json::parse(&j).expect("report JSON must parse");
        assert!(doc.get("p50_ms").unwrap().is_null());
        assert!(doc.get("phase_coverage").unwrap().is_null());
        assert_eq!(
            doc.get("requests_rejected").unwrap().as_f64(),
            Some(3.0)
        );
        // the aggregate file stays parseable too
        let arr = bench_json(&[("a".into(), &r)]);
        assert!(Json::parse(&arr).is_ok());
    }
}
