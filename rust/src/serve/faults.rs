//! Seeded, deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is parsed from a compact spec string
//! (`--fault-plan "seed=42,decode_err=0.01,page_starve=0.05,client_drop=0.02,stall_ms=50@0.01,reload_corrupt"`)
//! and threaded into the scheduler as an `Option<FaultPlan>`. Each named
//! [`FaultPoint`] draws from its *own* xoshiro stream (forked from the plan
//! seed), so enabling one fault class never perturbs the draw sequence of
//! another — two runs with the same seed and plan fire the same faults at
//! the same points, which is what makes the chaos suite differential.
//!
//! When no plan is configured the scheduler holds `None` and every
//! injection site is a single `if let`/flag branch that folds to the
//! untouched hot path: logits are bit-identical with faults disabled
//! (pinned by `tests/parity_decode.rs`).

use anyhow::{anyhow, bail, Result};
use crate::rng::Rng;
use std::time::Duration;

/// Named injection sites in the serving stack. Each point owns an
/// independent RNG stream and a fired/drawn counter pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    /// Engine prefill returns an error for the session being admitted.
    PrefillErr,
    /// Engine decode step fails for one active session (quarantined).
    DecodeErr,
    /// Paged-KV allocation fails (admission or mid-decode growth).
    PageStarve,
    /// The client vanishes mid-generation (socket drop equivalent).
    ClientDrop,
    /// The core loop stalls for `stall_ms` (exercises the watchdog).
    Stall,
    /// An artifact reload reads back corrupt (server rejects the swap).
    ReloadCorrupt,
    /// A KV page migration fails mid-compaction (the affected
    /// session is quarantined; the pass rolls its table back).
    CompactMove,
}

pub const N_POINTS: usize = 7;

impl FaultPoint {
    pub const ALL: [FaultPoint; N_POINTS] = [
        FaultPoint::PrefillErr,
        FaultPoint::DecodeErr,
        FaultPoint::PageStarve,
        FaultPoint::ClientDrop,
        FaultPoint::Stall,
        FaultPoint::ReloadCorrupt,
        FaultPoint::CompactMove,
    ];

    pub fn label(self) -> &'static str {
        match self {
            FaultPoint::PrefillErr => "prefill_err",
            FaultPoint::DecodeErr => "decode_err",
            FaultPoint::PageStarve => "page_starve",
            FaultPoint::ClientDrop => "client_drop",
            FaultPoint::Stall => "stall",
            FaultPoint::ReloadCorrupt => "reload_corrupt",
            FaultPoint::CompactMove => "compact_move",
        }
    }

    fn idx(self) -> usize {
        match self {
            FaultPoint::PrefillErr => 0,
            FaultPoint::DecodeErr => 1,
            FaultPoint::PageStarve => 2,
            FaultPoint::ClientDrop => 3,
            FaultPoint::Stall => 4,
            FaultPoint::ReloadCorrupt => 5,
            FaultPoint::CompactMove => 6,
        }
    }

    /// Stream salt: a fixed odd constant per point so `seed ^ salt`
    /// derives well-separated xoshiro states.
    fn salt(self) -> u64 {
        0x9E37_79B9_7F4A_7C15u64.wrapping_mul(self.idx() as u64 + 1) | 1
    }
}

/// A parsed, seeded fault schedule. One instance per scheduler; `fire`
/// mutates the per-point stream and counters.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    seed: u64,
    probs: [f64; N_POINTS],
    stall: Duration,
    streams: [Rng; N_POINTS],
    fired: [u64; N_POINTS],
    drawn: [u64; N_POINTS],
}

impl FaultPlan {
    /// Parse a spec like
    /// `seed=42,decode_err=0.01,page_starve=0.05,client_drop=0.02,stall_ms=50@0.01,reload_corrupt`.
    ///
    /// Grammar: comma-separated items. `seed=N` seeds every stream
    /// (default 0). `<point>=P` sets an injection probability in [0,1].
    /// `stall_ms=M@P` stalls the core loop for `M` ms with probability
    /// `P` per step. A bare point name (`reload_corrupt`) means
    /// probability 1.0.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut seed = 0u64;
        let mut probs = [0.0f64; N_POINTS];
        let mut stall_ms = 0u64;
        for item in spec.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (key, val) = match item.split_once('=') {
                Some((k, v)) => (k.trim(), Some(v.trim())),
                None => (item, None),
            };
            match key {
                "seed" => {
                    let Some(v) = val else {
                        bail!("fault-plan: seed needs a value");
                    };
                    seed = v
                        .parse::<u64>()
                        .map_err(|_| anyhow!("fault-plan: bad seed '{v}'"))?;
                }
                "stall_ms" => {
                    let Some(v) = val else {
                        bail!("fault-plan: stall_ms needs 'MS@PROB'");
                    };
                    let (ms, p) = match v.split_once('@') {
                        Some((ms, p)) => (ms.trim(), parse_prob(p.trim())?),
                        None => (v, 1.0),
                    };
                    stall_ms = ms
                        .parse::<u64>()
                        .map_err(|_| anyhow!("fault-plan: bad stall_ms '{ms}'"))?;
                    probs[FaultPoint::Stall.idx()] = p;
                }
                _ => {
                    let Some(point) = FaultPoint::ALL
                        .iter()
                        .copied()
                        .find(|p| p.label() == key && *p != FaultPoint::Stall)
                    else {
                        bail!("fault-plan: unknown key '{key}'");
                    };
                    let p = match val {
                        Some(v) => parse_prob(v)?,
                        None => 1.0,
                    };
                    probs[point.idx()] = p;
                }
            }
        }
        if probs[FaultPoint::Stall.idx()] > 0.0 && stall_ms == 0 {
            bail!("fault-plan: stall probability set but stall_ms is 0");
        }
        Ok(FaultPlan::from_parts(seed, probs, Duration::from_millis(stall_ms)))
    }

    fn from_parts(seed: u64, probs: [f64; N_POINTS], stall: Duration) -> FaultPlan {
        let streams = FaultPoint::ALL.map(|p| Rng::new(seed ^ p.salt()));
        FaultPlan {
            seed,
            probs,
            stall,
            streams,
            fired: [0; N_POINTS],
            drawn: [0; N_POINTS],
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Configured stall duration for [`FaultPoint::Stall`] firings.
    pub fn stall(&self) -> Duration {
        self.stall
    }

    /// Probability configured for a point (0.0 = never fires).
    pub fn prob(&self, point: FaultPoint) -> f64 {
        self.probs[point.idx()]
    }

    /// Draw the point's stream and decide whether the fault fires here.
    /// Zero-probability points never draw, so a plan that only enables
    /// `decode_err` leaves every other stream untouched.
    pub fn fire(&mut self, point: FaultPoint) -> bool {
        let i = point.idx();
        if self.probs[i] <= 0.0 {
            return false;
        }
        self.drawn[i] += 1;
        let hit = self.probs[i] >= 1.0 || self.streams[i].uniform() < self.probs[i];
        if hit {
            self.fired[i] += 1;
        }
        hit
    }

    /// Times `point` actually fired.
    pub fn fired(&self, point: FaultPoint) -> u64 {
        self.fired[point.idx()]
    }

    /// Times `point` was consulted (fired or not).
    pub fn drawn(&self, point: FaultPoint) -> u64 {
        self.drawn[point.idx()]
    }

    /// Total faults injected across every point.
    pub fn total_fired(&self) -> u64 {
        self.fired.iter().sum()
    }

    /// One-line human summary, e.g. for the drain log.
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        for p in FaultPoint::ALL {
            if self.probs[p.idx()] > 0.0 {
                parts.push(format!("{}={}", p.label(), self.fired(p)));
            }
        }
        format!("seed={} fired {} ({})", self.seed, self.total_fired(), parts.join(" "))
    }
}

fn parse_prob(s: &str) -> Result<f64> {
    let p = s
        .parse::<f64>()
        .map_err(|_| anyhow!("fault-plan: bad probability '{s}'"))?;
    if !(0.0..=1.0).contains(&p) || !p.is_finite() {
        bail!("fault-plan: probability '{s}' not in [0,1]");
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let p = FaultPlan::parse(
            "seed=42,decode_err=0.01,page_starve=0.05,client_drop=0.02,stall_ms=50@0.01,reload_corrupt",
        )
        .unwrap();
        assert_eq!(p.seed(), 42);
        assert_eq!(p.prob(FaultPoint::DecodeErr), 0.01);
        assert_eq!(p.prob(FaultPoint::PageStarve), 0.05);
        assert_eq!(p.prob(FaultPoint::ClientDrop), 0.02);
        assert_eq!(p.prob(FaultPoint::Stall), 0.01);
        assert_eq!(p.stall(), Duration::from_millis(50));
        assert_eq!(p.prob(FaultPoint::ReloadCorrupt), 1.0);
        assert_eq!(p.prob(FaultPoint::PrefillErr), 0.0);
        assert_eq!(p.prob(FaultPoint::CompactMove), 0.0);
    }

    #[test]
    fn parses_compact_move() {
        let p = FaultPlan::parse("seed=9,compact_move=0.25").unwrap();
        assert_eq!(p.prob(FaultPoint::CompactMove), 0.25);
    }

    #[test]
    fn bare_point_means_certain() {
        let mut p = FaultPlan::parse("seed=1,prefill_err").unwrap();
        for _ in 0..10 {
            assert!(p.fire(FaultPoint::PrefillErr));
        }
        assert_eq!(p.fired(FaultPoint::PrefillErr), 10);
        assert_eq!(p.drawn(FaultPoint::PrefillErr), 10);
    }

    #[test]
    fn zero_prob_never_draws() {
        let mut p = FaultPlan::parse("seed=7,decode_err=0.5").unwrap();
        for _ in 0..100 {
            assert!(!p.fire(FaultPoint::ClientDrop));
        }
        assert_eq!(p.drawn(FaultPoint::ClientDrop), 0);
        assert_eq!(p.total_fired(), 0);
    }

    #[test]
    fn same_seed_same_schedule() {
        let spec = "seed=99,decode_err=0.3,client_drop=0.2,page_starve=0.1";
        let mut a = FaultPlan::parse(spec).unwrap();
        let mut b = FaultPlan::parse(spec).unwrap();
        let mut trace_a = Vec::new();
        let mut trace_b = Vec::new();
        for i in 0..500 {
            let pt = FaultPoint::ALL[i % 4];
            trace_a.push(a.fire(pt));
            trace_b.push(b.fire(pt));
        }
        assert_eq!(trace_a, trace_b);
        assert!(a.total_fired() > 0, "0.3 prob over 500 draws should fire");
    }

    #[test]
    fn streams_are_independent() {
        // Enabling an extra point must not change another point's draws.
        let mut lone = FaultPlan::parse("seed=5,decode_err=0.5").unwrap();
        let mut both = FaultPlan::parse("seed=5,decode_err=0.5,client_drop=0.5").unwrap();
        for i in 0..200 {
            if i % 3 == 0 {
                both.fire(FaultPoint::ClientDrop);
            }
            assert_eq!(lone.fire(FaultPoint::DecodeErr), both.fire(FaultPoint::DecodeErr));
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(FaultPlan::parse("seed=abc").is_err());
        assert!(FaultPlan::parse("bogus_point=0.5").is_err());
        assert!(FaultPlan::parse("decode_err=1.5").is_err());
        assert!(FaultPlan::parse("decode_err=-0.1").is_err());
        assert!(FaultPlan::parse("stall_ms=0@0.5").is_err());
        assert!(FaultPlan::parse("stall=0.5").is_err(), "stall only via stall_ms");
        assert!(FaultPlan::parse("stall_ms=10@nan").is_err());
    }

    #[test]
    fn empty_spec_is_inert() {
        let mut p = FaultPlan::parse("seed=3").unwrap();
        for pt in FaultPoint::ALL {
            assert!(!p.fire(pt));
        }
        assert_eq!(p.total_fired(), 0);
        assert!(p.summary().contains("fired 0"));
    }

    #[test]
    fn summary_names_active_points() {
        let mut p = FaultPlan::parse("seed=1,reload_corrupt").unwrap();
        p.fire(FaultPoint::ReloadCorrupt);
        let s = p.summary();
        assert!(s.contains("reload_corrupt=1"), "{s}");
        assert!(!s.contains("decode_err"), "{s}");
    }
}
