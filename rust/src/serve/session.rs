//! Per-session serving state and the session table with TTL eviction.
//!
//! A session is one client request: a prompt, a generation budget, and
//! (once admitted) a KV-cache slot. Sessions move
//! `Queued -> Active -> Done`, with one failure *state* (`Evicted`)
//! covering several failure *reasons* — TTL/preemption, per-request
//! deadline expiry, engine-step quarantine, client disconnect — which
//! are distinguished by `Session::outcome` (a `SpanOutcome`). Requests
//! rejected by admission control never become sessions; they are
//! counted at the door (`scheduler::SchedStats`).

use crate::obs::span::SpanOutcome;
use crate::rng::Rng;
use std::collections::HashMap;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionState {
    /// admitted to the wait queue, no KV slot yet
    Queued,
    /// holds a KV slot and participates in the decode batch
    Active,
    /// holds a KV slot but is not decoding (client stalled); TTL
    /// eviction reclaims it
    Stalled,
    Done,
    Evicted,
}

#[derive(Debug)]
pub struct Session {
    pub id: u64,
    pub client: usize,
    pub prompt: Vec<i32>,
    pub generated: Vec<i32>,
    pub max_new: usize,
    pub slot: Option<usize>,
    pub state: SessionState,
    pub submitted_at: Instant,
    pub first_token_at: Option<Instant>,
    /// instant of the most recent sampled token — the scheduler's
    /// per-step inter-token-latency (ITL) recording measures each new
    /// token against this and then advances it
    pub last_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
    /// scheduler step of the last decode progress (drives TTL)
    pub last_active_step: u64,
    /// per-session sampling stream (deterministic given the workload
    /// seed and session id)
    pub rng: Rng,
    pub temperature: f32,
    /// wall-clock point after which the scheduler cancels this session
    /// with its partial tokens (`SpanOutcome::DeadlineExceeded`)
    pub deadline: Option<Instant>,
    /// why the session reached a terminal state; `None` while live.
    /// Distinguishes the failure exits (`Evicted` vs `Quarantined` vs
    /// `Disconnected` vs `DeadlineExceeded`) that all park `state` at
    /// `SessionState::Evicted`.
    pub outcome: Option<SpanOutcome>,
}

impl Session {
    // The feed-back invariant (the newest element of `generated` is
    // the one token not yet in the KV cache) is owned by
    // `engine::Engine::decode`, which takes the prompt/generated
    // slices directly — no concatenated history is materialized.

    pub fn is_finished(&self) -> bool {
        self.generated.len() >= self.max_new
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self.state, SessionState::Done | SessionState::Evicted)
    }
}

/// Owning table of all sessions, live and terminal.
#[derive(Default)]
pub struct SessionTable {
    map: HashMap<u64, Session>,
    next_id: u64,
}

impl SessionTable {
    pub fn new() -> SessionTable {
        SessionTable::default()
    }

    #[allow(clippy::too_many_arguments)]
    pub fn create(
        &mut self,
        client: usize,
        prompt: Vec<i32>,
        max_new: usize,
        state: SessionState,
        step: u64,
        seed: u64,
        temperature: f32,
        deadline_ms: Option<u64>,
    ) -> u64 {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(max_new > 0, "zero generation budget");
        let id = self.next_id;
        self.next_id += 1;
        let now = Instant::now();
        self.map.insert(
            id,
            Session {
                id,
                client,
                prompt,
                generated: Vec::with_capacity(max_new),
                max_new,
                slot: None,
                state,
                submitted_at: now,
                first_token_at: None,
                last_token_at: None,
                finished_at: None,
                last_active_step: step,
                rng: Rng::new(seed ^ id.wrapping_mul(0x9E37_79B9)),
                temperature,
                deadline: deadline_ms
                    .map(|ms| now + Duration::from_millis(ms)),
                outcome: None,
            },
        );
        id
    }

    pub fn get(&self, id: u64) -> &Session {
        &self.map[&id]
    }

    pub fn contains(&self, id: u64) -> bool {
        self.map.contains_key(&id)
    }

    pub fn get_mut(&mut self, id: u64) -> &mut Session {
        self.map.get_mut(&id).expect("unknown session id")
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn count_state(&self, s: SessionState) -> usize {
        self.map.values().filter(|x| x.state == s).count()
    }

    /// Iterate over all sessions, live and terminal (arbitrary order —
    /// callers that need determinism must sort). Used by invariant
    /// checks: e.g. the scheduler fuzz test asserts no two sessions
    /// ever hold the same KV slot.
    pub fn iter(&self) -> impl Iterator<Item = &Session> {
        self.map.values()
    }

    /// Drop a terminal session. Long-running servers must reap
    /// terminal sessions (the workload driver does, once the client
    /// has observed the outcome) or the table grows without bound.
    pub fn remove(&mut self, id: u64) -> Option<Session> {
        debug_assert!(
            self.map.get(&id).map(|s| s.is_terminal()).unwrap_or(true),
            "removing a live session"
        );
        self.map.remove(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table_with_one(state: SessionState, step: u64)
                      -> (SessionTable, u64) {
        let mut t = SessionTable::new();
        let id = t.create(0, vec![3, 4, 5], 4, state, step, 42, 0.0, None);
        (t, id)
    }

    #[test]
    fn lifecycle_positions() {
        let (mut t, id) = table_with_one(SessionState::Queued, 0);
        assert!(!t.get(id).is_finished());
        let s = t.get_mut(id);
        s.generated.push(9);
        assert!(!s.is_finished());
        s.generated.extend_from_slice(&[9, 9, 9]);
        assert!(s.is_finished());
    }

    #[test]
    fn ids_are_unique_and_rngs_distinct() {
        let mut t = SessionTable::new();
        let a = t.create(0, vec![3], 2, SessionState::Queued, 0, 7, 0.8, None);
        let b = t.create(1, vec![3], 2, SessionState::Queued, 0, 7, 0.8, None);
        assert_ne!(a, b);
        let ra = t.get_mut(a).rng.next_u64();
        let rb = t.get_mut(b).rng.next_u64();
        assert_ne!(ra, rb, "per-session sampling streams must differ");
    }

    #[test]
    fn remove_reaps_terminal_sessions() {
        let mut t = SessionTable::new();
        let id = t.create(0, vec![3], 2, SessionState::Queued, 0, 1, 0.0, None);
        t.get_mut(id).state = SessionState::Done;
        assert_eq!(t.len(), 1);
        let s = t.remove(id).expect("session existed");
        assert_eq!(s.id, id);
        assert_eq!(t.len(), 0);
        assert!(t.remove(id).is_none(), "double remove is a no-op");
    }

    #[test]
    fn deadline_is_armed_from_submit_time() {
        let mut t = SessionTable::new();
        let a = t.create(0, vec![3], 2, SessionState::Queued, 0, 1, 0.0,
                         Some(0));
        let b = t.create(0, vec![3], 2, SessionState::Queued, 0, 1, 0.0,
                         Some(60_000));
        let now = Instant::now();
        assert!(t.get(a).deadline.unwrap() <= now, "0ms expires at once");
        assert!(t.get(b).deadline.unwrap() > now);
        assert!(t.get(a).outcome.is_none());
    }

    #[test]
    fn terminal_states() {
        let (mut t, id) = table_with_one(SessionState::Queued, 0);
        assert!(!t.get(id).is_terminal());
        t.get_mut(id).state = SessionState::Evicted;
        assert!(t.get(id).is_terminal());
        assert_eq!(t.count_state(SessionState::Evicted), 1);
    }
}
